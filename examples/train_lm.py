"""End-to-end driver: train a ~100M-param LM with VP QAT, checkpoint,
restart, and serve it with VP-quantized weights.

    # CPU-sized demo (a few minutes):
    PYTHONPATH=src python examples/train_lm.py --steps 120

    # the real thing (TPU fleet): use repro.launch.train with --arch and
    # the production mesh; this example keeps everything single-host.
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)
from repro.optim import OptConfig, init_opt_state
from repro.optim.optimizer import OptState
from repro.train import make_train_step, CheckpointManager
from repro.data import DataConfig, SyntheticLM

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--vocab", type=int, default=4096)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# a qwen-style dense LM with VP QAT on every matmul
cfg = ModelConfig(
    name="demo-lm", family="dense", n_layers=args.layers,
    d_model=args.d_model, n_heads=args.d_model // 64,
    n_kv_heads=max(1, args.d_model // 128), d_ff=args.d_model * 4,
    vocab=args.vocab, qk_norm=True, dtype="float32",
    quant=QuantConfig(mode="vp"),
)
n_params = cfg.param_count()
print(f"model: {n_params/1e6:.1f}M params, VP({cfg.quant.M}) QAT")

opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch))
step = jax.jit(make_train_step(cfg, opt_cfg))

params = init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
with tempfile.TemporaryDirectory() as ckdir:
    mgr = CheckpointManager(ckdir, async_save=True)
    for i in range(args.steps // 2):
        params, opt, m = step(params, opt, data.batch_at(i))
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}")
    mgr.save(args.steps // 2, {"params": params, "opt": opt._asdict()},
             extra={"data_index": args.steps // 2})
    print("-- simulated crash + restart: restoring from checkpoint --")
    restored, manifest = mgr.restore(
        args.steps // 2, {"params": params, "opt": opt._asdict()})
    params, opt = restored["params"], OptState(**restored["opt"])
    for i in range(manifest["extra"]["data_index"], args.steps):
        params, opt, m = step(params, opt, data.batch_at(i))
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}")

print("-- exporting VP-quantized serving weights --")
qparams = quantize_params(params, cfg)
int8 = sum(l.size for l in jax.tree_util.tree_leaves(qparams)
           if hasattr(l, "dtype") and l.dtype == jnp.int8)
print(f"serving params: {int8/1e6:.1f}M int8 significands "
      f"(+ packed 2-bit indices) vs {n_params/1e6:.1f}M bf16 floats")
caches = init_cache(cfg, 2, 64)
prompt = data.batch_at(9999)["tokens"][:2, :32]
logits, caches = prefill(qparams, prompt, caches, cfg)
tok = jnp.argmax(logits, -1)[:, None]
outs = []
for _ in range(16):
    outs.append(int(tok[0, 0]))
    logits, caches = decode_step(qparams, tok, caches, cfg)
    tok = jnp.argmax(logits, -1)[:, None]
print("greedy continuation (token ids):", outs)
