"""Quickstart: the VP number format in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks through the paper's core objects: FXP2VP conversion (Fig. 2),
VP multiplication with offline exponent lists (Sec. II-B), the VP matmul
kernel, and the accuracy story on high-dynamic-range data.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    FXPFormat, VPFormat, fxp_quantize, fxp2vp, vp_to_float, vp_mul,
    product_scale_lut, vp_quantize, fxp_quantize_value,
)
from repro.kernels import ops

# --- 1. The paper's Fig. 2 example: FXP(8,1) -> VP(6,[1,-1]) -------------
fxp, vp = FXPFormat(8, 1), VPFormat(6, (1, -1))
x = jnp.asarray([22.0, -6.5])                  # real values
raw = fxp_quantize(x, fxp)                     # 8-bit two's complement
m, i = fxp2vp(raw, fxp, vp)                    # 6-bit significand + index
print("Fig.2:  x =", x.tolist())
print("        significand =", m.tolist(), " exponent index =", i.tolist())
print("        reconstructed =", vp_to_float(m, i, vp).tolist())

# --- 2. VP multiplication: no exponent addition --------------------------
y_vp = VPFormat(7, (1, -1))                    # Table I: y
w_vp = VPFormat(7, (11, 9, 7, 6))              # Table I: W
lut = product_scale_lut(y_vp, w_vp)            # built OFFLINE (2^(Ea+Eb))
print("\nProduct scale LUT (offline pairwise sums):", lut.tolist())

# --- 3. High-dynamic-range matmul: VP(7) vs FXP(7) vs FXP(9/12) ----------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_t(2, (256, 512)).clip(-8, 8) * 10, jnp.float32)
b = jnp.asarray(rng.standard_t(2, (512, 256)).clip(-8, 8) * 0.008,
                jnp.float32)
ta = vp_quantize(a, FXPFormat(9, 1), y_vp)
tb = vp_quantize(b, FXPFormat(12, 11), w_vp)
out = np.asarray(ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, y_vp, w_vp))
want = np.asarray(a) @ np.asarray(b)

def nmse(x):
    return np.mean((x - want) ** 2) / np.mean(want ** 2)

o7 = np.asarray(fxp_quantize_value(a, FXPFormat(7, 0))) @ np.asarray(
    fxp_quantize_value(b, FXPFormat(7, 6)))
o_wide = np.asarray(fxp_quantize_value(a, FXPFormat(9, 1))) @ np.asarray(
    fxp_quantize_value(b, FXPFormat(12, 11)))
print(f"\nmatmul NMSE:  VP(7,*)      = {nmse(out):.2e}   <- 7-bit multipliers")
print(f"              FXP(7)       = {nmse(o7):.2e}   <- same width, 230x worse")
print(f"              FXP(9/12)    = {nmse(o_wide):.2e}   <- the wide design VP matches")
print("\nThat's the paper: FXP-width hardware, FLP-class dynamic range.")
