"""The paper's case study end to end: beamspace LMMSE equalization with the
three MVM designs (A-FXP / B-FXP / B-VP) on simulated LoS mmWave channels.

    PYTHONPATH=src python examples/mimo_equalizer.py [--n 2000]

Reproduces, in one run: Fig. 7 (beamspace spikiness), Fig. 8 (NMSE bit
gap), Table I BER validation, CSPADE muting rates, the cost-model
area/power ratios (Fig. 11), and — beyond the paper — the wideband OFDM
pipeline: per-subcarrier LMMSE over a frequency-selective band, every
(subcarrier, realization) MVM served by ONE truly-batched VP kernel
launch, with per-subcarrier calibration cached by `WidebandCalibrator`.
"""
import argparse
import jax

from repro.mimo import (
    ChannelConfig, OFDMConfig, WidebandCalibrator, table1_specs, cspade,
    make_wideband_ensemble, equalize_wideband,
)
from repro.mimo.lmmse import equalize
from repro.mimo.ofdm import wideband_nmse, wideband_ber
from repro.mimo.sim import (
    make_ensemble, pdf_stats, nmse_vs_bitwidth, bitwidth_gap,
    ber_float, ber_quantized, calibrate_specs,
)
from repro.core import cost_model as cm

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=2000)
args = ap.parse_args()

print("=== generating LoS mmWave ensemble (B=64, U=8, 16-QAM, 20dB) ===")
ens = make_ensemble(jax.random.PRNGKey(0), ChannelConfig(), args.n, 20.0)
for name, x in [("ybar", ens.y_ant), ("y", ens.y_beam),
                ("Wbar", ens.w_ant), ("W", ens.w_beam)]:
    s = pdf_stats(x)
    print(f"  {name:5s} kurtosis={s['kurtosis']:7.1f}  papr={s['papr_db']:5.1f}dB")

print("\n=== Fig. 8: NMSE vs bitwidth ===")
nm = nmse_vs_bitwidth(ens)
for w in sorted(nm["antenna"]):
    print(f"  W={w}: antenna={nm['antenna'][w]:.2e}  beamspace={nm['beamspace'][w]:.2e}")
print(f"  beamspace needs {bitwidth_gap(nm):.2f} extra bits (paper: ~1.2)")

print("\n=== Table I BER validation (SNR 2 dB) ===")
ens_lo = make_ensemble(jax.random.PRNGKey(7), ChannelConfig(), args.n, 2.0)
specs = calibrate_specs(table1_specs(), ens_lo)
print(f"  float LMMSE: {ber_float(ens_lo, True):.4f}")
for s in specs:
    print(f"  {s.name:6s}: {ber_quantized(ens_lo, s):.4f}  "
          f"(y={s.y_fxp}{'/'+str(s.y_vp) if s.y_vp else ''}, "
          f"W={s.w_fxp}{'/'+str(s.w_vp) if s.w_vp else ''})")

print("\n=== CSPADE thresholds / muting ===")
tw, ty = cspade.calibrate_thresholds(ens.w_beam, ens.y_beam, 0.5)
print(f"  calibrated thresholds: tau_W={tw:.4f} tau_y={ty:.4f} "
      f"-> muting={float(cspade.muting_rate(ens.w_beam, ens.y_beam, tw, ty)):.2f}")

print("\n=== Wideband OFDM (beyond-paper): batched VP kernel over the band ===")
ofdm = OFDMConfig(n_subcarriers=16, n_taps=4)
n_wb = max(16, args.n // 64)
wens = make_wideband_ensemble(
    jax.random.PRNGKey(5), ChannelConfig(), ofdm, n_wb, 20.0)
cal = WidebandCalibrator(next(s for s in table1_specs() if s.name == "B-VP"))
wspecs = cal.specs_for(wens)
s_vp = equalize_wideband(wspecs, wens.w_beam, wens.y_beam, how="flat")
s_fl = equalize(wens.w_beam, wens.y_beam)
print(f"  S={ofdm.S} subcarriers x n={n_wb} realizations "
      f"-> one batched kernel call of {ofdm.S * n_wb} tile programs")
print(f"  per-subcarrier AGC gains cached: {cal.cache_sizes[0]} entries "
      f"(w_gain spread "
      f"{min(s.w_gain for s in wspecs):.3g}..{max(s.w_gain for s in wspecs):.3g})")
print(f"  NMSE  B-VP={wideband_nmse(s_vp, wens.s):.2e}  "
      f"float={wideband_nmse(s_fl, wens.s):.2e}")
print(f"  BER   B-VP={wideband_ber(s_vp, wens.bits):.4f}  "
      f"float={wideband_ber(s_fl, wens.bits):.4f}")

print("\n=== Fig. 11: cost model ===")
designs = cm.paper_designs()
tot = {k: cm.total(cm.mvm_area(s)) for k, s in designs.items()}
print(f"  area  B-FXP/A-FXP = {tot['B-FXP']/tot['A-FXP']:.2f} (paper ~1.25)")
print(f"  area  B-VP /B-FXP = {tot['B-VP']/tot['B-FXP']:.2f} (paper ~0.80)")
p = {k: sum(cm.mvm_power(s, muting_rate=0.5).values())
     for k, s in designs.items()}
print(f"  power B-VP /B-FXP = {p['B-VP']/p['B-FXP']:.2f} (paper 0.86-0.90)")
print(f"  FLP/VP CMAC array = {cm.flp_cmac_array_area(8)/cm.vp_cmac_array_area(designs['B-VP']):.2f} (paper 3.4)")
