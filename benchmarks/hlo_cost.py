"""Loop-aware HLO cost analyzer.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, which
makes it useless for scanned-layer models (a 62-layer scan reports 1
layer of FLOPs).  This module re-derives

    flops              (dot: 2*M*N*K; elementwise/reduce: n_elems)
    hbm_bytes          (sum of operand+result bytes of fusions/dots/
                        convs/copies/gathers/scatters — post-fusion, so a
                        reasonable proxy for HBM traffic)
    collective_bytes   (output bytes of all-gather/all-reduce/
                        reduce-scatter/all-to-all/collective-permute,
                        by kind)

from the OPTIMIZED HLO text, multiplying every computation by the product
of trip counts of the while-loops it is reached through.

Trip counts: jax.lax.scan lowers to a while whose condition compares the
induction variable against a constant K with direction=LT — we parse K
from the condition computation.  Unknown conditions default to 1 (warned).
"""
from __future__ import annotations

import gzip
import re
import sys
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for v in dims.split(","):
            if v:
                n *= int(v)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for v in m.group(2).split(","):
        if v:
            n *= int(v)
    return n


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.flops = 0.0
        self.hbm = 0.0
        self.coll: Dict[str, float] = defaultdict(float)
        self.calls: List[Tuple[str, str]] = []  # (kind, callee)
        self.while_pairs: List[Tuple[str, str]] = []  # (cond, body)
        self.trip_const: Optional[int] = None  # if this is a condition comp


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if not line:
            continue
        if line[0] not in " \t" and line.rstrip().endswith("{") \
                and ") -> " in line:
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = Computation(hdr.group(2))
                comps[cur.name] = cur
                if hdr.group(1):
                    entry = cur.name
                continue
        if cur is None or not line.strip() or line.strip() == "}":
            continue
        cur.lines.append(line)
    for comp in comps.values():
        _analyze(comp)
    comps["__entry__"] = comps[entry] if entry else next(iter(comps.values()))
    return comps


# %name = TYPE op(args), attrs      (scheduled HLO: operands by name only)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}]+)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = frozenset((
    "add", "multiply", "subtract", "divide", "exponential", "tanh",
    "rsqrt", "sqrt", "log", "maximum", "minimum", "power", "select",
    "compare", "and", "or", "xor", "negate", "abs", "floor", "ceil",
    "sign", "cosine", "sine", "logistic", "exponential-minus-one",
    "convert", "clamp"))

_HBM_OPS = frozenset((
    "copy", "copy-start", "gather", "scatter",
    "dynamic-slice", "concatenate", "transpose", "reduce", "sort", "pad",
    "reverse", "select-and-scatter"))


def _analyze(comp: Computation):
    symbols: Dict[str, str] = {}
    parsed = []
    for line in comp.lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        symbols[name] = rtype
        parsed.append((name, rtype, op, rest, line))

    def operand_types(rest: str):
        # operand list ends at the first top-level ')'
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    rest = rest[:i]
                    break
                depth -= 1
        return [symbols.get(nm, "") for nm in _OPERAND_RE.findall(rest)]

    for name, rtype, op, rest, line in parsed:
        if op == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            bm = re.search(r"body=%?([\w.\-]+)", line)
            if cm and bm:
                comp.while_pairs.append((cm.group(1), bm.group(1)))
            continue
        tm = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", line)
        if tm:
            comp.calls.append(("call", tm.group(1)))
        if op == "conditional":
            bm = _BRANCHES_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        comp.calls.append(("branch", b))
        # trip-count pattern: s32 constant in a while-condition computation
        if op == "constant" and re.match(r"s32\[\]", rtype):
            cm = re.search(r"constant\((\d+)\)", line)
            if cm:
                v = int(cm.group(1))
                comp.trip_const = max(comp.trip_const or 0, v)
        # ---- costs ----
        if op == "dot":
            out_elems = _result_elems(rtype)
            otypes = operand_types(rest)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if otypes and cdims:
                lhs_m = _SHAPE_RE.search(otypes[0] or "")
                if lhs_m:
                    dims = [int(v) for v in lhs_m.group(2).split(",") if v]
                    for ci in cdims.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            comp.flops += 2.0 * out_elems * k
            comp.hbm += _shape_bytes(rtype) + sum(
                _shape_bytes(t) for t in otypes)
        elif op == "convolution":
            out_elems = _result_elems(rtype)
            otypes = operand_types(rest)
            k = 1
            if len(otypes) > 1:
                km = _SHAPE_RE.search(otypes[1] or "")
                if km:
                    dims = [int(v) for v in km.group(2).split(",") if v]
                    # kernel spatial x in-features
                    out_m = _SHAPE_RE.search(rtype)
                    if out_m and dims:
                        k = max(1, int(np_prod(dims) //
                                       max(dims[-1], 1)))
            comp.flops += 2.0 * out_elems * k
            comp.hbm += _shape_bytes(rtype) + sum(
                _shape_bytes(t) for t in otypes)
        elif op == "fusion":
            otypes = operand_types(rest)
            total = _shape_bytes(rtype) + sum(_shape_bytes(t)
                                              for t in otypes)
            # In-place-update pattern (e.g. fused dynamic-update-slice of a
            # loop carry): an operand with the exact result type aliases
            # the output buffer — count the pair once, not twice.
            r_clean = re.sub(r"\{[^}]*\}", "", rtype).strip()
            for t in otypes:
                if re.sub(r"\{[^}]*\}", "", t).strip() == r_clean \
                        and _shape_bytes(t) > 0:
                    total -= _shape_bytes(t)
                    break
            comp.hbm += total
        elif op == "dynamic-update-slice":
            # in-place region update: traffic ~ 2x the UPDATE operand,
            # not the full (aliased) result buffer
            otypes = operand_types(rest)
            upd = otypes[1] if len(otypes) > 1 else rtype
            comp.hbm += 2 * _shape_bytes(upd)
        elif op in _HBM_OPS:
            comp.hbm += _shape_bytes(rtype)
        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                comp.coll[kind] += _shape_bytes(rtype)
                break
        if op in _ELEMENTWISE:
            comp.flops += _result_elems(rtype)


def np_prod(xs):
    n = 1
    for v in xs:
        n *= v
    return n


def total_costs(text: str) -> Dict[str, float]:
    comps = parse_hlo(text)
    entry = comps["__entry__"]
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def walk(name: str, depth=0) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return (0.0, 0.0, {})
        fl, hb = comp.flops, comp.hbm
        coll = dict(comp.coll)
        for _, callee in comp.calls:
            f2, h2, c2 = walk(callee, depth + 1)
            fl += f2
            hb += h2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0) + v
        for cond, body in comp.while_pairs:
            trips = comps[cond].trip_const if (
                cond in comps and comps[cond].trip_const) else 1
            f2, h2, c2 = walk(body, depth + 1)
            fc, hc, cc = walk(cond, depth + 1)
            fl += trips * (f2 + fc)
            hb += trips * (h2 + hc)
            for k, v in c2.items():
                coll[k] = coll.get(k, 0) + trips * v
        memo[name] = (fl, hb, coll)
        return memo[name]

    fl, hb, coll = walk(entry.name)
    return {
        "flops": fl,
        "hbm_bytes": hb,
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
    }


def load(path: str) -> str:
    if path.endswith(".gz"):
        with gzip.open(path, "rt") as f:
            return f.read()
    with open(path) as f:
        return f.read()


if __name__ == "__main__":
    for p in sys.argv[1:]:
        c = total_costs(load(p))
        print(p, {k: (f"{v:.3e}" if isinstance(v, float) else v)
                  for k, v in c.items() if k != "collective_bytes"})
