"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md section 'Roofline').

For every (arch x shape x mesh) cell:
  compute    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory     = HLO_bytes_per_device / HBM_bandwidth       [s]
  collective = collective_bytes_per_device / link_bw      [s]

HLO_* come from benchmarks/hlo_cost.py (loop-aware parse of the SPMD-
partitioned module, so all quantities are already per-device).
MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE train) /
2*N_active*tokens (decode/prefill), divided by the chip count.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 (394 int8),
819 GB/s HBM, ~50 GB/s/link ICI.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Optional

from . import hlo_cost

PEAK_FLOPS = 197e12       # bf16 / chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one new token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict, chips: int) -> float:
    """Per-device useful FLOPs for this cell."""
    n_act = rec["active_param_count"]
    shape = rec["shape"]
    toks = SHAPE_TOKENS[shape]
    if shape == "train_4k":
        total = 6.0 * n_act * toks
    else:  # forward-only
        total = 2.0 * n_act * toks
    return total / chips


def _cfg_of(rec: Dict):
    """Config + distribution hints for a cell (as the dry-run set them)."""
    import sys
    sys.path.insert(0, "src")
    from repro.configs import registry as reg
    cfg = reg.get_config(rec["arch"])
    seq_shard = False
    if rec["shape"] == "train_4k":
        from repro.launch import dryrun as dr
        seq_shard = rec["arch"] in dr.SEQ_SHARD_TRAIN
    return cfg, seq_shard


def _toks_dev(rec: Dict, chips: int, seq_shard: bool) -> float:
    """Residual-stream tokens materialized per device: tokens shard over
    the data axes; activations replicate over 'model' (16) unless the
    residual is sequence-sharded (Megatron-SP)."""
    model_size = 16
    toks = SHAPE_TOKENS[rec["shape"]] / chips * model_size
    if seq_shard:
        toks /= model_size
    return toks


# Activation-traffic model (bytes/device).  The CPU-backend HLO cannot
# stand in for TPU fusion behaviour, so the MEMORY term is analytic and
# the parsed-HLO bytes are kept as a diagnostic only:
#   per token per layer ~ dtype * RW * (4*d + 2*ff_eff)
#     ff_eff: dense d_ff | moe k*d_ff*1.25 | mamba 2*d_inner | rwkv d_ff+4d
#     RW = 2 (write+read); x1.5 under remat (recompute re-writes)
#   train multiplies by 3 (fwd + bwd reads + dact writes).
def _act_bytes(rec: Dict, chips: int, cfg, seq_shard: bool) -> float:
    d, L, ff = cfg.d_model, cfg.n_layers, cfg.d_ff
    if cfg.n_experts:
        ff_eff = cfg.experts_per_token * ff * 1.25
    elif cfg.family in ("hybrid", "ssm") and not cfg.rwkv:
        ff_eff = 2 * cfg.ssm_expand * d
    elif cfg.rwkv:
        ff_eff = ff + 4 * d
    else:
        ff_eff = ff
    rw = 2.0 * (1.5 if cfg.remat == "full" else 1.0)
    total = _toks_dev(rec, chips, seq_shard) * L * 2.0 * rw * (
        4 * d + 2 * ff_eff)
    if rec["shape"] == "train_4k":
        total *= 3.0
    return total


def analyze_cell(json_path: str) -> Optional[Dict]:
    rec = json.load(open(json_path))
    hlo_path = json_path.replace(".json", ".hlo.txt.gz")
    if not os.path.exists(hlo_path):
        return None
    costs = hlo_cost.total_costs(hlo_cost.load(hlo_path))
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    cfg, seq_shard = _cfg_of(rec)
    arg_b = rec["memory"]["argument_bytes"]
    out_b = rec["memory"]["output_bytes"]
    act_b = _act_bytes(rec, chips, cfg, seq_shard)

    t_compute = costs["flops"] / PEAK_FLOPS
    t_memory = (arg_b + out_b + act_b) / HBM_BW
    t_coll = costs["collective_total"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, chips)
    bound = max(terms.values())

    # Ideal times given the algorithm: compute at peak; memory = params
    # (+opt for train, +cache for decode) read/written once + one
    # residual-stream pass per layer.
    ideal_mem = arg_b + out_b
    ideal_mem += _toks_dev(rec, chips, seq_shard) * cfg.n_layers * 4 * \
        cfg.d_model * (3 if rec["shape"] == "train_4k" else 1)
    t_ideal = max(mf / PEAK_FLOPS, ideal_mem / HBM_BW)
    return {
        **rec,
        "hlo_flops": costs["flops"],
        "hlo_bytes_diag": costs["hbm_bytes"],
        "coll_bytes": costs["collective_total"],
        "coll_breakdown": costs["collective_bytes"],
        "arg_bytes": arg_b,
        "act_bytes": act_b,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / costs["flops"] if costs["flops"] else 0.0,
        "t_ideal": t_ideal,
        # score: how close the modeled bound is to the algorithmic ideal
        "roofline_frac": t_ideal / bound if bound else 0.0,
    }


def fmt_row(a: Dict) -> str:
    return ("| {arch} | {shape} | {mesh} | {q} | {tc:.2e} | {tm:.2e} | "
            "{tl:.2e} | {dom} | {ur:.2f} | {rf:.1%} |").format(
        arch=a["arch"], shape=a["shape"], mesh=a["mesh"],
        q=a.get("quant", "none"),
        tc=a["t_compute"], tm=a["t_memory"], tl=a["t_collective"],
        dom=a["dominant"], ur=a["useful_ratio"], rf=a["roofline_frac"])


HEADER = ("| arch | shape | mesh | quant | compute [s] | memory [s] | "
          "collective [s] | bound | MODEL/HLO | roofline |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    rows = []
    for p in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        try:
            a = analyze_cell(p)
        except Exception as e:
            print(f"[warn] {p}: {e}", file=sys.stderr)
            continue
        if a:
            rows.append(a)
    rows.sort(key=lambda a: (a["mesh"], a["arch"], a["shape"]))
    print(HEADER)
    for a in rows:
        print(fmt_row(a))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=2, default=str)
    # summary
    from collections import Counter
    doms = Counter(a["dominant"] for a in rows)
    print(f"\ncells: {len(rows)}  dominant-term histogram: {dict(doms)}")


if __name__ == "__main__":
    main()
