"""Mesh sweep driver: sharded packed-VP datapath over (B, S, mesh shape).

    PYTHONPATH=src python -m benchmarks.sweep --out BENCH_pr8.json
    PYTHONPATH=src python -m benchmarks.sweep --smoke --out smoke.json

In the spirit of maxtext's `run-sweeps.py`: the PARENT process never
touches jax — each sweep point runs in a fresh subprocess whose
`XLA_FLAGS` pins `--xla_force_host_platform_device_count` to that
point's device count, so one driver binary sweeps mesh shapes that a
single jax process could never revisit (device count is fixed at
backend init).  Each point writes a config-stamped per-point JSON; the
parent folds every row into one aggregate report (`--out`), the file
committed as `BENCH_pr8.json` and appended to `BENCH_TRAJECTORY.json`.

What each point measures, on a ("data", "model") best-effort mesh:

  mm_single       single-device `vp_dequant_matmul` oracle
  mm_gather       shard_map, packed words all-gathered then one full
                  matmul — the non-overlapped baseline (and the
                  JX-SHGATH anti-pattern: it re-materializes the full
                  weight on every device)
  mm_ring         shard_map collective matmul: per-chunk dequant-matmul
                  overlapped with the `ppermute` packed-word rotate
  attn_single     single-device packed-KV `vp_decode_attention`
  attn_seq_shard  shard_map with the KV cache sharded along S and
                  all-gathered as PACKED words + scales

Every sharded row asserts bit-identical outputs against its
single-device oracle INLINE (concatenation-only collectives on the ref
backend) — a sweep point that loses parity dies loudly rather than
reporting a speedup for wrong numbers.

Async-collective overlap flags: the TPU set maxtext ships (async
all-gather fusion + compute/collective overlap) is stamped into every
point's config as `tpu_async_flags`; this CPU-hosted XLA build rejects
them as unknown flags, so off-TPU the env applies only the host device
count and `applied_async_flags` records False.  On a TPU host the
driver exports them via LIBTPU_INIT_ARGS.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# The overlap flag set from maxtext's sweep driver (TPU-only: XLA's CPU
# flag parser hard-fails on unknown flags, so these are exported only
# when the worker platform is a TPU).
TPU_ASYNC_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_enable_async_collective_fusion_multiple_steps=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true")

# (tp, B, S, K, N): >= 3 mesh shapes, small + large (B, S) each.  The
# matmul is decode-shaped (M = B tokens in flight, K x N weight).  The
# (B, K, N) combinations are chosen in the BIT-STABLE regime of XLA's
# CPU dot: the inline parity asserts require the column-blocked dot
# (M, K, N/tp) to reduce over K in the same order as the full (M, K, N)
# dot, which XLA honors at these shapes for every swept tp but not
# everywhere (e.g. M=8, K=1024, N=2048 picks a different K strategy
# per N and drifts ~5e-8).  A grid edit that leaves the stable regime
# fails the assert loudly rather than benchmarking unverified numbers.
FULL_GRID = [(2, 8, 256, 256, 512), (4, 8, 256, 256, 512),
             (8, 8, 256, 256, 512),
             (2, 64, 1024, 2048, 4096), (4, 64, 1024, 2048, 4096),
             (8, 64, 1024, 2048, 4096)]
SMOKE_GRID = [(2, 4, 64, 128, 256)]


def _worker_env(tp: int) -> dict:
    env = dict(os.environ)
    flags = [f"--xla_force_host_platform_device_count={tp}"]
    prev = env.get("XLA_FLAGS", "")
    prev = " ".join(f for f in prev.split()
                    if "--xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = " ".join([prev] + flags).strip()
    if env.get("JAX_PLATFORMS", "cpu") not in ("cpu", ""):
        env["LIBTPU_INIT_ARGS"] = TPU_ASYNC_FLAGS
    return env


def run_point(tp: int, B: int, S: int, K: int, N: int,
              out_path: str, repeats: int) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.sweep", "--worker",
           "--tp", str(tp), "--batch", str(B), "--seq", str(S),
           "--dims", f"{K}x{N}", "--repeats", str(repeats),
           "--out", out_path]
    subprocess.run(cmd, env=_worker_env(tp), check=True,
                   cwd=os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__))))
    with open(out_path, encoding="utf-8") as f:
        return json.load(f)


def main_parent(args) -> int:
    grid = SMOKE_GRID if args.smoke else FULL_GRID
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    rows, points = [], []
    for tp, B, S, K, N in grid:
        t0 = time.perf_counter()
        point_path = os.path.join(
            outdir, f"sweep_tp{tp}_B{B}_S{S}.json")
        rep = run_point(tp, B, S, K, N, point_path, args.repeats)
        points.append(rep["config"])
        rows.extend(rep["rows"])
        print(f"# point tp={tp} B={B} S={S} done in "
              f"{time.perf_counter() - t0:.1f}s -> {point_path}")
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump({"rows": rows, "points": points}, f, indent=1)
        f.write("\n")
    print(f"# aggregate: {len(rows)} rows over {len(points)} points "
          f"-> {args.out}")
    return 0


# ---------------------------------------------------------------------------
# Worker: one (tp, B, S) point inside its own jax process
# ---------------------------------------------------------------------------

def _timeit(fn, n: int) -> float:
    """MIN wall-clock (us) over n runs; first call warms the compile."""
    fn()
    t = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t * 1e6


def main_worker(args) -> int:
    from functools import partial

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import QuantConfig
    from repro.kernels import ops as kops
    from repro.launch.mesh import best_effort_mesh
    from repro.models.layers import canonical_formats
    from repro.parallel import shard_ops

    tp, B, S = args.tp, args.batch, args.seq
    K, N = (int(d) for d in args.dims.split("x"))
    mesh = best_effort_mesh(tp)
    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    rows = []

    def emit(name, us, derived):
        # dict rows, matching benchmarks/run.py — the trajectory ledger
        # (benchmarks/trajectory.py) indexes rows by "name".
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.2f},{derived}")

    # ---- dequant matmul: single vs gather vs ring --------------------
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32) / K
    w_pk = kops.vp_quant(w, fxp, vp, packed=True)

    single = jax.jit(lambda a, b: kops.vp_dequant_matmul(a, b, vp))
    y_ref = np.asarray(single(x, w_pk))
    us_single = _timeit(lambda: single(x, w_pk).block_until_ready(),
                        args.repeats)
    emit(f"sweep_mm_single_tp{tp}_B{B}", us_single, f"K={K};N={N};tp=1")

    mode_us = {}
    for mode in ("gather", "ring"):
        fn = jax.jit(shard_map(
            partial(shard_ops.sharded_dequant_matmul, fmt=vp, mode=mode),
            mesh=mesh, in_specs=(P(), P(None, "model")), out_specs=P(),
            check_rep=False))
        y = np.asarray(fn(x, w_pk))
        assert np.array_equal(y, y_ref), \
            f"mm {mode} mode lost bit parity at tp={tp} B={B} K={K} N={N}"
        mode_us[mode] = _timeit(
            lambda f=fn: f(x, w_pk).block_until_ready(), args.repeats)
    speed = mode_us["gather"] / mode_us["ring"]
    emit(f"sweep_mm_gather_tp{tp}_B{B}", mode_us["gather"],
         f"vs_single={us_single / mode_us['gather']:.2f}x;parity=bit")
    emit(f"sweep_mm_ring_tp{tp}_B{B}", mode_us["ring"],
         f"ring_vs_gather={speed:.2f}x;parity=bit")

    # ---- packed-KV decode attention: single vs seq-sharded -----------
    H, KV, dh = 8, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, dh), jnp.float32)
    k_f = jax.random.normal(jax.random.PRNGKey(3), (B, S, KV, dh),
                            jnp.float32)
    v_f = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, dh),
                            jnp.float32)
    k_w = kops.vp_quant(k_f, fxp, vp, packed=True)
    v_w = kops.vp_quant(v_f, fxp, vp, packed=True)
    ones = jnp.ones((B, S, 1, 1), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)

    attn = jax.jit(lambda *a: kops.vp_decode_attention(*a, vp))
    o_ref = np.asarray(attn(q, k_w, v_w, ones, ones, lens))
    us_attn = _timeit(
        lambda: attn(q, k_w, v_w, ones, ones, lens).block_until_ready(),
        args.repeats)
    emit(f"sweep_attn_single_tp{tp}_B{B}_S{S}", us_attn,
         f"KV={KV};dh={dh};tp=1")

    sh_attn = jax.jit(shard_map(
        partial(shard_ops.sharded_decode_attention, fmt=vp, mode="seq"),
        mesh=mesh,
        in_specs=(P(), P(None, "model"), P(None, "model"),
                  P(None, "model"), P(None, "model"), P()),
        out_specs=P(), check_rep=False))
    o = np.asarray(sh_attn(q, k_w, v_w, ones, ones, lens))
    assert np.array_equal(o, o_ref), \
        f"seq-sharded attention lost bit parity at tp={tp} B={B} S={S}"
    us_sh = _timeit(
        lambda: sh_attn(q, k_w, v_w, ones, ones, lens).block_until_ready(),
        args.repeats)
    word_b = (vp.storage_bits + 7) // 8
    emit(f"sweep_attn_seqshard_tp{tp}_B{B}_S{S}", us_sh,
         f"parity=bit;gather_bytes/elem={word_b}(f32=4)")

    config = {
        "tp": tp, "B": B, "S": S, "K": K, "N": N,
        "mesh": dict(mesh.shape),
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "tpu_async_flags": TPU_ASYNC_FLAGS,
        "applied_async_flags": "LIBTPU_INIT_ARGS" in os.environ,
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump({"config": config, "rows": rows}, f, indent=1)
        f.write("\n")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.sweep",
        description="mesh-shape sweep for the sharded packed-VP datapath")
    p.add_argument("--out", default="BENCH_pr8.json")
    p.add_argument("--smoke", action="store_true",
                   help="one tiny point (CI dispatch check)")
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    p.add_argument("--tp", type=int, default=2, help=argparse.SUPPRESS)
    p.add_argument("--batch", type=int, default=8, help=argparse.SUPPRESS)
    p.add_argument("--seq", type=int, default=256, help=argparse.SUPPRESS)
    p.add_argument("--dims", default="2048x4096", help=argparse.SUPPRESS)
    args = p.parse_args(argv)
    return main_worker(args) if args.worker else main_parent(args)


if __name__ == "__main__":
    sys.exit(main())
