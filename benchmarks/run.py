"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] [--json F]

Prints ``name,us_per_call,derived`` CSV rows.  `derived` carries the
figure-level quantity being reproduced (NMSE gap in bits, area/power
ratios, BER deltas, muting rates ...) so each row maps 1:1 onto a claim
in the paper; EXPERIMENTS.md quotes these rows.

PR-2 additions: the batched-vs-masked engine sweep (the truly-batched
kernel grid against the legacy masked-diagonal fold, wall-clock + FLOP
count per realization count) and the wideband OFDM subcarrier-scaling
sweep.

PR-3 additions: the packed-word storage sweep (packed vs two-plane
kernel wall-clock + HBM bytes/element) and the block-size autotuner rows
(cold tune -> persisted cache -> autotuned launch vs the old hardcoded
256^3 default).

PR-4 additions: the serve-decode rows — LLM decode through the model
zoo's kernel-backed packed serving path (`vp_dequant_matmul` on packed
VP words, offline word-LUT dequant) against the legacy jnp-dequant
two-plane baseline, with bit-identical logits asserted inline
(BENCH_pr4.json records the committed run).

PR-5 additions: the decode-attention rows — packed-word VP KV cache
through the `vp_decode_attention` kernel op against the legacy
dequant-whole-cache planes baseline, swept over cache_len and batch
(plus a windowed row for the O(window) slice path), attention-output
parity asserted inline (BENCH_pr5.json records the committed run).
PR-7 additions: the serving rows — the continuous-batching paged
engine against the static same-length-batch driver on one calibrated
Poisson arrival trace (virtual-clock timing, per-request token parity
asserted inline; BENCH_pr7.json records the committed run).

`--smoke` runs only the sweeps at tiny shapes — a CI
dispatch check for every kernel execution path (batched/masked x
fused/unfused x packed/plane, flat/vmap wideband, cold/warm autotune
cache) that fails loudly on kernel dispatch errors.  `--json F` writes
all emitted rows to F (committed as BENCH_pr3.json; CI uploads the smoke
run's file as an artifact).  Timing is min-over-repeats (noise-robust).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FXPFormat, VPFormat, pack_vp, vp_quantize, cost_model as cm,
)
from repro.core.param_search import search_exponent_list, vp_nmse
from repro.kernels import autotune, ops, ref
from repro.mimo import (
    ChannelConfig, OFDMConfig, WidebandCalibrator, table1_specs, cspade,
    make_wideband_ensemble, equalize_wideband,
)
from repro.mimo.mvm_engine import equalize_vp_kernel, mvm_flops
from repro.mimo.ofdm import wideband_nmse
from repro.mimo.sim import (
    make_ensemble, pdf_stats, nmse_vs_bitwidth, bitwidth_gap,
    ber_float, ber_quantized, calibrate_specs,
)

ROWS = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _timeit(fn, n=3):
    """MIN wall-clock over n runs (first call warms compile caches).

    The mean of back-to-back runs (the PR-2 timer) let one GC pause or
    scheduler hiccup distort a row by multiples; min is the standard
    noise-floor statistic for microbenchmarks.
    """
    fn()  # warmup/compile
    t = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t * 1e6


# ---------------------------------------------------------------------------

def fig7_pdf_stats(n_ch: int):
    """Fig. 7: spiky beamspace PDFs (kurtosis/PAPR of re parts)."""
    t0 = time.perf_counter()
    ens = make_ensemble(jax.random.PRNGKey(0), ChannelConfig(), n_ch, 20.0)
    us = (time.perf_counter() - t0) * 1e6
    k = {name: pdf_stats(x)["kurtosis"] for name, x in
         [("ybar", ens.y_ant), ("y", ens.y_beam),
          ("Wbar", ens.w_ant), ("W", ens.w_beam)]}
    emit("fig7_pdf_kurtosis", us,
         f"ybar={k['ybar']:.1f};y={k['y']:.1f};"
         f"Wbar={k['Wbar']:.1f};W={k['W']:.1f} (beamspace spikier)")
    return ens


def fig8_nmse(ens):
    """Fig. 8: NMSE vs bitwidth; paper: beamspace needs ~1.2 extra bits."""
    t0 = time.perf_counter()
    nm = nmse_vs_bitwidth(ens)
    us = (time.perf_counter() - t0) * 1e6
    gap = bitwidth_gap(nm)
    pts = ";".join(f"W{w}:a={nm['antenna'][w]:.1e},b={nm['beamspace'][w]:.1e}"
                   for w in sorted(nm["antenna"]))
    emit("fig8_nmse_bit_gap", us, f"gap={gap:.2f}bits(paper~1.2);{pts}")


def tab1_ber(n_ch: int):
    """Table I: BER of A-FXP/B-FXP/B-VP vs float LMMSE (no visible gap)."""
    t0 = time.perf_counter()
    ens = make_ensemble(jax.random.PRNGKey(7), ChannelConfig(), n_ch, 2.0)
    specs = calibrate_specs(table1_specs(), ens)
    ref_a, ref_b = ber_float(ens, False), ber_float(ens, True)
    rows = []
    for s in specs:
        b = ber_quantized(ens, s)
        r = ref_b if s.beamspace else ref_a
        rows.append(f"{s.name}={b:.4f}(float={r:.4f})")
    us = (time.perf_counter() - t0) * 1e6
    emit("tab1_ber_snr2db", us, ";".join(rows))


def tab1_param_search(ens):
    """Sec. II-D: Monte-Carlo exponent-list search recovers a Table-I-class
    format for the beamspace W signal."""
    w = np.asarray(ens.w_beam.real).ravel()[:200000]
    w = w / np.abs(w).max()
    fxp = FXPFormat(12, 11)
    t0 = time.perf_counter()
    fmt, err = search_exponent_list(w, fxp, M=7, E=2)
    us = (time.perf_counter() - t0) * 1e6
    base = vp_nmse(w, fxp, VPFormat(7, (11, 9, 7, 6)))
    emit("sec2d_param_search", us,
         f"found=VP(7,{list(fmt.f)}) nmse={err:.2e}; "
         f"paper_list=[11,9,7,6] nmse={base:.2e}")


def fig11_area():
    """Fig. 11a: area breakdown + ratios (paper: B-VP ~0.8x B-FXP)."""
    t0 = time.perf_counter()
    designs = cm.paper_designs()
    areas = {k: cm.mvm_area(s) for k, s in designs.items()}
    tot = {k: cm.total(v) for k, v in areas.items()}
    us = (time.perf_counter() - t0) * 1e6
    emit("fig11a_area_ratios", us,
         f"BFXP/AFXP={tot['B-FXP']/tot['A-FXP']:.3f}(paper~1.25);"
         f"BVP/BFXP={tot['B-VP']/tot['B-FXP']:.3f}(paper~0.80);"
         f"RMshare_BFXP={areas['B-FXP']['rm']/tot['B-FXP']:.2f}(paper0.66)")


def fig11_power(ens):
    """Fig. 11b/c: power with LoS / non-LoS stimuli-derived muting rates."""
    t0 = time.perf_counter()
    designs = cm.paper_designs()
    # muting rates measured on our channel ensembles at calibrated thresholds
    tw, ty = cspade.calibrate_thresholds(
        ens.w_beam, ens.y_beam, target_rate=0.5)
    mut_los = float(cspade.muting_rate(ens.w_beam, ens.y_beam, tw, ty))
    ens_n = make_ensemble(jax.random.PRNGKey(3),
                          ChannelConfig(los=False), 400, 20.0)
    mut_nlos = float(cspade.muting_rate(ens_n.w_beam, ens_n.y_beam, tw, ty))
    out = []
    for name, mut in (("LoS", mut_los), ("nonLoS", mut_nlos)):
        p = {k: sum(cm.mvm_power(s, muting_rate=mut).values())
             for k, s in designs.items()}
        out.append(f"{name}:mut={mut:.2f},BVP/BFXP={p['B-VP']/p['B-FXP']:.3f}")
    us = (time.perf_counter() - t0) * 1e6
    emit("fig11bc_power_ratios", us,
         ";".join(out) + "(paper 0.86-0.90)")


def sec5b_flp():
    """Sec. V-B: custom-FLP CMAC array vs VP CMAC array (paper: 3.4x)."""
    t0 = time.perf_counter()
    designs = cm.paper_designs()
    vp_a = cm.vp_cmac_array_area(designs["B-VP"])
    flp_a = cm.flp_cmac_array_area(8)
    us = (time.perf_counter() - t0) * 1e6
    emit("sec5b_flp_vs_vp_area", us,
         f"FLP/VP={flp_a/vp_a:.2f}(paper3.4; unit-gate model recovers the "
         "multiplier+adder structure; remainder is timing-driven synthesis)")


# ---------------------------------------------------------------------------
# Kernel microbenches (CPU interpret mode — correctness-path timing only)
# ---------------------------------------------------------------------------

def kernel_bench():
    y_fxp, y_vp = FXPFormat(9, 1), VPFormat(7, (1, -1))
    w_fxp, w_vp = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_t(2, (512, 512)).clip(-8, 8) * 10,
                    jnp.float32)
    b = jnp.asarray(rng.standard_t(2, (512, 512)).clip(-8, 8) * 0.01,
                    jnp.float32)
    ta = vp_quantize(a, y_fxp, y_vp)
    tb = vp_quantize(b, w_fxp, w_vp)

    us = _timeit(lambda: jax.block_until_ready(
        ops.vp_quant(a, y_fxp, y_vp, interpret=True)))
    emit("kernel_vp_quant_512x512_interp", us, "bit-exact vs ref (tests)")
    us = _timeit(lambda: jax.block_until_ready(
        ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, y_vp, w_vp, interpret=True)))
    # NMSE of the full VP pipeline vs float matmul
    out = np.asarray(ref.vp_matmul_ref(ta.m, ta.i, tb.m, tb.i, y_vp, w_vp))
    want = np.asarray(a) @ np.asarray(b)
    nmse = float(np.mean((out - want) ** 2) / np.mean(want**2))
    emit("kernel_vp_matmul_512_interp", us, f"nmse_vs_float={nmse:.1e}")

    # Fused quantize+matmul (substrate kernel): float in, no quantized-plane
    # HBM round-trip; swept over kernel block sizes.
    for blk in (128, 256, 512):
        us = _timeit(lambda blocks=(blk, blk, blk): jax.block_until_ready(
            ops.vp_quant_matmul(a, b, y_fxp, y_vp, w_fxp, w_vp,
                                blocks=blocks, interpret=True)))
        emit(f"kernel_vp_quant_matmul_512_b{blk}_interp", us,
             "fused quant+matmul, one pallas_call (vs quant->HBM->matmul)")

    # Autotuned launch: measure candidates once (persisted in the on-disk
    # cache), then time the cache-hit launch.  The PR-2 default was the
    # hardcoded 256^3 row above — the autotuner's win over it is the
    # hot-path payoff of the tuning pass.
    shape, fmts = (512, 512, 512), (y_fxp, y_vp, w_fxp, w_vp)
    t0 = time.perf_counter()
    best = autotune.tune(
        "vp_quant_matmul", shape, fmts, "interpret",
        lambda blocks: jax.block_until_ready(
            ops.vp_quant_matmul(a, b, y_fxp, y_vp, w_fxp, w_vp,
                                blocks=blocks, interpret=True)))
    tune_us = (time.perf_counter() - t0) * 1e6
    us = _timeit(lambda: jax.block_until_ready(
        ops.vp_quant_matmul(a, b, y_fxp, y_vp, w_fxp, w_vp, interpret=True)))
    emit("kernel_vp_quant_matmul_512_autotuned_interp", us,
         f"blocks={best};tune_cost_us={tune_us:.0f};"
         "pr2_default_was_b256 (one-time tune, persisted cache)")

    # Packed-word storage: packed vs two-plane matmul at the same shape.
    ta_w = pack_vp(ta.m, ta.i, y_vp)
    tb_w = pack_vp(tb.m, tb.i, w_vp)
    us_plane = _timeit(lambda: jax.block_until_ready(
        ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, y_vp, w_vp, interpret=True)))
    us_packed = _timeit(lambda: jax.block_until_ready(
        ops.vp_matmul(ta_w, None, tb_w, None, y_vp, w_vp, interpret=True)))
    bits_plane = 16  # int8 significand plane + uint8 index plane
    emit("kernel_vp_matmul_512_packed_interp", us_packed,
         f"plane_us={us_plane:.0f};"
         f"bytes_per_elem_packed={y_vp.storage_bits / 8:.1f}/"
         f"{w_vp.storage_bits / 8:.1f}(y/W)"
         f";bytes_per_elem_plane={bits_plane / 8:.1f}"
         f";y_traffic_halved={'yes' if y_vp.storage_bits == 8 else 'NO'}"
         ";outputs bit-identical (tests/test_packing.py)")

    from repro.core import block_vp_quantize
    am, ai = block_vp_quantize(a / 16, y_fxp, y_vp, block=256, axis=-1)
    bm, bi = block_vp_quantize(b * 64, w_fxp, w_vp, block=256, axis=0)
    us = _timeit(lambda: jax.block_until_ready(
        ops.block_vp_matmul(am, ai, bm, bi, y_vp, w_vp, bk=256,
                            interpret=True)))
    emit("kernel_block_vp_matmul_512_interp", us,
         "int8-MXU path (beyond-paper)")


def batched_vs_masked(n_list=(8, 32, 128), n_time=5):
    """The PR-2 tentpole claim: the truly-batched grid beats the legacy
    masked-diagonal fold on wall-clock AND FLOP count once a few
    realizations are batched (the masked fold wastes n x FLOPs)."""
    cfg = ChannelConfig()
    ens = make_ensemble(jax.random.PRNGKey(11), cfg, max(n_list), 20.0)
    spec = calibrate_specs([s for s in table1_specs()
                            if s.name == "B-VP"], ens)[0]
    wins = 0
    for n in n_list:
        w, y = ens.w_beam[:n], ens.y_beam[:n]
        out = {}
        for mode in ("batched", "masked"):
            us = _timeit(lambda m=mode: jax.block_until_ready(
                equalize_vp_kernel(spec, w, y, mode=m)), n=n_time)
            out[mode] = us
            emit(f"engine_{mode}_n{n}", us,
                 f"flops={mvm_flops(n, cfg.U, cfg.B, mode)}")
        speedup = out["masked"] / out["batched"]
        fl_ratio = (mvm_flops(n, cfg.U, cfg.B, "masked")
                    / mvm_flops(n, cfg.U, cfg.B, "batched"))
        won = speedup > 1.0
        wins += won
        emit(f"engine_batched_speedup_n{n}", out["batched"],
             f"wallclock_x{speedup:.2f};flops_x{fl_ratio:.0f};"
             f"batched_wins={'yes' if won else 'NO'}")
    return wins == len(n_list)


def subcarrier_scaling(S_list=(4, 16, 64), n=16, n_time=5):
    """Wideband OFDM sweep: whole-band equalization cost vs subcarrier
    count through the flat (single batched kernel launch) path.

    Per-subcarrier cost must be monotone non-increasing with the batch
    (fixed launch overhead amortizes; nothing in the flat path scales
    superlinearly since the ref cascades were jit-fused — the PR-2
    S=64 regression came from eagerly materializing every cascade
    intermediate once the band's working set outgrew the cache).
    """
    cfg = ChannelConfig()
    base = next(s for s in table1_specs() if s.name == "B-VP")
    prev_per_sc = None
    monotone = True
    for S in S_list:
        ofdm = OFDMConfig(n_subcarriers=S, n_taps=4)
        ens = make_wideband_ensemble(
            jax.random.PRNGKey(13), cfg, ofdm, n, 20.0)
        specs = WidebandCalibrator(base).specs_for(ens)
        us = _timeit(lambda: jax.block_until_ready(
            equalize_wideband(specs, ens.w_beam, ens.y_beam, how="flat")),
            n=n_time)
        s_hat = equalize_wideband(specs, ens.w_beam, ens.y_beam, how="flat")
        nmse = wideband_nmse(s_hat, ens.s)
        per_sc = us / S
        if prev_per_sc is not None and per_sc > prev_per_sc * 1.05:
            monotone = False
        prev_per_sc = per_sc
        emit(f"ofdm_wideband_S{S}", us,
             f"us_per_subcarrier={per_sc:.1f};nmse={nmse:.2e};"
             f"batch={S * n}x(2U,B)x(B,2)")
    emit("ofdm_per_subcarrier_monotone", 0.0,
         f"non_increasing={'yes' if monotone else 'NO'}"
         " (PR-2 regressed 994->1093 us/sc from S=16 to S=64)")
    return monotone


def smoke():
    """Tiny-shape dispatch check over every new execution path.

    Exercises batched/masked x fused/unfused, the wideband flat/vmap
    paths, and the interpret-mode kernels — any kernel dispatch error
    (bad grid, block spec, scalar-prefetch plumbing) raises and fails
    the CI job.  Also asserts the batched-vs-masked parity, packed-vs-
    plane parity, and the autotune cache round-trip inline.
    """
    cfg = ChannelConfig()
    ens = make_ensemble(jax.random.PRNGKey(0), cfg, 8, 20.0)
    spec = calibrate_specs([s for s in table1_specs()
                            if s.name == "B-VP"], ens)[0]
    w, y = ens.w_beam, ens.y_beam
    outs = {}
    for mode in ("batched", "masked"):
        for fused in (False, True):
            for interp in (None, True):
                t0 = time.perf_counter()
                s = jax.block_until_ready(equalize_vp_kernel(
                    spec, w, y, mode=mode, fused=fused, interpret=interp))
                us = (time.perf_counter() - t0) * 1e6
                outs[(mode, fused, interp)] = np.asarray(s)
                emit(f"smoke_{mode}_{'fused' if fused else 'unfused'}_"
                     f"{'interp' if interp else 'ref'}", us, "dispatch ok")
    first = next(iter(outs.values()))
    assert all((v == first).all() for v in outs.values()), \
        "smoke parity violation across engine paths"

    # Packed-vs-plane parity on the kernel dispatch (both backends).
    for interp in (None, True):
        a_m, a_i = ops.vp_quant(ens.w_beam.real, spec.w_fxp, spec.w_vp,
                                interpret=interp)
        a_w = ops.vp_quant(ens.w_beam.real, spec.w_fxp, spec.w_vp,
                           interpret=interp, packed=True)
        assert (np.asarray(pack_vp(a_m, a_i, spec.w_vp))
                == np.asarray(a_w)).all(), "packed quant mismatch"
    emit("smoke_packed_parity", 0.0,
         f"packed quant == pack(plane quant); "
         f"y_storage_bits={spec.y_vp.storage_bits};"
         f"w_storage_bits={spec.w_vp.storage_bits}")

    # Autotune: measured tune -> on-disk JSON -> cold in-memory reload
    # hits.  (The CI job runs smoke twice — cold then warm cache — and
    # asserts the file survives in between.)
    rng = np.random.default_rng(5)
    sa = jnp.asarray(rng.standard_t(2, (32, 64)).clip(-8, 8) * 0.01,
                     jnp.float32)
    sb = jnp.asarray(rng.standard_t(2, (64, 8)).clip(-8, 8), jnp.float32)
    shape, fmts = (32, 64, 8), (spec.w_fxp, spec.w_vp, spec.y_fxp, spec.y_vp)
    t0 = time.perf_counter()
    best = autotune.tune(
        "vp_quant_matmul", shape, fmts, "interpret",
        lambda blocks: jax.block_until_ready(ops.vp_quant_matmul(
            sa, sb, spec.w_fxp, spec.w_vp, spec.y_fxp, spec.y_vp,
            blocks=blocks, interpret=True)))
    tune_us = (time.perf_counter() - t0) * 1e6
    key = autotune.make_key("vp_quant_matmul", shape, fmts, "interpret")
    autotune._caches.clear()  # fresh-process analogue
    got = autotune.get_cached(key)
    assert got == best, f"autotune cache round-trip failed: {got} != {best}"
    emit("smoke_autotune_roundtrip", tune_us,
         f"cache={autotune.cache_path()};blocks={got};"
         "tuned entry survives a cold in-memory reload")

    ofdm = OFDMConfig(n_subcarriers=4, n_taps=2)
    wens = make_wideband_ensemble(jax.random.PRNGKey(1), cfg, ofdm, 4, 20.0)
    specs = WidebandCalibrator(spec).specs_for(wens)
    for how in ("flat", "vmap", "shard_map"):
        t0 = time.perf_counter()
        s = jax.block_until_ready(equalize_wideband(
            specs, wens.w_beam, wens.y_beam, how=how))
        us = (time.perf_counter() - t0) * 1e6
        emit(f"smoke_ofdm_{how}", us, "dispatch ok")

    assert batched_vs_masked(n_list=(8, 16), n_time=2), \
        "batched engine lost to the masked fold at smoke shapes"
    assert subcarrier_scaling(S_list=(2, 4), n=4, n_time=3), \
        "per-subcarrier cost increased with batch (the PR-3 OFDM fix " \
        "regressed: amortization must not lose to a bigger working set)"
    # Serve-decode: at B=1 (single-stream skinny decode, where weight
    # dequant dominates the matvec) the kernel-backed packed path must
    # never LOSE to the jnp-dequant baseline (the >=1.2x target is
    # pinned by the committed BENCH_pr4.json full run; CI smoke only
    # guards against regression to parity or worse, which survives
    # runner noise).
    assert serve_decode_bench(n_steps=4, n_time=3, B=1) >= 1.0, \
        "kernel-backed serve decode lost to the jnp-dequant baseline"
    # Decode attention: the packed-KV kernel path must never LOSE to the
    # jnp dequant-whole-cache baseline even at smoke cache lengths (the
    # >=1.2x target at cache_len >= 1024 is pinned by the committed
    # BENCH_pr5.json full run).
    assert decode_attention_bench(cache_lens=(256,), batches=(1,),
                                  n_time=3, window_rows=False) >= 1.0, \
        "packed-KV decode attention lost to the dequant-whole-cache " \
        "baseline"
    # Paged engine: a tiny mixed trace through the full continuous-
    # batching path (paged admission, ragged lengths, power-of-two
    # decode buckets) with engine/static token parity asserted inline —
    # a dispatch check, not a perf gate (the >=1.5x target is pinned by
    # the committed BENCH_pr7.json full run).
    assert engine_serving_bench(smoke=True) > 0, \
        "paged serving engine failed the smoke trace"


def serve_decode_bench(n_steps=8, n_time=5, B=1):
    """PR-4: the serve-decode rows — LLM decode on the kernel-backed
    packed serving path (`vp_dequant_matmul` consuming packed VP words)
    vs the legacy jnp-dequant two-plane baseline.

    Same float weights, same logits (parity asserted inline; the
    cross-arch golden-parity suite pins it per arch); these rows time the
    difference: the packed path ships ONE word plane per weight,
    dequantizes through the offline whole-word LUT, and gathers packed
    embedding ROWS, while the baseline unpacks bit-packed index planes
    per step.  The advantage is largest exactly where serving lives —
    skinny decode (B=1 single-stream: the weight dequant dominates the
    matvec) — and compresses as the batch amortizes dequant over more
    rows.  Timing is interleaved between layouts per round so machine
    drift cancels.  Returns the wall-clock speedup at batch B.
    """
    from repro.configs.base import ModelConfig, QuantConfig
    from repro.models import (
        init_params, init_cache, prefill, decode_step, quantize_params,
    )

    cfg = ModelConfig(
        name="serve-bench", family="dense", n_layers=2, d_model=320,
        n_heads=4, n_kv_heads=2, d_ff=1280, vocab=8192, dtype="float32",
        quant=QuantConfig(mode="vp"))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    layouts = ("packed", "planes")
    state = {}
    logits = {}
    for layout in layouts:
        qp = quantize_params(params, cfg, layout=layout)
        t0 = time.perf_counter()
        lo, caches = jax.block_until_ready(
            prefill(qp, toks, init_cache(cfg, B, 8 + n_steps + 1), cfg))
        prefill_us = (time.perf_counter() - t0) * 1e6
        dec = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
        tok = jnp.argmax(lo, -1)[:, None]
        jax.block_until_ready(dec(qp, tok, caches)[0])  # compile warmup
        state[layout] = (dec, qp, tok, caches, prefill_us)
        logits[layout] = np.asarray(lo)
    # INTERLEAVED timing: alternate layouts within each round so slow
    # machine phases (GC, co-tenants) hit both equally — sequential
    # blocks would let minutes of drift masquerade as a layout effect.
    # Two untimed rounds first: the first post-compile executions pay
    # allocator/page-cache warmup that min-of-n cannot fully shed.
    for _ in range(2):
        for layout in layouts:
            dec, qp, tok, caches, _ = state[layout]
            c = caches
            for _ in range(n_steps):
                lo2, c = dec(qp, tok, c)
            jax.block_until_ready(lo2)
    best = {layout: float("inf") for layout in layouts}
    for _ in range(n_time):
        for layout in layouts:
            dec, qp, tok, caches, _ = state[layout]
            t0 = time.perf_counter()
            c = caches
            for _ in range(n_steps):
                lo2, c = dec(qp, tok, c)
            jax.block_until_ready(lo2)
            best[layout] = min(best[layout],
                               (time.perf_counter() - t0) / n_steps)
    out = {}
    for layout in layouts:
        us = best[layout] * 1e6
        out[layout] = us
        prefill_us = state[layout][4]
        name = "kernel" if layout == "packed" else "jnp_baseline"
        emit(f"serve_decode_{name}_b{B}", us,
             f"{B * 1e6 / us:.0f} tok/s;prefill_us={prefill_us:.0f};"
             f"layout={layout};d320xff1280xV8192x2L")
    from repro.kernels import substrate as _sub
    if _sub.resolve_backend(None) == "ref":
        # Both layouts run the same jnp ref dot on CPU: exactly equal.
        assert (logits["packed"] == logits["planes"]).all(), \
            "serve bench parity violation: packed logits != planes logits"
    else:
        # The Pallas kernel accumulates f32 per k-tile (different
        # summation order than one flat dot): tight tolerance, not bits.
        assert np.allclose(logits["packed"], logits["planes"],
                           rtol=1e-5, atol=1e-5), \
            "serve bench parity violation: packed logits != planes logits"
    speedup = out["planes"] / out["packed"]
    target = f"target>=1.2x;met={'yes' if speedup >= 1.2 else 'NO'};" \
        if B == 1 else ""
    emit(f"serve_decode_speedup_b{B}", out["packed"],
         f"kernel_vs_jnp_x{speedup:.2f};{target}logit parity asserted")
    return speedup


def decode_attention_bench(cache_lens=(1024, 2048), batches=(1, 4),
                           n_time=5, window_rows=True):
    """PR-5: packed-KV decode attention (the `vp_decode_attention`
    kernel op) vs the legacy jnp dequant-whole-cache planes baseline.

    Same float K/V, same attention output (parity asserted inline —
    bit-identical on the ref backend, where both layouts dequantize to
    the same reals and run the shared decode core); the rows time the
    difference: the packed cache ships ONE word plane per element and
    dequantizes through the offline whole-word LUT, while the baseline
    unpacks the bit-packed index plane and walks the select cascade over
    the ENTIRE Smax buffer every step.  The windowed rows additionally
    exercise the O(window) slice path against the legacy whole-cache
    mask.  Timing is interleaved per round (machine drift cancels).
    Returns the minimum full-span speedup over the sweep.
    """
    from repro.configs.base import QuantConfig
    from repro.kernels import ops as kops
    from repro.kernels import substrate as ksub
    from repro.models.attention import (
        decode_attention, dequantize_kv, kv_cache_formats, quantize_kv,
    )

    q_cfg = QuantConfig(mode="none", quantize_kv_cache=True)
    _, vp = kv_cache_formats(q_cfg)
    KV, dh, G = 2, 64, 2
    H = KV * G
    ref_backend = ksub.resolve_backend(None) == "ref"

    def _legacy_whole_cache(q, k_full, v_full, lens, window=None):
        # The pre-PR-5 path verbatim: scores for ALL Smax positions.
        B_, _, H_, dh_ = q.shape
        smax = k_full.shape[1]
        qr = q.reshape(B_, KV, H_ // KV, dh_) * dh_ ** -0.5
        s = jnp.einsum("bkgd,bksd->bkgs", qr,
                       k_full.transpose(0, 2, 1, 3))
        pos = jnp.arange(smax)[None, :]
        valid = pos < lens[:, None]
        if window:
            valid &= pos >= (lens[:, None] - window)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        out = jnp.einsum("bkgs,bksd->bkgd", p,
                         v_full.transpose(0, 2, 1, 3))
        return out.reshape(B_, 1, H_, dh_)

    min_speedup = float("inf")
    for B in batches:
        for S in cache_lens:
            key = jax.random.PRNGKey(29)
            kk, kv_, kq = jax.random.split(key, 3)
            k = jax.random.normal(kk, (B, S, KV, dh), jnp.float32) * 2
            v = jax.random.normal(kv_, (B, S, KV, dh), jnp.float32)
            q = jax.random.normal(kq, (B, 1, H, dh), jnp.float32)
            lens = jnp.full((B,), S, jnp.int32)
            w_k, s_k = quantize_kv(k, q_cfg)
            w_v, s_v = quantize_kv(v, q_cfg)
            m_k, i_k, ps_k = quantize_kv(k, q_cfg, layout="planes")
            m_v, i_v, ps_v = quantize_kv(v, q_cfg, layout="planes")

            kern = jax.jit(lambda q, lens: kops.vp_decode_attention(
                q, w_k, w_v, s_k, s_v, lens, vp))
            base = jax.jit(lambda q, lens: _legacy_whole_cache(
                q,
                dequantize_kv(m_k, i_k, ps_k, q_cfg, q.dtype),
                dequantize_kv(m_v, i_v, ps_v, q_cfg, q.dtype),
                lens))
            o_kern = np.asarray(kern(q, lens))
            o_base = np.asarray(base(q, lens))
            if ref_backend:
                assert (o_kern == o_base).all(), \
                    "decode-attention parity violation (packed vs planes)"
            else:
                assert np.allclose(o_kern, o_base, rtol=1e-5, atol=1e-5)

            def _time_pair(fns):
                # warm compile + allocator, then interleaved min-of-n
                best = {n: float("inf") for n in fns}
                for f in fns.values():
                    jax.block_until_ready(f(q, lens))
                    jax.block_until_ready(f(q, lens))
                for _ in range(n_time):
                    for n, f in fns.items():
                        t0 = time.perf_counter()
                        jax.block_until_ready(f(q, lens))
                        best[n] = min(best[n], time.perf_counter() - t0)
                return best

            fns = {"kernel": kern, "jnp_baseline": base}
            best = _time_pair(fns)
            for n in fns:
                emit(f"decode_attn_{n}_b{B}_s{S}", best[n] * 1e6,
                     f"packed_bits={vp.storage_bits};KV{KV}xdh{dh}xH{H};"
                     "full-span causal decode")
            speedup = best["jnp_baseline"] / best["kernel"]
            min_speedup = min(min_speedup, speedup)
            emit(f"decode_attn_speedup_b{B}_s{S}", best["kernel"] * 1e6,
                 f"kernel_vs_jnp_x{speedup:.2f};parity asserted"
                 f"{' (bit-identical)' if ref_backend else ''}")

            if window_rows and S >= max(cache_lens):
                window = max(128, S // 8)
                kern_w = jax.jit(lambda q, lens: kops.vp_decode_attention(
                    q, w_k, w_v, s_k, s_v, lens, vp, window=window))
                base_w = jax.jit(lambda q, lens: _legacy_whole_cache(
                    q,
                    dequantize_kv(m_k, i_k, ps_k, q_cfg, q.dtype),
                    dequantize_kv(m_v, i_v, ps_v, q_cfg, q.dtype),
                    lens, window=window))
                assert np.allclose(np.asarray(kern_w(q, lens)),
                                   np.asarray(base_w(q, lens)),
                                   rtol=1e-5, atol=1e-5), \
                    "windowed decode-attention parity violation"
                bw = _time_pair({"kernel": kern_w, "jnp_baseline": base_w})
                emit(f"decode_attn_window{window}_speedup_b{B}_s{S}",
                     bw["kernel"] * 1e6,
                     f"kernel_vs_jnp_x{bw['jnp_baseline']/bw['kernel']:.2f}"
                     f";O(window) slice vs O(Smax) mask;parity asserted")
    return min_speedup


def engine_serving_bench(n_req=12, max_slots=4, smoke=False, seed=0):
    """PR-7: the continuous-batching paged engine vs the static
    same-length-batch driver on one staggered (Poisson) arrival trace.

    Same model (VP-quantized weights + packed VP KV cache), same greedy
    sampling, same per-request token budgets; tokens are asserted
    identical request-by-request (the engine's full-capacity gathered
    view is bit-identical to the static B=1 path on the ref backend), so
    these rows time pure *scheduling*: in-flight batching over a paged
    cache vs head-of-line same-length batches that cannot ingest
    arrivals mid-decode.  Both sides charge measured compute to a
    virtual clock and jump idle arrival gaps, so the derived tokens/sec
    is a deterministic function of per-step compute, not of sleeps.
    The arrival process is calibrated off the measured decode step
    (mean gap = mean_gen * t_step / max_slots — the saturation point of
    `max_slots` slots), which keeps the trace meaningful across machine
    speeds."""
    from repro.configs.base import ModelConfig, QuantConfig
    from repro.models import (
        decode_step, init_cache, init_params, prefill, quantize_params,
    )
    from repro.serving import ServingEngine, VirtualClock

    quant = QuantConfig(mode="vp", quantize_kv_cache=True,
                        kv_layout="packed")
    cfg = ModelConfig(name="engine-bench", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=512, dtype="float32", quant=quant)
    params = quantize_params(init_params(jax.random.PRNGKey(seed), cfg),
                             cfg)
    if smoke:
        n_req, max_slots = 4, 2
        plens = [8 if i % 2 == 0 else 12 for i in range(n_req)]
        gens = [3 + i % 3 for i in range(n_req)]
    else:
        # distinct prompt lengths: real mixed traffic essentially never
        # repeats an exact length, and the static driver can only batch
        # requests whose prompts are EXACTLY the same length (its
        # rectangular prefill has no left-pad mask) — the engine's paged
        # views batch the mix natively, the static path serializes it.
        plens = [16 + 2 * i for i in range(n_req)]
        gens = [16 + (i * 9) % 17 for i in range(n_req)]    # ragged 16..32
    page_size = 8 if smoke else 16
    capacity = -(-(max(plens) + max(gens)) // page_size) * page_size
    total = sum(gens)
    kp = jax.random.PRNGKey(seed + 1)
    prompts = [
        [int(t) for t in jax.random.randint(
            jax.random.fold_in(kp, i), (plens[i],), 0, cfg.vocab)]
        for i in range(n_req)]

    # -- engine side (one engine: jit caches survive the warm run) ------
    eng = ServingEngine(params, cfg, max_slots=max_slots,
                        capacity=capacity, page_size=page_size,
                        decode_lookahead=2 if smoke else 4,
                        clock=VirtualClock())

    def run_engine(arrivals):
        base = eng.clock.now()
        for i in range(n_req):
            eng.submit(prompts[i], gens[i], base + arrivals[i])
        recs = {r["rid"]: r for r in eng.run()}
        eng.finished.clear()
        out = []
        for rid in sorted(recs)[-n_req:]:   # this wave, submission order
            r = recs[rid]
            out.append((r["arrival_time"], r["finish_time"], r["tokens"]))
        return out

    # -- static side (shared jit caches across warm + timed calls) ------
    pj, dj = {}, {}

    def _prefill_fn(B, S):
        if (B, S) not in pj:
            def f(p, t, c):
                lg, c2 = prefill(p, t, c, cfg)
                tok = jnp.argmax(lg.reshape(t.shape[0], -1), -1)
                return tok.astype(jnp.int32)[:, None], c2
            pj[(B, S)] = jax.jit(f)
        return pj[(B, S)]

    def _decode_fn(B):
        if B not in dj:
            def f(p, t, c):
                lg, c2 = decode_step(p, t, c, cfg)
                tok = jnp.argmax(lg.reshape(t.shape[0], -1), -1)
                return tok.astype(jnp.int32)[:, None], c2
            dj[B] = jax.jit(f)
        return dj[B]

    def run_static(arrivals):
        """FIFO static batching: serve the head-of-line request together
        with every waiting SAME-prompt-length request (rectangular batch,
        up to max_slots), pad generation to the batch max, and only then
        look at the queue again — the classic driver the engine
        replaces."""
        order = sorted(range(n_req), key=lambda i: (arrivals[i], i))
        now, nxt, pend = 0.0, 0, []
        toks = [[] for _ in range(n_req)]
        fin = [0.0] * n_req
        while nxt < n_req or pend:
            while nxt < n_req and arrivals[order[nxt]] <= now + 1e-12:
                pend.append(order[nxt])
                nxt += 1
            if not pend:
                now = max(now, arrivals[order[nxt]])
                continue
            head = pend[0]
            batch = [i for i in pend if plens[i] == plens[head]]
            batch = batch[:max_slots]
            for i in batch:
                pend.remove(i)
            B, S = len(batch), plens[head]
            gmax = max(gens[i] for i in batch)
            caches = init_cache(cfg, B, capacity)
            tokens = jnp.asarray([prompts[i] for i in batch], jnp.int32)
            t0 = time.perf_counter()
            tok, caches = _prefill_fn(B, S)(params, tokens, caches)
            tok_h = np.asarray(tok)     # one transfer, not B reads
            now += time.perf_counter() - t0
            for b, i in enumerate(batch):
                toks[i].append(int(tok_h[b, 0]))
                if gens[i] == 1:
                    fin[i] = now
            for step in range(1, gmax):
                t0 = time.perf_counter()
                tok, caches = _decode_fn(B)(params, tok, caches)
                tok_h = np.asarray(tok)
                now += time.perf_counter() - t0
                for b, i in enumerate(batch):
                    if step < gens[i]:
                        toks[i].append(int(tok_h[b, 0]))
                        if step == gens[i] - 1:
                            fin[i] = now
        return [(arrivals[i], fin[i], toks[i]) for i in range(n_req)]

    # -- warm every shape either path can hit, then calibrate -----------
    zeros = [0.0] * n_req
    run_engine(zeros)
    run_static(zeros)
    # the static driver can only form batches as large as a length
    # class's multiplicity, so only warm the shapes it can reach
    b_max = min(max_slots, max(plens.count(p) for p in set(plens)))
    tok = None
    for B in range(1, b_max + 1):
        for S in sorted(set(plens)):
            c = init_cache(cfg, B, capacity)
            tk = jnp.zeros((B, S), jnp.int32)
            tok, c = _prefill_fn(B, S)(params, tk, c)
        tok, c = _decode_fn(B)(params, tok, c)
        jax.block_until_ready(tok)
    # Calibrate off a warmed engine wave: offered load = 2x the engine's
    # saturated service rate, which keeps BOTH sides compute-bound
    # (under overload, measured tokens/sec is each side's service
    # capacity — robust to calibration noise; an arrival-bound trace
    # would just measure the gaps and push the ratio toward 1).
    cal = run_engine(zeros)
    mk_cal = (max(f for _, f, _ in cal)
              - min(a for a, _, _ in cal))
    rng = np.random.default_rng(seed)
    mean_gap = mk_cal / (2 * (n_req - 1))
    arrivals = [0.0] + [float(a) for a in np.cumsum(
        rng.exponential(scale=mean_gap, size=n_req - 1))]

    n_time = 1 if smoke else 3
    eng_waves = [run_engine(arrivals) for _ in range(n_time)]
    sta_waves = [run_static(arrivals) for _ in range(n_time)]
    for eng_recs, sta_recs in zip(eng_waves, sta_waves):
        for i, ((_, _, et), (_, _, st)) in enumerate(
                zip(eng_recs, sta_recs)):
            assert len(et) == gens[i], \
                f"engine made {len(et)} tokens for rid {i}, want {gens[i]}"
            assert et == st, \
                f"engine/static token divergence on request {i}: " \
                f"{et} != {st}"

    def _metrics(recs):
        t0 = min(a for a, _, _ in recs)
        t1 = max(f for _, f, _ in recs)
        lat = sorted(f - a for a, f, _ in recs)

        def pct(p):
            return lat[min(len(lat) - 1,
                           max(0, -(-p * len(lat) // 100) - 1))]

        return total / (t1 - t0), t1 - t0, pct(50), pct(99)

    # min-over-repeats at the wave level: each wave charges one-shot
    # perf_counter readings to the virtual clock, so score each side by
    # its least-disturbed wave (same convention as the kernel rows).
    e_tps, e_mk, e_p50, e_p99 = max(
        (_metrics(w) for w in eng_waves), key=lambda m: m[0])
    s_tps, s_mk, s_p50, s_p99 = max(
        (_metrics(w) for w in sta_waves), key=lambda m: m[0])
    speedup = e_tps / s_tps
    tag = (f"slots={max_slots};page={page_size};cap={capacity};"
           f"mean_gap_us={mean_gap * 1e6:.0f}")
    emit("engine_poisson_vp_packed", e_mk * 1e6 / total,
         f"tokens_per_s={e_tps:.1f};p50_s={e_p50:.4f};"
         f"p99_s={e_p99:.4f};{tag}")
    emit("static_poisson_vp_packed", s_mk * 1e6 / total,
         f"tokens_per_s={s_tps:.1f};p50_s={s_p50:.4f};"
         f"p99_s={s_p99:.4f};{tag}")
    emit("engine_vs_static_serving", e_mk * 1e6 / total,
         f"engine_vs_static_x{speedup:.2f};{n_req} Poisson arrivals, "
         f"ragged prompts+gens;tokens bit-identical per request")
    return speedup


def engine_chaos_bench(n_req=8, max_slots=4, smoke=False, seed=0):
    """PR-10: resilience rows — goodput under fault injection, and the
    cost of the per-slot finite check that buys the containment.

    One engine shape (VP weights + packed VP KV cache, deterministic
    virtual clock), three measurements:

      * fault-free goodput: every request carries a deadline calibrated
        to 3x the fault-free makespan; goodput = deadline-met tokens/sec;
      * chaos goodput: the same trace under a combined `FaultPlan`
        (persistent logit poison on one request -> quarantine -> degrade
        to the oracle path, one transient decode failure, a page-
        pressure spike, a straggling step) — the engine must finish the
        wave with every non-victim request deadline-met, so retained
        goodput measures what the fault mix actually costs;
      * finite-check overhead: identical fault-free waves with the
        per-slot check on vs off, min-over-repeats — asserted < 5% on
        the smoke shape (the check is one host `isfinite` over logits
        the engine already copied back; it must stay noise-level).
    """
    from repro.configs.base import ModelConfig, QuantConfig
    from repro.models import init_params, quantize_params
    from repro.serving import (
        FaultPlan, LogitPoison, PagePressure, ServingEngine, SlowStep,
        TransientFault, VirtualClock,
    )

    quant = QuantConfig(mode="vp", quantize_kv_cache=True,
                        kv_layout="packed")
    cfg = ModelConfig(name="chaos-bench", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=512, dtype="float32", quant=quant)
    params = quantize_params(init_params(jax.random.PRNGKey(seed), cfg),
                             cfg)
    if smoke:
        n_req, max_slots = 4, 2
    plens = [8 + 2 * (i % 3) for i in range(n_req)]
    gens = [4 + (i * 5) % 7 for i in range(n_req)]
    page_size = 8
    capacity = -(-(max(plens) + max(gens)) // page_size) * page_size
    kp = jax.random.PRNGKey(seed + 1)
    prompts = [
        [int(t) for t in jax.random.randint(
            jax.random.fold_in(kp, i), (plens[i],), 0, cfg.vocab)]
        for i in range(n_req)]

    def build(check_finite=True):
        return ServingEngine(
            params, cfg, max_slots=max_slots, capacity=capacity,
            page_size=page_size, clock=VirtualClock(),
            check_finite=check_finite, on_nonfinite="quarantine",
            degrade=True, degrade_after=2)

    def wave(eng, deadline_budget=None, plan=None):
        """One burst of the trace through `eng`; returns this wave's
        records.  `plan` faults are rebased to the wave's start time."""
        base = eng.clock.now()
        eng.faults = plan
        first = eng.stats["submitted"]
        for i in range(n_req):
            eng.submit(prompts[i], gens[i], base,
                       deadline=(base + deadline_budget)
                       if deadline_budget else None)
        recs = {r["rid"]: r for r in eng.run()}
        eng.finished.clear()
        return [recs[first + i] for i in range(n_req)]

    def makespan(recs):
        return (max(r["finish_time"] for r in recs
                    if r["finish_time"] is not None)
                - min(r["arrival_time"] for r in recs))

    def goodput(recs):
        good = sum(len(r["tokens"]) for r in recs if r["deadline_met"])
        return good / max(makespan(recs), 1e-9)

    def chaos_plan(base, victim_rid, mk):
        return FaultPlan([
            LogitPoison(rid=victim_rid, phase="decode"),
            TransientFault(kind="decode", times=1),
            PagePressure(at=base, release=base + 0.2 * mk, pages=2),
            SlowStep(at=base + 0.25 * mk, extra_s=0.1 * mk),
        ])

    n_time = 1 if smoke else 3
    eng = build(check_finite=True)
    mk_warm = makespan(wave(eng))               # warm every jit shape
    # Warm the containment paths too (quarantine re-prefill, retry,
    # degrade->oracle): the oracle's first dispatch compiles, and that
    # wall time is charged to the virtual clock — it must not land
    # inside a measured wave.
    base, rid0 = eng.clock.now(), eng.stats["submitted"]
    wave(eng, plan=chaos_plan(base, rid0 + 1, mk_warm))
    mk_cal = makespan(wave(eng))
    budget = 3.0 * mk_cal

    free_waves = [wave(eng, deadline_budget=budget) for _ in range(n_time)]
    g_free = max(goodput(w) for w in free_waves)
    mk_free = min(makespan(w) for w in free_waves)

    chaos_waves = []
    for _ in range(n_time):
        base, rid0 = eng.clock.now(), eng.stats["submitted"]
        chaos_waves.append(wave(eng, deadline_budget=budget,
                                plan=chaos_plan(base, rid0 + 1, mk_cal)))
    g_chaos = max(goodput(w) for w in chaos_waves)
    mk_chaos = min(makespan(w) for w in chaos_waves)
    for w in chaos_waves:                       # resilience contract
        outcomes = [r["outcome"] for r in w]
        assert all(o in ("ok", "retried", "degraded", "timeout",
                         "quarantined", "shed") for o in outcomes)
        assert outcomes[1] == "degraded", \
            f"poisoned request must degrade to the oracle path: {outcomes}"

    # Overhead of the per-slot screen.  The check itself is one host
    # `np.isfinite` over logits `decode_batch` already copied back, so
    # the true cost is noise-level — which is exactly why single waves
    # (~ms of virtual time charged from real step wall-clock) cannot
    # measure it: OS jitter per wave dwarfs it.  Interleave the two
    # variants and compare SUMMED makespans so jitter averages out
    # instead of landing on one side of the ratio.
    n_ovh = 10
    eng_nc = build(check_finite=False)
    wave(eng_nc)                                # warm the unchecked jits
    mk_on = mk_off = 0.0
    for _ in range(n_ovh):
        mk_on += makespan(wave(eng))
        mk_off += makespan(wave(eng_nc))
    overhead = mk_on / max(mk_off, 1e-12)
    if smoke:
        assert overhead < 1.05, \
            f"per-slot finite check cost {overhead:.3f}x (budget 1.05x)"

    total = sum(gens)
    retained = g_chaos / max(g_free, 1e-9)
    tag = f"slots={max_slots};page={page_size};cap={capacity};n={n_req}"
    emit("engine_goodput_fault_free", mk_free * 1e6 / total,
         f"goodput_tok_s={g_free:.1f};deadline_budget_s={budget:.4f};{tag}")
    emit("engine_goodput_chaos", mk_chaos * 1e6 / total,
         f"goodput_tok_s={g_chaos:.1f};retained_x{retained:.2f};"
         f"faults=poison+transient+page_spike+slow_step;"
         f"victim_degraded_to_oracle;{tag}")
    emit("engine_finite_check_overhead", mk_on / n_ovh * 1e6 / total,
         f"checked_vs_unchecked_x{overhead:.3f};per-slot host isfinite "
         f"on already-resident logits")
    return retained


def train_qat_bench(steps=6, n_time=3):
    """PR-9: VP-quantized TRAINING rows — the packed datapath is now
    differentiable end to end (custom-VJP packed-word backward kernels),
    so the fine-tune loop itself can run on packed words.

    Three step variants on one small dense LM, identical data:

      * f32 baseline (no quantization anywhere);
      * QAT fake (legacy fake-quant STE in the float graph);
      * QAT packed (packed-word forward AND backward kernels) WITH
        VP-compressed DP gradients and VP-packed Adam moments — the
        full compressed training configuration.

    `derived` carries the machine-independent quantities: the final
    losses (packed must track fake to ~1e-6 relative — same STE math,
    different gemm summation order; asserted inline) and the storage
    ratios — packed moments cut Adam state from 8 bytes/param to
    2*storage_bits/8, the VP grad codec cuts DP wire bytes 32/
    storage_bits vs f32.
    """
    from repro.configs.base import ModelConfig, QuantConfig
    from repro.core.packing import storage_dtype
    from repro.models import init_params
    from repro.models.layers import canonical_formats
    from repro.optim.optimizer import OptConfig, init_opt_state
    from repro.train import make_train_step
    from repro.train.compression import (
        CompressionConfig, init_compressor_state,
    )

    cfg = ModelConfig(
        name="train-bench", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, dtype="float32",
        quant=QuantConfig(mode="none"))
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=steps)
    opt_vp = OptConfig(lr=1e-3, warmup_steps=1, total_steps=steps,
                       moment_codec="vp")

    def batch(i):
        toks = jax.random.randint(jax.random.PRNGKey(100 + i),
                                  (4, 33), 0, cfg.vocab)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def run(qat_mode, compressed):
        qat = (QuantConfig(mode="vp", qat_mode=qat_mode)
               if qat_mode else None)
        cmp_cfg = CompressionConfig(codec="vp") if compressed else False
        ocfg = opt_vp if compressed else opt
        step = jax.jit(make_train_step(cfg, ocfg, compress_grads=cmp_cfg,
                                       qat=qat))
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_opt_state(params, ocfg)
        cmp = init_compressor_state(params) if compressed else None
        loss = None
        t0 = None
        for i in range(steps):
            if i == 1:  # step 0 pays compile; time the steady state
                t0 = time.perf_counter()
            if compressed:
                params, state, metrics, cmp = step(params, state,
                                                   batch(i), cmp)
            else:
                params, state, metrics = step(params, state, batch(i))
            loss = jax.block_until_ready(metrics["loss"])
        us = (time.perf_counter() - t0) * 1e6 / (steps - 1)
        return us, float(loss)

    us_f32, loss_f32 = run(None, False)
    us_fake, loss_fake = run("fake", False)
    us_pk, loss_pk = run("packed", True)
    assert abs(loss_fake - loss_pk) < 1e-3 * max(1.0, abs(loss_fake)), \
        f"packed QAT diverged from the fake-quant STE baseline: " \
        f"{loss_pk} vs {loss_fake}"

    _, vp = canonical_formats(QuantConfig(mode="vp"))
    word_bytes = np.dtype(storage_dtype(vp)).itemsize
    m_fxp, m_vp = opt_vp.moment_formats()
    mom_bytes = 2 * np.dtype(storage_dtype(m_vp)).itemsize
    emit("train_step_f32", us_f32, f"final_loss={loss_f32:.6f}")
    emit("train_step_qat_fake", us_fake, f"final_loss={loss_fake:.6f}")
    emit("train_step_qat_packed_compressed", us_pk,
         f"final_loss={loss_pk:.6f};loss_delta_vs_fake="
         f"{abs(loss_pk - loss_fake):.2e};"
         f"grad_wire_bytes_per_elem={word_bytes} (f32=4);"
         f"adam_moment_bytes_per_param={mom_bytes} (f32=8)")
    del m_fxp
    return abs(loss_pk - loss_fake)


def cspade_tile_stats(ens):
    """Tile-level CSPADE muting on real beamspace stimuli (TPU adaptation).

    Per realization: the equalization MVM W (U=8, B=64) x y (B,) tiled
    (8 x 8) along the beam axis — beam sparsity makes whole k-tiles quiet
    for W and y SIMULTANEOUSLY (same inactive beams), which is what the
    kernel's tile-skip exploits."""
    t0 = time.perf_counter()
    w = np.asarray(ens.w_beam.real)      # (n, 8, 64)
    y = np.asarray(ens.y_beam.real)      # (n, 64)
    tw = np.quantile(np.abs(w), 0.9)
    ty = np.quantile(np.abs(y), 0.9)
    # scalar-granularity reference (the ASIC's per-product muting)
    scalar = float(((np.abs(w) < tw)
                    & (np.abs(y)[:, None, :] < ty)).mean())
    rates = {}
    for bk in (2, 4, 8, 16):
        w_t = np.abs(w).reshape(w.shape[0], 8, 64 // bk, bk).max((1, 3))
        y_t = np.abs(y).reshape(y.shape[0], 64 // bk, bk).max(-1)
        rates[bk] = float(((w_t < tw) & (y_t < ty)).mean())
    us = (time.perf_counter() - t0) * 1e6
    emit("cspade_tile_muting_rate", us,
         f"scalar={scalar:.2f};"
         + ";".join(f"tile{bk}={r:.2f}" for bk, r in rates.items())
         + " (granularity cost of the systolic tile-skip adaptation)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape dispatch check of the new kernel "
                         "paths only (CI job)")
    ap.add_argument("--train", action="store_true",
                    help="run only the PR-9 training rows (QAT + "
                         "compressed-state train steps)")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the PR-10 resilience rows (goodput "
                         "under fault injection + finite-check overhead)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="also write the emitted rows to FILE as JSON")
    args, _ = ap.parse_known_args()
    n_ch = 400 if args.fast else 2000
    n_ber = 1000 if args.fast else 4000

    print("name,us_per_call,derived")
    if args.chaos:
        retained = engine_chaos_bench(smoke=args.smoke)
        assert retained > 0, "chaos goodput collapsed to zero"
    elif args.train:
        train_qat_bench()
    elif args.smoke:
        smoke()
    else:
        ens = fig7_pdf_stats(n_ch)
        fig8_nmse(ens)
        tab1_ber(n_ber)
        tab1_param_search(ens)
        fig11_area()
        fig11_power(ens)
        sec5b_flp()
        kernel_bench()
        cspade_tile_stats(ens)
        batched_vs_masked()
        subcarrier_scaling()
        serve_decode_bench(B=1)   # single-stream skinny decode
        serve_decode_bench(B=4)   # batched decode (dequant amortizes)
        min_x = decode_attention_bench()  # packed-KV cache attention
        assert min_x > 1.0, \
            f"packed-KV decode attention must beat the dequant-whole-" \
            f"cache baseline at every swept (B, cache_len); got {min_x:.2f}x"
        eng_x = engine_serving_bench()    # continuous-batching engine
        assert eng_x >= 1.5, \
            f"continuous-batching engine must reach >=1.5x aggregate " \
            f"tokens/sec over the static driver on staggered arrivals; " \
            f"got {eng_x:.2f}x"
        engine_chaos_bench()              # resilience: goodput under faults
        train_qat_bench()                 # packed-word QAT train steps

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"rows": [
                    {"name": n, "us_per_call": us, "derived": d}
                    for n, us, d in ROWS]},
                f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
