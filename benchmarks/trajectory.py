"""Append-only benchmark trajectory across PRs.

Each PR commits a point-in-time `BENCH_pr{N}.json` (the `--json` output
of `benchmarks.run`).  Those are snapshots; comparing two of them means
opening both by hand.  This module folds them into ONE committed
append-only ledger, `BENCH_TRAJECTORY.json`, so a perf regression shows
up as a readable per-metric time series instead of an archaeology dig:

    python -m benchmarks.trajectory append BENCH_pr6.json --label pr6
    python -m benchmarks.trajectory summarize
    python -m benchmarks.trajectory summarize --metric serve_decode

Rules of the ledger:

  * append-only — `append` refuses to overwrite or reorder; a label that
    already exists is an error (re-running a PR's benchmarks means a new
    label, e.g. `pr6b`, never silent replacement of committed history).
  * each entry is the FULL `rows` list of one `benchmarks.run` report,
    tagged with its label and source filename — no lossy distillation at
    append time; `summarize` does the distilling at read time.

`summarize` prints one line per metric: the per-label `us_per_call`
series and the last entry's `derived` payload (the paper-facing
quantity — NMSE gaps, BER, speedups).  Timings committed from different
machines are not comparable in absolute terms; the trajectory is for
spotting structural cliffs (a metric that doubles while its neighbours
hold) and for tracking the derived quantities, which ARE
machine-independent.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_TRAJECTORY.json")


def load(path: str = DEFAULT_PATH) -> Dict:
    if not os.path.exists(path):
        return {"entries": []}
    with open(path, encoding="utf-8") as f:
        traj = json.load(f)
    if "entries" not in traj or not isinstance(traj["entries"], list):
        raise ValueError(f"{path}: not a trajectory file "
                         f"(missing 'entries' list)")
    return traj


def append_report(traj: Dict, label: str, report: Dict,
                  source: str = "") -> Dict:
    """Append one benchmarks.run report under `label` (must be new)."""
    if not label:
        raise ValueError("empty trajectory label")
    taken = [e["label"] for e in traj["entries"]]
    if label in taken:
        raise ValueError(
            f"label {label!r} already in trajectory ({taken}); the "
            f"ledger is append-only — pick a fresh label instead of "
            f"rewriting committed history")
    rows = report.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"report has no 'rows' (keys: {list(report)})")
    traj["entries"].append(
        {"label": label, "source": source, "rows": rows})
    return traj


def save(traj: Dict, path: str = DEFAULT_PATH) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")


def metric_series(traj: Dict, metric: Optional[str] = None) -> List[Dict]:
    """Per-metric time series across entries, insertion-ordered.

    Returns [{name, series: [(label, us_per_call)...], derived}] where
    `derived` is the most recent entry's derived payload.  `metric`
    filters by substring.
    """
    order: List[str] = []
    by_name: Dict[str, Dict] = {}
    for entry in traj["entries"]:
        for row in entry["rows"]:
            name = row["name"]
            if metric and metric not in name:
                continue
            if name not in by_name:
                order.append(name)
                by_name[name] = {"name": name, "series": [],
                                 "derived": ""}
            by_name[name]["series"].append(
                (entry["label"], row.get("us_per_call")))
            if row.get("derived"):
                by_name[name]["derived"] = row["derived"]
    return [by_name[n] for n in order]


def _fmt_us(us) -> str:
    if us is None:
        return "-"
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def summarize(traj: Dict, metric: Optional[str] = None) -> str:
    labels = [e["label"] for e in traj["entries"]]
    lines = [f"trajectory: {len(labels)} entries ({', '.join(labels)})"]
    for m in metric_series(traj, metric):
        pts = " -> ".join(
            f"{lbl}:{_fmt_us(us)}" for lbl, us in m["series"])
        lines.append(f"{m['name']:44s} {pts}")
        if m["derived"]:
            lines.append(f"{'':44s}   last derived: {m['derived']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.trajectory",
        description="append-only cross-PR benchmark ledger")
    p.add_argument("--path", default=DEFAULT_PATH,
                   help="trajectory file (default: BENCH_TRAJECTORY.json "
                        "at the repo root)")
    sub = p.add_subparsers(dest="cmd", required=True)
    ap = sub.add_parser("append",
                        help="append one benchmarks.run --json report")
    ap.add_argument("report", help="BENCH_pr{N}.json to append")
    ap.add_argument("--label", required=True,
                    help="unique entry label, e.g. pr6")
    sp = sub.add_parser("summarize", help="print per-metric series")
    sp.add_argument("--metric", default=None,
                    help="substring filter on metric names")
    args = p.parse_args(argv)

    traj = load(args.path)
    if args.cmd == "append":
        with open(args.report, encoding="utf-8") as f:
            report = json.load(f)
        append_report(traj, args.label, report,
                      source=os.path.basename(args.report))
        save(traj, args.path)
        print(f"appended {args.label!r} "
              f"({len(report['rows'])} rows) -> {args.path}")
    else:
        print(summarize(traj, args.metric))
    return 0


if __name__ == "__main__":
    sys.exit(main())
