"""Packed-word backward kernels and the custom-VJP training rules.

Three layers of contract, mirroring the forward suites:

  * kernel parity — `vp_matmul_dx` / `vp_matmul_dw` through the Pallas
    interpreter against their jnp ref oracles (allclose: interpret
    accumulates per k-tile into an f32 scratch, the oracle contracts in
    one dot).
  * grad exactness — `jax.grad` through the custom-VJP ops is
    BIT-IDENTICAL on the ref backend to autodiff through the
    dequantize-then-matmul oracle: the hand-written backwards use the
    same `dot_general` dimension numbers XLA's dot transpose rule
    emits, so there is no tolerance to tune.
  * QAT end-to-end — fine-tuning zoo archs with `qat_mode="packed"`
    (packed-word Pallas forward AND backward) lands at the same final
    loss as the fake-quant STE baseline, with VP-packed gradient
    compression and VP-packed Adam moments active.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuantConfig
from repro.configs import registry
from repro.core.packing import dequant_words
from repro.kernels import ops as kops
from repro.kernels import ref, substrate
from repro.models.layers import canonical_formats
from repro.optim.optimizer import OptConfig, init_opt_state
from repro.train import make_train_step
from repro.train.compression import CompressionConfig, init_compressor_state

REF_BACKEND = substrate.resolve_backend(None) == "ref"


def _formats():
    return canonical_formats(QuantConfig(mode="vp"))


def _packed(key, shape, fxp, vp, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return kops.vp_quant(x * scale, fxp, vp, packed=True)


# ---------------------------------------------------------------------------
# Backward kernel bodies vs ref oracles (Pallas interpreter)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 24, 8)])
def test_dx_kernel_interpret_vs_ref(shape):
    M, K, N = shape
    fxp, vp = _formats()
    g = jax.random.normal(jax.random.PRNGKey(0), (M, N), jnp.float32)
    w = _packed(1, (K, N), fxp, vp)
    got = kops.vp_matmul_dx(g, w, vp, blocks=(8, 8, 8), interpret=True)
    want = ref.vp_matmul_dx_ref(g, w, vp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 8, 24)])
def test_dw_kernel_interpret_vs_ref(shape):
    M, K, N = shape
    fxp, vp = _formats()
    a_w = _packed(0, (M, K), fxp, vp)
    g = jax.random.normal(jax.random.PRNGKey(1), (M, N), jnp.float32)
    got = kops.vp_matmul_dw(a_w, g, vp, blocks=(8, 8, 8), interpret=True)
    want = ref.vp_matmul_dw_ref(a_w, g, vp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Custom-VJP grads vs autodiff oracles (bit-identical, ref backend)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
def test_dequant_matmul_grad_bit_identical():
    fxp, vp = _formats()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.float32)
    w = _packed(1, (32, 16), fxp, vp)
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 16), jnp.float32)

    def f(x):
        return jnp.vdot(kops.vp_dequant_matmul(x, w, vp), g)

    def oracle(x):
        return jnp.vdot(x @ dequant_words(w, vp, jnp.float32), g)

    np.testing.assert_array_equal(np.asarray(jax.grad(f)(x)),
                                  np.asarray(jax.grad(oracle)(x)))


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
def test_quant_matmul_ste_grads_bit_identical():
    fxp, vp = _formats()
    a = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 16), jnp.float32)

    def f(a, b):
        return jnp.vdot(
            kops.vp_quant_matmul(a, b, fxp, vp, fxp, vp), g)

    # STE oracle: the forward quantizes both operands; the backward
    # treats each quantizer as identity, so da/db contract g with the
    # QUANTIZED other operand.
    a_w = kops.vp_quant(a, fxp, vp, packed=True)
    b_w = kops.vp_quant(b, fxp, vp, packed=True)

    def oracle(a, b):
        qa = a + jax.lax.stop_gradient(
            dequant_words(a_w, vp, jnp.float32) - a)
        qb = b + jax.lax.stop_gradient(
            dequant_words(b_w, vp, jnp.float32) - b)
        return jnp.vdot(qa @ qb, g)

    da, db = jax.grad(f, argnums=(0, 1))(a, b)
    oa, ob = jax.grad(oracle, argnums=(0, 1))(a, b)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(oa))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(ob))


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
def test_qat_matmul_grads_bit_identical():
    fxp, vp = _formats()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 16), jnp.float32)

    def f(x, w):
        return jnp.vdot(kops.vp_qat_matmul(x, w, fxp, vp), g)

    w_q = kops.vp_quant(w, fxp, vp, packed=True)

    def oracle(x, w):
        qw = w + jax.lax.stop_gradient(
            dequant_words(w_q, vp, jnp.float32) - w)
        return jnp.vdot(x @ qw, g)

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    ox, ow = jax.grad(oracle, argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(ox))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(ow))


def test_packed_matmul_grads_are_float0():
    """Both operands of the packed serving matmul are integer words —
    differentiating THROUGH it must yield float0 cotangents (a silent
    f32 cotangent here would mean autodiff dequantized the weights)."""
    fxp, vp = _formats()
    a_w = _packed(0, (8, 16), fxp, vp)
    b_w = _packed(1, (16, 8), fxp, vp)
    x = jnp.ones((4, 8), jnp.float32)

    def f(x):
        y = kops.vp_matmul(a_w, None, b_w, None, vp, vp)
        return jnp.sum(x @ y)

    out = jax.grad(f)(x)  # must trace without touching the int operands
    assert out.shape == x.shape


# ---------------------------------------------------------------------------
# QAT end-to-end: packed kernels vs fake-quant STE baseline
# ---------------------------------------------------------------------------

def _batches(cfg, n, batch=2, seq=16):
    keys = jax.random.split(jax.random.PRNGKey(7), n)
    out = []
    for k in keys:
        toks = jax.random.randint(k, (batch, seq + 1), 0, cfg.vocab)
        out.append({"tokens": toks[:, :-1], "labels": toks[:, 1:]})
    return out


def _finetune(cfg, qat_mode, steps=3):
    from repro.models import init_params

    qat = QuantConfig(mode="vp", qat_mode=qat_mode)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=steps,
                        moment_codec="vp")
    cmp_cfg = CompressionConfig(codec="vp")
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, compress_grads=cmp_cfg, qat=qat))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = init_opt_state(params, opt_cfg)
    cmp_state = init_compressor_state(params)
    loss = None
    for batch in _batches(cfg, steps):
        params, opt_state, metrics, cmp_state = step_fn(
            params, opt_state, batch, cmp_state)
        loss = float(metrics["loss"])
    return loss


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen2-0.5b"])
def test_qat_packed_matches_fake_baseline(arch):
    """Packed-kernel QAT on zoo archs lands within tolerance of the
    fake-quant (planes) STE baseline, with VP-packed gradient
    compression AND VP-packed Adam moments active the whole run — the
    two paths compute the same STE math, differing only in gemm
    summation order (~1e-6 relative per step)."""
    cfg = registry.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, n_layers=1)
    fake = _finetune(cfg, "fake")
    packed = _finetune(cfg, "packed")
    assert np.isfinite(fake) and np.isfinite(packed)
    assert abs(fake - packed) < 1e-3 * max(1.0, abs(fake)), (fake, packed)
