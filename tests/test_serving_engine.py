"""Continuous-batching engine suite (PR 7).

The contract under test: the paged engine is a SCHEDULER, not a second
model — every token it emits must be bit-identical (on the jnp ref
backend) to the static per-request driver it replaces, for every quant
mode x KV-cache layout of the PR-4 golden matrix, for ragged prompts,
staggered arrivals, slot eviction/readmission, chunked prefill, and
fused decode run-ahead.  Alongside parity: allocator properties (page
disjointness, eviction returns pages, ragged lengths never read freed
or unwritten storage — pinned by poisoning page 0 and the whole free
list) and the PRNG-hygiene regressions from the serve-path fixes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig, QuantConfig
from repro.kernels import substrate
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)
from repro.serving import PagedKVCache, ServingEngine, VirtualClock
from repro.serving.profile import panel_keys

REF_BACKEND = substrate.resolve_backend(None) == "ref"

# Ragged prompts, ragged budgets, one late arrival; max_slots=2 forces
# queueing, eviction, and slot reuse with 3 requests.
REQS = [([1, 2, 3, 4, 5], 4, 0.0),
        (list(range(7)), 5, 0.0),
        ([9, 8, 7], 3, 0.05)]
CAP, PAGE, SLOTS = 24, 8, 2

# Weight-quant mode x KV-cache storage: the PR-4 golden matrix extended
# with the KV axis (KV quantization is independent of weight mode).
MATRIX = [(mode, kv)
          for mode in ("none", "fxp", "vp", "vp_block")
          for kv in ("float", "packed", "planes")]


def _quant(mode: str, kv: str) -> QuantConfig:
    kw = dict(mode=mode)
    if mode == "vp_block":
        kw["block"] = 16
    if kv != "float":
        kw.update(quantize_kv_cache=True, kv_layout=kv)
    return QuantConfig(**kw)


def _tiny_cfg(quant: QuantConfig) -> ModelConfig:
    return ModelConfig(name="tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=128, dtype="float32", quant=quant)


def _params(cfg):
    p = init_params(jax.random.PRNGKey(0), cfg)
    return quantize_params(p, cfg) if cfg.quant.mode != "none" else p


def _oracle_tokens(params, cfg, prompt, gen, cap=CAP):
    """Static per-request driver: B=1 prefill + greedy decode loop at
    max_len == the engine capacity (same mask span => same bits)."""
    caches = init_cache(cfg, 1, cap)
    logits, caches = prefill(
        params, jnp.asarray([prompt], jnp.int32), caches, cfg)
    toks = [int(np.asarray(logits).reshape(1, -1).argmax(-1)[0])]
    for _ in range(gen - 1):
        logits, caches = decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches, cfg)
        toks.append(int(np.asarray(logits).reshape(1, -1).argmax(-1)[0]))
    return toks


def _engine_tokens(params, cfg, reqs=REQS, **kw):
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("capacity", CAP)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("clock", VirtualClock())
    eng = ServingEngine(params, cfg, **kw)
    for prompt, gen, at in reqs:
        eng.submit(prompt, gen, at)
    return eng, [r["tokens"] for r in eng.run()]


def _assert_token_parity(got, reqs, params, cfg, cap=CAP):
    for (prompt, gen, _), toks in zip(reqs, got):
        assert len(toks) == gen
        if REF_BACKEND:
            want = _oracle_tokens(params, cfg, prompt, gen, cap)
            assert toks == want, (toks, want)


# -- engine == static, over the full quant x KV matrix -------------------


@pytest.mark.parametrize("mode,kv", MATRIX)
def test_engine_static_parity_matrix(mode, kv):
    cfg = _tiny_cfg(_quant(mode, kv))
    params = _params(cfg)
    _, got = _engine_tokens(params, cfg)
    _assert_token_parity(got, REQS, params, cfg)


@pytest.mark.parametrize("arch", ["zamba2-7b", "rwkv6-3b",
                                  "mixtral-8x22b", "qwen3-moe-30b-a3b"])
def test_engine_family_parity(arch):
    """Hybrid (mamba+attn), pure SSM, sliding-window+MoE: the dense
    ring / recurrent-state rows must round-trip through the engine's
    slot gather/commit exactly."""
    cfg = registry.get_smoke_config(arch)
    params = _params(cfg)
    _, got = _engine_tokens(params, cfg)
    _assert_token_parity(got, REQS, params, cfg)


def test_engine_rejects_encdec():
    cfg = registry.get_smoke_config("whisper-tiny")
    params = _params(cfg)
    with pytest.raises(ValueError, match="encoder-decoder"):
        ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                      page_size=PAGE)


@pytest.mark.parametrize("mode,kv",
                         [("none", "float"), ("vp", "packed")])
def test_chunked_prefill_token_match(mode, kv):
    """Chunked prefill reassociates the prompt attention reduction, so
    the contract is token-level agreement, not bit-identity."""
    cfg = _tiny_cfg(_quant(mode, kv))
    params = _params(cfg)
    _, got = _engine_tokens(params, cfg, prefill_chunk=4)
    _assert_token_parity(got, REQS, params, cfg)


def test_chunked_prefill_rejected_for_windowed():
    cfg = registry.get_smoke_config("mixtral-8x22b")
    params = _params(cfg)
    with pytest.raises(ValueError, match="full-causal"):
        ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                      page_size=PAGE, prefill_chunk=4)


def test_decode_lookahead_parity():
    """Fused run-ahead is dispatch amortization, not different math:
    any lookahead must emit the same tokens, with over-generation
    trimmed to each request's budget."""
    cfg = _tiny_cfg(_quant("vp", "packed"))
    params = _params(cfg)
    outs = [_engine_tokens(params, cfg, decode_lookahead=la)[1]
            for la in (1, 3, 4)]
    assert outs[0] == outs[1] == outs[2]
    _assert_token_parity(outs[0], REQS, params, cfg)


# -- allocator / isolation properties ------------------------------------


def test_poisoned_free_pages_never_read():
    """Garbage in the dummy page 0 AND in every free page must be
    invisible: pages are handed out as-is (admission never clears or
    copies), so any read past a request's committed span — or from a
    page freed by eviction and reused by a later request — would change
    tokens here."""
    cfg = _tiny_cfg(_quant("vp", "packed"))
    params = _params(cfg)
    _, clean = _engine_tokens(params, cfg)

    eng = ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                        page_size=PAGE, clock=VirtualClock())
    pages = jnp.asarray([0] + list(eng.kv.free_pages), jnp.int32)
    for k, pool in eng.kv.pools.items():
        poison = (jnp.iinfo(pool.dtype).max
                  if jnp.issubdtype(pool.dtype, jnp.integer) else 1e30)
        eng.kv.pools[k] = pool.at[:, pages].set(poison)
    for prompt, gen, at in REQS:
        eng.submit(prompt, gen, at)
    got = [r["tokens"] for r in eng.run()]
    assert got == clean


def test_allocated_page_sets_disjoint():
    cfg = _tiny_cfg(_quant("vp", "packed"))
    kv = PagedKVCache(cfg, max_slots=3, capacity=CAP, page_size=PAGE)
    total = kv.n_pages - 1
    owned = {}
    for total_len in (5, 16, 24):
        slot = kv.alloc(total_len)
        row = np.asarray(kv.block_table[slot])
        used = row[:kv.pages_needed(total_len)]
        assert (used > 0).all(), "allocated a reserved/dummy page"
        owned[slot] = set(used.tolist())
    sets = list(owned.values())
    for i in range(len(sets)):
        for j in range(i + 1, len(sets)):
            assert not (sets[i] & sets[j]), "page aliasing across slots"
    assert len(kv.free_pages) == total - sum(len(s) for s in sets)


def test_eviction_returns_pages():
    cfg = _tiny_cfg(_quant("vp", "packed"))
    params = _params(cfg)
    eng, _ = _engine_tokens(params, cfg)
    # every request retired => allocator fully drained back
    assert len(eng.kv.free_pages) == eng.kv.n_pages - 1
    assert not eng.scheduler.running and not eng.scheduler.waiting


def test_oversized_request_rejected():
    cfg = _tiny_cfg(_quant("vp", "packed"))
    params = _params(cfg)
    eng = ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                        page_size=PAGE, clock=VirtualClock())
    eng.submit(list(range(CAP)), 8, 0.0)   # prompt + gen > capacity
    with pytest.raises(ValueError, match="capacity"):
        eng.run()


def test_check_finite_raises_on_overflow():
    cfg = _tiny_cfg(QuantConfig(mode="none"))
    params = dict(_params(cfg))
    # inf weights -> nan logits (signed-inf cancellation in the matmul)
    params["lm_head"] = jnp.full_like(params["lm_head"], jnp.inf)
    eng = ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                        page_size=PAGE, clock=VirtualClock(),
                        check_finite=True, on_nonfinite="raise")
    eng.submit([1, 2, 3], 4, 0.0)
    with pytest.raises(FloatingPointError):
        eng.run()


# -- serve-path PRNG hygiene (the bugs the engine flushed out) -----------


def test_panel_keys_distinct_folds():
    """Every benchmark panel gets its own fold and every tensor within
    a panel its own split — no draw may correlate with any other (the
    old serve path reused ONE PRNGKey(0) for params, prompts, and every
    tuning panel)."""
    base = jax.random.PRNGKey(0)
    seen = set()
    for idx in range(4):
        for k in panel_keys(base, idx):
            seen.add(tuple(np.asarray(jax.random.key_data(k)).tolist()))
    seen.add(tuple(np.asarray(jax.random.key_data(base)).tolist()))
    assert len(seen) == 9, "panel key folds collided"


def test_engine_temperature_keys_advance():
    """Sampled decoding must fold a fresh key per step (greedy decoding
    legitimately reuses one key — argmax never consumes it)."""
    cfg = _tiny_cfg(QuantConfig(mode="none"))
    params = _params(cfg)
    eng = ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                        page_size=PAGE, temperature=0.7,
                        clock=VirtualClock())
    k1, k2 = eng._next_key(), eng._next_key()
    assert not np.array_equal(np.asarray(jax.random.key_data(k1)),
                              np.asarray(jax.random.key_data(k2)))
    greedy = ServingEngine(params, cfg, max_slots=SLOTS, capacity=CAP,
                           page_size=PAGE, clock=VirtualClock())
    assert np.array_equal(
        np.asarray(jax.random.key_data(greedy._next_key())),
        np.asarray(jax.random.key_data(greedy._next_key())))
