"""Substrate-layer kernel tests: fused vp_quant_matmul parity vs the ref
oracles, package-wide import smoke (catches Pallas API drift at collection
time), backend dispatch semantics, and CSPADE-mask parity between the
kernel and ref paths."""
import importlib
import pathlib
import pkgutil

import numpy as np
import jax.numpy as jnp
import pytest

import repro.kernels
from repro.core import FXPFormat, VPFormat, block_vp_quantize, vp_quantize
from repro.kernels import ops, ref, substrate

Y_FXP, Y_VP = FXPFormat(9, 1), VPFormat(7, (1, -1))
W_FXP, W_VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))


def rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_t(df=2, size=shape).astype(np.float32)
    return jnp.asarray(np.clip(x, -8, 8) * scale)


# ---------------------------------------------------------------------------
# import smoke / substrate hygiene
# ---------------------------------------------------------------------------

def test_kernels_package_imports():
    """Every module under repro.kernels imports cleanly — a bare
    `pltpu.CompilerParams` on jax 0.4.x (the seed crash) dies right here,
    at collection time, instead of deep inside an equalizer test."""
    pkg = repro.kernels
    mods = [m.name
            for m in pkgutil.iter_modules(pkg.__path__, pkg.__name__ + ".")]
    assert len(mods) >= 7, mods
    for name in mods:
        importlib.import_module(name)


def test_no_direct_compiler_params_outside_substrate():
    """Version-drift guard: the renamed Pallas TPU symbols are referenced
    only in substrate.py; every kernel launches through the shim."""
    root = pathlib.Path(repro.kernels.__path__[0])
    for p in sorted(root.glob("*.py")):
        if p.name == "substrate.py":
            continue
        text = p.read_text()
        assert "CompilerParams" not in text, p
        assert "PrefetchScalarGridSpec" not in text, p
        assert "pallas.tpu" not in text and "pallas import tpu" not in text, p


def test_resolve_backend_semantics():
    """interpret=True -> interpreter; None/False -> native only ON a TPU
    backend, ref everywhere else (explicit False must never force TPU
    lowering on CPU — the seed dispatch bug)."""
    assert substrate.resolve_backend(True) == "interpret"
    native_or_ref = "native" if substrate.on_tpu() else "ref"
    assert substrate.resolve_backend(None) == native_or_ref
    assert substrate.resolve_backend(False) == native_or_ref


def test_interpret_false_off_tpu_runs_every_op():
    """All five public ops accept an explicit interpret=False on any
    backend (the seed raised AttributeError/lowering errors on CPU)."""
    a = rand((64, 96), 0.9, 0)
    b = rand((96, 64), 0.02, 1)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)

    m, i = ops.vp_quant(a, Y_FXP, Y_VP, interpret=False)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(ta.m))
    out = ops.vp_dequant(m, i, Y_VP, interpret=False)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.vp_dequant_ref(ta.m, ta.i, Y_VP)))

    want = ref.vp_matmul_ref(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP)
    got = ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP, interpret=False)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    got = ops.vp_quant_matmul(
        a, b, Y_FXP, Y_VP, W_FXP, W_VP, interpret=False)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    am, ai = block_vp_quantize(a, Y_FXP, Y_VP, block=32, axis=-1)
    bm, bi = block_vp_quantize(b, W_FXP, W_VP, block=32, axis=0)
    got = ops.block_vp_matmul(
        am, ai, bm, bi, Y_VP, W_VP, bk=32, blocks=(32, 32, 32),
        interpret=False)
    want = ref.block_vp_matmul_ref(am, ai, bm, bi, Y_VP, W_VP, bk=32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused vp_quant_matmul parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(256, 256, 256), (100, 300, 50),
                                 (257, 129, 65)])
def test_fused_vp_quant_matmul_matches_refs(mkn):
    """Fused kernel (interpret mode) == vp_quant_ref on each operand
    followed by vp_matmul_ref, including ragged (padded) shapes."""
    M, K, N = mkn
    a = rand((M, K), 0.9, 2)
    b = rand((K, N), 0.02, 3)
    out_k = ops.vp_quant_matmul(
        a, b, Y_FXP, Y_VP, W_FXP, W_VP, interpret=True)
    a_m, a_i = ref.vp_quant_ref(a, Y_FXP, Y_VP)
    b_m, b_i = ref.vp_quant_ref(b, W_FXP, W_VP)
    out_r = ref.vp_matmul_ref(a_m, a_i, b_m, b_i, Y_VP, W_VP)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_fused_matches_unfused_kernel_path():
    """Fused and unfused kernel paths agree (same cascades, no HBM trip)."""
    a = rand((128, 256), 0.9, 4)
    b = rand((256, 128), 0.02, 5)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)
    unfused = ops.vp_matmul(
        ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP, blocks=(128, 128, 128),
        interpret=True)
    fused = ops.vp_quant_matmul(
        a, b, Y_FXP, Y_VP, W_FXP, W_VP, blocks=(128, 128, 128),
        interpret=True)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(unfused), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# CSPADE-mask parity: kernel vs ref
# ---------------------------------------------------------------------------

def _masked_case(seed):
    M = K = N = 512
    bm = bk = bn = 256
    a = rand((M, K), 0.9, seed)
    b = rand((K, N), 0.02, seed + 1)
    # Damp the second k-block of BOTH operands so its tile pairs fall below
    # the activity thresholds -> the masks genuinely mute that k step.
    damp_a = jnp.where(jnp.arange(K)[None, :] >= bk, 0.01, 1.0)
    damp_b = jnp.where(jnp.arange(K)[:, None] >= bk, 0.01, 1.0)
    a = a * damp_a
    b = b * damp_b
    a_m, a_i = ref.vp_quant_ref(a, Y_FXP, Y_VP)
    b_m, b_i = ref.vp_quant_ref(b, W_FXP, W_VP)
    a_act, b_act = ref.cspade_tile_masks(
        ref.vp_dequant_ref(a_m, a_i, Y_VP),
        ref.vp_dequant_ref(b_m, b_i, W_VP),
        bm, bk, bn, thresh_a=0.5, thresh_b=0.02)
    return a, b, (a_m, a_i, b_m, b_i), (a_act, b_act), (bm, bk, bn)


def test_cspade_masks_vp_matmul_kernel_vs_ref():
    a, b, planes, (a_act, b_act), tiles = _masked_case(6)
    a_m, a_i, b_m, b_i = planes
    # masks must actually mute something, or the test is vacuous
    assert int(np.asarray(a_act).sum()) < a_act.size \
        or int(np.asarray(b_act).sum()) < b_act.size
    out_k = ops.vp_matmul(
        a_m, a_i, b_m, b_i, Y_VP, W_VP,
        a_act=a_act, b_act=b_act, blocks=tiles, interpret=True)
    out_r = ref.vp_matmul_ref(
        a_m, a_i, b_m, b_i, Y_VP, W_VP,
        a_act=a_act, b_act=b_act, tiles=tiles)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_cspade_masks_fused_kernel_vs_ref():
    """The fused kernel honours the same tile-activity masks."""
    a, b, _, (a_act, b_act), tiles = _masked_case(8)
    out_k = ops.vp_quant_matmul(
        a, b, Y_FXP, Y_VP, W_FXP, W_VP,
        a_act=a_act, b_act=b_act, blocks=tiles, interpret=True)
    out_r = ref.vp_quant_matmul_ref(
        a, b, Y_FXP, Y_VP, W_FXP, W_VP,
        a_act=a_act, b_act=b_act, tiles=tiles)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)
