"""Cross-arch golden-parity suite for kernel-backed VP serving (PR 4).

The model zoo's serving matmuls route packed VP weight words through the
Pallas `vp_dequant_matmul` substrate (`models.layers.qdot`).  This suite
pins that path against the legacy jnp-dequant two-plane path — the
"golden" baseline that shipped in PRs 1–3 — for EVERY architecture's
smoke config and EVERY quant mode, at both serving shapes:

  decode   M = B        (skinny single-token step)
  prefill  M = S * B    (full-prompt batch)

For mode "vp" the parity is BIT-IDENTICAL on the jnp ref backend (the CI
environment): power-of-two scales are exact in any float dtype and both
layouts run the same contraction.  On a kernel backend (TPU) the Pallas
kernel accumulates f32 per k-tile — a different summation order than one
flat dot — so the suite scopes the exact asserts to the ref backend and
pins a 1e-6-class tolerance otherwise.  Also here: the all-zero-weight
`_pow2_scale` regression, the packed-checkpoint round-trip, and the
skinny-decode autotune profile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.core.packing import pack_vp, unpack_vp
from repro.kernels import autotune, ops, substrate
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)
from repro.models.layers import (
    canonical_formats, quantize_weight, qdot, _pow2_scale,
)

B, S = 2, 16
MODES = ("none", "fxp", "vp", "vp_block")

# Exact bit-parity is the contract of the shared jnp ref path; kernel
# backends reassociate the k-reduction (per-tile f32 accumulators).
REF_BACKEND = substrate.resolve_backend(None) == "ref"


def assert_parity(got, want, err_msg=""):
    if REF_BACKEND:
        np.testing.assert_array_equal(got, want, err_msg=err_msg)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                   err_msg=err_msg)


def _quant_config(mode: str, d_in: int) -> QuantConfig:
    if mode != "vp_block":
        return QuantConfig(mode=mode)
    # Pick the largest block dividing the contraction dim so the i_blk
    # (int8-MXU) path is exercised where the arch's width allows it; the
    # per-element fallback covers the rest.
    for blk in (256, 128, 64, 32, 16):
        if d_in % blk == 0:
            return QuantConfig(mode="vp_block", block=blk)
    return QuantConfig(mode="vp_block")


def _weight_panel(cfg):
    """A representative (d_model, d_ff) MLP weight panel for the arch."""
    return cfg.d_model, cfg.d_ff


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_qdot_golden_parity(arch, mode):
    """Kernel-backed qdot == legacy jnp-dequant qdot, per arch x mode,
    at decode (M=B, rank 2) and prefill (M=S*B, rank 3) shapes."""
    cfg = registry.get_smoke_config(arch)
    d_in, d_out = _weight_panel(cfg)
    q = _quant_config(mode, d_in)
    key = jax.random.PRNGKey(17)
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (d_in, d_out), jnp.float32) * 0.05
    x_prefill = jax.random.normal(kx, (B, S, d_in), jnp.float32)
    x_decode = x_prefill[:, 0]

    wq_serve = quantize_weight(w, q)                      # packed default
    wq_gold = quantize_weight(w, q, layout="planes")      # jnp baseline
    for x in (x_decode, x_prefill):
        got = qdot(x, wq_serve, q)
        want = qdot(x, wq_gold, q)
        assert got.shape == want.shape and got.dtype == want.dtype
        assert bool(jnp.isfinite(got).all()), (arch, mode)
        if mode == "vp":
            # packed words feed the kernel op; planes feed jnp dequant —
            # bit-for-bit on the ref backend, 1e-6 under k-tiled kernels.
            assert_parity(np.asarray(got), np.asarray(want),
                          err_msg=f"{arch} {mode}")
        else:
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
        # pinned tolerance against the float matmul (quantization error
        # only — a wrong kernel path shows up as a gross violation)
        ref = jnp.dot(x, w)
        rel = float(jnp.linalg.norm(got - ref)
                    / (jnp.linalg.norm(ref) + 1e-9))
        assert rel < (1e-6 if mode == "none" else 0.2), (arch, mode, rel)


@pytest.mark.parametrize("mode", ("vp", "vp_block"))
def test_qdot_packed_words_reach_the_kernel_op(monkeypatch, mode):
    """The serving layout actually calls the kernel op (not jnp dequant)."""
    calls = []
    orig = ops.vp_dequant_matmul

    def spy(*a, **k):
        calls.append(a[1].dtype)
        return orig(*a, **k)

    from repro.models import layers as L
    monkeypatch.setattr(L.kops, "vp_dequant_matmul", spy)
    q = QuantConfig(mode=mode)           # d_in below any block: vp_block
    w = jax.random.normal(jax.random.PRNGKey(0), (24, 8), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 24), jnp.float32)
    wq = quantize_weight(w, q)
    assert "w_packed" in wq
    qdot(x, wq, q)
    assert len(calls) == 1 and calls[0] == wq["w_packed"].dtype


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_model_logits_parity_vp(arch):
    """Full-model golden parity: packed-kernel serving vs planes baseline,
    prefill AND one decode step, for every arch (bit-identical on the
    ref backend; 1e-6 under k-tiled kernel accumulation)."""
    cfg = registry.get_smoke_config(arch, quant=QuantConfig(mode="vp"))
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    qp_k = quantize_params(params, cfg)                   # packed kernel
    qp_g = quantize_params(params, cfg, layout="planes")  # jnp golden
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)

    extra = None
    cross_kv = None
    if cfg.family == "vlm":
        extra = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        from repro.models.model import _encoder_forward, _cross_kv
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        cross_kv_k = _cross_kv(qp_k, _encoder_forward(qp_k, frames, cfg),
                               cfg)
        cross_kv_g = _cross_kv(qp_g, _encoder_forward(qp_g, frames, cfg),
                               cfg)
        assert_parity(np.asarray(cross_kv_k[0]), np.asarray(cross_kv_g[0]))
    outs = {}
    for name, qp in (("kernel", qp_k), ("golden", qp_g)):
        if cfg.family == "encdec":
            extra = cross_kv_k if name == "kernel" else cross_kv_g
            cross_kv = extra
        lo, caches = prefill(qp, toks, init_cache(cfg, B, 16), cfg,
                             patches=extra)
        nxt = jnp.argmax(lo, -1)[:, None]
        if cfg.family == "encdec":
            lo2, _ = decode_step(qp, nxt, caches, cfg, cross_kv=cross_kv)
        else:
            lo2, _ = decode_step(qp, nxt, caches, cfg)
        outs[name] = (np.asarray(lo), np.asarray(lo2))
    assert np.isfinite(outs["kernel"][0]).all(), arch
    assert_parity(outs["kernel"][0], outs["golden"][0],
                  err_msg=f"{arch} prefill")
    assert_parity(outs["kernel"][1], outs["golden"][1],
                  err_msg=f"{arch} decode")


@pytest.mark.parametrize("mkn", [(4, 64, 64), (1, 13, 50), (33, 96, 24)])
def test_vp_dequant_matmul_kernel_interpret_parity(mkn):
    """The Pallas kernel body (interpreter) == the ref oracle == plain
    dequant-then-dot, including ragged shapes through the op's padding
    (packed-word 0 decodes to real 0, so padding is exact)."""
    M, K, N = mkn
    q = QuantConfig(mode="vp")
    _, vp = canonical_formats(q)
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (K, N), jnp.float32) * 0.05
    wq = quantize_weight(w, q)
    x = jax.random.normal(key, (M, K), jnp.float32)
    ref_out = ops.vp_dequant_matmul(x, wq["w_packed"], vp)
    kern_out = ops.vp_dequant_matmul(x, wq["w_packed"], vp, interpret=True)
    assert kern_out.shape == (M, N)
    np.testing.assert_allclose(
        np.asarray(kern_out), np.asarray(ref_out), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("fxp", "vp", "vp_block"))
def test_quantize_weight_all_zero(mode):
    """All-zero weights: the pow2 clamp floor must not leak a spurious
    ~2^-100 scale; the round trip is exactly zero."""
    q = QuantConfig(mode=mode)
    z = jnp.zeros((32, 16), jnp.float32)
    assert float(_pow2_scale(z)) == 1.0
    wq = quantize_weight(z, q)
    scale = float(np.asarray(wq["scale"]))
    # fxp folds 1/127 into the stored scale; vp keeps the raw pow2.
    assert scale == pytest.approx(1.0 / 127.0 if mode == "fxp" else 1.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32), jnp.float32)
    out = qdot(x, wq, q)
    assert (np.asarray(out) == 0.0).all()
    # and the scale survives a nonzero neighbour unchanged (no regression
    # of the normal path)
    w = jnp.ones((32, 16), jnp.float32) * 0.3
    assert float(_pow2_scale(w)) == 0.5


def test_pow2_scale_all_zero_activations():
    """vp_block quantizes ACTIVATIONS dynamically with the same helper:
    an all-zero activation block must not be divided by a denormal."""
    q = QuantConfig(mode="vp_block", block=16)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    wq = quantize_weight(w, q)
    out = qdot(jnp.zeros((4, 32), jnp.float32), wq, q)
    assert (np.asarray(out) == 0.0).all()


def test_ckpt_roundtrip_packed_serving(tmp_path):
    """quantize_params -> CheckpointManager save/restore -> bit-identical
    packed words, scales, and logits."""
    from repro.train.ckpt import CheckpointManager

    cfg = registry.get_smoke_config(
        "qwen3-0.6b", quant=QuantConfig(mode="vp"))
    key = jax.random.PRNGKey(5)
    qparams = quantize_params(init_params(key, cfg), cfg)
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=False)
    mgr.save(7, qparams, extra={"layout": "packed"})
    assert mgr.latest_step() == 7
    restored, manifest = mgr.restore(7, qparams)
    assert manifest["extra"]["layout"] == "packed"
    for a, b in zip(jax.tree_util.tree_leaves(qparams),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    lo_a, _ = prefill(qparams, toks, init_cache(cfg, B, 16), cfg)
    lo_b, _ = prefill(restored, toks, init_cache(cfg, B, 16), cfg)
    np.testing.assert_array_equal(np.asarray(lo_a), np.asarray(lo_b))


def test_packed_weight_words_roundtrip_format():
    """The serving dict's packed words ARE `core.packing` words: unpack
    recovers the planes layout exactly (storage contract, not just value
    parity)."""
    q = QuantConfig(mode="vp")
    _, vp = canonical_formats(q)
    w = jax.random.normal(jax.random.PRNGKey(9), (40, 24), jnp.float32)
    wq = quantize_weight(w, q)
    wl = quantize_weight(w, q, layout="planes")
    m, i = unpack_vp(wq["w_packed"], vp)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(wl["m"]))
    np.testing.assert_array_equal(
        np.asarray(pack_vp(m, i, vp)), np.asarray(wq["w_packed"]))
    np.testing.assert_array_equal(
        np.asarray(wq["scale"]), np.asarray(wl["scale"]))


def test_decode_autotune_profile(tmp_path, monkeypatch):
    """The M=1..B skinny-decode profile persists one tuned entry per
    batch size, and `resolve_blocks` then launches the measured tiling."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    autotune._caches.clear()
    q = QuantConfig(mode="vp")
    _, vp = canonical_formats(q)
    K, N = 48, 24
    w = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(2), (K, N), jnp.float32),
        q)["w_packed"]
    x = jax.random.normal(jax.random.PRNGKey(3), (8, K), jnp.float32)

    def bench(M, blocks):
        jax.block_until_ready(
            ops.vp_dequant_matmul(x[:M], w, vp, blocks=blocks))

    profile = autotune.tune_serving_decode(
        "vp_dequant_matmul", K, N, (vp,), "ref", bench,
        batch_sizes=(1, 4, 8), repeats=1)
    assert set(profile) == {1, 4, 8}
    for M, blocks in profile.items():
        key = autotune.make_key(
            "vp_dequant_matmul", (M, K, N), (vp,), "ref")
        assert autotune.get_cached(key) == blocks
        assert autotune.resolve_blocks(
            "vp_dequant_matmul", (M, K, N), (vp,), "ref") == blocks
        # skinny profile never tiles beyond the padded operand
        assert blocks[0] <= autotune._pow2_at_least(M)


def test_block_vp_matmul_consults_autotune_cache(tmp_path, monkeypatch):
    """`block_vp_matmul(blocks=None)` resolves through the autotune cache
    with the k-tile pinned to the index block size (regression: the qdot
    vp_block path used to hardcode (256, block, 256), bypassing it)."""
    from repro.core import block_vp_quantize

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    autotune._caches.clear()
    q = QuantConfig(mode="vp_block", block=16)
    fxp, vp = canonical_formats(q)
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 16), jnp.float32)
    wq = quantize_weight(w, q)
    assert "i_blk" in wq
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32), jnp.float32)
    sa = _pow2_scale(x)
    a_m, a_i = block_vp_quantize(x / sa, fxp, vp, block=16, axis=-1)
    args = (a_m, a_i, wq["m"], wq["i_blk"], vp, vp)
    base = np.asarray(ops.block_vp_matmul(*args, bk=16))          # ref
    # Plant a tuned entry under the bk-pinned kernel key; the interpret
    # launch must resolve it — and even a cached entry with a WRONG
    # k-tile must come back pinned to bk, numerics unchanged.
    key = autotune.make_key(
        "block_vp_matmul_bk16", (4, 32, 16), (vp, vp), "interpret")
    autotune.record(key, (2, 999, 8))
    got = np.asarray(ops.block_vp_matmul(*args, bk=16, interpret=True))
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)
    # and qdot's blocks=None vp_block path == the op composition it
    # wraps (dynamic activation pow2 scale, block matmul, rescale)
    want = base * np.asarray(sa * wq["scale"])
    np.testing.assert_allclose(
        np.asarray(qdot(x, wq, q)), want, rtol=1e-6, atol=1e-6)
