"""Per-architecture smoke tests: REDUCED same-family configs, one forward/
train step on CPU, asserting output shapes and no NaNs (the FULL configs
are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import (
    init_params, loss_fn, init_cache, prefill, decode_step,
)

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg, True)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    assert bool(jnp.isfinite(gn)), arch


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_smoke_prefill_decode(arch):
    cfg = registry.get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    caches = init_cache(cfg, B, 24)
    extra = None
    cross_kv = None
    if cfg.family == "vlm":
        extra = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "encdec":
        from repro.models.model import _encoder_forward, _cross_kv
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc = _encoder_forward(params, frames, cfg)
        cross_kv = _cross_kv(params, enc, cfg)
        extra = cross_kv
    logits, caches = prefill(params, toks, caches, cfg, patches=extra)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    nxt = jnp.argmax(logits, -1)[:, None]
    if cfg.family == "encdec":
        logits2, _ = decode_step(params, nxt, caches, cfg, cross_kv=cross_kv)
    else:
        logits2, _ = decode_step(params, nxt, caches, cfg)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-3b", "zamba2-7b"])
def test_smoke_vp_quantized_serving(arch):
    """VP-quantized weights (paper technique) through each family's decode."""
    from repro.models import quantize_params

    cfg = registry.get_smoke_config(arch, quant=QuantConfig(mode="vp"))
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    qparams = quantize_params(params, cfg)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    caches = init_cache(cfg, B, 16)
    logits, _ = prefill(qparams, toks, caches, cfg)
    assert bool(jnp.isfinite(logits).all())
    # quantized path stays close to the float path
    caches2 = init_cache(cfg, B, 16)
    cfg_f = registry.get_smoke_config(arch)
    logits_f, _ = prefill(params, toks, caches2, cfg_f)
    rel = float(jnp.linalg.norm(logits - logits_f)
                / (jnp.linalg.norm(logits_f) + 1e-9))
    assert rel < 0.25, (arch, rel)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    t = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }
    for arch, (L, d, H, KV, ff, V) in t.items():
        cfg = registry.get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V), arch
    # family-specific extras
    assert registry.get_config("zamba2-7b").ssm_state == 64
    assert registry.get_config("qwen3-moe-30b-a3b").n_experts == 128
    assert registry.get_config("qwen3-moe-30b-a3b").experts_per_token == 8
    assert registry.get_config("mixtral-8x22b").n_experts == 8
    assert registry.get_config("mixtral-8x22b").experts_per_token == 2
    assert registry.get_config("mixtral-8x22b").sliding_window == 4096
    assert registry.get_config("gemma3-27b").local_global_period == 6
    assert registry.get_config("qwen3-0.6b").qk_norm
    assert registry.get_config("qwen2-0.5b").qkv_bias


def test_cell_enumeration():
    cells = registry.cells()
    assert len(cells) == 33  # 10*4 - 7 documented long_500k skips
    skips = [c for c in registry.cells(include_skipped=True)
             if c[2].startswith("SKIP")]
    assert len(skips) == 7
