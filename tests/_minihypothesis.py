"""Dependency-free stand-in for the subset of `hypothesis` this repo uses.

When the real `hypothesis` package is installed (requirements.txt lists
it; CI installs it) this module is never imported — conftest.py only
loads it as a fallback on minimal environments without the wheel.  Unlike
the old skip-shim it ACTUALLY RUNS the property tests: each `@given` test
executes `max_examples` deterministic pseudo-random examples drawn from
the declared strategies, so the property suite provides real coverage
everywhere instead of silently skipping.

Deliberately small: no shrinking, no example database, no health checks —
failures report the generated arguments and reproduce exactly on re-run
(the RNG is seeded from the test name).
"""
from __future__ import annotations

import random
import types
import zlib


class Unsatisfied(Exception):
    """Raised by `assume(False)`: discard this example, draw another."""


class Strategy:
    """Base strategy: something that can draw an example from an RNG."""

    def example(self, rng: random.Random):
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)

    def filter(self, pred):
        return _Filtered(self, pred)


class _Mapped(Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng))


class _Filtered(Strategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example(self, rng):
        for _ in range(100):
            v = self.base.example(rng)
            if self.pred(v):
                return v
        raise Unsatisfied("filter predicate rejected 100 draws")


class _Integers(Strategy):
    def __init__(self, min_value=-(2**31), max_value=2**31 - 1):
        self.lo, self.hi = min_value, max_value

    def example(self, rng):
        # Bias towards the boundaries now and then — cheap edge coverage.
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(self, min_value=-1e9, max_value=1e9, **_kw):
        self.lo, self.hi = min_value, max_value

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(Strategy):
    def example(self, rng):
        return rng.random() < 0.5


class _SampledFrom(Strategy):
    def __init__(self, elems):
        self.elems = list(elems)

    def example(self, rng):
        return rng.choice(self.elems)


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _OneOf(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng):
        return rng.choice(self.strategies).example(rng)


class _Lists(Strategy):
    def __init__(self, elem, min_size=0, max_size=10, **_kw):
        self.elem = elem
        self.min_size, self.max_size = min_size, max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _Tuples(Strategy):
    def __init__(self, *elems):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _Composite(Strategy):
    """Supports @st.composite functions: fn(draw, *args, **kwargs)."""

    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rng):
        def draw(strategy):
            return strategy.example(rng)

        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    def build(*args, **kwargs):
        return _Composite(fn, args, kwargs)

    return build


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


class _Data(Strategy):
    def example(self, rng):
        return _DataObject(rng)


def assume(condition):
    if not condition:
        raise Unsatisfied()
    return True


def settings(max_examples=None, deadline=None, **_kw):
    """Decorator recording run parameters for `given`.

    Works in either decorator order: applied below `@given` it annotates
    the test function before `given` wraps it; applied above, it updates
    the runner's own `__mh_settings__`, which the runner re-reads at call
    time.
    """

    def deco(fn):
        fn.__mh_settings__ = dict(
            getattr(fn, "__mh_settings__", {}) or {})
        if max_examples is not None:
            fn.__mh_settings__["max_examples"] = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test body over deterministic pseudo-random examples.

    The wrapper takes zero arguments so pytest never tries to resolve the
    strategy parameters as fixtures.  The RNG seed derives from the test
    name: every run draws the same example sequence, and a failure's
    arguments are visible in the assertion traceback.

    Examples discarded by `assume` / `.filter` exhaustion (whether raised
    while DRAWING or while running the body) are redrawn; if every
    attempt is discarded the runner fails loudly rather than passing a
    test that never executed.
    """

    def deco(fn):
        def runner():
            # Read from the runner itself so a `@settings` applied ABOVE
            # `@given` (which decorates the runner) still takes effect.
            sett = getattr(runner, "__mh_settings__", {}) or {}
            n = sett.get("max_examples", 20)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            ran = 0
            attempts = 0
            while ran < n and attempts < n * 20:
                attempts += 1
                try:
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {k: s.example(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)
                except Unsatisfied:
                    continue
                ran += 1
            assert ran > 0, (
                f"{fn.__name__}: every generated example was discarded "
                f"({attempts} attempts) — the property was never checked; "
                "loosen the strategies or the assume/filter conditions")

        runner.__name__ = getattr(fn, "__name__", "property_test")
        runner.__doc__ = getattr(fn, "__doc__", None)
        runner.__mh_settings__ = dict(getattr(fn, "__mh_settings__", {}))
        return runner

    return deco


def install(sys_modules):
    """Register stand-in `hypothesis` / `hypothesis.strategies` modules."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.sampled_from = _SampledFrom
    st.just = _Just
    st.one_of = _OneOf
    st.lists = _Lists
    st.tuples = _Tuples
    st.composite = composite
    st.data = _Data

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    hyp.__minihypothesis__ = True

    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
    return hyp
