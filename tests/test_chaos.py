"""Chaos suite (PR 10): fault injection against the serving engine.

The resilience contract under test, for every fault class in
`repro.serving.faults` (on the jnp ref backend, deterministic virtual
clock):

  * the engine NEVER crashes — every submitted request reaches a
    terminal outcome in {ok, retried, quarantined, degraded, timeout,
    shed};
  * UNAFFECTED requests emit tokens bit-identical to the fault-free
    run (containment: a poisoned slot's garbage lives only in its own
    reserved pages, and host-side poison never touches the device
    computation);
  * the page free-list is conserved (no leak, no double-free) and the
    dummy page 0 is never handed out or corrupted by injection;
  * deadlines/SLOs keep being enforced under injected slowdowns, with
    full page reclamation on every timeout/cancel path.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.kernels import paged, substrate
from repro.serving import (
    FaultPlan, KVBitFlip, LogitPoison, PagePressure, ServingEngine,
    SlowStep, TransientFault, VirtualClock,
)

REF_BACKEND = substrate.resolve_backend(None) == "ref"

REQS = [([1, 2, 3, 4, 5], 4, 0.0),
        (list(range(7)), 5, 0.0),
        ([9, 8, 7], 3, 0.05)]
CAP, PAGE, SLOTS = 24, 8, 2

KV_LAYOUTS = ["float", "packed", "planes"]


def _quant(kv: str) -> QuantConfig:
    if kv == "float":
        return QuantConfig(mode="vp")
    return QuantConfig(mode="vp", quantize_kv_cache=True, kv_layout=kv)


def _cfg(kv: str = "packed") -> ModelConfig:
    return ModelConfig(name="tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=128, dtype="float32", quant=_quant(kv))


def _params(cfg):
    from repro.models import init_params, quantize_params
    return quantize_params(init_params(jax.random.PRNGKey(0), cfg), cfg)


def _engine(params, cfg, **kw):
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("capacity", CAP)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("clock", VirtualClock())
    return ServingEngine(params, cfg, **kw)


def _submit_all(eng, reqs=REQS, **kw):
    for prompt, gen, at in reqs:
        eng.submit(prompt, gen, at, **kw)


def _baseline_tokens(params, cfg, reqs=REQS):
    """Fault-free engine run: the bit-exactness reference."""
    eng = _engine(params, cfg)
    _submit_all(eng, reqs)
    return {r["rid"]: r["tokens"] for r in eng.run()}


def _check_invariants(eng):
    """Free-list conservation + page 0 still reserved, after any run."""
    eng.kv.check_conservation()
    assert not eng.kv.slot_pages          # everything reclaimed
    assert len(eng.kv.free_pages) == eng.kv.n_pages - 1
    assert 0 not in eng.kv.free_pages


OUTCOMES = {"ok", "retried", "quarantined", "degraded", "timeout", "shed"}


# ---------------------------------------------------------------------------
# flip_bit primitive: exactly one bit of one word, never page 0


@pytest.mark.parametrize("kv", ["packed", "float"])
def test_flip_bit_touches_exactly_one_word(kv):
    cfg = _cfg(kv)
    eng = _engine(_params(cfg), cfg)
    key = sorted(eng.kv.pools)[0]
    pool = eng.kv.pools[key]
    before = np.asarray(pool).copy()
    after = np.asarray(paged.flip_bit(pool, page=3, offset=2, bit=4))
    page0_before = before[:, 0]
    page0_after = after[:, 0]
    np.testing.assert_array_equal(page0_before, page0_after)
    diff = (before != after) | (np.isnan(before) != np.isnan(after))
    assert diff.sum() == 1
    idx = tuple(int(i[0]) for i in np.nonzero(diff))
    assert idx[1] == 3 and idx[2] == 2
    # involution: flipping again restores the pool bit-exactly
    twice = np.asarray(paged.flip_bit(jnp.asarray(after), 3, 2, 4))
    assert before.tobytes() == twice.tobytes()


# ---------------------------------------------------------------------------
# logit poisoning -> per-slot quarantine


@pytest.mark.parametrize("value", [math.nan, math.inf])
def test_logit_poison_quarantines_only_victim(value):
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, check_finite=True,
                  faults=FaultPlan([LogitPoison(rid=1, phase="decode",
                                                value=value)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[1]["outcome"] == "quarantined"
    assert recs[1]["tokens"] == []          # poisoned transcript dropped
    for rid in (0, 2):                      # co-resident slots unharmed
        assert recs[rid]["outcome"] == "ok"
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    assert eng.stats["quarantined"] == 1
    assert eng.stats["fault_logit_poisons"] >= 1
    _check_invariants(eng)


def test_logit_poison_prefill_phase():
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, check_finite=True,
                  faults=FaultPlan([LogitPoison(rid=0, phase="prefill")]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == "quarantined"
    assert recs[1]["outcome"] == recs[2]["outcome"] == "ok"
    _check_invariants(eng)


def test_on_nonfinite_raise_is_all_or_nothing():
    """Legacy mode: the same poison hard-stops the whole engine."""
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, check_finite=True, on_nonfinite="raise",
                  faults=FaultPlan([LogitPoison(rid=1)]))
    _submit_all(eng)
    with pytest.raises(FloatingPointError):
        eng.run()


# ---------------------------------------------------------------------------
# quarantine escalation: retry, then degrade to the golden baseline


def test_quarantine_retry_then_ok():
    """A once-poisoned request (times=1) is requeued, re-runs clean,
    and finishes with bit-identical tokens."""
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, check_finite=True, degrade=True,
                  degrade_after=2,
                  faults=FaultPlan([LogitPoison(rid=0, times=1)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    for rid in (0, 1, 2):
        assert recs[rid]["outcome"] == "ok"
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    assert eng.stats["quarantine_requeues"] == 1
    assert eng.stats["degraded"] == 0
    _check_invariants(eng)


def test_repeated_quarantine_degrades():
    """A persistently-poisoned request lands on the static oracle path,
    flagged degraded — and its oracle tokens match the fault-free run."""
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, check_finite=True, degrade=True,
                  degrade_after=2,
                  faults=FaultPlan([LogitPoison(rid=0)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == "degraded"
    assert recs[1]["outcome"] == recs[2]["outcome"] == "ok"
    if REF_BACKEND:
        for rid in (0, 1, 2):   # the oracle path IS the parity baseline
            assert recs[rid]["tokens"] == base[rid]
    assert eng.stats["degraded"] == 1
    assert eng.stats["quarantine_events"] == 2
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# KV bit flips: silent corruption must stay inside the victim's pages


@pytest.mark.parametrize("kv", KV_LAYOUTS)
def test_kv_bitflip_isolated_to_victim(kv):
    cfg = _cfg(kv)
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    fp0 = None
    eng = _engine(params, cfg, check_finite=True,
                  faults=FaultPlan([KVBitFlip(rid=0, page_index=0,
                                              offset=1, bit=3)]))
    fp0 = eng.kv.page0_fingerprint()
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert eng.stats["fault_kv_bit_flips"] == 1
    # VP dequant of ANY word is finite -> silent corruption: rid 0 may
    # emit different tokens (or trip the finite check on a float cache),
    # but it must reach a terminal outcome and len <= its budget...
    assert recs[0]["outcome"] in OUTCOMES
    assert len(recs[0]["tokens"]) <= REQS[0][1]
    # ...while the OTHER requests never see the corruption:
    for rid in (1, 2):
        assert recs[rid]["outcome"] == "ok"
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    # the flip landed in rid 0's own pages, never the dummy page
    assert eng.kv.page0_fingerprint() == fp0
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# page-pressure spikes: admission backs up, engine waits, then drains


def test_page_pressure_delays_then_completes():
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, faults=FaultPlan(
        [PagePressure(at=0.0, release=0.25, pages=10_000)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert eng.stats["fault_page_spikes"] == 1
    for rid in (0, 1, 2):
        assert recs[rid]["outcome"] == "ok"
        # nothing could be admitted before the spike released
        assert recs[rid]["admitted_time"] >= 0.25
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    _check_invariants(eng)


def test_page_pressure_with_bounded_queue_sheds():
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, max_queue=1, faults=FaultPlan(
        [PagePressure(at=0.0, release=0.25, pages=10_000)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    outcomes = sorted(r["outcome"] for r in recs.values())
    assert outcomes.count("shed") == 1      # queue bound 1 + 1 admitted...
    assert eng.stats["shed"] == 1
    assert all(o in OUTCOMES for o in outcomes)
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# transient dispatch failures: retry with backoff


def test_transient_decode_step_retries():
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, faults=FaultPlan(
        [TransientFault(kind="decode", times=2)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert eng.stats["transient_faults"] == 2
    for rid in (0, 1, 2):
        assert recs[rid]["outcome"] in ("ok", "retried")
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    assert any(r["outcome"] == "retried" for r in recs.values())
    _check_invariants(eng)


def test_transient_prefill_exhaustion_quarantines():
    cfg = _cfg("packed")
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    eng = _engine(params, cfg, max_retries=1, faults=FaultPlan(
        [TransientFault(kind="prefill", rid=0, times=100)]))
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == "quarantined"
    for rid in (1, 2):
        assert recs[rid]["outcome"] == "ok"
        if REF_BACKEND:
            assert recs[rid]["tokens"] == base[rid]
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# slow steps + deadlines/SLOs


def test_slow_step_forces_timeout_under_slo():
    from repro.serving import SLO_CLASSES
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, faults=FaultPlan(
        [SlowStep(at=0.0, extra_s=30.0)]))
    _submit_all(eng, slo=SLO_CLASSES["interactive"])
    recs = {r["rid"]: r for r in eng.run()}
    assert eng.stats["fault_slow_steps"] == 1
    # a 30 s stall blows every interactive deadline before admission
    assert all(r["outcome"] == "timeout" for r in recs.values())
    assert all(not r["slo_met"] for r in recs.values())
    _check_invariants(eng)


def test_deadline_timeout_running_and_waiting():
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, max_slots=1)
    eng.submit(REQS[0][0], 64 // PAGE and 4, 0.0)           # no deadline
    eng.submit(REQS[1][0], 5, 0.0, deadline=1e-9)           # expires waiting
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == "ok"
    assert recs[1]["outcome"] == "timeout"
    assert recs[1]["tokens"] == []
    assert recs[1]["deadline_met"] is False
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# EDF + preemption


def test_edf_admits_tightest_deadline_first():
    cfg = _cfg("packed")
    params = _params(cfg)
    # Compute time (incl. first-dispatch jit compile) is charged to the
    # virtual clock, so both deadlines are generous; only their ORDER
    # matters to EDF.
    eng = _engine(params, cfg, max_slots=1, policy="edf")
    eng.submit(REQS[0][0], 4, 0.0, deadline=1e9)
    eng.submit(REQS[1][0], 5, 0.0, deadline=1e6)
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == recs[1]["outcome"] == "ok"
    # rid 1's deadline is tighter: it must start (and finish) first
    assert recs[1]["first_token_time"] < recs[0]["first_token_time"]


def test_preemption_resume_is_bit_exact():
    """A tight-deadline arrival evicts the running batch request; the
    victim re-prefills prompt+generated on re-admission and completes
    with tokens bit-identical to an uncontended run."""
    cfg = _cfg("packed")
    params = _params(cfg)
    solo = {}
    for prompt, gen, _ in REQS[:2]:
        eng = _engine(params, cfg, max_slots=1)
        eng.submit(prompt, gen, 0.0)
        solo[prompt[0]] = eng.run()[0]["tokens"]
    eng = _engine(params, cfg, max_slots=1, policy="edf", preempt=True)
    eng.submit(REQS[0][0], REQS[0][1], 0.0)                  # no deadline
    eng.submit(REQS[1][0], REQS[1][1], 1e-4, deadline=100.0)  # preempts
    recs = {r["rid"]: r for r in eng.run()}
    assert recs[0]["outcome"] == recs[1]["outcome"] == "ok"
    assert recs[0]["preemptions"] == 1
    assert eng.stats["preemptions"] == 1
    if REF_BACKEND:
        assert recs[0]["tokens"] == solo[REQS[0][0][0]]
        assert recs[1]["tokens"] == solo[REQS[1][0][0]]
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# shed backpressure without faults


def test_bounded_queue_sheds_newest():
    cfg = _cfg("packed")
    params = _params(cfg)
    eng = _engine(params, cfg, max_slots=1, max_queue=1)
    for i in range(4):
        eng.submit([1 + i, 2, 3], 3, 0.0)
    recs = {r["rid"]: r for r in eng.run()}
    outcomes = [recs[i]["outcome"] for i in range(4)]
    assert outcomes.count("shed") == 2      # 1 running + 1 queued survive
    assert outcomes[0] == "ok"              # head of line always serves
    assert eng.stats["shed"] == 2
    assert eng.stats["submitted"] == 4
    _check_invariants(eng)


# ---------------------------------------------------------------------------
# the combined chaos matrix


@pytest.mark.parametrize("kv", KV_LAYOUTS)
def test_chaos_matrix_combined(kv):
    """Every fault class at once, per KV layout: no crash, terminal
    outcomes for all, the untargeted request bit-identical, allocator
    conserved, page 0 untouched, deadlines still enforced."""
    cfg = _cfg(kv)
    params = _params(cfg)
    base = _baseline_tokens(params, cfg)
    plan = FaultPlan([
        LogitPoison(rid=1, phase="decode"),
        KVBitFlip(rid=0, page_index=0, offset=2, bit=1),
        PagePressure(at=0.0, release=0.1, pages=10_000),
        TransientFault(kind="decode", times=1),
        SlowStep(at=0.15, extra_s=0.05),
    ])
    eng = _engine(params, cfg, check_finite=True, degrade=True,
                  degrade_after=2, faults=plan)
    fp0 = eng.kv.page0_fingerprint()
    _submit_all(eng)
    recs = {r["rid"]: r for r in eng.run()}
    assert set(recs) == {0, 1, 2}
    assert all(r["outcome"] in OUTCOMES for r in recs.values())
    # rid 1 (poisoned every pass) must end degraded on the oracle path
    assert recs[1]["outcome"] == "degraded"
    # rid 2 is untargeted: bit-identical to the fault-free run
    if REF_BACKEND:
        assert recs[2]["tokens"] == base[2]
        assert recs[1]["tokens"] == base[1]   # oracle == parity baseline
    assert eng.kv.page0_fingerprint() == fp0
    assert eng.stats["fault_page_spikes"] == 1
    assert eng.stats["fault_slow_steps"] == 1
    assert eng.stats["transient_faults"] == 1
    assert eng.stats["fault_kv_bit_flips"] == 1
    _check_invariants(eng)


def test_fault_plan_reset_rearms():
    plan = FaultPlan([LogitPoison(rid=0, times=1),
                      TransientFault(kind="decode", times=1)])
    assert plan.take_transient("decode", None) is True
    assert plan.take_transient("decode", None) is False
    logits = np.zeros((4,), np.float32)
    assert plan.poison("decode", 0, 0, logits) is not None
    assert plan.poison("decode", 0, 1, logits) is None
    plan.reset()
    assert plan.take_transient("decode", None) is True
    assert plan.poison("decode", 0, 0, logits) is not None


def test_conservation_detects_double_free():
    cfg = _cfg("packed")
    eng = _engine(_params(cfg), cfg)
    eng.kv.check_conservation()
    eng.kv.free_pages.append(eng.kv.free_pages[-1])   # forge a dup
    with pytest.raises(AssertionError):
        eng.kv.check_conservation()
