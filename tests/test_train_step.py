"""Train-loop contracts: grad accumulation, compression, checkpointing.

Covers the PR-9 bugfix set end to end:

  * microbatched `make_train_step` — grad-accum parity with the
    single-shot path, aux metrics carried through the scan, and a clear
    up-front ValueError on a non-divisible batch (previously an opaque
    reshape error from inside `jax.lax.scan`);
  * error-feedback compression — the compressor is a contraction (the
    carried residual stays bounded over repeated steps instead of
    drifting), and mismatched grad/state trees raise with the
    offending leaf paths (previously a silent zip-truncate);
  * checkpointing — NamedTuple pytrees (OptState, packed-moment leaves,
    compressor residual) round-trip through save/restore (previously
    `type(template)(seq)` crashed on any NamedTuple), stale
    `.tmp_step_*` dirs from crashed async saves are swept on manager
    construction, and `run_with_restarts` resumes from the latest
    checkpoint to the same final params as an uninterrupted run.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import init_params
from repro.optim.optimizer import (
    OptConfig, OptState, apply_updates, init_opt_state, is_packed_moment,
)
from repro.train import (
    CheckpointManager, make_train_step, run_with_restarts,
)
from repro.train.compression import (
    CompressionConfig, compress_decompress, init_compressor_state,
)


def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                dtype="float32", quant=QuantConfig(mode="none"))
    base.update(kw)
    return ModelConfig(**base)


def _batch(cfg, key=0, batch=4, seq=8):
    toks = jax.random.randint(jax.random.PRNGKey(key),
                              (batch, seq + 1), 0, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def _opt_cfg(**kw):
    kw.setdefault("lr", 1e-3)
    kw.setdefault("warmup_steps", 1)
    kw.setdefault("total_steps", 10)
    return OptConfig(**kw)


# ---------------------------------------------------------------------------
# Microbatching
# ---------------------------------------------------------------------------

def test_grad_accum_parity():
    """microbatches=k must equal microbatches=1 up to f32 accumulation
    order: same loss, same updated params within tight tolerance."""
    cfg = _tiny_cfg()
    opt_cfg = _opt_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    outs = {}
    for k in (1, 2, 4):
        step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=k))
        p, s, m = step(params, init_opt_state(params), batch)
        outs[k] = (p, m)
    loss1 = float(outs[1][1]["loss"])
    for k in (2, 4):
        assert abs(float(outs[k][1]["loss"]) - loss1) < 1e-5
        for a, b in zip(jax.tree_util.tree_leaves(outs[1][0]),
                        jax.tree_util.tree_leaves(outs[k][0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)


def test_microbatch_metrics_match_single_path():
    """The scan path must surface the same (averaged) aux metric keys
    the single-shot path does — they were silently dropped before."""
    cfg = _tiny_cfg()
    opt_cfg = _opt_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    _, _, m1 = jax.jit(make_train_step(cfg, opt_cfg))(
        params, init_opt_state(params), batch)
    _, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))(
        params, init_opt_state(params), batch)
    assert set(m1.keys()) == set(m2.keys())
    for k in m1:
        assert np.asarray(m2[k]).shape == np.asarray(m1[k]).shape, k


def test_microbatch_indivisible_raises_clearly():
    cfg = _tiny_cfg()
    step = make_train_step(cfg, _opt_cfg(), microbatches=3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="divisible by microbatches=3"):
        step(params, init_opt_state(params), _batch(cfg, batch=4))


# ---------------------------------------------------------------------------
# Error-feedback compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["int8", "vp"])
def test_error_feedback_contraction(codec):
    """Residual boundedness: iterating the compressor on a CONSTANT
    gradient keeps |err| within one quantization step of that leaf's
    scale forever (no drift), and the running decoded mean converges to
    the true gradient — the property that keeps SGD convergence."""
    cfg = CompressionConfig(codec=codec)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 16),
                                jnp.float32)}
    state = init_compressor_state(g)
    amax = float(jnp.max(jnp.abs(g["w"])))
    # one-step quantization error bound per element
    bound = amax / 127.0 if codec == "int8" else amax
    total = np.zeros((16, 16), np.float32)
    for k in range(1, 21):
        deq, state = compress_decompress(g, state, cfg)
        total += np.asarray(deq["w"])
        err = np.abs(np.asarray(state["w"]))
        assert err.max() <= bound + 1e-6, (k, err.max(), bound)
    # sum of decoded == sum of true minus the final residual, so the
    # mean converges at rate 1/k
    mean_err = np.abs(total / 20 - np.asarray(g["w"])).max()
    assert mean_err <= (bound + 1e-6) / 20, mean_err


def test_compress_treedef_mismatch_raises_with_paths():
    g = {"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}
    state = {"a": jnp.zeros((4,))}
    with pytest.raises(ValueError, match=r"\['b'\]"):
        compress_decompress(g, state)


# ---------------------------------------------------------------------------
# Packed Adam moments
# ---------------------------------------------------------------------------

def test_packed_moments_track_f32_adam():
    """A few steps of packed-moment AdamW stay close to the f32-moment
    baseline on identical gradients (the EMA contracts the injected
    quantization error; nu rides storage as sqrt(nu))."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32),
                                     jnp.float32)}
    base_cfg = _opt_cfg()
    pk_cfg = _opt_cfg(moment_codec="vp")
    s0 = init_opt_state(params, base_cfg)
    s1 = init_opt_state(params, pk_cfg)
    assert all(is_packed_moment(m) for m in
               jax.tree_util.tree_leaves(s1.mu, is_leaf=is_packed_moment))
    p0, p1 = params, params
    for k in range(5):
        g = {"w": jax.random.normal(jax.random.PRNGKey(10 + k),
                                    (32, 32), jnp.float32)}
        p0, s0, _ = apply_updates(p0, g, s0, base_cfg)
        p1, s1, _ = apply_updates(p1, g, s1, pk_cfg)
    diff = np.abs(np.asarray(p0["w"]) - np.asarray(p1["w"])).max()
    step_size = float(base_cfg.lr)
    assert diff < 2 * step_size * 5, diff  # within O(lr) per step


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _full_state(cfg, opt_cfg):
    params = init_params(jax.random.PRNGKey(0), cfg)
    return {"params": params,
            "opt": init_opt_state(params, opt_cfg),
            "cmp": init_compressor_state(params)}


def test_ckpt_namedtuple_roundtrip(tmp_path):
    """Full train state — params + OptState NamedTuple (packed moments)
    + compressor residual — must survive save/restore structurally
    intact and bit-identical."""
    cfg = _tiny_cfg()
    state = _full_state(cfg, _opt_cfg(moment_codec="vp"))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)
    tree, manifest = mgr.restore(1, state)
    assert isinstance(tree["opt"], OptState)
    assert all(is_packed_moment(m) for m in jax.tree_util.tree_leaves(
        tree["opt"].mu, is_leaf=is_packed_moment))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 1


def test_ckpt_stale_tmp_swept_and_restore_survives(tmp_path):
    """A save that crashes mid-write leaves `.tmp_step_*` + `.LATEST.tmp`
    orphans; a new manager must sweep them and still restore the last
    COMPLETED checkpoint."""
    cfg = _tiny_cfg()
    state = _full_state(cfg, _opt_cfg())
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)
    # simulate a crash mid-save of step 2: tmp dir + pointer temp left
    tmp = tmp_path / ".tmp_step_2_99999"
    tmp.mkdir()
    (tmp / "arrays.npz").write_bytes(b"partial garbage")
    (tmp_path / ".LATEST.tmp").write_text("2")

    mgr2 = CheckpointManager(str(tmp_path), async_save=False)
    names = set(os.listdir(tmp_path))
    assert not any(n.startswith(".tmp_step_") for n in names), names
    assert ".LATEST.tmp" not in names
    assert mgr2.latest_step() == 1
    tree, _ = mgr2.restore(1, state)
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(tree)[0]),
        np.asarray(jax.tree_util.tree_leaves(state)[0]))


def test_run_with_restarts_resumes_from_latest(tmp_path):
    """Integration: a training loop that dies mid-run restarts from the
    latest checkpoint and finishes with EXACTLY the params of an
    uninterrupted run (deterministic data by step index)."""
    cfg = _tiny_cfg()
    opt_cfg = _opt_cfg(moment_codec="vp")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    total_steps = 6

    def train(ckpt_dir, crash_at=None):
        mgr = CheckpointManager(ckpt_dir, async_save=False)
        crashed = {"done": False}

        def loop(attempt):
            params = init_params(jax.random.PRNGKey(0), cfg)
            opt_state = init_opt_state(params, opt_cfg)
            start = 0
            if mgr.latest_step() is not None:
                s = mgr.latest_step()
                restored, manifest = mgr.restore(
                    s, {"params": params, "opt": opt_state})
                params, opt_state = restored["params"], restored["opt"]
                start = manifest["extra"]["idx"]
            for i in range(start, total_steps):
                if (crash_at is not None and i == crash_at
                        and not crashed["done"]):
                    crashed["done"] = True
                    raise RuntimeError("simulated node failure")
                params, opt_state, _ = step_fn(
                    params, opt_state, _batch(cfg, key=i))
                mgr.save(i + 1, {"params": params, "opt": opt_state},
                         extra={"idx": i + 1})
            return params

        return run_with_restarts(loop, max_restarts=2)

    p_clean = train(str(tmp_path / "clean"))
    p_crashed = train(str(tmp_path / "crashed"), crash_at=3)
    for a, b in zip(jax.tree_util.tree_leaves(p_clean),
                    jax.tree_util.tree_leaves(p_crashed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
