"""Substrate integration tests: training loop, checkpoint/restart equality,
gradient compression, data-pipeline determinism, optimizer behaviour.
(Fault-tolerance coverage — heartbeats/stragglers/elastic re-mesh/restarts
and checkpoint integrity — lives in tests/test_ft.py.)"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.optim import OptConfig, init_opt_state, apply_updates, schedule
from repro.train import (
    make_train_step, CheckpointManager, compress_decompress,
    init_compressor_state,
)
from repro.data import DataConfig, DataState, SyntheticLM

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32")
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def _setup():
    params = init_params(jax.random.PRNGKey(0), CFG)
    return params, init_opt_state(params)


def test_loss_decreases():
    params, opt = _setup()
    data = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=8))
    step = jax.jit(make_train_step(CFG, OPT))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_restart_bitexact():
    """Training N steps == training k, checkpoint, restore, train N-k."""
    data = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=8))
    step = jax.jit(make_train_step(CFG, OPT))

    params, opt = _setup()
    for i in range(6):
        params, opt, _ = step(params, opt, data.batch_at(i))
    direct = params

    params, opt = _setup()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        for i in range(3):
            params, opt, _ = step(params, opt, data.batch_at(i))
        mgr.save(3, {"params": params, "opt": opt._asdict()},
                 extra={"data_index": 3})
        # simulate a crash: fresh state, restore
        params2, opt2 = _setup()
        restored, manifest = mgr.restore(
            3, {"params": params2, "opt": opt2._asdict()})
        params2 = restored["params"]
        from repro.optim.optimizer import OptState
        opt2 = OptState(**restored["opt"])
        for i in range(manifest["extra"]["data_index"], 6):
            params2, opt2, _ = step(params2, opt2, data.batch_at(i))

    for a, b in zip(jax.tree_util.tree_leaves(direct),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.ones((4,)) * s})
        assert mgr.latest_step() == 4
        assert mgr.all_steps() == [3, 4]  # gc kept last 2


def test_compression_error_feedback_contraction():
    """Error feedback keeps the cumulative compression error bounded."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_t(3, size=(64, 64)), jnp.float32)}
    state = init_compressor_state(g)
    total_err = []
    acc_true = np.zeros((64, 64))
    acc_sent = np.zeros((64, 64))
    for i in range(20):
        g = {"w": jnp.asarray(rng.standard_t(3, size=(64, 64)) * 0.1,
                              jnp.float32)}
        sent, state = compress_decompress(g, state)
        acc_true += np.asarray(g["w"])
        acc_sent += np.asarray(sent["w"])
        total_err.append(np.abs(acc_true - acc_sent).max())
    # residual carried, cumulative error stays at one-step quantization size
    assert total_err[-1] < 0.05, total_err[-1]


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_pipeline_deterministic(seed):
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4, seed=seed)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_pipeline_host_sharding_partitions():
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=8)
    full = SyntheticLM(cfg, host_id=0, n_hosts=1)
    h0 = SyntheticLM(cfg, host_id=0, n_hosts=2)
    h1 = SyntheticLM(cfg, host_id=1, n_hosts=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    # different hosts generate different data
    assert not np.array_equal(np.asarray(h0.batch_at(0)["tokens"]),
                              np.asarray(h1.batch_at(0)["tokens"]))


def test_pipeline_resume():
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4)
    pipe = SyntheticLM(cfg)
    it = pipe.resume_iter(DataState(5))
    batch, state = next(it)
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  np.asarray(pipe.batch_at(5)["tokens"]))
    assert state.batch_index == 6


def test_schedule_shape():
    assert float(schedule(OPT, jnp.asarray(0))) < OPT.lr * 0.6
    peak = float(schedule(OPT, jnp.asarray(OPT.warmup_steps)))
    assert abs(peak - OPT.lr) / OPT.lr < 1e-5
    end = float(schedule(OPT, jnp.asarray(OPT.total_steps)))
    assert abs(end - OPT.lr * OPT.min_lr_frac) / OPT.lr < 1e-5


def test_grad_clipping():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    opt = init_opt_state(params)
    _, _, m = apply_updates(params, grads, opt, OptConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) > 1.0  # raw norm reported
