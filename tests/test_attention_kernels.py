"""Fused VP-cache attention: cross-layout KV parity + kernel conformance.

PR 5 moves the serving attention hot path onto the packed-word VP cache:
`quantize_kv` emits ONE packed word per element, `attn_block` hands the
cache words to the `vp_decode_attention` kernel op, and prefill gains a
fused flash kernel on TPU backends.  This suite pins:

  * packed-vs-planes cache parity, BIT-IDENTICAL on the jnp ref backend
    (the CI environment): per element and end-to-end through every
    decode grid — full/windowed/rolling-ring, GQA, decode vs
    prefill-tail cache writes;
  * property tests: the packed KV round-trip under RANDOM (M, E)
    formats recovers the planes layout exactly;
  * kernel conformance: the Pallas decode and flash-prefill kernel
    bodies (interpreter) match their jnp oracles, including ragged
    (padded) cache lengths and chunk-unaligned sequence lengths;
  * the `_pick_chunk` prime-length regression: a prime Sq now pads to
    one power-of-two chunk instead of degrading to chunk=1 and an S^2
    singleton-pair scan;
  * the decode window-slice fast path == the legacy whole-cache mask.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.core import FXPFormat, default_vp_format
from repro.core.packing import pack_vp, storage_dtype, unpack_vp
from repro.kernels import autotune, ops, ref as kref, substrate
from repro.models import init_params, init_cache, prefill, decode_step
from repro.models.attention import (
    _chunk_and_pad,
    decode_attention,
    dequantize_kv,
    dequantize_kv_packed,
    flash_attention,
    kv_cache_formats,
    quantize_kv,
)

REF_BACKEND = substrate.resolve_backend(None) == "ref"
KVQ = QuantConfig(mode="none", quantize_kv_cache=True)


def assert_parity(got, want, err_msg=""):
    """Bit-identical on the shared jnp ref path; tight otherwise."""
    if REF_BACKEND:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=err_msg)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6, err_msg=err_msg)


def _random_kv(key, B, S, KV, dh):
    kk, kv_, kq = jax.random.split(key, 3)
    k = jax.random.normal(kk, (B, S, KV, dh), jnp.float32) * 2.0
    v = jax.random.normal(kv_, (B, S, KV, dh), jnp.float32)
    return k, v, kq


# ---------------------------------------------------------------------------
# Satellite: _pick_chunk prime-length regression
# ---------------------------------------------------------------------------

def test_chunk_and_pad_never_degenerates():
    # the old largest-divisor policy gave chunk=1 for any prime
    assert _chunk_and_pad(509) == (512, 512)
    assert _chunk_and_pad(512) == (512, 512)
    assert _chunk_and_pad(700) == (512, 1024)
    assert _chunk_and_pad(16) == (16, 16)
    for s in (127, 509, 1021):
        c, sp = _chunk_and_pad(s)
        assert c >= min(s, 128) and sp % c == 0 and sp >= s


@pytest.mark.parametrize("pattern,window,sq,sk", [
    ("causal", None, 509, 509),     # prime: the regression shape
    ("local", 37, 127, 127),
    ("full", None, 37, 53),         # ragged cross-attention
])
def test_flash_attention_unaligned_lengths(pattern, window, sq, sk):
    """Chunk-unaligned (incl. prime) lengths pad+mask instead of
    degrading to singleton chunks; output matches the O(S^2) oracle."""
    B, KV, G, dh = 2, 2, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, sq, KV * G, dh), jnp.float32)
    k, v, _ = _random_kv(jax.random.PRNGKey(1), B, sk, KV, dh)
    out = flash_attention(q, k, v, pattern=pattern, window=window)
    want = kref.flash_prefill_ref(q, k, v, pattern=pattern, window=window)
    assert out.shape == (B, sq, KV * G, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: decode window slicing == legacy whole-cache mask
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,smax,lens", [
    (16, 100, (3, 40, 100)),
    (64, 256, (1, 200, 256)),
])
def test_decode_window_slice_matches_whole_cache_mask(window, smax, lens):
    B, KV, G, dh = len(lens), 2, 3, 16
    H = KV * G
    key = jax.random.PRNGKey(2)
    k, v, kq = _random_kv(key, B, smax, KV, dh)
    q = jax.random.normal(kq, (B, 1, H, dh), jnp.float32)
    cache_len = jnp.asarray(lens, jnp.int32)
    got = decode_attention(q, k, v, cache_len, window=window)

    # legacy path: scores for ALL smax positions, mask, softmax
    qr = q.reshape(B, KV, G, dh) * dh ** -0.5
    s = jnp.einsum("bkgd,bksd->bkgs", qr, k.transpose(0, 2, 1, 3))
    pos = jnp.arange(smax)[None, :]
    valid = (pos < cache_len[:, None]) & (pos >= cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bkgs,bksd->bkgd", p, v.transpose(0, 2, 1, 3))
    want = want.reshape(B, 1, H, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Property: packed KV round-trip under random (M, E) formats
# ---------------------------------------------------------------------------

@given(M=st.integers(3, 8), E=st.integers(1, 2), seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_packed_kv_roundtrip_random_formats(M, E, seed):
    """quantize_kv packed words == pack(planes) and dequantize exactly,
    for random KV formats on the canonical FXP grid."""
    q = QuantConfig(mode="none", M=M, E=E, quantize_kv_cache=True)
    fxp, vp = kv_cache_formats(q)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 5, 2, 8), jnp.float32) * 3.0
    w, s = quantize_kv(x, q)
    assert w.dtype == storage_dtype(vp) and w.shape == x.shape
    m, i_packed, s2 = quantize_kv(x, q, layout="planes")
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    mw, iw = unpack_vp(w, vp)
    np.testing.assert_array_equal(np.asarray(mw), np.asarray(m))
    np.testing.assert_array_equal(
        np.asarray(pack_vp(mw, iw, vp)), np.asarray(w))
    deq_w = dequantize_kv_packed(w, s, q, jnp.float32)
    deq_p = dequantize_kv(m, i_packed, s2, q, jnp.float32)
    np.testing.assert_array_equal(np.asarray(deq_w), np.asarray(deq_p))


# ---------------------------------------------------------------------------
# Cross-layout cache parity: packed vs planes, every decode grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,rolling,G", [
    (None, False, 1),        # full span, MHA
    (None, False, 4),        # full span, GQA
    (24, False, 2),          # bounded window, buffer larger than window
    (24, True, 2),           # rolling ring (buffer IS the window)
])
def test_packed_vs_planes_decode_parity(window, rolling, G):
    """The tentpole contract: packed-word decode attention is
    bit-identical to the legacy dequant-whole-cache planes path on the
    ref backend (power-of-two scales are exact; both run the shared
    decode core)."""
    B, smax, KV, dh = 3, 64, 2, 16
    H = KV * G
    key = jax.random.PRNGKey(5)
    k, v, kq = _random_kv(key, B, smax, KV, dh)
    q = jax.random.normal(kq, (B, 1, H, dh), jnp.float32)
    lens = jnp.asarray([7, 40, 64], jnp.int32)
    _, vp = kv_cache_formats(KVQ)

    w_k, s_k = quantize_kv(k, KVQ)
    w_v, s_v = quantize_kv(v, KVQ)
    got = ops.vp_decode_attention(q, w_k, w_v, s_k, s_v, lens, vp,
                                  window=window, rolling=rolling)

    m_k, i_k, ps_k = quantize_kv(k, KVQ, layout="planes")
    m_v, i_v, ps_v = quantize_kv(v, KVQ, layout="planes")
    k_full = dequantize_kv(m_k, i_k, ps_k, KVQ, q.dtype)
    v_full = dequantize_kv(m_v, i_v, ps_v, KVQ, q.dtype)
    want = decode_attention(q, k_full, v_full, lens, window=window,
                            rolling=rolling)
    assert got.shape == want.shape == (B, 1, H, dh)
    assert_parity(got, want, err_msg=f"w={window} roll={rolling} G={G}")


def test_prefill_tail_vs_decode_write_parity():
    """Writing position S via a one-shot prefill quantize vs a decode
    append produces bit-identical packed words and scales (per-position
    pow2 scales make the quantization independent of the write route)."""
    B, S, KV, dh = 2, 9, 2, 16
    k, _, _ = _random_kv(jax.random.PRNGKey(7), B, S, KV, dh)
    w_all, s_all = quantize_kv(k, KVQ)                     # prefill route
    w_head, s_head = quantize_kv(k[:, :S - 1], KVQ)        # decode route
    w_tail, s_tail = quantize_kv(k[:, S - 1:], KVQ)
    np.testing.assert_array_equal(
        np.asarray(w_all),
        np.asarray(jnp.concatenate([w_head, w_tail], axis=1)))
    np.testing.assert_array_equal(
        np.asarray(s_all),
        np.asarray(jnp.concatenate([s_head, s_tail], axis=1)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b",
                                  "gemma3-27b"])
def test_model_kv_cache_layout_parity(arch):
    """Full-model golden parity across cache layouts: packed-kernel
    serving vs the planes jnp baseline, prefill + two decode steps, over
    causal / SWA-rolling-ring / local-global architectures."""
    outs = {}
    for layout in ("packed", "planes"):
        q = dataclasses.replace(KVQ, kv_layout=layout)
        cfg = registry.get_smoke_config(arch, quant=q)
        key = jax.random.PRNGKey(11)
        params = init_params(key, cfg)
        toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
        caches = init_cache(cfg, 2, 16)
        lo, caches = prefill(params, toks, caches, cfg)
        nxt = jnp.argmax(lo, -1)[:, None]
        lo2, caches = decode_step(params, nxt, caches, cfg)
        lo3, _ = decode_step(params, jnp.argmax(lo2, -1)[:, None],
                             caches, cfg)
        assert bool(jnp.isfinite(lo3).all()), (arch, layout)
        outs[layout] = tuple(np.asarray(x) for x in (lo, lo2, lo3))
    for stage in range(3):
        assert_parity(outs["packed"][stage], outs["planes"][stage],
                      err_msg=f"{arch} stage {stage}")


def test_init_cache_layouts():
    q = dataclasses.replace(KVQ, kv_layout="packed")
    cfg = registry.get_smoke_config("qwen3-0.6b", quant=q)
    _, vp = kv_cache_formats(cfg.quant)
    c = init_cache(cfg, 2, 16)[0]["sub0"]
    assert set(c) == {"k_w", "k_s", "v_w", "v_s", "len"}
    assert c["k_w"].dtype == storage_dtype(vp)
    cfg_p = registry.get_smoke_config(
        "qwen3-0.6b", quant=dataclasses.replace(KVQ, kv_layout="planes"))
    cp = init_cache(cfg_p, 2, 16)[0]["sub0"]
    assert {"k_m", "k_i", "k_s"} <= set(cp)


# ---------------------------------------------------------------------------
# Kernel conformance (interpret mode vs the jnp oracles)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,rolling,smax,G", [
    (None, False, 128, 2),
    (None, False, 100, 2),   # ragged: op pads the seq axis
    (16, False, 100, 1),
    (16, True, 100, 3),
])
def test_decode_attention_kernel_interpret_parity(window, rolling, smax, G):
    """The Pallas decode kernel body (interpreter) == the packed oracle,
    including the cache_len-aware tile skip and seq padding."""
    B, KV, dh = 2, 2, 32
    H = KV * G
    key = jax.random.PRNGKey(13)
    k, v, kq = _random_kv(key, B, smax, KV, dh)
    q = jax.random.normal(kq, (B, 1, H, dh), jnp.float32)
    lens = jnp.asarray([smax // 3, smax], jnp.int32)
    _, vp = kv_cache_formats(KVQ)
    w_k, s_k = quantize_kv(k, KVQ)
    w_v, s_v = quantize_kv(v, KVQ)
    args = (q, w_k, w_v, s_k, s_v, lens, vp)
    want = kref.vp_decode_attention_ref(*args, window=window,
                                        rolling=rolling)
    got = ops.vp_decode_attention(*args, window=window, rolling=rolling,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("pattern,window,sq,sk,G", [
    ("causal", None, 64, 64, 2),
    ("causal", None, 509, 509, 1),   # prime -> padded grid + fringe mask
    ("local", 24, 64, 64, 2),
    ("full", None, 37, 53, 4),       # ragged cross-attention shapes
])
def test_flash_prefill_kernel_interpret_parity(pattern, window, sq, sk, G):
    B, KV, dh = 2, 2, 16
    key = jax.random.PRNGKey(17)
    q = jax.random.normal(key, (B, sq, KV * G, dh), jnp.float32)
    k, v, _ = _random_kv(jax.random.PRNGKey(19), B, sk, KV, dh)
    want = kref.flash_prefill_ref(q, k, v, pattern=pattern, window=window)
    got = ops.flash_prefill(q, k, v, pattern=pattern, window=window,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    scan = flash_attention(q, k, v, pattern=pattern, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(scan),
                               rtol=1e-5, atol=1e-6)


def test_decode_kernel_rolling_ring_wrap_with_padding():
    """Regression: a rolling ring whose buffer is NOT a tile multiple,
    decoded past the wrap (lengths > buffer).  The kernel's ring clamp
    must use the REAL buffer length — clamping to the padded length let
    zero-score padding columns into the softmax denominator."""
    B, smax, KV, dh, G = 2, 24, 2, 32, 2
    H = KV * G
    key = jax.random.PRNGKey(31)
    k, v, kq = _random_kv(key, B, smax, KV, dh)
    q = jax.random.normal(kq, (B, 1, H, dh), jnp.float32)
    lens = jnp.asarray([30, 100], jnp.int32)   # both past the wrap
    _, vp = kv_cache_formats(KVQ)
    w_k, s_k = quantize_kv(k, KVQ)
    w_v, s_v = quantize_kv(v, KVQ)
    args = (q, w_k, w_v, s_k, s_v, lens, vp)
    want = kref.vp_decode_attention_ref(*args, window=smax, rolling=True)
    # blocks=(1, 32, 1): the 24-slot ring pads to 32 inside the op
    got = ops.vp_decode_attention(*args, window=smax, rolling=True,
                                  blocks=(1, 32, 1), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Autotune plumbing for the attention kernels
# ---------------------------------------------------------------------------

def test_attn_candidates_shapes():
    for sq, sk in ((1, 64), (4, 1024), (509, 509)):
        cands = autotune.attn_candidates(sq, sk)
        assert cands, (sq, sk)
        for bq, bk, one in cands:
            assert one == 1
            assert bq <= max(128, autotune._pow2_at_least(sq))
            assert bk <= max(512, autotune._pow2_at_least(sk))
            assert bq & (bq - 1) == 0 and bk & (bk - 1) == 0


def test_resolve_attn_blocks_cache_roundtrip(tmp_path, monkeypatch):
    """A tuned entry keyed on the FULL decode geometry (incl. window and
    rolling) is what `ops.vp_decode_attention` launches next time."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    autotune._caches.clear()
    _, vp = kv_cache_formats(KVQ)
    shape = (2, 256, 2, 32, 16, 0)
    key = autotune.make_key("vp_decode_attention", shape, (vp,),
                            "interpret")
    autotune.record(key, (1, 64, 1))
    got = autotune.resolve_attn_blocks(
        "vp_decode_attention", shape, (vp,), "interpret", sq=2, sk=256)
    assert got == (1, 64, 1)
    # a DIFFERENT window must not hit the same entry
    other = autotune.resolve_attn_blocks(
        "vp_decode_attention", (2, 256, 2, 32, 32, 0), (vp,), "interpret",
        sq=2, sk=256)
    assert other == (2, 256, 1)
    # and the tuned tile actually drives the kernel launch, numerics
    # unchanged vs the heuristic tile
    B, smax, KV, dh, G = 2, 256, 2, 32, 1
    k, v, kq = _random_kv(jax.random.PRNGKey(23), B, smax, KV, dh)
    q = jax.random.normal(kq, (B, 1, KV * G, dh), jnp.float32)
    lens = jnp.asarray([100, 256], jnp.int32)
    w_k, s_k = quantize_kv(k, KVQ)
    w_v, s_v = quantize_kv(v, KVQ)
    out_tuned = ops.vp_decode_attention(
        q, w_k, w_v, s_k, s_v, lens, vp, window=16, interpret=True)
    out_explicit = ops.vp_decode_attention(
        q, w_k, w_v, s_k, s_v, lens, vp, window=16, blocks=(1, 128, 1),
        interpret=True)
    np.testing.assert_allclose(np.asarray(out_tuned),
                               np.asarray(out_explicit),
                               rtol=1e-6, atol=1e-6)
