"""Sharded-execution parity suite (PR 8).

The contract under test: every collective on the sharded packed-VP
datapath is a pure CONCATENATION (all-gather of output column blocks /
head shards / expert outputs; the ppermute ring writes disjoint column
blocks), so on the jnp ref backend the shard_map'd ops, the full-model
forwards, and the mesh-constructed serving engine are all BIT-IDENTICAL
to their single-device oracles — across the quant x KV-layout matrix,
for all three weight-sharding modes, and for the expert-parallel MoE
branch.  Runs on the 8-host-device platform `tests/conftest.py` pins.

Also here: the `shard_param_specs` placement rules (which leaves shard,
which error when they cannot), the autotune mesh-key migration shim,
and the JX-SHGATH lint rule (the `gather` mode's full-weight
re-materialization is flagged; `ring`/`column` stay clean).
"""
import json
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, QuantConfig
from repro.kernels import autotune, substrate
from repro.kernels import ops as kops
from repro.launch import mesh as mesh_mod
from repro.models import (
    decode_step, init_cache, init_params, prefill, quantize_params,
)
from repro.models.layers import canonical_formats
from repro.parallel import shard_ops

REF_BACKEND = substrate.resolve_backend(None) == "ref"
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices (conftest flag)")


def _mesh(data=1, model=8):
    return mesh_mod.elastic_mesh(1, data, model)


def _tiny_cfg(quant, family="dense", **kw):
    base = dict(name="tiny", family=family, n_layers=2, d_model=64,
                n_heads=8, n_kv_heads=4, d_ff=128, vocab=128,
                dtype="float32", quant=quant)
    if family == "moe":
        base.update(n_experts=8, experts_per_token=2)
    base.update(kw)
    return ModelConfig(**base)


def _quant(mode="vp", kv="packed", **kw):
    if kv != "float":
        kw.update(quantize_kv_cache=True, kv_layout=kv)
    if mode == "vp_block":
        kw.setdefault("block", 16)
    return QuantConfig(mode=mode, **kw)


# ---------------------------------------------------------------------------
# Op-level parity
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
@pytest.mark.parametrize("mode", shard_ops.MODES)
@pytest.mark.parametrize("tp", [2, 8])
def test_dequant_matmul_parity(mode, tp):
    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128), jnp.float32)
    w_pk = kops.vp_quant(w, fxp, vp, packed=True)
    y_ref = np.asarray(kops.vp_dequant_matmul(x, w_pk, vp))
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_dequant_matmul, fmt=vp, mode=mode),
        mesh=_mesh(model=tp) if tp == 8 else _mesh(4, 2),
        in_specs=(P(), P(None, "model")), out_specs=P(), check_rep=False))
    assert np.array_equal(np.asarray(fn(x, w_pk)), y_ref)


def test_dequant_matmul_bad_mode():
    _, vp = canonical_formats(QuantConfig(mode="vp"))
    with pytest.raises(ValueError, match="mode"):
        shard_ops.sharded_dequant_matmul(
            jnp.zeros((2, 4)), jnp.zeros((4, 8), jnp.int16), vp,
            mode="scatter")


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
@pytest.mark.parametrize("mode", ["seq", "heads"])
def test_decode_attention_parity(mode):
    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    B, S, H, KV, dh = 2, 32, 8, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, dh), jnp.float32)
    k_w = kops.vp_quant(k, fxp, vp, packed=True)
    v_w = kops.vp_quant(v, fxp, vp, packed=True)
    ones = jnp.ones((B, S, 1, 1), jnp.float32)
    lens = jnp.asarray([S, S // 2], jnp.int32)
    o_ref = np.asarray(
        kops.vp_decode_attention(q, k_w, v_w, ones, ones, lens, vp))
    if mode == "seq":
        in_specs = (P(), P(None, "model"), P(None, "model"),
                    P(None, "model"), P(None, "model"), P())
    else:
        in_specs = (P(None, None, "model"), P(None, None, "model"),
                    P(None, None, "model"), P(), P(), P())
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_decode_attention, fmt=vp, mode=mode),
        mesh=_mesh(model=8 if mode == "seq" else 4) if mode == "seq"
        else _mesh(2, 4),
        in_specs=in_specs, out_specs=P(), check_rep=False))
    assert np.array_equal(np.asarray(fn(q, k_w, v_w, ones, ones, lens)),
                          o_ref)


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
def test_flash_prefill_parity():
    from repro.models.attention import flash_attention

    B, S, H, KV, dh = 2, 16, 8, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, dh), jnp.float32)
    o_ref = np.asarray(flash_attention(q, k, v))
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_flash_prefill),
        mesh=_mesh(2, 4),
        in_specs=(P(None, None, "model"), P(None, None, "model"),
                  P(None, None, "model")),
        out_specs=P(), check_rep=False))
    assert np.array_equal(np.asarray(fn(q, k, v)), o_ref)


# ---------------------------------------------------------------------------
# Backward collectives (PR 9): dx psum/ring, local dw, DP grad codec
# ---------------------------------------------------------------------------

def _bwd_operands():
    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), (16, 128), jnp.float32)
    return (vp, fxp, kops.vp_quant(x, fxp, vp, packed=True),
            kops.vp_quant(w, fxp, vp, packed=True), g)


@pytest.mark.skipif(not REF_BACKEND, reason="oracle parity is a ref check")
@pytest.mark.parametrize("mode", ["psum", "ring"])
@pytest.mark.parametrize("tp", [2, 8])
def test_sharded_matmul_dx_parity(mode, tp):
    """dx across psum/ring modes vs the single-device backward kernel.

    Unlike the forward modes (concatenation-exact), dx REDUCES partial
    products across shards, so the contract is allclose, not bit-equal:
    psum/ring add the same tp partials in different orders."""
    vp, _, _, w_pk, g = _bwd_operands()
    dx_ref = np.asarray(kops.vp_matmul_dx(g, w_pk, vp))
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_matmul_dx, fmt=vp, mode=mode),
        mesh=_mesh(model=tp) if tp == 8 else _mesh(4, 2),
        in_specs=(P(), P(None, "model")), out_specs=P(),
        check_rep=False))
    np.testing.assert_allclose(np.asarray(fn(g, w_pk)), dx_ref,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(not REF_BACKEND, reason="oracle parity is a ref check")
def test_sharded_matmul_dx_ring_scatter_output():
    """gather=False leaves dx row-sharded; the reassembled shards must
    equal the gathered result."""
    vp, _, _, w_pk, g = _bwd_operands()
    dx_ref = np.asarray(kops.vp_matmul_dx(g, w_pk, vp))
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_matmul_dx, fmt=vp, mode="ring",
                gather=False),
        mesh=_mesh(model=8), in_specs=(P(), P(None, "model")),
        out_specs=P("model"), check_rep=False))
    np.testing.assert_allclose(np.asarray(fn(g, w_pk)), dx_ref,
                               rtol=1e-5, atol=1e-5)


def test_sharded_matmul_dx_bad_mode():
    _, vp = canonical_formats(QuantConfig(mode="vp"))
    with pytest.raises(ValueError, match="mode"):
        shard_ops.sharded_matmul_dx(
            jnp.zeros((2, 8)), jnp.zeros((4, 8), jnp.int16), vp,
            mode="gather")


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
def test_sharded_matmul_dw_local_bit_exact():
    """The weight-grad shard is computed purely locally (no collective),
    so it is BIT-identical to the matching slice of the full dw."""
    vp, _, x_pk, _, g = _bwd_operands()
    dw_ref = np.asarray(kops.vp_matmul_dw(x_pk, g, vp))
    fn = jax.jit(shard_map(
        partial(shard_ops.sharded_matmul_dw, fmt=vp),
        mesh=_mesh(model=8), in_specs=(P(), P()),
        out_specs=P(None, "model"), check_rep=False))
    assert np.array_equal(np.asarray(fn(x_pk, g)), dw_ref)


@pytest.mark.skipif(not REF_BACKEND, reason="oracle parity is a ref check")
@pytest.mark.parametrize("codec", ["int8", "vp"])
def test_dp_compress_reduce_oracle(codec):
    """Compressed DP reduction == per-rank local compress, then mean —
    with per-rank residuals carried in the returned state."""
    from repro.train.compression import (
        CompressionConfig, compress_decompress, init_compressor_state,
    )

    dp = 8
    cfg = CompressionConfig(codec=codec)
    grads = {"w": jax.random.normal(jax.random.PRNGKey(3),
                                    (dp, 16, 16), jnp.float32)}
    state = init_compressor_state(grads)
    fn = jax.jit(shard_map(
        partial(shard_ops.dp_compress_reduce, axis="data", config=cfg),
        mesh=_mesh(8, 1),
        in_specs=({"w": P("data")}, {"w": P("data")}),
        out_specs=({"w": P()}, {"w": P("data")}), check_rep=False))
    red, new_state = fn(grads, state)
    deqs, errs = [], []
    for i in range(dp):
        d, e = compress_decompress({"w": grads["w"][i:i + 1]},
                                   {"w": state["w"][i:i + 1]}, cfg)
        deqs.append(np.asarray(d["w"]))
        errs.append(np.asarray(e["w"]))
    oracle = np.mean(np.concatenate(deqs, 0), axis=0)
    np.testing.assert_allclose(np.asarray(red["w"][0]), oracle,
                               rtol=1e-6, atol=1e-7)
    # jit-vs-eager f32 rounding (~1e-7) on the residual subtraction
    np.testing.assert_allclose(np.asarray(new_state["w"]),
                               np.concatenate(errs, 0),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Full-model parity: quant x KV-layout matrix, dense + MoE (EP)
# ---------------------------------------------------------------------------

MATRIX = [("vp", "packed"), ("vp", "planes"), ("fxp", "packed"),
          ("vp_block", "packed"), ("vp", "float")]


def _model_oracle_and_sharded(cfg, mesh, B=2, S=16, cap=32):
    params = init_params(jax.random.PRNGKey(0), cfg)
    if cfg.quant.mode != "none":
        params = quantize_params(params, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = init_cache(cfg, B, cap)
    logits1, caches1 = jax.jit(
        lambda p, t, c: prefill(p, t, c, cfg))(params, tokens, caches)
    tok = jnp.argmax(logits1, -1).astype(jnp.int32)[:, None]
    dlogits1, caches1 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg))(params, tok, caches1)

    placed = shard_ops.place_params(params, cfg, mesh)
    prefill_fn, decode_fn = shard_ops.sharded_forward_fns(
        params, cfg, mesh)
    logits2, caches2 = jax.jit(prefill_fn)(placed, tokens, caches)
    dlogits2, caches2 = jax.jit(decode_fn)(placed, tok, caches2)
    return (logits1, dlogits1, caches1), (logits2, dlogits2, caches2)


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
@pytest.mark.parametrize("mode,kv", MATRIX)
def test_model_parity_dense(mode, kv):
    cfg = _tiny_cfg(_quant(mode, kv))
    (l1, d1, c1), (l2, d2, c2) = _model_oracle_and_sharded(cfg, _mesh())
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
@pytest.mark.parametrize("mode", ["vp", "none"])
def test_model_parity_moe_expert_parallel(mode):
    cfg = _tiny_cfg(_quant(mode, "packed" if mode == "vp" else "float"),
                    family="moe")
    (l1, d1, _), (l2, d2, _) = _model_oracle_and_sharded(cfg, _mesh())
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))


# ---------------------------------------------------------------------------
# Serving engine under a mesh (TP and DP x TP)
# ---------------------------------------------------------------------------

REQS = [([1, 2, 3, 4, 5], 4, 0.0), (list(range(7)), 5, 0.0),
        ([9, 8, 7], 3, 0.05)]


def _engine_tokens(cfg, params, mesh):
    from repro.serving import ServingEngine, VirtualClock

    eng = ServingEngine(params, cfg, max_slots=2, capacity=24, page_size=8,
                        clock=VirtualClock(), mesh=mesh)
    for prompt, gen, t in REQS:
        eng.submit(prompt, gen, t)
    return {r["rid"]: r["tokens"] for r in eng.run()}


@pytest.mark.skipif(not REF_BACKEND, reason="bit parity is a ref contract")
@pytest.mark.parametrize("data,model", [(1, 8), (2, 4)])
def test_engine_mesh_parity(data, model):
    cfg = _tiny_cfg(_quant("vp", "packed"), n_heads=4, n_kv_heads=2)
    params = quantize_params(init_params(jax.random.PRNGKey(0), cfg), cfg)
    want = _engine_tokens(cfg, params, None)
    got = _engine_tokens(cfg, params, _mesh(data, model))
    assert got == want


# ---------------------------------------------------------------------------
# Placement rules + mesh factory
# ---------------------------------------------------------------------------

def test_shard_specs_divisibility_error():
    cfg = _tiny_cfg(_quant("vp", "packed"), d_ff=100)  # 100 % 8 != 0
    params = quantize_params(init_params(jax.random.PRNGKey(0), cfg), cfg)
    with pytest.raises(shard_ops.ShardSpecError, match="divisible"):
        shard_ops.shard_param_specs(params, cfg, tp=8)


def test_shard_specs_replicate_floats():
    cfg = _tiny_cfg(QuantConfig(mode="none"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    specs = shard_ops.shard_param_specs(params, cfg, tp=8)
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))


def test_mesh_validation_errors():
    with pytest.raises(ValueError, match="devices"):
        mesh_mod.elastic_mesh(1, 3, 5)
    with pytest.raises(ValueError, match=">= 1"):
        mesh_mod.elastic_mesh(0, 1, 8)
    with pytest.raises(ValueError, match="exposes"):
        mesh_mod.best_effort_mesh(1024)
    m = mesh_mod.best_effort_mesh(8)
    assert dict(m.shape) == {"data": 1, "model": 8}
    assert dict(mesh_mod.best_effort_mesh(4, prefer="data").shape) == \
        {"data": 4, "model": 1}


# ---------------------------------------------------------------------------
# Autotune mesh keys + migration shim
# ---------------------------------------------------------------------------

def test_autotune_mesh_key_scoped():
    key0 = autotune.make_key("vp_dequant_matmul", (8, 64, 128), (), "ref")
    assert key0.endswith("|mesh=1")
    with autotune.mesh_scope("model8.N"):
        key8 = autotune.make_key("vp_dequant_matmul", (8, 64, 128), (),
                                 "ref")
    assert key8.endswith("|mesh=model8.N") and key8 != key0


def test_autotune_cache_migration(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    legacy = {"vp_matmul|64x64x64|VP(4,[0,2])|ref": [64, 64, 64]}
    path.write_text(json.dumps(legacy))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    # the shim rewrites the legacy 4-part key to the canonical |mesh=1 form
    entry = autotune.get_cached(
        "vp_matmul|64x64x64|VP(4,[0,2])|ref|mesh=1")
    assert entry == (64, 64, 64)


# ---------------------------------------------------------------------------
# JX-SHGATH lint
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not REF_BACKEND,
                    reason="lint traces the ref dequant graph")
def test_lint_flags_gather_not_ring():
    from repro.analysis import jaxpr_lint

    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    x = jnp.zeros((8, 256), jnp.float32)
    w_pk = kops.vp_quant(jnp.zeros((256, 512), jnp.float32), fxp, vp,
                         packed=True)

    def traced(mode):
        fn = shard_map(
            partial(shard_ops.sharded_dequant_matmul, fmt=vp, mode=mode),
            mesh=_mesh(), in_specs=(P(), P(None, "model")),
            out_specs=P(), check_rep=False)
        return jax.make_jaxpr(fn)(x, w_pk)

    flagged = jaxpr_lint.lint_sharded_traced(traced("gather"), where="t")
    assert len(flagged) == 1 and flagged[0]["rule"] == "JX-SHGATH"
    assert jaxpr_lint.lint_sharded_traced(traced("ring"), where="t") == []
    assert jaxpr_lint.lint_sharded_traced(traced("column"), where="t") == []


def test_check_sharded_serving_clean():
    from repro.analysis import rules

    assert [f for f in rules.check_sharded()
            if f.rule == "JX-SHGATH"] == []
