"""Unit-gate cost model: reproduces the paper's VLSI comparison trends."""
from repro.core import cost_model as cm


def test_area_ratios_match_paper_trends():
    designs = cm.paper_designs()
    tot = {k: cm.total(cm.mvm_area(s)) for k, s in designs.items()}
    # B-FXP larger than A-FXP (paper: +25%); we allow the model's +30-45%
    assert 1.2 < tot["B-FXP"] / tot["A-FXP"] < 1.5
    # B-VP saves area vs B-FXP (paper: -20%)
    assert 0.70 < tot["B-VP"] / tot["B-FXP"] < 0.88


def test_rm_dominates_bfxp_area():
    areas = cm.mvm_area(cm.paper_designs()["B-FXP"])
    share = areas["rm"] / cm.total(areas)
    assert 0.55 < share < 0.78  # paper: 0.66


def test_power_savings_band():
    designs = cm.paper_designs()
    for mut in (0.3, 0.5):
        p = {k: sum(cm.mvm_power(s, muting_rate=mut).values())
             for k, s in designs.items()}
        r = p["B-VP"] / p["B-FXP"]
        assert 0.75 < r < 0.95, (mut, r)  # paper: 0.86-0.90


def test_flp_much_larger_than_vp():
    designs = cm.paper_designs()
    ratio = cm.flp_cmac_array_area(8) / cm.vp_cmac_array_area(
        designs["B-VP"])
    assert ratio > 2.0  # paper: 3.4 (gate model recovers >2x)


def test_converter_cheaper_than_multiplier():
    """The whole point: FXP2VP+VP2FXP overhead < the multiplier shrink."""
    from repro.core import FXPFormat, VPFormat, product_format

    y_fxp, y_vp = FXPFormat(9, 1), VPFormat(7, (1, -1))
    w_fxp, w_vp = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
    rm_fxp = cm.multiplier_area(9, 12)
    rm_vp = cm.multiplier_area(7, 7)
    conv = (cm.fxp2vp_area(y_fxp, y_vp) / 64  # amortized over the DOTP
            + cm.fxp2vp_area(w_fxp, w_vp) / 64
            + cm.vp2fxp_area(product_format(y_vp, w_vp), FXPFormat(20, 12)))
    assert rm_vp + conv < rm_fxp


def test_multiplier_area_monotone():
    assert cm.multiplier_area(7, 7) < cm.multiplier_area(9, 12)
    assert cm.multiplier_area(9, 12) < cm.multiplier_area(12, 12)
