"""The kernel-path B-VP equalizer == the numerical model of the design."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mimo import ChannelConfig, table1_specs
from repro.mimo.sim import make_ensemble, calibrate_specs, qam16_demod_hard
from repro.mimo.equalizer import equalize_quantized
from repro.mimo.mvm_engine import equalize_vp_kernel


@pytest.fixture(scope="module")
def setup():
    ens = make_ensemble(jax.random.PRNGKey(2), ChannelConfig(), 64, 10.0)
    specs = {s.name: s for s in calibrate_specs(table1_specs(), ens)}
    return ens, specs["B-VP"]


def test_kernel_path_matches_model_path(setup):
    """4-RM complex VP MVM through the kernel == fake-quant einsum."""
    ens, spec = setup
    s_kernel = equalize_vp_kernel(spec, ens.w_beam, ens.y_beam,
                                  interpret=None)  # ref dispatch on CPU
    s_model = equalize_quantized(spec, ens.w_beam, ens.y_beam)
    np.testing.assert_allclose(
        np.asarray(s_kernel), np.asarray(s_model), rtol=2e-4, atol=2e-4)


def test_kernel_path_interpret_mode(setup):
    """Same equalization through the actual Pallas kernel body."""
    ens, spec = setup
    w, y = ens.w_beam[:8], ens.y_beam[:8]
    s_kernel = equalize_vp_kernel(spec, w, y, interpret=True)
    s_model = equalize_quantized(spec, w, y)
    np.testing.assert_allclose(
        np.asarray(s_kernel), np.asarray(s_model), rtol=2e-4, atol=2e-4)


def test_kernel_path_ber_sane(setup):
    """Hard-decision symbols through the kernel path match the model path
    (same BER -> same silicon-worthy behaviour)."""
    ens, spec = setup
    bits_k = qam16_demod_hard(
        equalize_vp_kernel(spec, ens.w_beam, ens.y_beam))
    bits_m = qam16_demod_hard(
        equalize_quantized(spec, ens.w_beam, ens.y_beam))
    assert (np.asarray(bits_k) == np.asarray(bits_m)).mean() > 0.999


def test_cspade_masks_change_little_at_mild_threshold(setup):
    """With CSPADE tile masks at a mild quantile the estimate barely moves
    (quiet x quiet products carry almost no energy)."""
    ens, spec = setup
    s_full = equalize_vp_kernel(spec, ens.w_beam, ens.y_beam)
    s_muted = equalize_vp_kernel(spec, ens.w_beam, ens.y_beam,
                                 cspade_threshold_quantile=0.2)
    err = float(jnp.linalg.norm(s_muted - s_full)
                / jnp.linalg.norm(s_full))
    assert err < 0.05, err
