"""Shared test config: make `hypothesis` optional WITHOUT losing coverage.

Several modules do a hard `from hypothesis import given, settings,
strategies as st` at the top.  With the real package installed (it is in
requirements.txt; CI installs it) nothing here runs.  On minimal
environments without the wheel we install `tests/_minihypothesis.py` into
`sys.modules` BEFORE the test modules import it — a tiny functional
stand-in that actually EXECUTES each property test over deterministic
pseudo-random examples, so the property suite passes with real coverage
instead of skipping (the pre-PR-2 shim replaced every @given test with a
skip).
"""
import importlib.util
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "_minihypothesis",
        os.path.join(os.path.dirname(__file__), "_minihypothesis.py"),
    )
    _mh = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mh)
    _mh.install(sys.modules)
