"""Shared test config: make `hypothesis` optional.

Several modules do a hard `from hypothesis import given, settings,
strategies as st` at the top; on minimal environments (no hypothesis
wheel) that used to kill collection of 4 of 9 test modules.  When the real
package is missing we install a tiny stub into sys.modules BEFORE the test
modules import it, so:

  * the module-level import succeeds and every non-property test in the
    module still collects and runs;
  * each @given property test is replaced by a zero-arg function that
    skips cleanly at run time (zero-arg so pytest doesn't try to resolve
    the hypothesis-strategy parameters as fixtures).

With hypothesis installed the stub is inert and property tests run
normally.
"""
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Placeholder strategy object: composes/calls to itself."""

        def __init__(self, name):
            self._name = name

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return _Strategy(f"{self._name}.{name}")

        def __repr__(self):
            return f"<stub strategy {self._name}>"

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "one_of", "just", "composite", "data"):
        setattr(_st, _name, _Strategy(_name))

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    _hyp.assume = lambda *a, **k: True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
