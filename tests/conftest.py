"""Shared test config: make `hypothesis` optional WITHOUT losing coverage.

Several modules do a hard `from hypothesis import given, settings,
strategies as st` at the top.  With the real package installed (it is in
requirements.txt; CI installs it) nothing here runs.  On minimal
environments without the wheel we install `tests/_minihypothesis.py` into
`sys.modules` BEFORE the test modules import it — a tiny functional
stand-in that actually EXECUTES each property test over deterministic
pseudo-random examples, so the property suite passes with real coverage
instead of skipping (the pre-PR-2 shim replaced every @given test with a
skip).
"""
import importlib.util
import os
import sys

# Expose 8 host devices BEFORE anything imports jax, so the sharded
# parity suite (tests/test_sharded_parity.py) runs in-process on real
# shard_map meshes.  Harmless for the rest of the suite: ops dispatch
# is backend-keyed, not device-count-keyed, and jit on one device of
# eight compiles exactly as on one of one.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "_minihypothesis",
        os.path.join(os.path.dirname(__file__), "_minihypothesis.py"),
    )
    _mh = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mh)
    _mh.install(sys.modules)


@pytest.fixture(autouse=True, scope="session")
def _isolated_autotune_cache(tmp_path_factory):
    """Point the autotune cache at a per-run temp file for ALL tests.

    Without this, tests resolving `blocks=None` would read whatever a
    prior benchmark run persisted to the developer's global cache
    (~/.cache/repro-vp/autotune.json) — kernel tilings, and thus the
    exact configurations under test, would depend on machine state.
    (tests/test_autotune.py re-points it per-test via monkeypatch.)
    """
    old = os.environ.get("REPRO_AUTOTUNE_CACHE")
    path = str(tmp_path_factory.mktemp("autotune") / "autotune.json")
    os.environ["REPRO_AUTOTUNE_CACHE"] = path
    yield
    if old is None:
        os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
    else:
        os.environ["REPRO_AUTOTUNE_CACHE"] = old
