"""Wideband OFDM pipeline tests: channels, calibration cache, execution
paths (flat / vmap / shard_map) and end-to-end NMSE/BER sanity."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.mimo import (
    ChannelConfig, OFDMConfig, WidebandCalibrator,
    generate_wideband_channels, make_wideband_ensemble, equalize_wideband,
    table1_specs,
)
from repro.mimo.lmmse import equalize
from repro.mimo.ofdm import wideband_nmse, wideband_ber

CFG = ChannelConfig()
OFDM = OFDMConfig(n_subcarriers=8, n_taps=3)


@pytest.fixture(scope="module")
def wideband():
    ens = make_wideband_ensemble(jax.random.PRNGKey(0), CFG, OFDM, 16, 20.0)
    base = next(s for s in table1_specs() if s.name == "B-VP")
    cal = WidebandCalibrator(base)
    return ens, cal, cal.specs_for(ens)


def test_wideband_channel_shapes_and_power():
    h = generate_wideband_channels(
        jax.random.PRNGKey(1), CFG, OFDM, 8)
    assert h.shape == (OFDM.S, 8, CFG.B, CFG.U)
    # Unit-total-power PDP keeps the per-antenna gain convention:
    # E[|H[s]|^2] ~ 1 per entry, uniformly across the band.
    p = np.asarray(jnp.mean(jnp.abs(h) ** 2, axis=(1, 2, 3)))
    assert np.all(p > 0.5) and np.all(p < 2.0), p


def test_wideband_channel_frequency_correlation():
    """Adjacent subcarriers are correlated, far ones less — the DFT of a
    short tapped-delay line, not i.i.d. redraws per subcarrier."""
    ofdm = OFDMConfig(n_subcarriers=16, n_taps=2)
    h = generate_wideband_channels(jax.random.PRNGKey(2), CFG, ofdm, 8)
    v = np.asarray(h).reshape(ofdm.S, -1)

    def corr(i, j):
        a, b = v[i], v[j]
        return abs(np.vdot(a, b)) / (np.linalg.norm(a) * np.linalg.norm(b))

    near = np.mean([corr(s, s + 1) for s in range(ofdm.S - 1)])
    far = corr(0, ofdm.S // 2)
    assert near > 0.8, near
    assert far < near, (far, near)


def test_calibrator_caches_and_gains_vary(wideband):
    ens, cal, specs = wideband
    assert cal.cache_sizes[0] == ens.S
    # Repeated calls hit the cache (same objects back).
    again = cal.specs_for(ens)
    assert all(a is b for a, b in zip(specs, again))
    # Beamspace statistics drift across the band -> per-subcarrier gains.
    assert len({s.w_gain for s in specs}) > 1


def test_vp_param_search_cached_and_sane(wideband):
    ens, cal, _ = wideband
    fmt = cal.search_vp_format(0, ens.w_beam[0], M=7, E=2)
    assert fmt is cal.search_vp_format(0, ens.w_beam[0], M=7, E=2)
    assert fmt.M == 7 and fmt.K == 4
    # Sec. II-D endpoint rules against the base FXP(12, 11) grid.
    assert fmt.max_f == 11 and fmt.min_f == 7 - (12 - 11) == 6


def test_execution_paths_bitidentical(wideband):
    ens, _, specs = wideband
    s_flat = equalize_wideband(specs, ens.w_beam, ens.y_beam, how="flat")
    s_vmap = equalize_wideband(specs, ens.w_beam, ens.y_beam, how="vmap")
    s_shard = equalize_wideband(specs, ens.w_beam, ens.y_beam,
                                how="shard_map")
    assert s_flat.shape == (ens.S, 16, CFG.U)
    np.testing.assert_array_equal(np.asarray(s_flat), np.asarray(s_vmap))
    np.testing.assert_array_equal(np.asarray(s_flat), np.asarray(s_shard))


def test_interpret_kernel_matches_ref(wideband):
    ens, _, specs = wideband
    s_ref = equalize_wideband(specs[:2], ens.w_beam[:2], ens.y_beam[:2])
    s_int = equalize_wideband(specs[:2], ens.w_beam[:2], ens.y_beam[:2],
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_int))


def test_wideband_nmse_close_to_float(wideband):
    """B-VP quantization stays a small perturbation of float LMMSE over
    the whole band (the paper's 'no noticeable degradation' claim)."""
    ens, _, specs = wideband
    s_vp = equalize_wideband(specs, ens.w_beam, ens.y_beam)
    s_float = equalize(ens.w_beam, ens.y_beam)
    nmse_vp = wideband_nmse(s_vp, ens.s)
    nmse_float = wideband_nmse(s_float, ens.s)
    assert nmse_vp < 5 * nmse_float, (nmse_vp, nmse_float)
    assert wideband_ber(s_vp, ens.bits) <= wideband_ber(s_float, ens.bits) \
        + 0.01


def test_spec_count_and_format_validation(wideband):
    ens, _, specs = wideband
    with pytest.raises(ValueError, match="one spec per subcarrier"):
        equalize_wideband(specs[:-1], ens.w_beam, ens.y_beam)
    import dataclasses
    from repro.core import VPFormat
    rogue = dataclasses.replace(specs[1], w_vp=VPFormat(7, (11, 9, 8, 6)))
    with pytest.raises(ValueError, match="static format"):
        equalize_wideband([specs[0], rogue] + list(specs[2:]),
                          ens.w_beam, ens.y_beam)
