"""Property-based conformance suite for the core VP format layer.

Pins the algebra of the paper's Sec. II number format over RANDOM legal
(M, E, f) configurations — not just the Table-I formats the rest of the
suite exercises:

  * round-trip exactness: any value on the VP grid survives
    float -> FXP -> VP -> float bit-for-bit (VP multiplication being
    exact, eq. 1, rests on this);
  * truncation semantics: fxp2vp drops LSBs by arithmetic shift, so
    quantization is a FLOOR on the selected local grid — q(x) <= x and
    -q(-x) >= x bracket the FXP value within one local step (the
    hardware's two's-complement truncation is exactly this asymmetry);
  * sign symmetry on representable values (where no truncation happens
    and the significand avoids the asymmetric -2^(M-1) endpoint);
  * monotonicity: quantization never reorders inputs;
  * dynamic-range coverage vs FXP: a VP(M, f) with E index bits beats
    the same-total-bitwidth FXP(M+E) dynamic range whenever the exponent
    spread exceeds E (the paper's headline claim), and saturates within
    one coarse step of the reference FXP(W, F) ceiling.

Runs under real `hypothesis` when installed, else under the functional
fallback in tests/_minihypothesis.py (same strategies API).
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    FXPFormat,
    VPFormat,
    default_vp_format,
    fxp_quantize,
    fxp2vp,
    vp_fake_quant,
    vp_to_float,
)


def _legal_config(W, M, E, F_off, no_overflow=False, max_gap=None):
    """Map free integers onto a legal (fxp, vp) pair or None.

    `no_overflow` additionally requires the Sec. II-D rule
    W - F == M - min(f) (formats violating it — every E=0 format with
    M < W — saturate large values and void the bracket/coverage claims).
    `max_gap` bounds adjacent exponent-list gaps: quantization has dead
    zones (and loses monotonicity) when f_k - f_{k+1} > M - 1, exactly
    as in the hardware circuit.
    """
    if M >= W:
        return None
    fxp = FXPFormat(W, W - 1 - F_off)
    try:
        vp = default_vp_format(fxp, M, E)
    except ValueError:
        return None
    if no_overflow and (fxp.W - fxp.F) != (vp.M - vp.min_f):
        return None
    if max_gap is not None and vp.K > 1:
        if max(a - b for a, b in zip(vp.f, vp.f[1:])) > max_gap:
            return None
    return fxp, vp


CONFIG = dict(
    W=st.integers(6, 16),
    M=st.integers(4, 10),
    E=st.integers(0, 3),
    F_off=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)


def _representable(rng, fxp, vp, n=512, avoid_lo=False):
    """Random exact VP values inside the FXP range (float32-exact)."""
    lo = vp.raw_min + (1 if avoid_lo else 0)
    m = rng.integers(lo, vp.raw_max + 1, n)
    i = rng.integers(0, vp.K, n)
    v = m * 2.0 ** (-np.asarray(vp.f)[i])
    v = v[np.abs(v) <= fxp.max]
    return v.astype(np.float32)


@given(**CONFIG)
@settings(max_examples=40, deadline=None)
def test_roundtrip_exact_on_representable(W, M, E, F_off, seed):
    """float -> FXP -> VP -> float is the identity on the VP grid."""
    cfg = _legal_config(W, M, E, F_off)
    if cfg is None:
        return
    fxp, vp = cfg
    v = _representable(np.random.default_rng(seed), fxp, vp)
    if v.size == 0:
        return
    m, i = fxp2vp(fxp_quantize(jnp.asarray(v), fxp), fxp, vp)
    back = np.asarray(vp_to_float(m, i, vp))
    np.testing.assert_array_equal(back, v)


@given(**CONFIG)
@settings(max_examples=40, deadline=None)
def test_truncation_floor_ceil_bracket(W, M, E, F_off, seed):
    """q(x) <= x_fxp <= -q(-x), gap at most one LOCAL resolution step.

    The hardware drops LSBs by arithmetic shift (floor towards -inf), so
    negating the input flips the truncation direction; the two quantized
    values bracket the FXP-rounded input within the coarser of the two
    selected steps 2^-f_i.  Requires the no-overflow rule — saturating
    formats clamp instead of truncate.
    """
    cfg = _legal_config(W, M, E, F_off, no_overflow=True)
    if cfg is None:
        return
    fxp, vp = cfg
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-1, 1, 1024) * fxp.max * 0.98).astype(np.float32)
    raw = fxp_quantize(jnp.asarray(x), fxp)
    x_fxp = np.asarray(raw, np.float64) * 2.0 ** (-fxp.F)
    q_pos = np.asarray(vp_fake_quant(jnp.asarray(x), fxp, vp), np.float64)
    q_neg = np.asarray(vp_fake_quant(jnp.asarray(-x), fxp, vp), np.float64)
    _, i_pos = fxp2vp(raw, fxp, vp)
    _, i_neg = fxp2vp(fxp_quantize(jnp.asarray(-x), fxp), fxp, vp)
    f = np.asarray(vp.f)
    step = np.maximum(2.0 ** -f[np.asarray(i_pos)],
                      2.0 ** -f[np.asarray(i_neg)])
    assert (q_pos <= x_fxp + 1e-12).all(), "floor exceeded the input"
    assert (-q_neg >= x_fxp - 1e-12).all(), "ceil fell below the input"
    assert ((-q_neg - q_pos) <= step + 1e-12).all(), "bracket wider than 1 ulp"


@given(**CONFIG)
@settings(max_examples=40, deadline=None)
def test_sign_symmetry_on_representable(W, M, E, F_off, seed):
    """q(-v) == -q(v) for exact values avoiding the -2^(M-1) endpoint.

    Two's complement is asymmetric at raw_min (its negation is not
    representable), so symmetry is claimed — and holds exactly — on the
    symmetric sub-grid.
    """
    cfg = _legal_config(W, M, E, F_off)
    if cfg is None:
        return
    fxp, vp = cfg
    v = _representable(np.random.default_rng(seed), fxp, vp, avoid_lo=True)
    if v.size == 0:
        return
    q_pos = np.asarray(vp_fake_quant(jnp.asarray(v), fxp, vp))
    q_neg = np.asarray(vp_fake_quant(jnp.asarray(-v), fxp, vp))
    np.testing.assert_array_equal(q_neg, -q_pos)


@given(**CONFIG)
@settings(max_examples=40, deadline=None)
def test_quantization_monotone(W, M, E, F_off, seed):
    """Sorted inputs stay sorted after VP fake-quant (no reordering).

    Holds whenever adjacent exponent options overlap (gap <= M - 1) and
    saturation clamps at the ends (no-overflow rule) — a wider gap opens
    a dead zone where values just past the fine range truncate below the
    fine-range ceiling, in the circuit as much as here.
    """
    cfg = _legal_config(W, M, E, F_off, no_overflow=True, max_gap=M - 1)
    if cfg is None:
        return
    fxp, vp = cfg
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(-1, 1, 1024) * fxp.max * 1.1).astype(np.float32)
    q = np.asarray(vp_fake_quant(jnp.asarray(x), fxp, vp))
    assert (np.diff(q) >= 0).all(), "quantization reordered inputs"


@given(**CONFIG)
@settings(max_examples=40, deadline=None)
def test_dynamic_range_vs_fxp(W, M, E, F_off, seed):
    """VP dynamic range vs fixed point (the paper's headline claim).

    (a) Against the same-total-bitwidth FXP(M+E, max_f): whenever the
        exponent spread max_f - min_f exceeds E, the VP format covers a
        STRICTLY larger max/resolution ratio at equal storage bits.
    (b) Against the reference FXP(W, F) grid it quantizes: the VP ceiling
        sits within one coarse step 2^-min_f of the FXP ceiling (the
        Sec. II-D no-overflow rule leaves at most one coarse ulp on the
        table).
    """
    del seed
    cfg = _legal_config(W, M, E, F_off, no_overflow=True)
    if cfg is None:
        return
    fxp, vp = cfg
    # (a) equal-bitwidth comparison: DR = max / resolution.
    dr_vp = vp.max / vp.resolution
    fxp_same_bits = FXPFormat(M + vp.E, vp.max_f)
    dr_fxp = fxp_same_bits.max / fxp_same_bits.scale
    if vp.max_f - vp.min_f > vp.E:
        assert dr_vp > dr_fxp, (
            f"{vp} DR {dr_vp:.3g} <= FXP({M + vp.E}) DR {dr_fxp:.3g}")
    # (b) coverage of the reference grid.
    assert vp.max <= fxp.max + 1e-12
    assert fxp.max - vp.max < 2.0 ** (-vp.min_f), (
        f"{vp} saturates more than one coarse step below {fxp}")


@given(seed=st.integers(0, 2**31 - 1), M=st.sampled_from([5, 7, 9]))
@settings(max_examples=20, deadline=None)
def test_vpformat_validation_rejects_illegal_lists(seed, M):
    """Constructor invariants: |f| power of two, descending order."""
    rng = np.random.default_rng(seed)
    f3 = tuple(sorted(rng.choice(20, 3, replace=False) - 5, reverse=True))
    try:
        VPFormat(M, f3)
        assert False, "|f|=3 accepted"
    except ValueError:
        pass
    lo, hi = sorted(rng.choice(20, 2, replace=False) - 5)
    try:
        VPFormat(M, (int(lo), int(hi)))
        assert False, "ascending list accepted"
    except ValueError:
        pass
