"""Fault-tolerance suite: heartbeats/eviction, straggler detection,
elastic re-mesh under shrinking host sets, crash-restart containment,
and checkpoint-corruption detection + fallback.

The controller/heartbeat tests moved here from test_substrate.py when
PR 10 grew the FT surface; substrate keeps the training-loop and
checkpoint-equality coverage.
"""
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (
    CheckpointCorruptError, CheckpointManager, FaultToleranceController,
    FTConfig, run_with_restarts,
)


# ---------------------------------------------------------------------------
# controller: heartbeats / eviction / stragglers


def test_ft_heartbeats_and_eviction():
    ctl = FaultToleranceController(4, FTConfig(dead_after=2))
    for h in range(4):
        ctl.heartbeat(h, 1.0)
    assert ctl.healthy() == [0, 1, 2, 3]
    # host 2 stops beating
    for _ in range(3):
        for h in (0, 1, 3):
            ctl.heartbeat(h, 1.0)
        ctl.tick()
    assert 2 not in ctl.healthy()
    assert ctl.topology_changed([0, 1, 2, 3])


def test_ft_straggler_detection():
    ctl = FaultToleranceController(4, FTConfig(straggler_factor=2.0))
    for _ in range(12):
        for h in range(4):
            ctl.heartbeat(h, 5.0 if h == 1 else 1.0)
        ctl.tick()
    assert 1 not in ctl.healthy()
    assert 0 in ctl.healthy()


def test_ft_straggler_recovery():
    """A straggler that speeds back up (flaky link settles) rejoins the
    healthy set once its EMA decays under the threshold."""
    ctl = FaultToleranceController(4, FTConfig(straggler_factor=2.0,
                                               ema=0.5))
    for _ in range(10):
        for h in range(4):
            ctl.heartbeat(h, 8.0 if h == 1 else 1.0)
        ctl.tick()
    assert 1 not in ctl.healthy()
    for _ in range(20):
        for h in range(4):
            ctl.heartbeat(h, 1.0)
        ctl.tick()
    assert 1 in ctl.healthy()


def test_ft_heartbeat_revives_missed_count():
    """Missed-beat aging resets on any heartbeat BEFORE eviction; a
    host that skips dead_after-1 rounds then beats stays healthy."""
    ctl = FaultToleranceController(2, FTConfig(dead_after=3))
    for h in range(2):
        ctl.heartbeat(h, 1.0)
    for _ in range(3):          # host 1 silent for dead_after rounds...
        ctl.heartbeat(0, 1.0)
        ctl.tick()
    assert 1 in ctl.healthy()   # ...but not yet PAST dead_after
    ctl.heartbeat(1, 1.0)       # beats just in time
    ctl.tick()
    assert 1 in ctl.healthy()


def test_ft_all_hosts_dead():
    ctl = FaultToleranceController(2, FTConfig(dead_after=1))
    for h in range(2):
        ctl.heartbeat(h, 1.0)
    for _ in range(3):
        ctl.tick()
    assert ctl.healthy() == []
    with pytest.raises(RuntimeError):
        ctl.propose_mesh(chips_per_host=64, model_axis=16)


# ---------------------------------------------------------------------------
# elastic re-mesh


def test_ft_elastic_mesh_proposal():
    ctl = FaultToleranceController(8)
    for h in range(8):
        ctl.heartbeat(h, 1.0)
    # lose 3 of 8 hosts (each 64 chips): 5*64 = 320 chips, model=16
    for h in (5, 6, 7):
        ctl.hosts[h].alive = False
    pods, data, model = ctl.propose_mesh(chips_per_host=64, model_axis=16)
    assert model == 16
    assert pods * data * model <= 320
    assert data & (data - 1) == 0  # power of two


def test_ft_elastic_mesh_shrinking_sequence():
    """Hosts die one by one; every proposal must fit the survivors,
    keep the model axis intact, monotonically shrink, and bump the
    generation each transition — until the fleet can no longer hold one
    model shard, which must raise instead of proposing a broken mesh."""
    ctl = FaultToleranceController(8)
    for h in range(8):
        ctl.heartbeat(h, 1.0)
    prev_data = None
    gens = []
    for dead in range(0, 7):          # kill hosts 7,6,...,1 in turn
        if dead:
            ctl.hosts[8 - dead].alive = False
        n_chips = len(ctl.healthy()) * 8
        if n_chips < 8:
            break
        pods, data, model = ctl.propose_mesh(chips_per_host=8,
                                             model_axis=8)
        assert model == 8
        assert pods * data * model <= n_chips
        assert data & (data - 1) == 0
        if prev_data is not None:
            assert data <= prev_data
        prev_data = data
        gens.append(ctl.generation)
    assert gens == sorted(gens) and len(set(gens)) == len(gens)
    # one host of 4 chips left cannot hold an 8-wide model axis
    for h in range(1, 8):
        ctl.hosts[h].alive = False
    with pytest.raises(RuntimeError):
        ctl.propose_mesh(chips_per_host=4, model_axis=8)


# ---------------------------------------------------------------------------
# crash containment


def test_run_with_restarts():
    calls = []

    def loop(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("simulated node failure")
        return "done"

    assert run_with_restarts(loop, max_restarts=3) == "done"
    assert calls == [0, 1, 2]


def test_run_with_restarts_exhaustion():
    """A loop that never recovers re-raises after max_restarts+1
    attempts — crashes are contained, not swallowed."""
    calls = []

    def loop(attempt):
        calls.append(attempt)
        raise RuntimeError("hard down")

    with pytest.raises(RuntimeError, match="hard down"):
        run_with_restarts(loop, max_restarts=2)
    assert calls == [0, 1, 2]


def test_run_with_restarts_non_runtime_error_propagates():
    """Only RuntimeError (the simulated node-failure channel) restarts;
    a programming error must fail fast on the first attempt."""
    calls = []

    def loop(attempt):
        calls.append(attempt)
        raise ValueError("bug, not a node failure")

    with pytest.raises(ValueError):
        run_with_restarts(loop, max_restarts=3)
    assert calls == [0]


# ---------------------------------------------------------------------------
# checkpoint integrity


def _save_steps(mgr, steps):
    # x is deliberately KB-sized so corruption tests can flip bytes
    # deep inside the array payload (not the zip/npy headers, which
    # would make np.load itself fail rather than the checksum).
    for s in steps:
        mgr.save(s, {"x": jnp.ones((1024,)) * s, "y": jnp.zeros((3,))},
                 extra={"data_index": s})


TEMPLATE = {"x": jnp.zeros((1024,)), "y": jnp.zeros((3,))}


def test_ckpt_manifest_has_checksums():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        _save_steps(mgr, [1])
        with open(os.path.join(d, "step_1", "manifest.json")) as f:
            manifest = json.load(f)
        assert "arrays.npz" in manifest["files"]
        assert len(manifest["files"]["arrays.npz"]) == 64  # sha256 hex
        mgr.verify(1)  # intact checkpoint verifies clean


def test_ckpt_corruption_detected_and_fallback():
    """Flip bytes in the newest checkpoint: restore() must refuse it,
    restore_latest() must fall back to the previous intact step."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=5, async_save=False)
        _save_steps(mgr, [1, 2])
        npz = os.path.join(d, "step_2", "arrays.npz")
        with open(npz, "r+b") as f:
            f.seek(2000)                   # inside x's array payload
            f.write(b"\xde\xad\xbe\xef")
        # the checksum fails CLEANLY before np.load would trip over the
        # npz's own CRC with an obscure BadZipFile mid-restore
        with pytest.raises(CheckpointCorruptError, match="checksum"):
            mgr.restore(2, TEMPLATE)
        res = mgr.restore_latest(TEMPLATE)
        assert res is not None
        tree, manifest, step = res
        assert step == 1
        assert manifest["extra"]["data_index"] == 1
        np.testing.assert_array_equal(np.asarray(tree["x"]),
                                      np.ones((1024,)))


def test_ckpt_missing_file_detected():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        _save_steps(mgr, [1])
        os.remove(os.path.join(d, "step_1", "arrays.npz"))
        with pytest.raises(CheckpointCorruptError, match="missing"):
            mgr.verify(1)


def test_ckpt_all_corrupt_returns_none():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        _save_steps(mgr, [1])
        with open(os.path.join(d, "step_1", "arrays.npz"), "r+b") as f:
            f.seek(2000)
            f.write(b"\x00\x00\x00\x01")
        assert mgr.restore_latest(TEMPLATE) is None


def test_ckpt_pre_checksum_manifest_still_restores():
    """Manifests written before the integrity field verify trivially —
    old checkpoints keep restoring."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        _save_steps(mgr, [1])
        mpath = os.path.join(d, "step_1", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        del manifest["files"]
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        tree, m = mgr.restore(1, TEMPLATE)
        assert m["extra"]["data_index"] == 1
        np.testing.assert_array_equal(np.asarray(tree["x"]),
                                      np.ones((1024,)))
