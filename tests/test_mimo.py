"""MIMO application tests: statistical reproduction of the paper's claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mimo import (
    ChannelConfig, generate_channels, dft_matrix, to_beamspace,
    lmmse_matrix, equalize, table1_specs,
)
from repro.mimo.sim import (
    make_ensemble, pdf_stats, nmse_vs_bitwidth, bitwidth_gap,
    ber_float, ber_quantized, calibrate_specs, qam16_mod, qam16_demod_hard,
)
from repro.mimo import cspade


@pytest.fixture(scope="module")
def ensemble():
    return make_ensemble(
        jax.random.PRNGKey(0), ChannelConfig(), 1500, snr_db=20.0)


@pytest.fixture(scope="module")
def ensemble_low_snr():
    return make_ensemble(
        jax.random.PRNGKey(7), ChannelConfig(), 4000, snr_db=2.0)


def test_dft_unitary():
    f = dft_matrix(64)
    np.testing.assert_allclose(
        np.asarray(f @ f.conj().T), np.eye(64), atol=1e-5)


def test_qam_roundtrip():
    s, bits = qam16_mod(jax.random.PRNGKey(3), (512,))
    np.testing.assert_array_equal(
        np.asarray(qam16_demod_hard(s)), np.asarray(bits))
    # unit average energy
    assert abs(float(jnp.mean(jnp.abs(s) ** 2)) - 1.0) < 0.05


def test_channel_normalization(ensemble):
    # E[|h_bu|^2] ~ 1 per entry
    p = float(jnp.mean(jnp.abs(ensemble.h_ant) ** 2))
    assert 0.8 < p < 1.2, p


def test_beamspace_statistically_equivalent(ensemble):
    """Unitary F => float equalization identical in both domains (eq. 3)."""
    s_ant = equalize(ensemble.w_ant, ensemble.y_ant)
    s_beam = equalize(ensemble.w_beam, ensemble.y_beam)
    np.testing.assert_allclose(
        np.asarray(s_ant), np.asarray(s_beam), atol=2e-3)


def test_fig7_beamspace_is_spiky(ensemble):
    """Beamspace signals have much heavier tails (higher kurtosis/PAPR)."""
    k_y_ant = pdf_stats(ensemble.y_ant)["kurtosis"]
    k_y_beam = pdf_stats(ensemble.y_beam)["kurtosis"]
    k_w_ant = pdf_stats(ensemble.w_ant)["kurtosis"]
    k_w_beam = pdf_stats(ensemble.w_beam)["kurtosis"]
    assert k_y_beam > k_y_ant + 3
    assert k_w_beam > k_w_ant + 20


def test_fig8_nmse_monotone_and_gap(ensemble):
    """NMSE halves ~4x per bit; beamspace needs ~1 extra bit (paper: 1.2)."""
    nm = nmse_vs_bitwidth(ensemble)
    for dom in ("antenna", "beamspace"):
        vals = [nm[dom][w] for w in sorted(nm[dom])]
        assert all(a > b for a, b in zip(vals, vals[1:]))  # monotone down
    for w in nm["antenna"]:
        assert nm["beamspace"][w] > nm["antenna"][w]       # beamspace worse
    gap = bitwidth_gap(nm)
    assert 0.5 < gap < 2.0, gap  # paper: ~1.2 ("1-to-2 bits" in Sec. IV-C)


def test_table1_ber_no_visible_gap(ensemble_low_snr):
    """BER of each quantized design tracks float LMMSE (paper Sec. IV-C)."""
    ens = ensemble_low_snr
    specs = calibrate_specs(table1_specs(), ens)
    ref_ant = ber_float(ens, False)
    ref_beam = ber_float(ens, True)
    assert ref_beam > 1e-3  # measurable BER at this SNR
    for spec in specs:
        ref = ref_beam if spec.beamspace else ref_ant
        got = ber_quantized(ens, spec)
        # "no visible gap": within 15% relative of the float BER.
        assert got < ref * 1.15 + 1e-4, (spec.name, got, ref)


def test_bvp_matches_bfxp_accuracy(ensemble_low_snr):
    """The headline: 7-bit-significand VP matches the 9/12-bit FXP design."""
    ens = ensemble_low_snr
    specs = {s.name: s for s in calibrate_specs(table1_specs(), ens)}
    ber_bfxp = ber_quantized(ens, specs["B-FXP"])
    ber_bvp = ber_quantized(ens, specs["B-VP"])
    assert ber_bvp < ber_bfxp * 1.1 + 1e-4, (ber_bvp, ber_bfxp)


def test_cspade_muting_rate_and_calibration(ensemble):
    w, y = ensemble.w_beam, ensemble.y_beam
    tw, ty = cspade.calibrate_thresholds(w, y, target_rate=0.5)
    r = float(cspade.muting_rate(w, y, tw, ty))
    assert 0.4 < r < 0.6, r
    # Beamspace mutes far more than antenna domain at the same thresholds
    # would for its own calibrated 50% point — sanity: antenna-domain rate
    # with beamspace thresholds differs strongly from 0.5.
    r_ant = float(cspade.muting_rate(ensemble.w_ant, ensemble.y_ant, tw, ty))
    assert abs(r_ant - r) > 0.05


def test_lmmse_identity_high_snr():
    """With tiny noise, W ~ pseudo-inverse: W H ~ I."""
    h = generate_channels(jax.random.PRNGKey(5), ChannelConfig(), 8)
    w = lmmse_matrix(h, 1e-6)
    prod = np.asarray(w @ h)
    eye = np.broadcast_to(np.eye(8), prod.shape)
    np.testing.assert_allclose(prod, eye, atol=1e-2)
