"""Pallas kernel validation: interpret=True (kernel body on CPU) vs ref.py.

Sweeps shapes (tile-aligned and ragged), formats, and block sizes; asserts
bit-exact (quant) / allclose (matmul) agreement with the pure-jnp oracles.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FXPFormat, VPFormat, vp_quantize
from repro.kernels import ops, ref

Y_FXP, Y_VP = FXPFormat(9, 1), VPFormat(7, (1, -1))
W_FXP, W_VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))

SHAPES = [(256, 256), (512, 256), (64, 128), (100, 70), (300, 513)]


def rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    # Heavy-tailed (high-dynamic-range) stimuli, like beamspace signals.
    x = rng.standard_t(df=2, size=shape).astype(np.float32)
    return jnp.asarray(np.clip(x, -8, 8) * scale)


@pytest.mark.parametrize("shape", SHAPES)
def test_vp_quant_kernel_bitexact(shape):
    x = rand(shape, 1.0, 0)
    m_k, i_k = ops.vp_quant(x, Y_FXP, Y_VP, interpret=True)
    m_r, i_r = ref.vp_quant_ref(x, Y_FXP, Y_VP)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vp_dequant_kernel_exact(shape, dtype):
    x = rand(shape, 0.9, 1)
    t = vp_quantize(x, W_FXP, W_VP)
    out_k = ops.vp_dequant(t.m, t.i, W_VP, dtype, interpret=True)
    out_r = ref.vp_dequant_ref(t.m, t.i, W_VP, dtype)
    np.testing.assert_array_equal(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32))


@pytest.mark.parametrize("mkn", [(256, 256, 256), (512, 256, 256),
                                 (100, 300, 50), (257, 129, 65)])
def test_vp_matmul_kernel_vs_ref(mkn):
    M, K, N = mkn
    a = rand((M, K), 0.9, 2)
    b = rand((K, N), 0.02, 3)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)
    out_k = ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP, interpret=True)
    out_r = ref.vp_matmul_ref(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_vp_matmul_accuracy_vs_fxp():
    """The paper's accuracy claim in miniature: a 7-bit-significand VP
    matmul on high-dynamic-range data is ~wide-FXP(9/12) accurate, and
    orders of magnitude better than an equal-width FXP(7) matmul."""
    from repro.core import fxp_quantize_value

    M, K, N = 256, 512, 256
    # Scales matched to the Table I formats' dynamic ranges: y-like values
    # span +-100 (heavy-tailed), W-like entries are small.
    a = rand((M, K), 10.0, 4)
    b = rand((K, N), 0.008, 5)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)
    out = np.asarray(ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP,
                                   interpret=True))
    want = np.asarray(a) @ np.asarray(b)

    def nmse(x):
        return np.mean((x - want) ** 2) / np.mean(want ** 2)

    nmse_vp = nmse(out)
    # Equal-significand-width pure FXP baseline (7-bit operands).
    o7 = np.asarray(fxp_quantize_value(a, FXPFormat(7, 0))) @ np.asarray(
        fxp_quantize_value(b, FXPFormat(7, 6)))
    # Wide FXP baseline (the B-FXP design: 9/12-bit operands).
    o_wide = np.asarray(fxp_quantize_value(a, Y_FXP)) @ np.asarray(
        fxp_quantize_value(b, W_FXP))
    assert nmse_vp < 1e-3, nmse_vp
    assert nmse_vp < nmse(o7) / 50, (nmse_vp, nmse(o7))
    assert nmse_vp < nmse(o_wide) * 10, (nmse_vp, nmse(o_wide))


def test_vp_matmul_cspade_skip():
    """Muted tile-pairs (both operands quiet) contribute zero, others exact."""
    M = K = N = 512
    bm = bk = bn = 256
    a = rand((M, K), 0.9, 6)
    b = rand((K, N), 0.02, 7)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)
    a_deq = ref.vp_dequant_ref(ta.m, ta.i, Y_VP)
    b_deq = ref.vp_dequant_ref(tb.m, tb.i, W_VP)
    a_act, b_act = ref.cspade_tile_masks(
        a_deq, b_deq, bm, bk, bn, thresh_a=0.5, thresh_b=0.02)
    out_k = ops.vp_matmul(
        ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP,
        a_act=a_act, b_act=b_act, interpret=True)
    out_r = ref.vp_matmul_ref(
        ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP,
        a_act=a_act, b_act=b_act, tiles=(bm, bk, bn))
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mkn", [(256, 512, 256), (128, 256, 384)])
def test_block_vp_matmul_kernel_vs_ref(mkn):
    from repro.core import block_vp_quantize

    M, K, N = mkn
    bk = 256
    a = rand((M, K), 0.9, 8)
    b = rand((K, N), 0.02, 9)
    a_m, a_i = block_vp_quantize(a, Y_FXP, Y_VP, block=bk, axis=-1)
    b_m, b_i = block_vp_quantize(b, W_FXP, W_VP, block=bk, axis=0)
    out_k = ops.block_vp_matmul(
        a_m, a_i, b_m, b_i, Y_VP, W_VP, bk=bk, interpret=True)
    out_r = ref.block_vp_matmul_ref(
        a_m, a_i, b_m, b_i, Y_VP, W_VP, bk=bk)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


@given(
    M=st.sampled_from([64, 256, 300]),
    K=st.sampled_from([128, 256]),
    N=st.sampled_from([128, 256, 131]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=8, deadline=None)
def test_property_vp_matmul_linear(M, K, N, seed):
    """Property: kernel output == dequant(A) @ dequant(B) for random data."""
    a = rand((M, K), 0.7, seed)
    b = rand((K, N), 0.015, seed + 1)
    ta = vp_quantize(a, Y_FXP, Y_VP)
    tb = vp_quantize(b, W_FXP, W_VP)
    out = ops.vp_matmul(ta.m, ta.i, tb.m, tb.i, Y_VP, W_VP, interpret=True)
    want = ref.vp_dequant_ref(ta.m, ta.i, Y_VP) @ ref.vp_dequant_ref(
        tb.m, tb.i, W_VP)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)
