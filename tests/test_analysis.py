"""Static analyzer: soundness against brute force, contracts, VMEM model.

The analyzer's job is to hand out safety certificates, so its own tests
are adversarial: every bound is checked against an independently
constructed worst case (`bitwidth.brute_force_worst_sum`) over random
formats — no false "safe" verdicts (soundness), and the bound is
achieved (tightness, so the certificates are not vacuously conservative).
The autotune-pruning test pins the acceptance criterion that a
VMEM-infeasible candidate tiling is rejected WITHOUT ever being timed.
"""
import json
import os

import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import (
    bitwidth, contracts, rules, srclint, vmem, VPContractError,
)
from repro.core import FXPFormat, VPFormat
from repro.kernels import autotune

Y_VP, W_VP = VPFormat(7, (1, -1)), VPFormat(7, (11, 9, 7, 6))
Y_FXP, W_FXP = FXPFormat(9, 1), FXPFormat(12, 11)

# Random-but-valid format strategies: M/W small enough that products and
# sums stay in exact-int range for the brute-force oracle (python ints
# are unbounded anyway), f lists descending with power-of-two length and
# every 2^-f an f32 normal.
_f_values = st.integers(min_value=-20, max_value=40)
_vp_formats = st.tuples(
    st.integers(min_value=2, max_value=9),
    st.lists(_f_values, min_size=1, max_size=8, unique=True),
).filter(lambda t: (len(t[1]) & (len(t[1]) - 1)) == 0).map(
    lambda t: VPFormat(t[0], tuple(sorted(t[1], reverse=True))))
_fxp_formats = st.tuples(
    st.integers(min_value=2, max_value=16),
    st.integers(min_value=-4, max_value=20),
).map(lambda t: FXPFormat(*t))
_formats = st.one_of(_vp_formats, _fxp_formats)


# ---------------------------------------------------------------------------
# Bitwidth proofs vs the brute-force oracle
# ---------------------------------------------------------------------------

@given(a=_formats, b=_formats)
@settings(max_examples=80, deadline=None)
def test_product_interval_corrects_paper_width_claim(a, b):
    # Sec. II claims the significand product fits M_a + M_b - 1 signed
    # bits.  The analyzer surfaced the off-by-one: min * min hits
    # +2^(Ma+Mb-2), one past the (Ma+Mb-1)-bit signed max, so the true
    # width is M_a + M_b — while every OTHER product does fit the
    # claimed width (harmless at runtime: vp_mul computes in int32).
    Ma = a.M if isinstance(a, VPFormat) else a.W
    Mb = b.M if isinstance(b, VPFormat) else b.W
    iv = bitwidth.product_interval(a, b)
    assert iv.hi == a.raw_min * b.raw_min == 1 << (Ma + Mb - 2)
    assert iv.signed_bits == Ma + Mb
    # Excluding the single extreme pair restores the paper's width.
    assert bitwidth.Interval(iv.lo, iv.hi - 1).signed_bits == Ma + Mb - 1
    assert iv.mag == bitwidth.brute_force_worst_sum(a, b, 1)


@given(a=_formats, b=_formats,
       k=st.integers(min_value=0, max_value=1 << 20))
@settings(max_examples=120, deadline=None)
def test_int_no_wrap_bound_sound_and_tight(a, b, k):
    for accum, bits in (("int32", 32), ("int16", 16)):
        limit = (1 << (bits - 1)) - 1
        k_max = bitwidth.max_safe_k(a, b, accum)
        # Soundness: everything the analyzer certifies really fits.
        if k <= k_max:
            assert bitwidth.brute_force_worst_sum(a, b, k) <= limit
        proof = bitwidth.analyze_matmul(a, b, k, accum)
        assert proof.safe == (k <= k_max)
        assert proof.wraps == (k > k_max)
        # Tightness: one more accumulation step overflows for real.
        if k_max < (1 << 40):
            assert bitwidth.brute_force_worst_sum(a, b, k_max + 1) > limit


@given(a=_formats, b=_formats,
       k=st.integers(min_value=0, max_value=1 << 20))
@settings(max_examples=120, deadline=None)
def test_f32_exactness_bound_sound_and_tight(a, b, k):
    limit = 1 << bitwidth.F32_MANTISSA_BITS
    k_max = bitwidth.max_safe_k(a, b, "float32")
    worst = bitwidth.brute_force_worst_sum(a, b, k, fine_grid=True)
    if k <= k_max:
        assert worst <= limit
    if k_max < (1 << 40):
        assert bitwidth.brute_force_worst_sum(
            a, b, k_max + 1, fine_grid=True) > limit
    proof = bitwidth.analyze_matmul(a, b, k, "float32")
    assert proof.safe == (k <= k_max)
    assert not proof.wraps  # float accumulators round, never wrap


def test_table1_and_zoo_horizons():
    # The README's quoted numbers: pin them so doc and analyzer agree.
    assert bitwidth.max_safe_k(Y_VP, W_VP, "float32") == 32
    assert bitwidth.max_safe_k(Y_VP, W_VP, "int32") == 524287
    assert bitwidth.max_safe_k(Y_FXP, W_FXP, "float32") == 32
    assert bitwidth.max_safe_k(Y_FXP, W_FXP, "int32") == 4095
    zoo = VPFormat(7, (11, 9, 8, 6))
    assert bitwidth.max_safe_k(zoo, zoo, "float32") == 4
    assert bitwidth.max_safe_k(zoo, zoo, "int32") == 524287


def test_field_and_scale_checks():
    for fmt in (Y_VP, W_VP):
        assert bitwidth.check_pack_fields(fmt) == []
        assert bitwidth.check_scale_exponents(fmt) == []
    # 2^-200 is below the f32 normal range: denormal/zero dequant scale.
    assert bitwidth.check_scale_exponents(VPFormat(7, (200, 0)))
    # 2^+200 overflows to inf.
    assert bitwidth.check_scale_exponents(VPFormat(7, (0, -200)))
    # M + E too wide for any packed word is a pack-field violation.
    assert bitwidth.check_pack_fields(VPFormat(40, (1, -1)))
    # A huge upshift between FXP grid and a VP option wraps int32.
    assert bitwidth.check_quantize_shifts(FXPFormat(12, 0),
                                          VPFormat(7, (40, 0)))
    assert bitwidth.check_quantize_shifts(W_FXP, W_VP) == []


def test_contracts_raise_with_explanation():
    contracts.require_format_serviceable(W_VP)  # canonical: fine
    with pytest.raises(VPContractError, match="denormal"):
        contracts.require_format_serviceable(VPFormat(7, (200, 0)))
    with pytest.raises(VPContractError, match="wraparound"):
        contracts.require_quant_safe(FXPFormat(12, 0), VPFormat(7, (40, 0)))
    # int16 accumulation of 12x12-bit products wraps almost immediately.
    with pytest.raises(VPContractError, match="OVERFLOWS"):
        contracts.require_int_accum_safe(W_FXP, W_FXP, 256, accum="int16")
    # The shipped block-VP config (int32, bk=256) is certified.
    assert contracts.require_int_accum_safe(Y_VP, W_VP, 256)


# ---------------------------------------------------------------------------
# VMEM footprint model + autotune pruning
# ---------------------------------------------------------------------------

def test_vmem_model_monotone_and_bounded():
    fmts = (Y_VP, W_VP)
    small = vmem.kernel_vmem_bytes("vp_matmul", (64, 64, 64), fmts)
    big = vmem.kernel_vmem_bytes("vp_matmul", (256, 256, 256), fmts)
    assert small and big and small < big
    # The shipped default tilings all fit the real 16 MiB budget...
    for kernel, fmtseq in [("vp_matmul", fmts), ("vp_matmul_packed", fmts),
                           ("vp_dequant_matmul", (W_VP,)),
                           ("vp_quant_matmul", fmts),
                           ("block_vp_matmul_bk256", fmts)]:
        ok, need = vmem.vmem_feasible(kernel, (256, 256, 256), fmtseq,
                                      (4096, 4096, 4096))
        assert ok, (kernel, need)
    # ...and absurd tiles do not.
    ok, need = vmem.vmem_feasible("vp_matmul", (2048, 2048, 2048), fmts)
    assert not ok and need > vmem.vmem_budget_bytes()


def test_vmem_unknown_kernel_never_pruned():
    assert vmem.kernel_vmem_bytes("mystery_kernel", (1 << 20,) * 3) is None
    assert vmem.vmem_feasible("mystery_kernel", (1 << 20,) * 3) \
        == (True, None)


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "12345")
    assert vmem.vmem_budget_bytes() == 12345


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune._caches.pop(path, None)
    return path


def test_autotune_prunes_infeasible_before_timing(tmp_cache, monkeypatch):
    # Acceptance criterion: an over-budget candidate is rejected WITHOUT
    # being timed.  Budget chosen so (64,64,64) fits the
    # vp_dequant_matmul model (~115 KB) and (256,256,256) (~1.8 MB)
    # does not.
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", str(200_000))
    timed = []

    def bench(blocks):
        timed.append(tuple(blocks))

    best = autotune.tune(
        "vp_dequant_matmul", (256, 256, 256), (W_VP,), "interpret",
        bench_fn=bench,
        candidates=[(256, 256, 256), (64, 64, 64)])
    assert best == (64, 64, 64)
    assert (256, 256, 256) not in timed     # pruned, never launched
    assert (64, 64, 64) in timed
    # The pruned-in winner was persisted like any tuned entry.
    key = autotune.make_key(
        "vp_dequant_matmul", (256, 256, 256), (W_VP,), "interpret")
    assert autotune.get_cached(key) == (64, 64, 64)


def test_autotune_all_infeasible_raises(tmp_cache, monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "1000")
    calls = []
    with pytest.raises(RuntimeError, match="VMEM budget"):
        autotune.tune(
            "vp_dequant_matmul", (256, 256, 256), (W_VP,), "interpret",
            bench_fn=lambda b: calls.append(b),
            candidates=[(256, 256, 256), (128, 128, 128)])
    assert calls == []  # nothing was ever timed


# ---------------------------------------------------------------------------
# Source lint
# ---------------------------------------------------------------------------

def test_srclint_rules(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("import os\nimport sys\nprint(sys.path)\n")
    found = srclint.lint_file(str(p), "mod.py")
    assert [f["rule"] for f in found] == ["SL-F401"]
    assert "`os`" in found[0]["detail"]

    init = tmp_path / "__init__.py"
    init.write_text("import os\n")  # re-export files are exempt
    assert srclint.lint_file(str(init), "pkg/__init__.py") == []

    launch = tmp_path / "serve.py"
    launch.write_text("import sys\nassert sys.argv\n")
    found = srclint.lint_file(str(launch), "launch/serve.py")
    assert [f["rule"] for f in found] == ["SL-ASSERT"]

    bad = tmp_path / "bad.py"
    bad.write_text("def f(:\n")
    assert [f["rule"] for f in
            srclint.lint_file(str(bad), "bad.py")] == ["SL-SYNTAX"]


def test_src_tree_is_clean_of_error_findings(tmp_cache):
    # The committed tree must carry ZERO error-severity findings in the
    # non-model checks (model JX-WMAT warns are baselined).  tmp_cache
    # keeps the VM-CACHE audit off the developer's real autotune cache.
    findings = rules.run_all(models=False)
    errors = [f for f in findings if f.severity == "error"]
    assert errors == [], [str(f) for f in errors]
    assert all(f.rule in rules.RULES for f in findings)


def test_baseline_file_matches_loader():
    path = rules.default_baseline_path()
    assert os.path.exists(path)
    accepted = rules.load_baseline(path)
    raw = json.load(open(path))
    assert sorted(accepted) == sorted(raw["accepted"])
    # Baselined keys are rule|where pairs for rules that exist.
    for key in accepted:
        rule, _ = key.split("|", 1)
        assert rule in rules.RULES


# ---------------------------------------------------------------------------
# Serving failure path (the de-asserted smoke check)
# ---------------------------------------------------------------------------

def test_serve_finite_check_raises_not_asserts():
    from repro.launch.serve import _require_finite

    _require_finite(jnp.ones((2, 4)), "prefill")  # finite: no-op
    with pytest.raises(FloatingPointError, match="non-finite decode"):
        _require_finite(jnp.array([1.0, float("nan")]), "decode (x, vp)")
    with pytest.raises(FloatingPointError, match="prefill"):
        _require_finite(jnp.array([float("inf")]), "prefill (x, vp)")
