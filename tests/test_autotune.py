"""Autotuner: heuristic clamping, cache persistence, resolution order.

The autotuner must (a) never tile beyond the padded operand shape — the
small-shape padding fix for the MVM engine's (2U, B) x (B, 2) products —
(b) persist measured winners across processes via the JSON cache, and
(c) resolve explicit blocks > cached entry > heuristic, in that order.
"""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import FXPFormat, VPFormat
from repro.kernels import autotune, ops

W_FXP, W_VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
Y_FXP, Y_VP = FXPFormat(9, 1), VPFormat(7, (1, -1))


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the autotune cache at a fresh per-test file."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    # Drop any in-memory layer for this path across tests.
    autotune._caches.pop(path, None)
    return path


# ---------------------------------------------------------------------------
# Heuristic: shape-clamped defaults
# ---------------------------------------------------------------------------

def test_heuristic_clamps_to_padded_shape():
    # The MVM engine shape: one snug tile per axis, not 256^3.
    assert autotune.heuristic_blocks(16, 64, 2) == (16, 64, 2)
    # Ragged dims round up to the next power of two, never past the base.
    assert autotune.heuristic_blocks(13, 50, 3) == (16, 64, 4)
    # Large dims keep the standard base tile.
    assert autotune.heuristic_blocks(512, 512, 512) == (256, 256, 256)
    assert autotune.heuristic_blocks(512, 512, 512, base=(512,) * 3) \
        == (512, 512, 512)
    # A block never exceeds its padded dimension.
    for dims in [(1, 1, 1), (7, 300, 2), (256, 31, 1000)]:
        b = autotune.heuristic_blocks(*dims)
        for blk, d in zip(b, dims):
            assert blk <= max(256, 1 << (d - 1).bit_length())
            assert blk >= min(d, 1)


def test_ops_default_blocks_small_shapes(tmp_cache):
    """ops with blocks=None run small operands without 256^3 padding and
    match the explicitly-clamped call bit for bit."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_t(2, (16, 64)).clip(-8, 8) * 0.01,
                    jnp.float32)
    b = jnp.asarray(rng.standard_t(2, (64, 2)).clip(-8, 8), jnp.float32)
    got = ops.vp_quant_matmul(
        a, b, W_FXP, W_VP, Y_FXP, Y_VP, interpret=True)
    want = ops.vp_quant_matmul(
        a, b, W_FXP, W_VP, Y_FXP, Y_VP, blocks=(16, 64, 2), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Cache: persistence, round-trip, resolution order
# ---------------------------------------------------------------------------

def test_cache_roundtrip_on_disk(tmp_cache):
    key = autotune.make_key(
        "vp_matmul", (128, 128, 128), (W_VP, Y_VP), "interpret")
    assert autotune.get_cached(key) is None
    autotune.record(key, (64, 128, 32))
    # The file exists and parses back to the same entry...
    with open(tmp_cache) as f:
        on_disk = json.load(f)
    assert on_disk[key] == [64, 128, 32]
    # ... and a COLD in-memory layer (fresh process analogue) re-reads it.
    autotune._caches.pop(tmp_cache, None)
    assert autotune.get_cached(key) == (64, 128, 32)


def test_resolution_order(tmp_cache):
    shape, fmts = (128, 128, 128), (W_VP, Y_VP)
    key = autotune.make_key("vp_matmul", shape, fmts, "interpret")
    # No cache: heuristic.
    assert autotune.resolve_blocks("vp_matmul", shape, fmts, "interpret") \
        == autotune.heuristic_blocks(*shape)
    # Cached entry wins over the heuristic.
    autotune.record(key, (32, 32, 32))
    assert autotune.resolve_blocks("vp_matmul", shape, fmts, "interpret") \
        == (32, 32, 32)
    # Explicit blocks win over everything.
    assert autotune.resolve_blocks(
        "vp_matmul", shape, fmts, "interpret", blocks=(8, 8, 8)) == (8, 8, 8)
    # Different backend/formats/shape = different key = no hit.
    assert autotune.resolve_blocks("vp_matmul", shape, fmts, "native") \
        == autotune.heuristic_blocks(*shape)


def test_corrupt_cache_starts_empty(tmp_cache):
    with open(tmp_cache, "w") as f:
        f.write("{not json")
    autotune._caches.pop(tmp_cache, None)
    assert autotune.get_cached("anything") is None
    # Recording over a corrupt file repairs it.
    autotune.record("k", (1, 2, 3))
    autotune._caches.pop(tmp_cache, None)
    assert autotune.get_cached("k") == (1, 2, 3)


def test_clear_cache(tmp_cache):
    autotune.record("k", (1, 2, 3))
    assert os.path.exists(tmp_cache)
    autotune.clear_cache()
    assert not os.path.exists(tmp_cache)
    assert autotune.get_cached("k") is None


def test_tune_measures_and_persists(tmp_cache):
    """tune() picks the fastest candidate and persists it for resolve."""
    import time

    calls = []

    def bench(blocks):
        calls.append(blocks)
        time.sleep(0.02 if blocks != (16, 64, 2) else 0.0)

    shape, fmts = (16, 64, 2), (W_VP, Y_VP)
    best = autotune.tune(
        "vp_matmul", shape, fmts, "interpret", bench,
        candidates=[(8, 8, 2), (16, 64, 2), (16, 16, 2)], repeats=2)
    assert best == (16, 64, 2)
    assert set(calls) == {(8, 8, 2), (16, 64, 2), (16, 16, 2)}
    # Resolution now hits the tuned entry, including after a cold reload.
    autotune._caches.pop(tmp_cache, None)
    assert autotune.resolve_blocks(
        "vp_matmul", shape, fmts, "interpret") == (16, 64, 2)
    # A second tune() is a pure cache hit: no more bench calls.
    n = len(calls)
    assert autotune.tune(
        "vp_matmul", shape, fmts, "interpret", bench) == (16, 64, 2)
    assert len(calls) == n


def test_tune_survives_failing_candidate(tmp_cache):
    def bench(blocks):
        if blocks == (8, 8, 8):
            raise RuntimeError("does not lower")

    best = autotune.tune(
        "vp_matmul", (32, 32, 32), (W_VP,), "interpret", bench,
        candidates=[(8, 8, 8), (32, 32, 32)], repeats=1)
    assert best == (32, 32, 32)


def test_tune_raises_when_all_candidates_fail(tmp_cache):
    """A broken bench_fn must fail LOUDLY, not persist a fake winner."""
    def bench(blocks):
        raise ValueError("mask grid mismatch")

    with pytest.raises(RuntimeError, match="all 2 feasible candidates failed"):
        autotune.tune(
            "vp_matmul", (32, 32, 32), (W_VP,), "interpret", bench,
            candidates=[(8, 8, 8), (32, 32, 32)], repeats=1)
    # ... and nothing was recorded for the key.
    key = autotune.make_key("vp_matmul", (32, 32, 32), (W_VP,), "interpret")
    assert autotune.get_cached(key) is None


def test_native_backend_floors_to_mosaic_min_tile(tmp_cache):
    """TPU-native heuristic tiles never go below the (8, 128) f32 min
    tile; interpret/ref keep the snug shape clamp."""
    shape, fmts = (16, 64, 2), (W_VP, Y_VP)
    assert autotune.resolve_blocks("vp_matmul", shape, fmts, "interpret") \
        == (16, 64, 2)
    assert autotune.resolve_blocks("vp_matmul", shape, fmts, "native") \
        == (16, 128, 128)
    # Explicit blocks and cached (measured-on-native) entries pass as-is.
    assert autotune.resolve_blocks(
        "vp_matmul", shape, fmts, "native", blocks=(16, 64, 2)) \
        == (16, 64, 2)


def test_record_merges_with_concurrent_writer(tmp_cache):
    """A stale in-memory snapshot must not erase a peer's entries."""
    autotune.record("k1", (1, 1, 1))          # our process writes k1
    # A "peer process" writes k2 directly to disk behind our back.
    with open(tmp_cache) as f:
        data = json.load(f)
    data["k2"] = [2, 2, 2]
    with open(tmp_cache, "w") as f:
        json.dump(data, f)
    # Our stale snapshot records k3 — k2 must survive the write.
    autotune.record("k3", (3, 3, 3))
    autotune._caches.pop(tmp_cache, None)
    assert autotune.get_cached("k1") == (1, 1, 1)
    assert autotune.get_cached("k2") == (2, 2, 2)
    assert autotune.get_cached("k3") == (3, 3, 3)


def _record_worker(path, start, wid, n):
    # Runs in a child process: hammer record() on worker-unique keys.
    os.environ["REPRO_AUTOTUNE_CACHE"] = path
    from repro.kernels import autotune as at
    at._caches.pop(path, None)
    start.wait()
    for i in range(n):
        at.record(f"w{wid}.k{i}", (wid + 1, i + 1, 1))


def test_record_cross_process_writers_lose_no_entries(tmp_cache):
    """N processes hammering record() concurrently: the file ends up with
    the union of every writer's entries (the flock closes the read->
    rename lost-update gap the in-process merge test can't see)."""
    import multiprocessing as mp

    ctx = mp.get_context("fork")
    workers, per = 4, 25
    start = ctx.Event()
    procs = [ctx.Process(target=_record_worker,
                         args=(tmp_cache, start, w, per))
             for w in range(workers)]
    for p in procs:
        p.start()
    start.set()
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    with open(tmp_cache) as f:
        data = json.load(f)
    missing = [f"w{w}.k{i}" for w in range(workers) for i in range(per)
               if f"w{w}.k{i}" not in data]
    assert not missing, f"lost {len(missing)} entries: {missing[:5]}"
    assert data["w0.k0"] == [1, 1, 1]
