"""Tensor-level quantization API: VPTensor round trips, block-VP
invariants, STE gradients, per-layer weight quantization."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FXPFormat, VPFormat, default_vp_format,
    vp_quantize, vp_dequantize, vp_fake_quant, vp_fake_quant_ste,
    block_vp_quantize, block_vp_dequantize,
)
from repro.configs.base import QuantConfig
from repro.models.layers import quantize_weight, qdot, canonical_formats

FXP, VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))


def hdr(shape, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.clip(rng.standard_t(2, shape), -10, 10) * scale * 0.09,
        jnp.float32)


def test_vptensor_roundtrip_matches_fake_quant():
    x = hdr((64, 128), 0)
    t = vp_quantize(x, FXP, VP)
    np.testing.assert_array_equal(
        np.asarray(vp_dequantize(t)), np.asarray(vp_fake_quant(x, FXP, VP)))


def test_vptensor_storage_dtypes():
    t = vp_quantize(hdr((32, 32), 1), FXP, VP)
    assert t.m.dtype == jnp.int8
    assert t.i.dtype == jnp.uint8
    assert int(jnp.max(t.i)) < VP.K


@given(seed=st.integers(0, 1000), M=st.sampled_from([5, 7, 9]),
       E=st.sampled_from([1, 2]))
@settings(max_examples=20, deadline=None)
def test_property_fake_quant_error_bound(seed, M, E):
    """Quantize-dequantize error is bounded by the LOCAL resolution
    2^-f_sel at every element (truncation), never more."""
    vp = default_vp_format(FXP, M, E)
    x = hdr((256,), seed)
    from repro.core import fxp_quantize, fxp2vp
    raw = fxp_quantize(x, FXP)
    m, i = fxp2vp(raw, FXP, vp)
    xq = np.asarray(vp_fake_quant(x, FXP, vp))
    xr = np.asarray(raw, np.float64) * 2.0 ** -FXP.F  # FXP-rounded x
    f_sel = np.asarray([vp.f[k] for k in np.asarray(i)])
    assert (np.abs(xq - xr) < 2.0 ** (-f_sel) + 1e-9).all()


def test_block_vp_no_overflow_and_error():
    x = hdr((16, 512), 3)
    m, i_blk = block_vp_quantize(x, FXP, VP, block=128, axis=-1)
    assert np.abs(np.asarray(m)).max() <= VP.raw_max
    back = block_vp_dequantize(m, i_blk, VP, block=128, axis=-1)
    rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
    assert rel < 0.15, rel
    # block index = max of per-element indices in the block (BFP rule)
    from repro.core import fxp_quantize, fxp2vp
    _, i_elt = fxp2vp(fxp_quantize(x, FXP), FXP, VP)
    i_max = np.asarray(i_elt).reshape(16, 4, 128).max(-1)
    np.testing.assert_array_equal(np.asarray(i_blk), i_max)


def test_ste_gradient_is_identity():
    x = hdr((64,), 4)
    g = jax.grad(lambda v: jnp.sum(vp_fake_quant_ste(v, FXP, VP) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


@pytest.mark.parametrize("mode", ["fxp", "vp", "vp_block"])
def test_quantize_weight_qdot_consistency(mode):
    """quantize_weight + qdot approximates the float matmul for every
    serving mode, with mode-appropriate tolerance."""
    q = QuantConfig(mode=mode, block=64)
    w = hdr((128, 96), 5, scale=0.3)
    x = hdr((8, 128), 6, scale=2.0)
    wq = quantize_weight(w, q)
    got = np.asarray(qdot(x, wq, q))
    want = np.asarray(x @ w)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    tol = {"fxp": 0.05, "vp": 0.05, "vp_block": 0.15}[mode]
    assert rel < tol, (mode, rel)


def test_vp_weight_storage_is_packed():
    """Serving representations, both layouts.

    Default "packed": ONE packed VP word per element (the layout the
    Pallas vp_dequant_matmul kernel consumes directly).  Legacy "planes":
    int8 significands + PACKED index plane (4 indices/byte for E=2)
    => ~10.25 bits/element, kept as the jnp-dequant golden baseline."""
    from repro.core.packing import storage_dtype
    from repro.models.layers import canonical_formats

    q = QuantConfig(mode="vp")
    _, vp = canonical_formats(q)
    w = hdr((256, 64), 7)
    wq = quantize_weight(w, q)
    assert set(wq) == {"w_packed", "scale"}
    assert wq["w_packed"].dtype == storage_dtype(vp)
    assert wq["w_packed"].shape == (256, 64)
    wl = quantize_weight(w, q, layout="planes")
    assert wl["m"].dtype == jnp.int8 and wl["m"].shape == (256, 64)
    assert wl["i_packed"].dtype == jnp.uint8
    assert wl["i_packed"].shape == (64, 64)  # 256/4 packed along d_in
    bits = (wl["m"].size * 8 + wl["i_packed"].size * 8) / w.size
    assert bits <= 10.5, bits