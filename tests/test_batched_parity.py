"""Backend-parity matrix for the truly-batched VP kernel grid.

The batched grid (PR 2) must be a pure FLOP-count optimization: every
cell of the (backend x fusion x engine-mode) matrix below is pinned
BIT-IDENTICAL — same quantize cascades, same f32 tile contractions, so
there is no tolerance anywhere in this file.

  * op level: batched kernels vs per-slice unbatched kernels vs ref
    oracles, including ragged (non-tile-multiple) shapes and G=1;
  * engine level: mode="batched" vs the legacy masked-diagonal fold
    (mode="masked"), fused and unfused, ref and interpret backends,
    n in {1, 3, 8} realizations;
  * CSPADE: batched per-(batch, tile) masks vs the ref muting oracle.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import FXPFormat, VPFormat
from repro.kernels import ops, ref
from repro.mimo import ChannelConfig, table1_specs
from repro.mimo.sim import make_ensemble, calibrate_specs
from repro.mimo.mvm_engine import equalize_vp_kernel, mvm_flops

W_FXP, W_VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
Y_FXP, Y_VP = FXPFormat(9, 1), VPFormat(7, (1, -1))


def _operands(G, M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_t(2, (G, M, K)).clip(-8, 8) * 0.01,
                    jnp.float32)
    b = jnp.asarray(rng.standard_t(2, (G, K, N)).clip(-8, 8), jnp.float32)
    return a, b


@pytest.fixture(scope="module")
def ens_spec():
    ens = make_ensemble(jax.random.PRNGKey(2), ChannelConfig(), 8, 10.0)
    specs = {s.name: s for s in calibrate_specs(table1_specs(), ens)}
    return ens, specs["B-VP"]


# ---------------------------------------------------------------------------
# Op level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 16, 64, 2), (5, 16, 64, 2),
                                   (3, 13, 50, 1)])
@pytest.mark.parametrize("interpret", [None, True],
                         ids=["ref", "interpret"])
def test_batched_fused_equals_unfused_equals_ref(shape, interpret):
    G, M, K, N = shape
    blocks = (16, 64, 2)
    a, b = _operands(G, M, K, N)
    fused = ops.vp_quant_matmul_batched(
        a, b, W_FXP, W_VP, Y_FXP, Y_VP, blocks=blocks, interpret=interpret)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret)
    unfused = ops.vp_matmul_batched(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, blocks=blocks, interpret=interpret)
    oracle = ref.vp_quant_matmul_batched_ref(
        a, b, W_FXP, W_VP, Y_FXP, Y_VP, tiles=blocks)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(oracle))


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
def test_batched_equals_per_slice_unbatched(interpret):
    G, M, K, N = 4, 16, 64, 2
    blocks = (16, 64, 2)
    a, b = _operands(G, M, K, N, seed=3)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret)
    batched = np.asarray(ops.vp_matmul_batched(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, blocks=blocks, interpret=interpret))
    for g in range(G):
        one = np.asarray(ops.vp_matmul(
            a_m[g], a_i[g], b_m[g], b_i[g], W_VP, Y_VP,
            blocks=blocks, interpret=interpret))
        np.testing.assert_array_equal(batched[g], one)


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
def test_batched_cspade_masks_match_oracle(interpret):
    G, M, K, N = 6, 16, 64, 2
    blocks = (16, 64, 2)
    a, b = _operands(G, M, K, N, seed=5)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP)
    a_deq = ref.vp_dequant_ref(a_m, a_i, W_VP)
    b_deq = ref.vp_dequant_ref(b_m, b_i, Y_VP)
    # Aggressive thresholds so some (batch, tile) pairs actually mute.
    ta = float(jnp.quantile(jnp.abs(a_deq).reshape(G, -1).max(1), 0.5))
    tb = float(jnp.quantile(jnp.abs(b_deq).reshape(G, -1).max(1), 0.5))
    a_act, b_act = ref.cspade_tile_masks_batched(a_deq, b_deq, *blocks, ta, tb)
    assert a_act.shape == (G, 1, 1) and b_act.shape == (G, 1, 1)
    got = ops.vp_matmul_batched(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, a_act=a_act, b_act=b_act,
        blocks=blocks, interpret=interpret)
    want = ref.vp_matmul_batched_ref(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, a_act=a_act, b_act=b_act,
        tiles=blocks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_mask_shape_validation():
    G, M, K, N = 2, 16, 64, 2
    a, b = _operands(G, M, K, N)
    bad = jnp.ones((G, 2, 2), jnp.int32)
    with pytest.raises(ValueError, match="CSPADE"):
        ops.vp_matmul_batched(
            *ops.vp_quant(a, W_FXP, W_VP), *ops.vp_quant(b, Y_FXP, Y_VP),
            W_VP, Y_VP, a_act=bad, b_act=bad, blocks=(16, 64, 2))


# ---------------------------------------------------------------------------
# Engine level: batched mode vs the legacy masked-diagonal fold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 3, 8])
@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
def test_engine_batched_bitidentical_to_masked(ens_spec, n, fused, interpret):
    ens, spec = ens_spec
    w, y = ens.w_beam[:n], ens.y_beam[:n]
    s_batched = equalize_vp_kernel(
        spec, w, y, mode="batched", fused=fused, interpret=interpret)
    s_masked = equalize_vp_kernel(
        spec, w, y, mode="masked", fused=fused, interpret=interpret)
    assert s_batched.shape == (n, spec_U(ens))
    np.testing.assert_array_equal(np.asarray(s_batched), np.asarray(s_masked))


def spec_U(ens):
    return ens.w_beam.shape[1]


def test_engine_default_dispatch_bitidentical(ens_spec):
    """The fused=None policy may pick different kernels per mode; values
    must still agree bit for bit."""
    ens, spec = ens_spec
    s_batched = equalize_vp_kernel(spec, ens.w_beam, ens.y_beam,
                                   mode="batched")
    s_masked = equalize_vp_kernel(spec, ens.w_beam, ens.y_beam,
                                  mode="masked")
    np.testing.assert_array_equal(np.asarray(s_batched), np.asarray(s_masked))


def test_engine_rejects_unknown_mode(ens_spec):
    ens, spec = ens_spec
    with pytest.raises(ValueError, match="mode"):
        equalize_vp_kernel(spec, ens.w_beam, ens.y_beam, mode="turbo")


def test_flop_accounting_masked_overhead():
    """The whole point of the batched grid: masked does n x the FLOPs."""
    n, U, B = 16, 8, 64
    assert mvm_flops(n, U, B, "batched") == 8 * n * U * B
    assert mvm_flops(n, U, B, "masked") == n * mvm_flops(n, U, B, "batched")
