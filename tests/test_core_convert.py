"""Bit-exactness and invariant tests for the core VP format.

The arithmetic fxp2vp implementation must be bit-identical to the paper's
Fig. 3 circuit (MSB-equality + LOD + bit-window mux), which we implement
literally in `fxp2vp_bitwindow`.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FXPFormat,
    VPFormat,
    product_format,
    default_vp_format,
    fxp_quantize,
    fxp_to_float,
    fxp2vp,
    fxp2vp_bitwindow,
    vp2fxp,
    vp_to_float,
    vp_mul,
    vp_mul_to_fxp,
    product_scale_lut,
    pack_indices,
    unpack_indices,
)

# The paper's own formats (Table I + figures).
PAPER_CASES = [
    (FXPFormat(8, 1), VPFormat(6, (1, -1))),          # Fig. 2
    (FXPFormat(9, 1), VPFormat(7, (1, -1))),          # Table I, y
    (FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))),  # Table I, W
    # Fig. 4 uses list [3,1,2,0]; that order is legal for VP2FXP but FXP2VP's
    # LOD requires descending (Sec. II-C), so we test the sorted variant.
    (FXPFormat(12, 3), VPFormat(9, (3, 2, 1, 0))),
]


def all_raw_values(fxp):
    return jnp.arange(fxp.raw_min, fxp.raw_max + 1, dtype=jnp.int32)


@pytest.mark.parametrize("fxp,vp", PAPER_CASES)
def test_fxp2vp_matches_bitwindow_oracle(fxp, vp):
    """Arithmetic conversion == literal paper circuit, for EVERY input."""
    raw = all_raw_values(fxp)
    m_a, i_a = fxp2vp(raw, fxp, vp)
    m_b, i_b = fxp2vp_bitwindow(raw, fxp, vp)
    np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))


@pytest.mark.parametrize("fxp,vp", PAPER_CASES)
def test_significand_in_range_and_no_overflow(fxp, vp):
    raw = all_raw_values(fxp)
    m, i, ovf = fxp2vp(raw, fxp, vp, return_overflow=True)
    m, i, ovf = np.asarray(m), np.asarray(i), np.asarray(ovf)
    assert m.min() >= vp.raw_min and m.max() <= vp.raw_max
    assert i.min() >= 0 and i.max() < vp.K
    if fxp.W - fxp.F == vp.M - vp.min_f and fxp.F >= vp.max_f:
        # Sec. II-D no-overflow condition holds -> nothing saturates.
        assert not ovf.any()


@pytest.mark.parametrize("fxp,vp", PAPER_CASES)
def test_precision_loss_bound(fxp, vp):
    """|x - VP(x)| < 2^-f_i (truncation drops LSBs below the selected point),
    and conversion is EXACT whenever the value fits at the selected f_i."""
    raw = all_raw_values(fxp)
    m, i = fxp2vp(raw, fxp, vp)
    x = np.asarray(fxp_to_float(raw, fxp, jnp.float64))
    xq = np.asarray(vp_to_float(m, i, vp, jnp.float64))
    f_sel = np.asarray([vp.f[k] for k in np.asarray(i)])
    err = np.abs(x - xq)
    assert (err < 2.0 ** (-f_sel) + 1e-12).all()
    # Values with few significant bits are exact.
    small = np.abs(np.asarray(raw)) <= vp.raw_max
    if fxp.F <= vp.max_f:
        assert (err[small] == 0).all()


@pytest.mark.parametrize("fxp,vp", PAPER_CASES)
def test_greedy_precision_is_optimal(fxp, vp):
    """The LOD picks the LARGEST f_i that avoids overflow => the error is
    minimal among all valid exponent options."""
    raw = np.asarray(all_raw_values(fxp))
    m, i = map(np.asarray, fxp2vp(raw, fxp, vp))
    x = raw * 2.0 ** (-fxp.F)
    best = np.full_like(x, np.inf)
    for k, fk in enumerate(vp.f):
        s = fxp.F - fk
        mk = raw >> s if s >= 0 else raw << (-s)
        valid = (mk >= vp.raw_min) & (mk <= vp.raw_max)
        errk = np.abs(mk * 2.0 ** (-fk) - x)
        best = np.where(valid, np.minimum(best, errk), best)
    got = np.abs(m * 2.0 ** (-np.asarray([vp.f[k] for k in i])) - x)
    np.testing.assert_allclose(got, best, atol=1e-12)


def test_paper_fig2_examples():
    """Fig. 2: FXP(8,1) -> VP(6,[1,-1]).

    Case 1: 00101100_2 with F=1 => value 22.0 -> 3 equal MSBs? bits are
    0,0,1 -> not all equal -> i=1, upper 6 bits 001011 = 11 -> 11*2^1 = 22. OK
    Case 2: 11110011_2 (two's complement -13 raw) F=1 => -6.5 -> MSBs 1,1,1
    equal -> i=0, lower 6 bits 110011 = -13 -> -13*2^-1 = -6.5 exactly.
    """
    fxp, vp = FXPFormat(8, 1), VPFormat(6, (1, -1))
    raw = jnp.asarray([44, -13], jnp.int32)  # 00101100, 11110011
    m, i = fxp2vp(raw, fxp, vp)
    np.testing.assert_array_equal(np.asarray(i), [1, 0])
    np.testing.assert_array_equal(np.asarray(m), [11, -13])
    np.testing.assert_allclose(
        np.asarray(vp_to_float(m, i, vp)), [22.0, -6.5])


@pytest.mark.parametrize("fxp,vp", PAPER_CASES)
def test_vp2fxp_roundtrip_exact_when_wide_enough(fxp, vp):
    """VP -> FXP back onto the original grid loses nothing beyond the FXP2VP
    truncation: converting the VP value to FXP(W,F) reproduces the VP value
    exactly when F >= all selected f_i."""
    raw = all_raw_values(fxp)
    m, i = fxp2vp(raw, fxp, vp)
    back = vp2fxp(m, i, vp, fxp)
    x_vp = np.asarray(vp_to_float(m, i, vp, jnp.float64))
    x_back = np.asarray(fxp_to_float(back, fxp, jnp.float64))
    if fxp.F >= vp.max_f:
        np.testing.assert_allclose(x_back, x_vp, atol=1e-12)


@given(
    W=st.integers(6, 16),
    M=st.integers(4, 10),
    E=st.integers(0, 3),
    F_off=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_property_random_formats_bitexact(W, M, E, F_off, seed):
    """Hypothesis sweep: arbitrary legal formats, arithmetic == bit circuit."""
    if M >= W:
        return
    F = W - 1 - F_off
    fxp = FXPFormat(W, F)
    try:
        vp = default_vp_format(fxp, M, E)
    except ValueError:
        return
    rng = np.random.default_rng(seed)
    raw = jnp.asarray(
        rng.integers(fxp.raw_min, fxp.raw_max + 1, size=256), jnp.int32)
    m_a, i_a = fxp2vp(raw, fxp, vp)
    m_b, i_b = fxp2vp_bitwindow(raw, fxp, vp)
    np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))


def test_vp_mul_exact():
    """VP multiply == real-value multiply, exactly, for full operand sweeps."""
    fy, vy = FXPFormat(9, 1), VPFormat(7, (1, -1))
    fw, vw = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
    rng = np.random.default_rng(0)
    ra = jnp.asarray(rng.integers(fy.raw_min, fy.raw_max + 1, 512), jnp.int32)
    rb = jnp.asarray(rng.integers(fw.raw_min, fw.raw_max + 1, 512), jnp.int32)
    ma, ia = fxp2vp(ra, fy, vy)
    mb, ib = fxp2vp(rb, fw, vw)
    mp, ip, pfmt = vp_mul(ma, ia, vy, mb, ib, vw)
    want = np.asarray(vp_to_float(ma, ia, vy, jnp.float64)) * np.asarray(
        vp_to_float(mb, ib, vw, jnp.float64))
    got = np.asarray(vp_to_float(mp, ip, pfmt, jnp.float64))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    # Product significand respects the (Ma+Mb-1)-bit bound.
    assert np.abs(np.asarray(mp)).max() <= 2 ** (pfmt.M - 1)
    # LUT path agrees.
    lut = np.asarray(product_scale_lut(vy, vw, jnp.float64))
    np.testing.assert_allclose(np.asarray(mp) * lut[np.asarray(ip)], want)


def test_vp_mul_to_fxp_matches_float_path():
    fy, vy = FXPFormat(9, 1), VPFormat(7, (1, -1))
    fw, vw = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
    out = FXPFormat(24, 12)
    rng = np.random.default_rng(1)
    ra = jnp.asarray(rng.integers(fy.raw_min, fy.raw_max + 1, 256), jnp.int32)
    rb = jnp.asarray(rng.integers(fw.raw_min, fw.raw_max + 1, 256), jnp.int32)
    ma, ia = fxp2vp(ra, fy, vy)
    mb, ib = fxp2vp(rb, fw, vw)
    raw_out = vp_mul_to_fxp(ma, ia, vy, mb, ib, vw, out)
    exact = np.asarray(vp_to_float(ma, ia, vy, jnp.float64)) * np.asarray(
        vp_to_float(mb, ib, vw, jnp.float64))
    got = np.asarray(fxp_to_float(raw_out, out, jnp.float64))
    # out has F=12 >= max product fractional length is 22 -> truncation to
    # 2^-12 grid.
    assert np.max(np.abs(got - exact)) < 2.0 ** (-out.F) + 1e-12


@given(E=st.sampled_from([1, 2, 4]), n_blocks=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_index_packing_roundtrip(E, n_blocks, seed):
    per = 8 // E
    n = per * n_blocks
    rng = np.random.default_rng(seed)
    i = jnp.asarray(rng.integers(0, 1 << E, size=(3, n)), jnp.uint8)
    packed = pack_indices(i, E)
    assert packed.shape == (3, n // per)
    un = unpack_indices(packed, E, n)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(i))


def test_product_format_pairwise_sums():
    a, b = VPFormat(7, (1, -1)), VPFormat(7, (11, 9, 7, 6))
    p = product_format(a, b)
    assert p.M == 13
    assert p.f == (12, 10, 8, 7, 10, 8, 6, 5)
