"""Packed VP words: round-trip exactness and packed-vs-plane bit-identity.

The packed layout (core.packing: sign+significand+index in one int8/int16
word) is a pure storage optimization — every consumer must produce EXACTLY
the bits the two-plane layout produces.  This file pins that:

  * property tests: pack -> unpack round-trips exactly over RANDOM
    VPFormats and random in-range (m, i) planes; `storage_bits` matches
    the packed dtype;
  * the O(1) bit-assembled scale (`substrate.scale_bit_assemble`) is
    bit-identical to the K-way select-chain oracle (`scale_lut_gather`)
    — powers of two are exact in f32, so there is NO tolerance;
  * kernel outputs on packed operands are bit-identical to the two-plane
    path across ref x interpret backends, fused x unfused composition,
    batched x unbatched, including ragged (padded) shapes.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    FXPFormat, VPFormat, pack_vp, unpack_vp, storage_dtype,
)
from repro.kernels import ops, ref, substrate as sub

W_FXP, W_VP = FXPFormat(12, 11), VPFormat(7, (11, 9, 7, 6))
Y_FXP, Y_VP = FXPFormat(9, 1), VPFormat(7, (1, -1))


@st.composite
def vp_formats(draw):
    """Random VPFormat: M in [2, 12], K in {1, 2, 4, 8}, f descending."""
    M = draw(st.integers(2, 12))
    E = draw(st.integers(0, 3))
    K = 1 << E
    top = draw(st.integers(-4, 14))
    # Distinct descending entries starting at `top`.
    gaps = draw(st.lists(st.integers(1, 3), min_size=K - 1, max_size=K - 1))
    f = [top]
    for g in gaps:
        f.append(f[-1] - g)
    return VPFormat(M, tuple(f))


def _random_planes(fmt, seed, shape=(17, 23)):
    rng = np.random.default_rng(seed)
    m = rng.integers(fmt.raw_min, fmt.raw_max + 1, shape)
    i = rng.integers(0, fmt.K, shape)
    return jnp.asarray(m, jnp.int32), jnp.asarray(i, jnp.int32)


# ---------------------------------------------------------------------------
# Property tests: round trip + storage accounting + scale identity
# ---------------------------------------------------------------------------

@given(fmt=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip_exact(fmt, seed):
    m, i = _random_planes(fmt, seed)
    w = pack_vp(m, i, fmt)
    assert w.dtype == storage_dtype(fmt)
    m2, i2 = unpack_vp(w, fmt)
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i))


@given(fmt=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_unpack_cascade_matches_oracle(fmt, seed):
    """The in-kernel shift/mask unpack == the pure-jnp packing oracle."""
    m, i = _random_planes(fmt, seed)
    w = pack_vp(m, i, fmt)
    mk, ik = sub.unpack_cascade(w, fmt)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(i))


@given(fmt=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_bit_assembled_scale_bit_identical(fmt, seed):
    """O(1) bit-assembly == K-way select chain, bit for bit."""
    _, i = _random_planes(fmt, seed)
    want = np.asarray(sub.scale_lut_gather(i, fmt, jnp.float32))
    got = np.asarray(sub.scale_of_index(i, fmt, jnp.float32))
    np.testing.assert_array_equal(got, want)
    if sub._fpack_params(fmt) is not None:
        # When the fast path is admissible, test it EXPLICITLY too (on
        # wide-span K=8 lists scale_of_index may have fallen back).
        np.testing.assert_array_equal(
            np.asarray(sub.scale_bit_assemble(i, fmt)), want)


@given(fmt=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_dequant_words_bit_identical(fmt, seed):
    """The whole-word offline dequant LUT (PR 4, `dequant_words`) ==
    unpack + exponent scale, bit for bit, over random formats.

    Formats up to 12 information bits take the one-gather LUT path;
    wider ones fall back to shift/mask — both must equal the two-plane
    dequant exactly (every LUT entry is int * 2^-f, exact in f32)."""
    from repro.core import dequant_words
    from repro.core.convert import vp_to_float

    m, i = _random_planes(fmt, seed)
    w = pack_vp(m, i, fmt)
    want = np.asarray(vp_to_float(m, i, fmt, jnp.float32))
    got = np.asarray(dequant_words(w, fmt, jnp.float32))
    np.testing.assert_array_equal(got, want)


@given(fmt=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_dequant_matmul_random_formats(fmt, seed):
    """`vp_dequant_matmul` (the serving op: real x packed weights) ==
    dequant-then-dot over random formats, bit for bit on the ref path."""
    rng = np.random.default_rng(seed)
    m, i = _random_planes(fmt, seed, shape=(32, 8))
    w = pack_vp(m, i, fmt)
    x = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
    got = ops.vp_dequant_matmul(x, w, fmt)
    from repro.core.convert import vp_to_float
    want = jnp.dot(x, vp_to_float(m, i, fmt, jnp.float32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(fmt=vp_formats())
@settings(max_examples=40, deadline=None)
def test_storage_bits_accounting(fmt):
    bits = fmt.M + fmt.E
    assert fmt.storage_bits == (8 if bits <= 8 else 16 if bits <= 16 else 32)
    assert fmt.storage_bits >= bits
    # The packed word always beats or matches the two-plane layout's 16.
    if bits <= 8:
        assert fmt.storage_bits == 8 < 16


def test_paper_formats_storage():
    """Table-I formats: y packs to ONE byte (halved), W to two.

    Both ADMIT the O(1) bit-assembled scale, but at K <= 4 the kernel
    policy (`scale_of_index`) keeps the shorter select chain — the
    bit-assembly engages for K > 4 (covered by the K=8 kernel test
    below)."""
    assert Y_VP.storage_bits == 8
    assert storage_dtype(Y_VP) == jnp.int8
    assert W_VP.storage_bits == 16
    assert storage_dtype(W_VP) == jnp.int16
    assert sub._fpack_params(Y_VP) is not None
    assert sub._fpack_params(W_VP) is not None


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
def test_k8_format_engages_bit_assembly_in_kernels(interpret):
    """A K=8 format runs the O(1) bit-assembled scale INSIDE the packed
    kernels (scale_of_index engages it for K > 4) and must still match
    the two-plane path bit for bit."""
    fmt8 = VPFormat(6, (8, 7, 6, 5, 4, 3, 2, 1))
    assert sub._fpack_params(fmt8) is not None and fmt8.K > 4
    rng = np.random.default_rng(3)
    a_m = jnp.asarray(
        rng.integers(fmt8.raw_min, fmt8.raw_max + 1, (24, 32)), jnp.int32)
    a_i = jnp.asarray(rng.integers(0, fmt8.K, (24, 32)), jnp.int32)
    b_m = jnp.asarray(
        rng.integers(Y_VP.raw_min, Y_VP.raw_max + 1, (32, 8)), jnp.int32)
    b_i = jnp.asarray(rng.integers(0, Y_VP.K, (32, 8)), jnp.int32)
    plane = ops.vp_matmul(a_m, a_i, b_m, b_i, fmt8, Y_VP,
                          interpret=interpret)
    packed = ops.vp_matmul(
        pack_vp(a_m, a_i, fmt8), None, pack_vp(b_m, b_i, Y_VP), None,
        fmt8, Y_VP, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(plane))
    deq = ops.vp_dequant(pack_vp(a_m, a_i, fmt8), None, fmt8,
                         interpret=interpret)
    np.testing.assert_array_equal(
        np.asarray(deq),
        np.asarray(ops.vp_dequant(a_m, a_i, fmt8, interpret=interpret)))


# ---------------------------------------------------------------------------
# Packed-vs-plane kernel bit-identity (ref x interpret, ragged shapes)
# ---------------------------------------------------------------------------

def _float_operands(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_t(2, (M, K)).clip(-8, 8) * 0.01, jnp.float32)
    b = jnp.asarray(rng.standard_t(2, (K, N)).clip(-8, 8), jnp.float32)
    return a, b


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
@pytest.mark.parametrize("mkn", [(64, 64, 64), (13, 50, 3)])
def test_quant_packed_equals_packed_planes(mkn, interpret):
    a, _ = _float_operands(*mkn)
    m, i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    w = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret, packed=True)
    assert w.dtype == storage_dtype(W_VP)
    np.testing.assert_array_equal(
        np.asarray(w), np.asarray(pack_vp(m, i, W_VP)))


def test_dequant_misuse_raises_clearly():
    """vp_dequant(w, fmt) — format in the index slot — must fail loudly."""
    w = jnp.zeros((4, 4), jnp.int16)
    with pytest.raises(TypeError, match="THIRD argument"):
        ops.vp_dequant(w, W_VP)
    with pytest.raises(TypeError, match="THIRD argument"):
        ops.vp_dequant(w, None, None)


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
@pytest.mark.parametrize("mkn", [(64, 64, 64), (13, 50, 3)])
def test_dequant_packed_bit_identical(mkn, interpret):
    a, _ = _float_operands(*mkn)
    m, i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    w = pack_vp(m, i, W_VP)
    d_plane = ops.vp_dequant(m, i, W_VP, interpret=interpret)
    d_packed = ops.vp_dequant(w, None, W_VP, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(d_packed), np.asarray(d_plane))


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
@pytest.mark.parametrize("mkn", [(64, 64, 64), (13, 50, 3)])
def test_matmul_packed_bit_identical(mkn, interpret):
    a, b = _float_operands(*mkn)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret)
    a_w = pack_vp(a_m, a_i, W_VP)
    b_w = pack_vp(b_m, b_i, Y_VP)
    plane = ops.vp_matmul(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, interpret=interpret)
    packed = ops.vp_matmul(
        a_w, None, b_w, None, W_VP, Y_VP, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(plane))


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
def test_matmul_mixed_layout_bit_identical(interpret):
    """One packed operand + one plane pair still matches the plane path."""
    a, b = _float_operands(32, 48, 8)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret)
    a_w = pack_vp(a_m, a_i, W_VP)
    plane = ops.vp_matmul(a_m, a_i, b_m, b_i, W_VP, Y_VP,
                          interpret=interpret)
    mixed = ops.vp_matmul(a_w, None, b_m, b_i, W_VP, Y_VP,
                          interpret=interpret)
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(plane))


@pytest.mark.parametrize("interpret", [None, True], ids=["ref", "interpret"])
@pytest.mark.parametrize("shape", [(1, 16, 64, 2), (5, 16, 64, 2),
                                   (3, 13, 50, 1)])
def test_batched_matmul_packed_bit_identical(shape, interpret):
    G, M, K, N = shape
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_t(2, (G, M, K)).clip(-8, 8) * 0.01,
                    jnp.float32)
    b = jnp.asarray(rng.standard_t(2, (G, K, N)).clip(-8, 8), jnp.float32)
    a_m, a_i = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret)
    a_w = ops.vp_quant(a, W_FXP, W_VP, interpret=interpret, packed=True)
    b_w = ops.vp_quant(b, Y_FXP, Y_VP, interpret=interpret, packed=True)
    plane = ops.vp_matmul_batched(
        a_m, a_i, b_m, b_i, W_VP, Y_VP, interpret=interpret)
    packed = ops.vp_matmul_batched(
        a_w, None, b_w, None, W_VP, Y_VP, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(plane))
    # ... and the fused float path still matches both (it never packs).
    fused = ops.vp_quant_matmul_batched(
        a, b, W_FXP, W_VP, Y_FXP, Y_VP, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(packed))


@given(fmt_a=vp_formats(), fmt_b=vp_formats(), seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_packed_matmul_random_formats(fmt_a, fmt_b, seed):
    """Packed == plane matmul over RANDOM format pairs (ref backend)."""
    rng = np.random.default_rng(seed)
    a_m = jnp.asarray(
        rng.integers(fmt_a.raw_min, fmt_a.raw_max + 1, (24, 32)), jnp.int32)
    a_i = jnp.asarray(rng.integers(0, fmt_a.K, (24, 32)), jnp.int32)
    b_m = jnp.asarray(
        rng.integers(fmt_b.raw_min, fmt_b.raw_max + 1, (32, 8)), jnp.int32)
    b_i = jnp.asarray(rng.integers(0, fmt_b.K, (32, 8)), jnp.int32)
    plane = ops.vp_matmul(a_m, a_i, b_m, b_i, fmt_a, fmt_b)
    packed = ops.vp_matmul(
        pack_vp(a_m, a_i, fmt_a), None, pack_vp(b_m, b_i, fmt_b), None,
        fmt_a, fmt_b)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(plane))
