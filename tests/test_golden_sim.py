"""Golden regression: pinned Fig. 7 / Fig. 8 reproduction statistics.

`sim.golden_stats` reduces a fixed-seed ensemble to a handful of floats
(beamspace kurtosis, NMSE curve endpoints, the bitwidth gap).  The values
below were produced at PR 2 on the CPU ref path; kernel or format-layer
refactors that change quantization numerics move them by far more than
the tolerance, while backend/BLAS noise stays well inside it.

If a change moves these numbers ON PURPOSE (e.g. a channel-model fix),
re-pin them in the same commit and say why in its message.
"""
import numpy as np
import pytest

from repro.mimo.sim import golden_stats

GOLDEN = {
    "kurtosis_y_beam": 8.97633171081543,
    "kurtosis_w_beam": 217.68136596679688,
    "kurtosis_y_ant": -0.15325212478637695,
    "nmse_ant_w6": 0.011574624197438316,
    "nmse_ant_w10": 3.850493708403612e-05,
    "nmse_beam_w6": 0.017346624633117473,
    "nmse_beam_w10": 0.0001826580368721932,
    "bit_gap": 0.7244533333406231,
}


@pytest.fixture(scope="module")
def stats():
    return golden_stats(seed=0, n=128)


def test_golden_keys(stats):
    assert set(stats) == set(GOLDEN)


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_value(stats, key):
    got, want = stats[key], GOLDEN[key]
    np.testing.assert_allclose(
        got, want, rtol=2e-3, atol=1e-8,
        err_msg=f"{key} drifted from the pinned Fig. 7/8 reproduction")


def test_golden_orderings(stats):
    """Structural claims that must survive any re-pin: beamspace is
    spikier than antenna domain (Fig. 7) and needs more bits at equal
    NMSE (Fig. 8)."""
    assert stats["kurtosis_y_beam"] > stats["kurtosis_y_ant"] + 1.0
    assert stats["kurtosis_w_beam"] > stats["kurtosis_y_beam"]
    assert stats["nmse_beam_w6"] > stats["nmse_ant_w6"]
    assert stats["nmse_ant_w10"] < stats["nmse_ant_w6"]
    assert stats["bit_gap"] > 0.0
