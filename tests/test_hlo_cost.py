"""Loop-aware HLO cost parser: validate against programs with known FLOPs."""
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, ".")
from benchmarks import hlo_cost  # noqa: E402


def _flops_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return hlo_cost.total_costs(comp.as_text())


def test_plain_dot():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    c = _flops_of(lambda w, x: x @ w, w, x)
    expect = 2 * 32 * 256 * 256
    assert abs(c["flops"] - expect) / expect < 0.05


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=17)
        return h

    c = _flops_of(f, w, x)
    expect = 2 * 32 * 256 * 256 * 17
    assert c["flops"] >= expect
    assert c["flops"] < expect * 1.2


def test_nested_scans_multiply():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)

    def f(w, x):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=5)
            return jnp.tanh(h2), None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    c = _flops_of(f, w, x)
    expect = 2 * 16 * 128 * 128 * 15
    assert c["flops"] >= expect
    assert c["flops"] < expect * 1.2


def test_dus_not_counted_as_full_buffer():
    """dynamic-update-slice traffic ~ the update, not the aliased buffer."""
    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(buf, upd):
        def body(b, i):
            return jax.lax.dynamic_update_slice(b, upd * 1.0, (i, 0)), None
        b, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return b

    c = _flops_of(f, buf, upd)
    # aliasing heuristic: the carried 4MB buffer is counted once per trip
    # at most (in-place fused DUS), not operand+result twice
    assert c["hbm_bytes"] <= 64 * (1024 * 1024 * 4 + 64 * 4096 * 4), \
        c["hbm_bytes"]
