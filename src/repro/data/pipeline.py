"""Deterministic, resumable synthetic token pipeline.

Produces language-model batches from a counter-based PRNG stream: batch i
is a pure function of (seed, i), so any host can regenerate any shard —
restart/elastic-rescale resume is just "set the counter" (the counter is
stored in the checkpoint manifest).  Per-host sharding takes every
n_hosts-th batch row.

The synthetic distribution is Zipfian over the vocab with short-range
repetition structure, so models actually learn (loss decreases) and the
pipeline exercises the same shapes/dtypes as a real tokenized corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_p: float = 0.3       # probability of short-range copy


@dataclasses.dataclass
class DataState:
    """Checkpointable pipeline position."""
    batch_index: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert cfg.global_batch % n_hosts == 0
        self.local_batch = cfg.global_batch // n_hosts
        # Zipf lookup table (shared, deterministic)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(p / p.sum())

    def batch_at(self, index: int, state: Optional[DataState] = None
                 ) -> Dict[str, jnp.ndarray]:
        """Batch `index`, host-sharded; pure function of (seed, index)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, index, self.host_id]))
        shape = (self.local_batch, cfg.seq_len + 1)
        u = rng.random(shape)
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab - 1)
        # short-range copies give learnable structure
        copy = rng.random(shape) < cfg.repeat_p
        lag = rng.integers(1, 8, size=shape)
        idx = np.maximum(np.arange(cfg.seq_len + 1)[None, :] - lag, 0)
        toks = np.where(copy, np.take_along_axis(toks, idx, 1), toks)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1

    def resume_iter(self, state: DataState):
        i = state.batch_index
        while True:
            yield self.batch_at(i), DataState(i + 1)
            i += 1
