"""LoS mmWave massive-MIMO channel generator (QuaDRiGa-style LoS, ULA).

The paper generates 1e5 antenna-domain uplink channels with QuaDRiGa [5]
in LoS conditions (B=64 ULA, U=8 single-antenna UEs).  QuaDRiGa is a
MATLAB ray-tracing-flavoured statistical simulator; we reproduce its LoS
geometry in JAX: each UE contributes a dominant direct path plus a few
weak scattered clusters (Rician), with half-wavelength ULA steering
vectors.  This yields the defining property the paper exploits —
approximate beamspace sparsity (spiky PDFs, Fig. 7) — with the same
qualitative statistics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    B: int = 64                 # BS antennas (ULA, lambda/2 spacing)
    U: int = 8                  # single-antenna UEs
    n_clusters: int = 4         # scattered clusters per UE (LoS: weak)
    rician_k_db: float = 15.0   # LoS-to-scatter power ratio
    sector_deg: float = 60.0    # UE angles uniform in +-sector
    los: bool = True            # LoS vs non-LoS conditions
    angle_spread_deg: float = 7.5   # per-cluster angular spread around UE


def steering(b: int, sin_theta):
    """ULA steering vector(s) a(theta): exp(j*pi*sin(theta)*[0..B-1])."""
    n = jnp.arange(b, dtype=jnp.float32)
    phase = jnp.pi * sin_theta[..., None] * n
    return jnp.exp(1j * phase).astype(jnp.complex64)


def generate_channels(key, cfg: ChannelConfig, n: int) -> jax.Array:
    """n antenna-domain channel matrices, shape (n, B, U) complex64.

    Columns are normalized to unit average per-antenna gain
    (E[|h_bu|^2] = 1), matching the paper's per-stream SNR convention.
    """
    k_ang, k_cl, k_g, k_ph = jax.random.split(key, 4)
    s = jnp.sin(jnp.deg2rad(
        jax.random.uniform(k_ang, (n, cfg.U), minval=-cfg.sector_deg,
                           maxval=cfg.sector_deg)))
    # Cluster angles around each UE direction.
    spread = jnp.deg2rad(cfg.angle_spread_deg)
    d_ang = jax.random.normal(k_cl, (n, cfg.U, cfg.n_clusters)) * spread
    s_cl = jnp.clip(s[..., None] + jnp.sin(d_ang), -1.0, 1.0)
    # Path gains: LoS path fixed power, clusters exponentially decaying.
    k_lin = 10.0 ** (cfg.rician_k_db / 10.0)
    if cfg.los:
        p_los = k_lin / (1.0 + k_lin)
        p_cl = (1.0 - p_los)
    else:
        p_los = 0.0
        p_cl = 1.0
    decay = jnp.exp(-jnp.arange(cfg.n_clusters) / 1.5)
    p_k = p_cl * decay / decay.sum()
    g_cl = (jax.random.normal(k_g, (n, cfg.U, cfg.n_clusters, 2))
            * jnp.sqrt(0.5)).astype(jnp.float32)
    g_cl = (g_cl[..., 0] + 1j * g_cl[..., 1]) * jnp.sqrt(p_k)
    phi = jax.random.uniform(k_ph, (n, cfg.U), maxval=2 * jnp.pi)
    g_los = jnp.sqrt(p_los) * jnp.exp(1j * phi)

    a_los = steering(cfg.B, s)                  # (n, U, B)
    a_cl = steering(cfg.B, s_cl)                # (n, U, C, B)
    h = (g_los[..., None] * a_los
         + jnp.einsum("nuc,nucb->nub", g_cl, a_cl))
    return jnp.transpose(h, (0, 2, 1)).astype(jnp.complex64)  # (n, B, U)


def awgn(key, shape, n0: float):
    """Complex Gaussian noise with per-entry variance n0."""
    g = jax.random.normal(key, shape + (2,)) * jnp.sqrt(n0 / 2.0)
    return (g[..., 0] + 1j * g[..., 1]).astype(jnp.complex64)
