"""Monte-Carlo NMSE / BER harness (paper Sec. III-A and V).

Reproduces:
  * Fig. 7: spiky beamspace PDFs (we report kurtosis / dynamic-range stats);
  * Fig. 8: NMSE vs operand bitwidth, antenna vs beamspace (~1.2-bit gap);
  * Table I validation: BER of the three quantized designs vs float LMMSE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FXPFormat, fxp_quantize_value
from .channel import ChannelConfig, generate_channels, awgn
from .beamspace import to_beamspace
from .lmmse import lmmse_matrix, equalize
from .equalizer import EqualizerSpec, calibrate, equalize_quantized

# ---------------------------------------------------------------------------
# 16-QAM (gray-coded, Es = 1)
# ---------------------------------------------------------------------------

_QAM_LEVELS = jnp.asarray([-3.0, -1.0, 1.0, 3.0]) / jnp.sqrt(10.0)
# Gray code for levels [-3,-1,1,3] -> bit pairs (00,01,11,10)
_GRAY = jnp.asarray([0, 1, 3, 2])
_INV_GRAY = jnp.asarray([0, 1, 3, 2])  # self-inverse for 2-bit gray


def qam16_mod(key, shape):
    """Random 16-QAM symbols + their bit labels.

    Returns (symbols complex64 `shape`, bits uint8 `shape + (4,)`)."""
    ki, kq = jax.random.split(key)
    idx_i = jax.random.randint(ki, shape, 0, 4)
    idx_q = jax.random.randint(kq, shape, 0, 4)
    sym = _QAM_LEVELS[idx_i] + 1j * _QAM_LEVELS[idx_q]
    bits_i = _GRAY[idx_i]
    bits_q = _GRAY[idx_q]
    bits = jnp.stack(
        [(bits_i >> 1) & 1, bits_i & 1, (bits_q >> 1) & 1, bits_q & 1],
        axis=-1,
    ).astype(jnp.uint8)
    return sym.astype(jnp.complex64), bits


def qam16_demod_hard(s):
    """Hard-decision demodulation -> bit labels (shape + (4,))."""
    def level_idx(x):
        bounds = jnp.asarray([-2.0, 0.0, 2.0]) / jnp.sqrt(10.0)
        return jnp.searchsorted(bounds, x[..., None][..., 0])

    idx_i = jnp.clip(level_idx(s.real), 0, 3)
    idx_q = jnp.clip(level_idx(s.imag), 0, 3)
    bits_i = _GRAY[idx_i]
    bits_q = _GRAY[idx_q]
    return jnp.stack(
        [(bits_i >> 1) & 1, bits_i & 1, (bits_q >> 1) & 1, bits_q & 1],
        axis=-1,
    ).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Ensemble generation (channels, receive vectors, LMMSE matrices)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ensemble:
    h_ant: jax.Array   # (n, B, U) antenna-domain channels
    h_beam: jax.Array  # (n, B, U)
    w_ant: jax.Array   # (n, U, B) LMMSE matrices
    w_beam: jax.Array  # (n, U, B)
    y_ant: jax.Array   # (n, B) received vectors (one per channel)
    y_beam: jax.Array  # (n, B)
    s: jax.Array       # (n, U) transmitted symbols
    bits: jax.Array    # (n, U, 4)
    n0: float


def make_ensemble(key, cfg: ChannelConfig, n: int, snr_db: float) -> Ensemble:
    """Paper Sec. III-A: n channels, one 16-QAM receive vector each."""
    kh, ks, kn = jax.random.split(key, 3)
    h = generate_channels(kh, cfg, n)
    # Per-stream SNR with E[|h|^2]~1 per antenna and Es=1: N0 = 10^(-SNR/10).
    n0 = float(10.0 ** (-snr_db / 10.0))
    s, bits = qam16_mod(ks, (n, cfg.U))
    noise = awgn(kn, (n, cfg.B), n0)
    y = jnp.einsum("nbu,nu->nb", h, s) + noise
    hb = to_beamspace(h, axis=-2)
    yb = to_beamspace(y, axis=-1)
    w = lmmse_matrix(h, n0)
    wb = lmmse_matrix(hb, n0)
    return Ensemble(h, hb, w, wb, y, yb, s, bits, n0)


# ---------------------------------------------------------------------------
# Fig. 7: distribution statistics (spikiness of beamspace signals)
# ---------------------------------------------------------------------------

def pdf_stats(x) -> Dict[str, float]:
    """Kurtosis & peak-to-average stats of the real part (paper Fig. 7)."""
    v = np.asarray(x.real).ravel()
    v = v / (v.std() + 1e-30)
    return {
        "kurtosis": float(np.mean(v**4) - 3.0),
        "papr_db": float(10 * np.log10(np.max(v**2) / np.mean(v**2))),
        "frac_below_0p1sigma": float(np.mean(np.abs(v) < 0.1)),
    }


# ---------------------------------------------------------------------------
# Fig. 8: NMSE vs bitwidth
# ---------------------------------------------------------------------------

def _global_unit_scale(x) -> float:
    """Single scalar putting re/im of the whole ensemble into (-1, 1)."""
    amax = float(np.max(np.abs(
        np.stack([np.asarray(x.real), np.asarray(x.imag)]))))
    return (1.0 - 1e-6) / max(amax, 1e-30)


def nmse_vs_bitwidth(ens: Ensemble, widths: Sequence[int] = range(6, 11)
                     ) -> Dict[str, Dict[int, float]]:
    """Quantize (W, W-1)-normalized inputs, NMSE of the dot product (eq. 4).

    Only the INPUTS are quantized; the multiply runs in float — exactly the
    paper's methodology.
    """
    out = {"antenna": {}, "beamspace": {}}
    for domain, (w, y) in {
        "antenna": (ens.w_ant, ens.y_ant),
        "beamspace": (ens.w_beam, ens.y_beam),
    }.items():
        gw, gy = _global_unit_scale(w), _global_unit_scale(y)
        wn, yn = w * gw, y * gy
        ref = jnp.einsum("nub,nb->nu", wn, yn)
        den = float(jnp.mean(jnp.abs(ref) ** 2))
        for W in widths:
            fmt = FXPFormat(W, W - 1)

            def q(x):
                return (fxp_quantize_value(x.real, fmt)
                        + 1j * fxp_quantize_value(x.imag, fmt))

            est = jnp.einsum("nub,nb->nu", q(wn), q(yn))
            num = float(jnp.mean(jnp.abs(est - ref) ** 2))
            out[domain][int(W)] = num / den
    return out


def bitwidth_gap(nmse: Dict[str, Dict[int, float]]) -> float:
    """Horizontal gap (in bits) between the two NMSE curves.

    For each NMSE level reached by the antenna curve, find the (linearly
    interpolated) bitwidth where the beamspace curve reaches it; average
    the difference.  Paper: ~1.2 bits.
    """
    wa = sorted(nmse["antenna"])
    la = np.log10([nmse["antenna"][w] for w in wa])
    lb = np.log10([nmse["beamspace"][w] for w in wa])
    gaps = []
    for i, w in enumerate(wa):
        target = la[i]
        # find where beamspace curve crosses `target`
        j = np.searchsorted(-lb, -target)  # lb is decreasing
        if j == 0 or j >= len(wa):
            continue
        frac = (lb[j - 1] - target) / (lb[j - 1] - lb[j] + 1e-30)
        w_beam = wa[j - 1] + frac * (wa[j] - wa[j - 1])
        gaps.append(w_beam - w)
    return float(np.mean(gaps)) if gaps else float("nan")


# ---------------------------------------------------------------------------
# BER: Table I validation
# ---------------------------------------------------------------------------

def ber_float(ens: Ensemble, beamspace: bool) -> float:
    w, y = (ens.w_beam, ens.y_beam) if beamspace else (ens.w_ant, ens.y_ant)
    s_hat = equalize(w, y)
    bits = qam16_demod_hard(s_hat)
    return float(jnp.mean(bits != ens.bits))


def ber_quantized(ens: Ensemble, spec: EqualizerSpec) -> float:
    w, y = ((ens.w_beam, ens.y_beam) if spec.beamspace
            else (ens.w_ant, ens.y_ant))
    s_hat = equalize_quantized(spec, w, y)
    bits = qam16_demod_hard(s_hat)
    return float(jnp.mean(bits != ens.bits))


def calibrate_specs(specs, ens: Ensemble):
    """Calibrate AGC gains of each design on the ensemble."""
    out = []
    for spec in specs:
        w, y = ((ens.w_beam, ens.y_beam) if spec.beamspace
                else (ens.w_ant, ens.y_ant))
        out.append(calibrate(spec, w, y))
    return out


# ---------------------------------------------------------------------------
# Golden statistics (regression anchor for kernel/format refactors)
# ---------------------------------------------------------------------------

def golden_stats(seed: int = 0, n: int = 128, snr_db: float = 20.0
                 ) -> Dict[str, float]:
    """Deterministic scalar summary of the Fig. 7 / Fig. 8 reproduction.

    One fixed-seed ensemble reduced to a handful of floats: beamspace/
    antenna kurtosis and the NMSE curve endpoints.  The golden regression
    test (tests/test_golden_sim.py) pins these values so kernel or format
    refactors cannot silently drift the paper's reproduction.
    """
    ens = make_ensemble(jax.random.PRNGKey(seed), ChannelConfig(), n, snr_db)
    nm = nmse_vs_bitwidth(ens, widths=(6, 8, 10))
    return {
        "kurtosis_y_beam": pdf_stats(ens.y_beam)["kurtosis"],
        "kurtosis_w_beam": pdf_stats(ens.w_beam)["kurtosis"],
        "kurtosis_y_ant": pdf_stats(ens.y_ant)["kurtosis"],
        "nmse_ant_w6": nm["antenna"][6],
        "nmse_ant_w10": nm["antenna"][10],
        "nmse_beam_w6": nm["beamspace"][6],
        "nmse_beam_w10": nm["beamspace"][10],
        "bit_gap": bitwidth_gap(nm),
    }
