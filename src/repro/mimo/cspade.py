"""CSPADE sparsity-adaptive thresholding (paper Sec. IV-A, ref. [11]).

A partial product is skipped ("muted") when the magnitudes of BOTH operands
fall below predetermined thresholds — beamspace W and y are approximately
sparse, so most partial products qualify and their multipliers see no input
toggling (dynamic-power saving in the ASIC; tile-skip in the TPU kernel).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def muting_mask(w_plane, y_plane, thresh_w: float, thresh_y: float):
    """Per-partial-product muting: both real operands below threshold.

    w_plane (..., U, B) and y_plane (..., B) are REAL planes (re or im).
    Returns bool (..., U, B): True = muted.
    """
    quiet_w = jnp.abs(w_plane) < thresh_w
    quiet_y = (jnp.abs(y_plane) < thresh_y)[..., None, :]
    return quiet_w & quiet_y


def muting_rate(w, y, thresh_w: float, thresh_y: float) -> jnp.ndarray:
    """Average muting rate over the 4 RMs of each complex multiplier.

    w (..., U, B) complex, y (..., B) complex.  The four real multipliers
    of a CM consume (wr,yr), (wi,yi), (wr,yi), (wi,yr).
    """
    rates = []
    for wp in (w.real, w.imag):
        for yp in (y.real, y.imag):
            rates.append(muting_mask(wp, yp, thresh_w, thresh_y).mean())
    return jnp.mean(jnp.asarray(rates))


def calibrate_thresholds(w, y, target_rate: float = 0.5,
                         tol: float = 0.02, iters: int = 24
                         ) -> Tuple[float, float]:
    """Pick thresholds as a common quantile of |w| and |y| planes hitting a
    target muting rate (bisection over the quantile)."""
    import numpy as np

    wabs = np.abs(np.stack([np.asarray(w.real), np.asarray(w.imag)])).ravel()
    yabs = np.abs(np.stack([np.asarray(y.real), np.asarray(y.imag)])).ravel()
    lo, hi = 0.0, 1.0
    for _ in range(iters):
        q = 0.5 * (lo + hi)
        tw, ty = float(np.quantile(wabs, q)), float(np.quantile(yabs, q))
        r = float(muting_rate(w, y, tw, ty))
        if abs(r - target_rate) < tol:
            break
        if r < target_rate:
            lo = q
        else:
            hi = q
    return tw, ty
