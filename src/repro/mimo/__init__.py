"""The paper's application: beamspace LMMSE equalization for mmWave
massive MU-MIMO (Sec. III-V)."""
from .channel import ChannelConfig, generate_channels, awgn, steering
from .beamspace import dft_matrix, to_beamspace, from_beamspace
from .lmmse import lmmse_matrix, equalize
from .equalizer import EqualizerSpec, table1_specs, calibrate, equalize_quantized
from . import sim, cspade, ofdm
from .ofdm import (
    OFDMConfig, WidebandCalibrator, WidebandEnsemble,
    generate_wideband_channels, make_wideband_ensemble, equalize_wideband,
)

__all__ = [
    "ChannelConfig", "generate_channels", "awgn", "steering",
    "dft_matrix", "to_beamspace", "from_beamspace",
    "lmmse_matrix", "equalize",
    "EqualizerSpec", "table1_specs", "calibrate", "equalize_quantized",
    "OFDMConfig", "WidebandCalibrator", "WidebandEnsemble",
    "generate_wideband_channels", "make_wideband_ensemble",
    "equalize_wideband",
    "sim", "cspade", "ofdm",
]
