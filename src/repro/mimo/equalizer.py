"""The three MVM equalizer designs (paper Sec. IV, Table I).

  A-FXP: antenna domain, FXP operands  ybar:(7,1)   Wbar:(11,10)
  B-FXP: beamspace,      FXP operands  y:(9,1)      W:(12,11)
  B-VP:  beamspace,      VP operands   y:VP(7,[1,-1]) W:VP(7,[11,9,7,6])

Signals are mapped onto the hardware formats by a static AGC gain per
stream (calibrated once over a Monte-Carlo ensemble, like a designer
fixing the input scaling), then quantized re/im separately.  Following the
paper's methodology, quantization is the only error source: the multiply/
accumulate math runs exactly (VP multiplication is exact by construction;
accumulators are wide enough).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import (
    FXPFormat,
    VPFormat,
    fxp_quantize_value,
    vp_fake_quant,
)


@dataclasses.dataclass(frozen=True)
class EqualizerSpec:
    name: str
    beamspace: bool
    y_fxp: FXPFormat
    w_fxp: FXPFormat
    y_vp: Optional[VPFormat] = None
    w_vp: Optional[VPFormat] = None
    # Static AGC gains (set by `calibrate`).
    y_gain: float = 1.0
    w_gain: float = 1.0

    @property
    def is_vp(self) -> bool:
        return self.y_vp is not None


def table1_specs() -> Tuple[EqualizerSpec, EqualizerSpec, EqualizerSpec]:
    return (
        EqualizerSpec("A-FXP", False, FXPFormat(7, 1), FXPFormat(11, 10)),
        EqualizerSpec("B-FXP", True, FXPFormat(9, 1), FXPFormat(12, 11)),
        EqualizerSpec("B-VP", True, FXPFormat(9, 1), FXPFormat(12, 11),
                      VPFormat(7, (1, -1)), VPFormat(7, (11, 9, 7, 6))),
    )


def calibrate(spec: EqualizerSpec, w_samples, y_samples,
              headroom: float = 0.98) -> EqualizerSpec:
    """Fix the AGC gains so the calibration ensemble fills the FXP ranges."""
    import numpy as np

    def gain(x, fmt: FXPFormat):
        amax = float(np.max(np.abs(
            np.stack([np.asarray(x.real), np.asarray(x.imag)]))))
        return headroom * fmt.max / max(amax, 1e-30)

    return dataclasses.replace(
        spec,
        y_gain=gain(y_samples, spec.y_fxp),
        w_gain=gain(w_samples, spec.w_fxp),
    )


def _quant_plane(x, spec_fxp: FXPFormat, spec_vp: Optional[VPFormat]):
    if spec_vp is None:
        return fxp_quantize_value(x, spec_fxp)
    return vp_fake_quant(x, spec_fxp, spec_vp)


def quantize_inputs(spec: EqualizerSpec, w, y):
    """Quantize equalizer inputs onto the design's formats (re/im planes).

    Returns (wq, yq) back in PHYSICAL units (gains divided out), so that
    s_hat = wq @ yq estimates the unscaled symbols directly.
    """
    def q(x, gain, fxp, vp):
        xr = _quant_plane(x.real * gain, fxp, vp)
        xi = _quant_plane(x.imag * gain, fxp, vp)
        return (xr + 1j * xi) / gain

    wq = q(w, spec.w_gain, spec.w_fxp, spec.w_vp)
    yq = q(y, spec.y_gain, spec.y_fxp, spec.y_vp)
    return wq, yq


def equalize_quantized(spec: EqualizerSpec, w, y):
    """One equalization s_hat = W y with quantized inputs.

    w (..., U, B) complex, y (..., B) complex — both already in the domain
    the spec expects (antenna vs beamspace chosen by the caller).
    """
    wq, yq = quantize_inputs(spec, w, y)
    return jnp.einsum("...ub,...b->...u", wq, yq)
