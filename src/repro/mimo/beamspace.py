"""Beamspace transforms (paper eq. 3): y = F ybar, H = F Hbar.

F is the unitary DFT matrix of size B; since F is unitary the beamspace
system model is statistically equivalent to the antenna-domain one, but
mmWave LoS channels become approximately sparse in beamspace.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp


@functools.lru_cache(maxsize=8)
def dft_matrix(b: int):
    """Unitary DFT matrix F (B x B), complex64."""
    n = jnp.arange(b)
    f = jnp.exp(-2j * jnp.pi * jnp.outer(n, n) / b) / jnp.sqrt(b)
    return f.astype(jnp.complex64)


def to_beamspace(x, axis: int = -2):
    """Apply F along the antenna axis (works for (..., B, U) and (..., B))."""
    b = x.shape[axis]
    f = dft_matrix(b)
    return jnp.moveaxis(
        jnp.tensordot(f, jnp.moveaxis(x, axis, 0), axes=1), 0, axis)


def from_beamspace(x, axis: int = -2):
    b = x.shape[axis]
    f = dft_matrix(b)
    return jnp.moveaxis(
        jnp.tensordot(f.conj().T, jnp.moveaxis(x, axis, 0), axes=1), 0, axis)
