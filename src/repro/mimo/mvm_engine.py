"""The B-VP MVM engine proper: complex equalization through the Pallas
VP-matmul kernel (Fig. 9c / Fig. 10 as a TPU kernel call).

`equalizer.equalize_quantized` models the DESIGNS numerically (fake-quant
einsum — bit-identical values); this module runs the same computation
through the actual kernel path:

  * FXP2VP conversion of the re/im planes (kernels.vp_quant),
  * complex MVM as 4 real VP matmuls (the paper's 4-RM CM structure),
  * CSPADE tile-activity masks muting quiet tile pairs,

batched over channel realizations by stacking the U-row equalization
matrices into one tall (n*U, B) operand — exactly how a fleet would batch
MVM requests.  Tested against `equalize_quantized` in tests/test_mimo_engine.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import FXPFormat, VPFormat
from repro.kernels import ops, ref, substrate
from .equalizer import EqualizerSpec


def _vp_planes(x, gain, fxp: FXPFormat, vp: VPFormat, interpret):
    return ops.vp_quant(x * gain, fxp, vp, interpret=interpret)


def equalize_vp_kernel(
    spec: EqualizerSpec,
    w: jax.Array,            # (n, U, B) complex
    y: jax.Array,            # (n, B) complex
    cspade_threshold_quantile: Optional[float] = None,
    interpret: Optional[bool] = None,
    fused: Optional[bool] = None,
) -> jax.Array:
    """s_hat (n, U) complex through the VP kernel path.

    The complex MVM uses the 3-matmul (Karatsuba) real decomposition?  No —
    the paper's SP-CM is the plain 4-RM structure, so we do 4 real products
    with shared quantized operands:
      re = Wr yr - Wi yi ;  im = Wr yi + Wi yr
    Implemented as ONE (2nU, B) x (B, 2n->grouped) batch?  Keeping it
    simple and faithful: the y operand is per-realization, so we run the
    kernel per plane on block-diagonal-free batched shapes by folding the
    realization index into the row dimension and using a matmul against a
    per-realization column — i.e. an einsum-of-tiles the kernel executes
    as (nU, B) x (B, n) with a mask selecting the matching realization.
    For the framework benchmark we instead fold realizations into the
    CONTRACTION-free row dim: rows = n*U, and the y matrix holds each
    realization's vector in its own column; the result's (row, col) pairs
    with col == row's realization are the wanted dot products.

    `fused` selects the fused quantize+matmul kernel (ops.vp_quant_matmul,
    one pallas_call per product, no quantized-plane round-trip).  The
    default (None) uses it only when ALL of: no CSPADE masks are requested
    (their calibration needs the materialized planes), the grid fan-out is
    small (<= 4 tiles per output axis — the fused kernel re-quantizes each
    operand tile once per opposing output tile), and a kernel backend is
    active (TPU-native or interpret; on the CPU ref path fusion saves no
    HBM and would re-quantize the shared operands).  Numerics are
    identical on every path — same cascades throughout.
    """
    assert spec.is_vp
    n, U, B = w.shape
    fxp_y, vp_y = spec.y_fxp, spec.y_vp
    fxp_w, vp_w = spec.w_fxp, spec.w_vp

    wr = w.real.reshape(n * U, B).astype(jnp.float32)
    wi = w.imag.reshape(n * U, B).astype(jnp.float32)
    yr = y.real.T.astype(jnp.float32)   # (B, n)
    yi = y.imag.T.astype(jnp.float32)

    M, K = wr.shape
    N = yr.shape[1]

    def _div_tile(sz, target):
        t = min(target, sz)
        while sz % t:
            t -= 1
        return t

    tiles = (_div_tile(M, 256), _div_tile(K, 256), _div_tile(N, 256))

    if fused is None:
        # CSPADE mask calibration needs the materialized planes, so masked
        # runs stay on the unfused path.  Otherwise fold the quantization
        # into the matmul pallas_call (no quantized-plane HBM round-trip)
        # — but only while the grid fan-out is small: the fused kernel
        # re-quantizes each A tile N/bn times and each B tile M/bm times,
        # so past a few tiles per output axis the redundant cascade work
        # outgrows the saved HBM traffic.
        # ...and only on a kernel backend: the ref path materializes the
        # planes regardless, so fusion would just re-quantize the operands
        # shared by the 4-RM products (8 cascades instead of 4).
        nm = -(-M // tiles[0])
        nn = -(-N // tiles[2])
        fused = (cspade_threshold_quantile is None
                 and max(nm, nn) <= 4
                 and substrate.resolve_backend(interpret) != "ref")

    if fused:
        if cspade_threshold_quantile is not None:
            raise ValueError(
                "fused path has no materialized planes to calibrate masks on")

        def mmf(a_f, b_f):
            return ops.vp_quant_matmul(
                a_f, b_f, fxp_w, vp_w, fxp_y, vp_y,
                blocks=tiles, interpret=interpret)

        wrg, wig = wr * spec.w_gain, wi * spec.w_gain
        yrg, yig = yr * spec.y_gain, yi * spec.y_gain
        rr = mmf(wrg, yrg)    # (nU, n)
        ii = mmf(wig, yig)
        ri = mmf(wrg, yig)
        ir = mmf(wig, yrg)
    else:
        wr_m, wr_i = _vp_planes(wr, spec.w_gain, fxp_w, vp_w, interpret)
        wi_m, wi_i = _vp_planes(wi, spec.w_gain, fxp_w, vp_w, interpret)
        yr_m, yr_i = _vp_planes(yr, spec.y_gain, fxp_y, vp_y, interpret)
        yi_m, yi_i = _vp_planes(yi, spec.y_gain, fxp_y, vp_y, interpret)

        a_act = b_act = None
        if cspade_threshold_quantile is not None:
            q = cspade_threshold_quantile
            ta = jnp.quantile(jnp.abs(wr) * spec.w_gain, q)
            tb = jnp.quantile(jnp.abs(yr) * spec.y_gain, q)
            Wd = ref.vp_dequant_ref(wr_m, wr_i, vp_w) * spec.w_gain
            Yd = ref.vp_dequant_ref(yr_m, yr_i, vp_y) * spec.y_gain
            a_act, b_act = ref.cspade_tile_masks(Wd, Yd, *tiles, ta, tb)

        def mm(am, ai, bm_, bi):
            return ops.vp_matmul(am, ai, bm_, bi, vp_w, vp_y,
                                 a_act=a_act, b_act=b_act, blocks=tiles,
                                 interpret=interpret)

        rr = mm(wr_m, wr_i, yr_m, yr_i)    # (nU, n)
        ii = mm(wi_m, wi_i, yi_m, yi_i)
        ri = mm(wr_m, wr_i, yi_m, yi_i)
        ir = mm(wi_m, wi_i, yr_m, yr_i)

    re = (rr - ii) / (spec.w_gain * spec.y_gain)
    im = (ri + ir) / (spec.w_gain * spec.y_gain)
    # select each row's own realization column
    rows = jnp.arange(n * U)
    cols = rows // U
    s = re[rows, cols] + 1j * im[rows, cols]
    return s.reshape(n, U)
