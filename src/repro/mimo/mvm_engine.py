"""The B-VP MVM engine proper: complex equalization through the Pallas
VP-matmul kernel (Fig. 9c / Fig. 10 as a TPU kernel call).

`equalizer.equalize_quantized` models the DESIGNS numerically (fake-quant
einsum — bit-identical values); this module runs the same computation
through the actual kernel path:

  * FXP2VP conversion of the re/im planes (kernels.vp_quant),
  * complex MVM as 4 real VP matmuls (the paper's 4-RM CM structure),
  * CSPADE tile-activity masks muting quiet tile pairs,

batched over channel realizations by stacking the U-row equalization
matrices into one tall (n*U, B) operand — exactly how a fleet would batch
MVM requests.  Tested against `equalize_quantized` in tests/test_mimo_engine.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import FXPFormat, VPFormat
from repro.kernels import ops, ref
from .equalizer import EqualizerSpec


def _vp_planes(x, gain, fxp: FXPFormat, vp: VPFormat, interpret):
    return ops.vp_quant(x * gain, fxp, vp, interpret=interpret)


def equalize_vp_kernel(
    spec: EqualizerSpec,
    w: jax.Array,            # (n, U, B) complex
    y: jax.Array,            # (n, B) complex
    cspade_threshold_quantile: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """s_hat (n, U) complex through the VP kernel path.

    The complex MVM uses the 3-matmul (Karatsuba) real decomposition?  No —
    the paper's SP-CM is the plain 4-RM structure, so we do 4 real products
    with shared quantized operands:
      re = Wr yr - Wi yi ;  im = Wr yi + Wi yr
    Implemented as ONE (2nU, B) x (B, 2n->grouped) batch?  Keeping it
    simple and faithful: the y operand is per-realization, so we run the
    kernel per plane on block-diagonal-free batched shapes by folding the
    realization index into the row dimension and using a matmul against a
    per-realization column — i.e. an einsum-of-tiles the kernel executes
    as (nU, B) x (B, n) with a mask selecting the matching realization.
    For the framework benchmark we instead fold realizations into the
    CONTRACTION-free row dim: rows = n*U, and the y matrix holds each
    realization's vector in its own column; the result's (row, col) pairs
    with col == row's realization are the wanted dot products.
    """
    assert spec.is_vp
    n, U, B = w.shape
    fxp_y, vp_y = spec.y_fxp, spec.y_vp
    fxp_w, vp_w = spec.w_fxp, spec.w_vp

    wr = w.real.reshape(n * U, B).astype(jnp.float32)
    wi = w.imag.reshape(n * U, B).astype(jnp.float32)
    yr = y.real.T.astype(jnp.float32)   # (B, n)
    yi = y.imag.T.astype(jnp.float32)

    wr_m, wr_i = _vp_planes(wr, spec.w_gain, fxp_w, vp_w, interpret)
    wi_m, wi_i = _vp_planes(wi, spec.w_gain, fxp_w, vp_w, interpret)
    yr_m, yr_i = _vp_planes(yr, spec.y_gain, fxp_y, vp_y, interpret)
    yi_m, yi_i = _vp_planes(yi, spec.y_gain, fxp_y, vp_y, interpret)

    a_act = b_act = None
    M, K = wr.shape
    N = yr.shape[1]

    def _div_tile(sz, target):
        t = min(target, sz)
        while sz % t:
            t -= 1
        return t

    tiles = (_div_tile(M, 256), _div_tile(K, 256), _div_tile(N, 256))
    if cspade_threshold_quantile is not None:
        q = cspade_threshold_quantile
        ta = jnp.quantile(jnp.abs(wr) * spec.w_gain, q)
        tb = jnp.quantile(jnp.abs(yr) * spec.y_gain, q)
        Wd = ref.vp_dequant_ref(wr_m, wr_i, vp_w) * spec.w_gain
        Yd = ref.vp_dequant_ref(yr_m, yr_i, vp_y) * spec.y_gain
        a_act, b_act = ref.cspade_tile_masks(Wd, Yd, *tiles, ta, tb)

    def mm(am, ai, bm_, bi):
        return ops.vp_matmul(am, ai, bm_, bi, vp_w, vp_y,
                             a_act=a_act, b_act=b_act, blocks=tiles,
                             interpret=interpret)

    rr = mm(wr_m, wr_i, yr_m, yr_i)    # (nU, n)
    ii = mm(wi_m, wi_i, yi_m, yi_i)
    ri = mm(wr_m, wr_i, yi_m, yi_i)
    ir = mm(wi_m, wi_i, yr_m, yr_i)

    re = (rr - ii) / (spec.w_gain * spec.y_gain)
    im = (ri + ir) / (spec.w_gain * spec.y_gain)
    # select each row's own realization column
    rows = jnp.arange(n * U)
    cols = rows // U
    s = re[rows, cols] + 1j * im[rows, cols]
    return s.reshape(n, U)
