"""The B-VP MVM engine proper: complex equalization through the Pallas
VP-matmul kernels (Fig. 9c / Fig. 10 as TPU kernel calls).

`equalizer.equalize_quantized` models the DESIGNS numerically (fake-quant
einsum — bit-identical values); this module runs the same computation
through the actual kernel path.  Two execution modes:

  * ``mode="batched"`` (default): the truly-batched grid.  Realization g
    runs its OWN (2U, B) x (B, 2) tile program on the kernel's leading
    batch grid dimension — the A operand stacks the W re/im planes along
    rows and the B operand holds [y_re, y_im] as two columns, so ONE
    pallas_call produces all four real products of the paper's 4-RM
    complex-multiplier structure for every realization.  FLOPs are
    8·n·U·B, independent of how many realizations ride along.

  * ``mode="masked"`` (legacy, kept as the parity oracle): realizations
    are folded into a tall (n·U, B) x (B, n) matmul and the (row, col)
    pairs with col == row's realization are selected afterwards — n x
    wasted FLOPs/memory traffic (4·2·n²·U·B FLOPs), which is exactly the
    waste the batched grid removes.  `tests/test_batched_parity.py` pins
    the two modes bit-identical on every backend for mask-free runs
    (fused and unfused).  With CSPADE enabled the modes are NOT
    comparable bit-for-bit: the mask GEOMETRY differs by design —
    batched mutes per (realization, tile) on the stacked [W_re; W_im] /
    [y_re, y_im] operands, masked mutes tiles of the folded (nU, B) /
    (B, n) planes with thresholds sampled from the real planes only.

Both modes run the same quantize/dequant cascades:

  * FXP2VP conversion of the re/im planes (kernels.vp_quant), or the
    in-register fused cascade (kernels.vp_quant_matmul[_batched]);
  * complex MVM as 4 real VP products (the paper's 4-RM CM structure);
  * CSPADE tile-activity masks muting quiet tile pairs — per (batch,
    tile) in batched mode, i.e. whole quiet realizations get skipped.

Fused vs unfused dispatch (the `fused=None` default): the fused kernel is
chosen when (a) no CSPADE masks are requested — their calibration needs
the materialized planes; (b) the output-grid fan-out is small (<= 4 tiles
per output axis — the fused kernel re-quantizes each operand tile once
per opposing output tile, so past a few tiles the redundant cascade work
outgrows the saved HBM round-trip; batched MVM shapes are a single tile,
so they always qualify); and (c) a kernel backend is active (TPU-native
or interpret — the CPU ref path materializes planes regardless, so fusion
would only re-quantize shared operands).  Numerics are identical on every
path — same cascades throughout.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import FXPFormat, VPFormat
from repro.kernels import autotune, ops, ref, substrate
from .equalizer import EqualizerSpec


def _vp_planes(x, gain, fxp: FXPFormat, vp: VPFormat, interpret):
    return ops.vp_quant(x * gain, fxp, vp, interpret=interpret)


def _decision_tiles(blocks, M: int, K: int, N: int):
    """Tiles used for the fused-vs-unfused decision: the caller's explicit
    blocks, else the autotuner's shape-clamped heuristic (which is also
    what ops.py resolves to absent a tuned cache entry)."""
    return blocks if blocks is not None else autotune.heuristic_blocks(M, K, N)


def _div_tile(sz: int, target: int) -> int:
    """Largest divisor of `sz` that is <= target."""
    t = min(target, sz)
    while sz % t:
        t -= 1
    return t


def _mask_tiles(blocks, M: int, K: int, N: int):
    """Tile grid for the CSPADE paths: explicit blocks win; otherwise the
    heuristic snapped DOWN to exact divisors of the operand shape (mask
    construction reshapes on the grid, so tiles must divide exactly)."""
    if blocks is not None:
        return tuple(blocks)
    h = autotune.heuristic_blocks(M, K, N)
    return (_div_tile(M, h[0]), _div_tile(K, h[1]), _div_tile(N, h[2]))


def _pick_fused(fused: Optional[bool], cspade_q, nm: int, nn: int,
                interpret) -> bool:
    """The fused-vs-unfused dispatch policy (see module docstring)."""
    if fused is not None:
        return fused
    return (cspade_q is None
            and max(nm, nn) <= 4
            and substrate.resolve_backend(interpret) != "ref")


def _rpad(g, ndim: int):
    """Right-pad a gain's shape with 1s to broadcast over trailing dims."""
    g = jnp.asarray(g, jnp.float32)
    return g.reshape(g.shape + (1,) * (ndim - g.ndim))


@jax.jit
def stack_complex_operands(w, y, w_gain=1.0, y_gain=1.0):
    """Pack a complex MVM batch into the 4-RM batched-kernel operands.

    w (..., U, B) complex, y (..., B) complex; gains are scalars or
    arrays broadcasting over the LEADING dims (e.g. per-subcarrier (S,)
    for (S, n, U, B) operands — gains ride outside the quantizer, so
    they fold into the operands here and divide back out of the
    products).  Returns a (..., 2U, B) = [W_re; W_im] rows and
    b (..., B, 2) = [y_re, y_im] columns — the single source of truth
    for the packing shared by the narrowband engine and the wideband
    OFDM path.  Jitted: eagerly this is ~10 dispatched ops per call on
    the serving hot path; fused it is one.
    """
    wg = _rpad(w_gain, w.ndim)
    yg = _rpad(y_gain, y.ndim)
    wr = w.real.astype(jnp.float32) * wg
    wi = w.imag.astype(jnp.float32) * wg
    yr = y.real.astype(jnp.float32) * yg
    yi = y.imag.astype(jnp.float32) * yg
    a = jnp.concatenate([wr, wi], axis=-2)           # (..., 2U, B)
    b = jnp.stack([yr, yi], axis=-1)                 # (..., B, 2)
    return a, b


@jax.jit
def combine_products(out, gain=1.0):
    """(..., 2U, 2) raw 4-RM products -> complex (..., U) estimates.

    `gain` is the w_gain*y_gain product (scalar or broadcastable over
    the leading dims) divided back out of the physical-unit estimate.
    """
    U = out.shape[-2] // 2
    g = _rpad(gain, out.ndim - 1)
    re = (out[..., :U, 0] - out[..., U:, 1]) / g     # Wr yr - Wi yi
    im = (out[..., :U, 1] + out[..., U:, 0]) / g     # Wr yi + Wi yr
    return re + 1j * im


def batched_complex_mvm(
    a: jax.Array,            # (G, 2U, B) float — stacked [W_re; W_im] rows
    b: jax.Array,            # (G, B, 2) float — [y_re, y_im] columns
    fxp_w: FXPFormat, vp_w: VPFormat,
    fxp_y: FXPFormat, vp_y: VPFormat,
    cspade_threshold_quantile: Optional[float] = None,
    interpret: Optional[bool] = None,
    fused: Optional[bool] = None,
    blocks: Optional[tuple] = None,
) -> jax.Array:
    """All four real products of G complex MVMs in ONE batched kernel call.

    Operands are already AGC-scaled into the hardware formats' ranges.
    Returns the raw (G, 2U, 2) product tensor; with U = rows/2:
      out[:, :U, 0] = W_re y_re   out[:, :U, 1] = W_re y_im
      out[:, U:, 0] = W_im y_re   out[:, U:, 1] = W_im y_im
    This is the entry point the wideband OFDM path folds subcarriers into
    (mimo/ofdm.py): anything expressible as a batch of complex MVMs rides
    the same leading batch grid dimension.

    `blocks=None` defers the tile choice to the autotuner (ops.py resolves
    a tuned cache entry, else the shape-clamped heuristic).  The mask-free
    unfused path quantizes to PACKED VP words — one HBM plane per operand.
    """
    G, M, K = a.shape
    N = b.shape[-1]
    dt = _decision_tiles(blocks, M, K, N)
    fused = _pick_fused(fused, cspade_threshold_quantile,
                        -(-M // dt[0]), -(-N // dt[2]), interpret)

    if fused:
        if cspade_threshold_quantile is not None:
            raise ValueError(
                "fused path has no materialized planes to calibrate masks on")
        return ops.vp_quant_matmul_batched(
            a, b, fxp_w, vp_w, fxp_y, vp_y,
            blocks=blocks, interpret=interpret)

    if cspade_threshold_quantile is None:
        # Packed words: half the quantized-operand HBM traffic, outputs
        # bit-identical to the two-plane path (tests/test_packing.py).
        a_w = ops.vp_quant(a, fxp_w, vp_w, interpret=interpret, packed=True)
        b_w = ops.vp_quant(b, fxp_y, vp_y, interpret=interpret, packed=True)
        return ops.vp_matmul_batched(
            a_w, None, b_w, None, vp_w, vp_y,
            blocks=blocks, interpret=interpret)

    # CSPADE calibration needs materialized (m, i) planes, and the masks
    # pin the tile grid — resolve it here and pass it down explicitly.
    tiles = _mask_tiles(blocks, M, K, N)
    a_m, a_i = ops.vp_quant(a, fxp_w, vp_w, interpret=interpret)
    b_m, b_i = ops.vp_quant(b, fxp_y, vp_y, interpret=interpret)

    q = cspade_threshold_quantile
    ta = jnp.quantile(jnp.abs(a), q)
    tb = jnp.quantile(jnp.abs(b), q)
    a_deq = ref.vp_dequant_ref(a_m, a_i, vp_w)
    b_deq = ref.vp_dequant_ref(b_m, b_i, vp_y)
    a_act, b_act = ref.cspade_tile_masks_batched(
        a_deq, b_deq, *tiles, ta, tb)

    return ops.vp_matmul_batched(
        a_m, a_i, b_m, b_i, vp_w, vp_y,
        a_act=a_act, b_act=b_act, blocks=tiles, interpret=interpret)


def _equalize_batched(
    spec: EqualizerSpec, w, y, cspade_threshold_quantile, interpret, fused,
    blocks=None,
):
    a, b = stack_complex_operands(w, y, spec.w_gain, spec.y_gain)
    out = batched_complex_mvm(
        a, b, spec.w_fxp, spec.w_vp, spec.y_fxp, spec.y_vp,
        cspade_threshold_quantile=cspade_threshold_quantile,
        interpret=interpret, fused=fused, blocks=blocks)
    return combine_products(out, spec.w_gain * spec.y_gain)   # (n, U)


def _equalize_masked(
    spec: EqualizerSpec, w, y, cspade_threshold_quantile, interpret, fused,
    blocks=None,
):
    """Legacy masked-diagonal path (the PR-1 engine), kept as the parity
    oracle for the batched grid: fold realizations into the row axis, run
    (nU, B) x (B, n), select each row's own realization column."""
    n, U, B = w.shape
    fxp_y, vp_y = spec.y_fxp, spec.y_vp
    fxp_w, vp_w = spec.w_fxp, spec.w_vp

    wr = w.real.reshape(n * U, B).astype(jnp.float32)
    wi = w.imag.reshape(n * U, B).astype(jnp.float32)
    yr = y.real.T.astype(jnp.float32)   # (B, n)
    yi = y.imag.T.astype(jnp.float32)

    M, K = wr.shape
    N = yr.shape[1]
    dt = _decision_tiles(blocks, M, K, N)
    fused = _pick_fused(fused, cspade_threshold_quantile,
                        -(-M // dt[0]), -(-N // dt[2]), interpret)

    if fused:
        if cspade_threshold_quantile is not None:
            raise ValueError(
                "fused path has no materialized planes to calibrate masks on")

        def mmf(a_f, b_f):
            return ops.vp_quant_matmul(
                a_f, b_f, fxp_w, vp_w, fxp_y, vp_y,
                blocks=blocks, interpret=interpret)

        wrg, wig = wr * spec.w_gain, wi * spec.w_gain
        yrg, yig = yr * spec.y_gain, yi * spec.y_gain
        rr = mmf(wrg, yrg)    # (nU, n)
        ii = mmf(wig, yig)
        ri = mmf(wrg, yig)
        ir = mmf(wig, yrg)
    elif cspade_threshold_quantile is None:
        # Mask-free unfused: packed word planes (one HBM plane each).
        def _packed(x, gain, fxp, vp):
            return ops.vp_quant(
                x * gain, fxp, vp, interpret=interpret, packed=True)

        wr_w = _packed(wr, spec.w_gain, fxp_w, vp_w)
        wi_w = _packed(wi, spec.w_gain, fxp_w, vp_w)
        yr_w = _packed(yr, spec.y_gain, fxp_y, vp_y)
        yi_w = _packed(yi, spec.y_gain, fxp_y, vp_y)

        def mmp(aw, bw):
            return ops.vp_matmul(aw, None, bw, None, vp_w, vp_y,
                                 blocks=blocks, interpret=interpret)

        rr = mmp(wr_w, yr_w)    # (nU, n)
        ii = mmp(wi_w, yi_w)
        ri = mmp(wr_w, yi_w)
        ir = mmp(wi_w, yr_w)
    else:
        tiles = _mask_tiles(blocks, M, K, N)
        wr_m, wr_i = _vp_planes(wr, spec.w_gain, fxp_w, vp_w, interpret)
        wi_m, wi_i = _vp_planes(wi, spec.w_gain, fxp_w, vp_w, interpret)
        yr_m, yr_i = _vp_planes(yr, spec.y_gain, fxp_y, vp_y, interpret)
        yi_m, yi_i = _vp_planes(yi, spec.y_gain, fxp_y, vp_y, interpret)

        q = cspade_threshold_quantile
        ta = jnp.quantile(jnp.abs(wr) * spec.w_gain, q)
        tb = jnp.quantile(jnp.abs(yr) * spec.y_gain, q)
        Wd = ref.vp_dequant_ref(wr_m, wr_i, vp_w) * spec.w_gain
        Yd = ref.vp_dequant_ref(yr_m, yr_i, vp_y) * spec.y_gain
        a_act, b_act = ref.cspade_tile_masks(Wd, Yd, *tiles, ta, tb)

        def mm(am, ai, bm_, bi):
            return ops.vp_matmul(am, ai, bm_, bi, vp_w, vp_y,
                                 a_act=a_act, b_act=b_act, blocks=tiles,
                                 interpret=interpret)

        rr = mm(wr_m, wr_i, yr_m, yr_i)    # (nU, n)
        ii = mm(wi_m, wi_i, yi_m, yi_i)
        ri = mm(wr_m, wr_i, yi_m, yi_i)
        ir = mm(wi_m, wi_i, yr_m, yr_i)

    re = (rr - ii) / (spec.w_gain * spec.y_gain)
    im = (ri + ir) / (spec.w_gain * spec.y_gain)
    # select each row's own realization column
    rows = jnp.arange(n * U)
    cols = rows // U
    s = re[rows, cols] + 1j * im[rows, cols]
    return s.reshape(n, U)


def equalize_vp_kernel(
    spec: EqualizerSpec,
    w: jax.Array,            # (n, U, B) complex
    y: jax.Array,            # (n, B) complex
    cspade_threshold_quantile: Optional[float] = None,
    interpret: Optional[bool] = None,
    fused: Optional[bool] = None,
    mode: str = "batched",
    blocks: Optional[tuple] = None,
) -> jax.Array:
    """s_hat (n, U) complex through the VP kernel path.

    `mode` selects the execution strategy (see module docstring):
    "batched" runs each realization as its own tile program on the batched
    kernel grid; "masked" is the legacy folded (nU, B) x (B, n) matmul
    with diagonal selection.  Mask-free runs are bit-identical across
    modes (batched does 1/n of the work); with
    `cspade_threshold_quantile` set, each mode mutes on its own tile
    geometry and the outputs may differ within the muting perturbation.
    `blocks=None` defers tiling to the autotuner (see kernels.autotune).
    """
    assert spec.is_vp
    if mode == "batched":
        return _equalize_batched(
            spec, w, y, cspade_threshold_quantile, interpret, fused, blocks)
    if mode == "masked":
        return _equalize_masked(
            spec, w, y, cspade_threshold_quantile, interpret, fused, blocks)
    raise ValueError(f"unknown mode {mode!r} (want 'batched' or 'masked')")


def mvm_flops(n: int, U: int, B: int, mode: str = "batched") -> int:
    """Real-MAC FLOP count of one complex equalization batch.

    batched: 4 real products of (U, B)·(B,) per realization = 8·n·U·B.
    masked:  4 folded (nU, B) x (B, n) matmuls = 8·n²·U·B — the n x
    overhead the batched grid removes.
    """
    if mode == "batched":
        return 8 * n * U * B
    if mode == "masked":
        return 8 * n * n * U * B
    raise ValueError(f"unknown mode {mode!r}")
