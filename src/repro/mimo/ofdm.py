"""Wideband OFDM equalization on the truly-batched VP kernel grid.

The paper's workload is one LMMSE MVM per symbol time; a real wideband
system runs that MVM on EVERY OFDM subcarrier of every symbol — S
independent (U, B) x (B,) products per channel use (cf. "Customizing
Number Representation and Precision", Sentieys & Menard 2022, on
per-signal format tuning at scale).  This module grows the narrowband
demo into that serving-shaped pipeline:

  * `generate_wideband_channels`: tapped-delay-line extension of the LoS
    mmWave generator — L delay taps with an exponential power-delay
    profile, DFT across taps gives per-subcarrier frequency responses
    H[s] (correlated across s, like a real frequency-selective channel);
  * `make_wideband_ensemble`: per-subcarrier 16-QAM symbols, AWGN,
    beamspace transform, and LMMSE matrices — shapes carry a leading
    subcarrier axis (S, n, ...);
  * `WidebandCalibrator`: cached per-subcarrier calibration — AGC gains
    per subcarrier (beamspace statistics drift across the band) and,
    optionally, per-subcarrier VP exponent-list selection through
    `core.param_search` (paper Sec. II-D run once per subcarrier, cached
    so repeated symbols/frames reuse the search);
  * `equalize_wideband`: the execution path.  All (subcarrier,
    realization) MVMs fold into ONE leading batch grid dimension of the
    batched VP kernel (`mvm_engine.batched_complex_mvm`) — per-subcarrier
    AGC gains are applied to the operands up front and divided out of the
    products, so a single fused pallas_call serves the whole band.
    `how="vmap"` maps the same computation over the subcarrier axis, and
    `how="shard_map"` shards it over a device mesh axis via
    `parallel.sharding.shard_over_subcarriers` — the fleet-scale layout
    where each device owns a slab of the band.

Execution-path equivalence: the gains ride OUTSIDE the quantizer in every
path (scale in, quantize, divide out), so flat / vmap / shard_map produce
bit-identical estimates; `tests/test_ofdm.py` pins this.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VPFormat, param_search
from .channel import ChannelConfig, generate_channels, awgn
from .beamspace import to_beamspace
from .lmmse import lmmse_matrix
from .equalizer import EqualizerSpec, calibrate
from .mvm_engine import (
    batched_complex_mvm, combine_products, stack_complex_operands,
)
from .sim import qam16_mod


@dataclasses.dataclass(frozen=True)
class OFDMConfig:
    """Wideband dimensioning: S subcarriers over an L-tap delay channel."""

    n_subcarriers: int = 16
    n_taps: int = 4             # delay taps (frequency selectivity)
    tap_decay: float = 1.5      # exponential power-delay-profile constant

    @property
    def S(self) -> int:
        return self.n_subcarriers


def generate_wideband_channels(
    key, cfg: ChannelConfig, ofdm: OFDMConfig, n: int,
) -> jax.Array:
    """Per-subcarrier channels H[s], shape (S, n, B, U) complex64.

    Tapped-delay-line model: each tap is an independent draw of the LoS
    mmWave geometry (same UE population statistics), weighted by an
    exponential power-delay profile; the frequency response at subcarrier
    s is the DFT of the taps, H[s] = sum_l h_l * exp(-2pi*j*s*l/S).
    Power is normalized so E[|H|^2] per antenna matches the narrowband
    generator (the per-stream SNR convention is unchanged).
    """
    L, S = ofdm.n_taps, ofdm.S
    keys = jax.random.split(key, L)
    taps = jnp.stack(
        [generate_channels(k, cfg, n) for k in keys])      # (L, n, B, U)
    pdp = jnp.exp(-jnp.arange(L) / ofdm.tap_decay)
    pdp = pdp / pdp.sum()                                  # unit total power
    taps = taps * jnp.sqrt(pdp)[:, None, None, None].astype(taps.dtype)
    phase = jnp.exp(
        -2j * jnp.pi * jnp.outer(jnp.arange(S), jnp.arange(L)) / S
    ).astype(taps.dtype)                                   # (S, L)
    return jnp.einsum("sl,lnbu->snbu", phase, taps)


@dataclasses.dataclass
class WidebandEnsemble:
    """Per-subcarrier ensembles; every array carries a leading S axis."""

    h_beam: jax.Array   # (S, n, B, U) beamspace channels
    w_beam: jax.Array   # (S, n, U, B) LMMSE matrices
    y_beam: jax.Array   # (S, n, B) received vectors
    s: jax.Array        # (S, n, U) transmitted symbols
    bits: jax.Array     # (S, n, U, 4)
    n0: float

    @property
    def S(self) -> int:
        return self.h_beam.shape[0]


def make_wideband_ensemble(
    key, cfg: ChannelConfig, ofdm: OFDMConfig, n: int, snr_db: float,
) -> WidebandEnsemble:
    """S-subcarrier extension of `sim.make_ensemble` (beamspace domain)."""
    kh, ks, kn = jax.random.split(key, 3)
    h = generate_wideband_channels(kh, cfg, ofdm, n)       # (S, n, B, U)
    n0 = float(10.0 ** (-snr_db / 10.0))
    s, bits = qam16_mod(ks, (ofdm.S, n, cfg.U))
    noise = awgn(kn, (ofdm.S, n, cfg.B), n0)
    y = jnp.einsum("snbu,snu->snb", h, s) + noise
    hb = to_beamspace(h, axis=-2)
    yb = to_beamspace(y, axis=-1)
    wb = lmmse_matrix(hb, n0)
    return WidebandEnsemble(hb, wb, yb, s, bits, n0)


class WidebandCalibrator:
    """Cached per-subcarrier calibration / VP-parameter selection.

    Calibration is a serving-time fixed cost: AGC gains (and, when
    requested, the Sec. II-D exponent-list search) depend only on the
    subcarrier's signal statistics, not on the symbol stream, so they are
    computed once per subcarrier and reused across frames.  The cache key
    is the subcarrier index; `specs_for` vectorizes over the whole band.
    """

    def __init__(self, base_spec: EqualizerSpec):
        assert base_spec.is_vp, "wideband path is the B-VP design"
        self.base_spec = base_spec
        self._spec_cache: Dict[tuple, EqualizerSpec] = {}
        self._vp_cache: Dict[Tuple[int, int, int], VPFormat] = {}

    @staticmethod
    def _fingerprint(x) -> tuple:
        """Cheap content stamp so a DIFFERENT ensemble never hits a stale
        cache entry: shape plus a few leading values (deterministic for a
        given ensemble, negligible next to the calibration itself)."""
        head = np.asarray(jnp.ravel(x)[:4])
        return (x.shape, head.tobytes())

    def spec_for(self, s_idx: int, w_s, y_s) -> EqualizerSpec:
        """AGC-calibrated spec for one subcarrier (cached).

        The cache key includes a fingerprint of the operands, so repeated
        frames of the SAME ensemble reuse the gains while a new ensemble
        (different SNR, different channels) recalibrates instead of
        silently inheriting mismatched gains.
        """
        key = (s_idx, self._fingerprint(w_s), self._fingerprint(y_s))
        if key not in self._spec_cache:
            self._spec_cache[key] = calibrate(self.base_spec, w_s, y_s)
        return self._spec_cache[key]

    def specs_for(self, ens: WidebandEnsemble) -> Sequence[EqualizerSpec]:
        return [self.spec_for(s, ens.w_beam[s], ens.y_beam[s])
                for s in range(ens.S)]

    def search_vp_format(
        self, s_idx: int, w_s, M: Optional[int] = None,
        E: Optional[int] = None, max_samples: int = 100_000,
    ) -> VPFormat:
        """Per-subcarrier exponent-list search (Sec. II-D), cached.

        Runs `param_search.search_exponent_list` on the subcarrier's
        normalized W-plane samples against the base spec's FXP grid.
        """
        M = self.base_spec.w_vp.M if M is None else M
        E = self.base_spec.w_vp.E if E is None else E
        key = (s_idx, M, E)
        if key not in self._vp_cache:
            samples = np.asarray(jnp.real(w_s)).ravel()[:max_samples]
            amax = np.abs(samples).max()
            samples = samples / max(amax, 1e-30)
            fmt, _ = param_search.search_exponent_list(
                samples, self.base_spec.w_fxp, M=M, E=E)
            self._vp_cache[key] = fmt
        return self._vp_cache[key]

    @property
    def cache_sizes(self) -> Tuple[int, int]:
        return len(self._spec_cache), len(self._vp_cache)


def _stack_operands(specs: Sequence[EqualizerSpec], w, y):
    """Scale per-subcarrier and stack into batched-kernel operands.

    w (S, n, U, B), y (S, n, B) -> a (S, n, 2U, B), b (S, n, B, 2) floats
    plus the per-subcarrier gain products (S,) to divide back out.
    Packing itself is `mvm_engine.stack_complex_operands` — one source of
    truth for the 4-RM layout across narrowband and wideband paths.
    """
    gw = jnp.asarray([sp.w_gain for sp in specs], jnp.float32)
    gy = jnp.asarray([sp.y_gain for sp in specs], jnp.float32)
    a, b = stack_complex_operands(w, y, gw, gy)
    return a, b, gw * gy


def equalize_wideband(
    specs: Sequence[EqualizerSpec],
    w: jax.Array,            # (S, n, U, B) complex
    y: jax.Array,            # (S, n, B) complex
    how: str = "flat",
    interpret: Optional[bool] = None,
    fused: Optional[bool] = None,
    mesh=None,
    blocks: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """s_hat (S, n, U) through the batched VP kernel, whole band at once.

    `specs` holds one AGC-calibrated B-VP spec per subcarrier (see
    `WidebandCalibrator`); all must share the same static formats — only
    the gains may differ per subcarrier (gains are applied outside the
    quantizer, so they fold into the operands).

    how="flat": fold (S, n) into one leading batch dim — ONE batched
        kernel launch of S·n tile programs (the serving path).
    how="vmap": `jax.vmap` of the per-subcarrier batch over S (the
        autobatching path; identical numerics).
    how="shard_map": shard the subcarrier axis over `mesh`'s "sc" axis
        via `parallel.sharding.shard_over_subcarriers`, each device
        running the flat path on its slab (requires S % mesh size == 0).

    `blocks=None` defers the kernel tiling to `kernels.autotune`
    (persisted tuned entry when one exists, else the shape-clamped
    heuristic — the MVM tile never pads beyond the (2U, B) x (B, 2)
    operands).
    """
    S, n, U, B = w.shape
    if len(specs) != S:
        raise ValueError(f"need one spec per subcarrier: {len(specs)} != {S}")
    fxp_w, vp_w = specs[0].w_fxp, specs[0].w_vp
    fxp_y, vp_y = specs[0].y_fxp, specs[0].y_vp
    for sp in specs:
        if (sp.w_fxp, sp.w_vp, sp.y_fxp, sp.y_vp) != (
                fxp_w, vp_w, fxp_y, vp_y):
            raise ValueError(
                "wideband batch requires one static format across the band "
                "(only AGC gains may vary per subcarrier)")

    a, b, g = _stack_operands(specs, w, y)

    def _flat(a_f, b_f):
        S_f = a_f.shape[0]
        out = batched_complex_mvm(
            a_f.reshape(S_f * n, 2 * U, B), b_f.reshape(S_f * n, B, 2),
            fxp_w, vp_w, fxp_y, vp_y, interpret=interpret, fused=fused,
            blocks=blocks)
        return out.reshape(S_f, n, 2 * U, 2)

    if how == "flat":
        out = _flat(a, b)
    elif how == "vmap":
        out = jax.vmap(
            lambda a_s, b_s: batched_complex_mvm(
                a_s, b_s, fxp_w, vp_w, fxp_y, vp_y,
                interpret=interpret, fused=fused, blocks=blocks))(a, b)
    elif how == "shard_map":
        from repro.parallel.sharding import shard_over_subcarriers
        out = shard_over_subcarriers(_flat, mesh=mesh, n_subcarriers=S)(a, b)
    else:
        raise ValueError(
            f"unknown how {how!r} (want 'flat', 'vmap' or 'shard_map')")

    return combine_products(out, g)


def wideband_nmse(s_hat, s_true) -> float:
    """Band-averaged NMSE of the equalized symbols."""
    num = float(jnp.mean(jnp.abs(s_hat - s_true) ** 2))
    den = float(jnp.mean(jnp.abs(s_true) ** 2))
    return num / den


def wideband_ber(s_hat, bits) -> float:
    """Hard-decision BER over the whole band."""
    from .sim import qam16_demod_hard

    got = qam16_demod_hard(s_hat)
    return float(jnp.mean(got != bits))
