"""LMMSE preprocessing and equalization (paper Sec. III).

Preprocessing: W = (H^H H + (N0/Es) I)^-1 H^H   (per channel realization)
Equalization:  s_hat = W y                       (one MVM per symbol time)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lmmse_matrix(h: jax.Array, n0_over_es: float) -> jax.Array:
    """W for channel(s) h: (..., B, U) -> (..., U, B)."""
    hh = jnp.swapaxes(h.conj(), -1, -2)           # (..., U, B)
    gram = hh @ h                                  # (..., U, U)
    u = gram.shape[-1]
    reg = gram + n0_over_es * jnp.eye(u, dtype=gram.dtype)
    return jnp.linalg.solve(reg, hh)


def equalize(w: jax.Array, y: jax.Array) -> jax.Array:
    """s_hat = W y for batched w (..., U, B), y (..., B)."""
    return jnp.einsum("...ub,...b->...u", w, y)
