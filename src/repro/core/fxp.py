"""Two's-complement fixed-point quantization in pure JAX integer ops.

Raw representation: int32 arrays holding the W-bit two's-complement
significand (W <= 31).  All shifts are arithmetic (jnp.right_shift on
signed ints sign-extends).
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FXPFormat


def fxp_quantize(x, fmt: FXPFormat, rounding: str = "nearest"):
    """Quantize real `x` to the raw integer FXP grid (saturating).

    rounding: 'nearest' (round-half-away-from-zero, matching common DSP
    quantizers) or 'trunc' (floor, i.e. drop LSBs as hardware truncation).
    """
    scaled = jnp.asarray(x, jnp.float64 if jnp.asarray(x).dtype == jnp.float64 else jnp.float32) * (2.0 ** fmt.F)
    if rounding == "nearest":
        raw = jnp.round(scaled)
    elif rounding == "trunc":
        raw = jnp.floor(scaled)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    raw = jnp.clip(raw, fmt.raw_min, fmt.raw_max)
    return raw.astype(jnp.int32)


def fxp_to_float(raw, fmt: FXPFormat, dtype=jnp.float32):
    """Real value of raw FXP integers."""
    return raw.astype(dtype) * jnp.asarray(2.0 ** (-fmt.F), dtype)


def fxp_saturate(raw, fmt: FXPFormat):
    """Clip raw integers into the W-bit two's-complement range."""
    return jnp.clip(raw, fmt.raw_min, fmt.raw_max).astype(jnp.int32)


def fxp_quantize_value(x, fmt: FXPFormat, rounding: str = "nearest"):
    """Quantize-dequantize: nearest representable FXP real value."""
    return fxp_to_float(fxp_quantize(x, fmt, rounding), fmt)


def choose_fxp_fraction(max_abs: float, W: int) -> FXPFormat:
    """Pick F so that values with |x| <= max_abs fit in FXP(W, F).

    F = W - 1 - ceil(log2(max_abs)) for max_abs > 0; signals normalized to
    (-1, 1) get F = W - 1 (the paper's convention in Sec. III-A).
    """
    import math

    if max_abs <= 0:
        return FXPFormat(W, W - 1)
    int_bits = max(0, math.ceil(math.log2(max_abs + 1e-300)))
    # one extra integer bit if max_abs is an exact power of two boundary case
    if max_abs > (1 << int_bits) - 2.0 ** -(W - 1 - int_bits):
        int_bits += 0  # clip handles the boundary; raw_max saturates
    return FXPFormat(W, W - 1 - int_bits)
