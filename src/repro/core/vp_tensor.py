"""VPTensor: a pytree container for VP-quantized arrays.

Stores the significand plane (int8 for M <= 8, else int16/int32) and the
exponent-index plane (uint8, optionally bit-packed 2-bit/4-bit for storage &
bandwidth accounting).  The format is static aux data, so VPTensor flows
through jit/pjit without retracing on values.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .formats import FXPFormat, VPFormat


def significand_dtype(M: int):
    if M <= 8:
        return jnp.int8
    if M <= 16:
        return jnp.int16
    return jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VPTensor:
    """VP-quantized tensor: significand plane + exponent-index plane."""

    m: jax.Array            # significands, significand_dtype(fmt.M)
    i: jax.Array            # exponent indices, uint8 (unpacked)
    fmt: VPFormat           # static
    fxp: FXPFormat          # static: the FXP grid this was quantized from

    def tree_flatten(self):
        return (self.m, self.i), (self.fmt, self.fxp)

    @classmethod
    def tree_unflatten(cls, aux, children):
        m, i = children
        fmt, fxp = aux
        return cls(m=m, i=i, fmt=fmt, fxp=fxp)

    @property
    def shape(self):
        return self.m.shape

    @property
    def storage_bits_per_element(self) -> float:
        """Packed storage cost: M-bit significand + E-bit index.

        The planes round up to 8-bit container lanes for the significand and
        pack indices at 2^E states per element (e.g. E=2 -> 4 per byte)."""
        sig_bits = jnp.dtype(significand_dtype(self.fmt.M)).itemsize * 8
        return sig_bits + self.fmt.E

    def to_float(self, dtype=jnp.float32) -> jax.Array:
        scales = jnp.asarray([2.0 ** (-fk) for fk in self.fmt.f], dtype)
        return self.m.astype(dtype) * scales[self.i.astype(jnp.int32)]

    def __repr__(self):
        return f"VPTensor(shape={self.m.shape}, fmt={self.fmt}, fxp={self.fxp})"


# ---------------------------------------------------------------------------
# Index-plane bit packing (storage/bandwidth; kernels consume unpacked u8).
# ---------------------------------------------------------------------------

def pack_indices(i: jax.Array, E: int) -> jax.Array:
    """Pack E-bit indices along the last axis into a uint8 plane.

    Requires E in {1, 2, 4, 8} and last-dim divisible by 8//E.
    """
    if E == 0:
        return jnp.zeros(i.shape[:-1] + (0,), jnp.uint8)
    if E not in (1, 2, 4, 8):
        raise ValueError(f"packing supports E in {{1,2,4,8}}, got {E}")
    per = 8 // E
    if i.shape[-1] % per:
        raise ValueError(f"last dim {i.shape[-1]} not divisible by {per}")
    u = i.astype(jnp.uint8).reshape(*i.shape[:-1], i.shape[-1] // per, per)
    out = jnp.zeros(u.shape[:-1], jnp.uint8)
    for j in range(per):
        out = out | jnp.left_shift(u[..., j], jnp.uint8(j * E))
    return out


def unpack_indices(packed: jax.Array, E: int, n: int) -> jax.Array:
    """Inverse of `pack_indices`; `n` is the unpacked last-dim size."""
    if E == 0:
        return jnp.zeros(packed.shape[:-1] + (n,), jnp.uint8)
    per = 8 // E
    shifts = jnp.arange(per, dtype=jnp.uint8) * E
    mask = jnp.uint8((1 << E) - 1)
    u = jnp.bitwise_and(
        jnp.right_shift(packed[..., :, None], shifts), mask
    )
    return u.reshape(*packed.shape[:-1], packed.shape[-1] * per)[..., :n]
