"""VP arithmetic (paper Sec. II-B).

A VP multiplier is a plain FXP multiplier on the significands; the product's
exponent index is the CONCATENATION of the operand indices, and the product's
exponent list is the pairwise sum f_a (+) f_b built offline
(`formats.product_format`).  No exponent addition happens "in hardware" —
downstream VP2FXP consumes the concatenated index directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FXPFormat, VPFormat, product_format
from .convert import vp2fxp


def vp_mul(m_a, i_a, a_fmt: VPFormat, m_b, i_b, b_fmt: VPFormat):
    """Multiply two VP numbers elementwise.

    Returns (m_p, i_p, p_fmt): the significand product (exact, int32 — valid
    for M_a + M_b - 1 <= 31), the concatenated exponent index
    (i_a << E_b) | i_b, and the offline product format.
    """
    m_p = jnp.asarray(m_a, jnp.int32) * jnp.asarray(m_b, jnp.int32)
    i_p = jnp.left_shift(jnp.asarray(i_a, jnp.int32), b_fmt.E) | jnp.asarray(i_b, jnp.int32)
    return m_p, i_p, product_format(a_fmt, b_fmt)


def vp_mul_to_fxp(m_a, i_a, a_fmt: VPFormat, m_b, i_b, b_fmt: VPFormat,
                  out_fmt: FXPFormat):
    """VP x VP -> FXP product, as in the paper's SP-CM (Fig. 10).

    Each real-valued multiplier is followed by a VP2FXP converter so that all
    additions downstream run in plain FXP.
    """
    m_p, i_p, p_fmt = vp_mul(m_a, i_a, a_fmt, m_b, i_b, b_fmt)
    return vp2fxp(m_p, i_p, p_fmt, out_fmt)


def product_scale_lut(a_fmt: VPFormat, b_fmt: VPFormat, dtype=jnp.float32):
    """2^(E_a+E_b)-entry LUT of product scales 2^-(f_a[ia]+f_b[ib]).

    Indexed by the concatenated exponent index — the TPU-native realization
    of "no exponent addition": the only per-product exponent work is one tiny
    table lookup.
    """
    p = product_format(a_fmt, b_fmt)
    return jnp.asarray([2.0 ** (-fv) for fv in p.f], dtype)
