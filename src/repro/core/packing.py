"""Packed VP words: sign+significand+exponent-index in ONE machine word.

The two-plane layout (int8 significand plane + uint8 index plane) ships
every VP element as two HBM bytes even though a paper-class format only
carries M + E <= 16 information bits.  Packing both fields into a single
integer word — the software analogue of how fixed-posit packs all fields
into one word (Gohil et al.) — halves the HBM traffic whenever the format
fits one byte (M + E <= 8, e.g. the Table-I y format VP(7,[1,-1])) and
never costs more than the two planes did.

Word layout (``w`` is two's complement, E = index bitwidth):

        bit:  [ S-1 ............ E | E-1 ...... 0 ]
               sign + significand m  exponent index i

i.e. ``w = (m << E) | i`` = ``m * 2^E + i`` (the low E bits of ``m << E``
are zero, so bit-or IS addition).  Unpacking is two machine ops:
``m = w >> E`` (arithmetic shift — the sign rides the top bit for free)
and ``i = w & (K - 1)``; both are exactly what `substrate.unpack_cascade`
runs in-kernel.

These functions are pure jnp and serve as the round-trip oracle for the
in-kernel unpack path (tests/test_packing.py property-tests
``unpack_vp(pack_vp(m, i)) == (m, i)`` over random formats).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .formats import VPFormat

# Widest format served by the offline whole-word dequant LUT: one gather
# from a 2^bits-entry f32 table.  12 information bits = 4096 entries
# (16 KiB) — beyond that the table outgrows cache locality and the
# two-op unpack + exponent scale wins.  `repro.analysis` references this
# constant when budgeting LUT-consuming paths.
WORD_LUT_MAX_BITS = 12


def storage_dtype(fmt: VPFormat):
    """The packed-word dtype for a format: int8 / int16 / int32."""
    bits = fmt.storage_bits
    if bits <= 8:
        return jnp.int8
    if bits <= 16:
        return jnp.int16
    return jnp.int32


def pack_vp(m, i, fmt: VPFormat):
    """Pack (significand, index) planes into one packed-word plane.

    `m` int (any int dtype) in [raw_min, raw_max], `i` int in [0, K);
    returns ``(m << E) | i`` in `storage_dtype(fmt)` — one byte per
    element when M + E <= 8, two when <= 16.
    """
    E = fmt.E
    w = jnp.left_shift(m.astype(jnp.int32), E)
    w = jnp.bitwise_or(w, i.astype(jnp.int32))
    return w.astype(storage_dtype(fmt))


def unpack_vp(w, fmt: VPFormat):
    """Invert `pack_vp`: packed words -> (int32 significand, int32 index).

    The arithmetic right shift sign-extends the significand; the mask
    K - 1 extracts the index from the low bits (two's-complement low bits
    are position-valued regardless of sign).
    """
    wi = w.astype(jnp.int32)
    m = jnp.right_shift(wi, fmt.E)
    i = jnp.bitwise_and(wi, fmt.K - 1)
    return m, i


# ---------------------------------------------------------------------------
# Whole-word dequant LUT (the paper's offline exponent LUT, word-granular)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dequant_lut_np(fmt: VPFormat) -> np.ndarray:
    """Offline table: packed-word low bits -> real value, 2^(M+E) entries.

    A packed VP word carries only M + E information bits, so the ENTIRE
    dequant (sign-extend, index extract, exponent scale) collapses into
    one table lookup built offline — the software analogue of the paper's
    Sec. II-B offline LUTs, lifted from exponent-granular to
    word-granular.  Every entry is (M-bit int) * 2^-f_i: exactly
    representable in f32, so LUT dequant is BIT-IDENTICAL to the
    shift/mask/scale path (tests/test_packing.py pins it).
    """
    bits = fmt.M + fmt.E
    assert bits <= WORD_LUT_MAX_BITS, fmt
    idx = np.arange(1 << bits)
    m = (idx >> fmt.E).astype(np.int64)
    m = np.where(m >= (1 << (fmt.M - 1)), m - (1 << fmt.M), m)
    i = idx & (fmt.K - 1)
    return (m * (2.0 ** (-np.asarray(fmt.f, np.float64))[i])).astype(
        np.float32)


def dequant_words(w, fmt: VPFormat, dtype=jnp.float32):
    """Packed words -> real values via the cheapest exact path.

    Formats up to 12 information bits (4096-entry table) dequantize with
    ONE gather from the offline word LUT; wider formats (or non-f32
    consumers, where LUT entries would round) fall back to the two-op
    unpack + exponent scale.  Both are exact and bit-identical in f32.
    """
    bits = fmt.M + fmt.E
    if bits <= WORD_LUT_MAX_BITS and dtype == jnp.float32:
        lut = jnp.asarray(_dequant_lut_np(fmt))
        u = jnp.bitwise_and(w.astype(jnp.int32), (1 << bits) - 1)
        return jnp.take(lut, u, axis=0)
    m, i = unpack_vp(w, fmt)
    scales = jnp.asarray([2.0 ** (-fk) for fk in fmt.f], dtype)
    return m.astype(dtype) * scales[i]
