"""FXP <-> VP conversion (paper Sec. II-C / II-E), bit-exact in pure JAX.

The paper's FXP2VP circuit checks, for each fractional-length option f_k,
whether the MSBs x[W-1 : M+(F-f_k)-1] are all equal (redundant sign bits),
feeds the K check bits to a leading-one detector to pick the smallest valid
index i (largest f_i, i.e. most precision), and muxes out the significand
window x[(F-f_i)+M-1 : (F-f_i)].

Arithmetic equivalence used here (property-tested in tests/test_convert.py
against the literal bit-window oracle `fxp2vp_bitwindow`):

  MSBs of x above bit position (M + s_k - 1) all equal, where s_k = F - f_k
    <=>  the arithmetic right shift (x >> s_k) fits in M signed bits.

Because f is sorted DESCENDING, s_k is ascending and validity is monotone in
k, so `argmax(valid)` is exactly the LOD output.

When the Sec. II-D no-overflow condition (W - F == M - min(f)) does not hold
for a given format, the last option can still overflow; we saturate the
significand in that case (flagged by `fxp2vp(..., return_overflow=True)`).
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FXPFormat, VPFormat


def _shift(v, s: int):
    """Arithmetic shift by static amount s (right for s>0, left for s<0)."""
    if s >= 0:
        return jnp.right_shift(v, s)
    return jnp.left_shift(v, -s)


def fxp2vp(raw, fxp: FXPFormat, vp: VPFormat, return_overflow: bool = False):
    """Convert raw FXP(W,F) integers to VP(M,f) (significand, index).

    Args:
      raw: int32 array of W-bit two's-complement raw values.
      return_overflow: also return a bool array marking saturated elements.

    Returns:
      (m, i[, overflow]): int32 significands in [-2^(M-1), 2^(M-1)-1],
      int32 exponent indices in [0, K).
    """
    raw = jnp.asarray(raw, jnp.int32)
    lo, hi = vp.raw_min, vp.raw_max

    m_sel = None
    i_sel = None
    valid_any = None
    # Unrolled over the (static, small) exponent list: first valid k wins.
    for k in range(vp.K):
        s_k = fxp.F - vp.f[k]
        m_k = _shift(raw, s_k)
        valid_k = (m_k >= lo) & (m_k <= hi)
        if m_sel is None:
            m_sel = jnp.where(valid_k, m_k, 0)
            i_sel = jnp.where(valid_k, 0, 0)
            valid_any = valid_k
        else:
            take = valid_k & ~valid_any
            m_sel = jnp.where(take, m_k, m_sel)
            i_sel = jnp.where(take, k, i_sel)
            valid_any = valid_any | valid_k
    # No valid option (format violates the no-overflow rule): saturate at the
    # smallest fractional length.
    s_last = fxp.F - vp.f[-1]
    m_last = jnp.clip(_shift(raw, s_last), lo, hi)
    overflow = ~valid_any
    m = jnp.where(overflow, m_last, m_sel).astype(jnp.int32)
    i = jnp.where(overflow, vp.K - 1, i_sel).astype(jnp.int32)
    if return_overflow:
        return m, i, overflow
    return m, i


def fxp2vp_bitwindow(raw, fxp: FXPFormat, vp: VPFormat):
    """Literal bit-window oracle of the paper's Fig. 3 circuit.

    Implements the MSB-equality checks + LOD + mux exactly as described, by
    explicit bit extraction on the W-bit two's-complement pattern.  Used only
    in tests to prove `fxp2vp` is bit-identical to the published circuit.
    """
    raw = jnp.asarray(raw, jnp.int32)
    W, F, M = fxp.W, fxp.F, vp.M
    # Unsigned W-bit pattern of the two's-complement value.
    u = jnp.where(raw < 0, raw + (1 << W), raw).astype(jnp.uint32)

    def bit(pos):
        return (jnp.right_shift(u, pos) & jnp.uint32(1)).astype(jnp.int32)

    m_sel, i_sel, valid_any = None, None, None
    for k in range(vp.K):
        s_k = F - vp.f[k]
        top = M + s_k - 1  # lowest MSB position that must match the sign
        # Equality of bits [W-1 : top]; positions outside [0, W-1] count as
        # the sign bit (sign extension of the stored pattern).
        ref = bit(W - 1)
        eq = jnp.ones_like(raw, bool)
        for pos in range(max(top, 0), W - 1):
            eq = eq & (bit(pos) == ref)
        if top < 0:
            # Window extends below the LSB: bits there are zero-padded; they
            # must also equal the sign for the check to pass.
            eq = eq & (ref == 0)
        # Significand window: bits [s_k + M - 1 : s_k] (s_k may be negative
        # for left shifts; out-of-range-low bits read as 0, high as sign).
        m_k = jnp.zeros_like(raw)
        for j in range(M):
            pos = s_k + j
            if pos < 0:
                b = jnp.zeros_like(raw)
            elif pos <= W - 1:
                b = bit(pos)
            else:
                b = ref
            m_k = m_k + jnp.left_shift(b, j)
        # Interpret the M-bit window as two's complement.
        m_k = jnp.where(m_k >= (1 << (M - 1)), m_k - (1 << M), m_k)
        if m_sel is None:
            m_sel, i_sel, valid_any = jnp.where(eq, m_k, 0), jnp.zeros_like(raw), eq
        else:
            take = eq & ~valid_any
            m_sel = jnp.where(take, m_k, m_sel)
            i_sel = jnp.where(take, k, i_sel)
            valid_any = valid_any | eq
    m_last = jnp.clip(_shift(raw, F - vp.f[-1]), vp.raw_min, vp.raw_max)
    m = jnp.where(valid_any, m_sel, m_last).astype(jnp.int32)
    i = jnp.where(valid_any, i_sel, vp.K - 1).astype(jnp.int32)
    return m, i


def vp2fxp(m, i, vp: VPFormat, fxp: FXPFormat, saturate: bool = True):
    """Convert VP(M,f) (significand, index) to raw FXP(W,F) integers.

    Paper Sec. II-E: zero-pad W-M LSBs then arithmetic right shift by
    S_k = (W-F) - (M-f_k); equivalently raw = m * 2^(F - f_k) with
    truncation when F < f_k.  Unrolled mux over the static exponent list.
    """
    m = jnp.asarray(m, jnp.int32)
    i = jnp.asarray(i, jnp.int32)
    out = jnp.zeros_like(m)
    for k in range(vp.K):
        s = fxp.F - vp.f[k]  # left-shift amount (negative => right shift)
        out = jnp.where(i == k, _shift(m, -s), out)
    if saturate:
        out = jnp.clip(out, fxp.raw_min, fxp.raw_max)
    return out.astype(jnp.int32)


def vp_to_float(m, i, vp: VPFormat, dtype=jnp.float32):
    """Exact real value of VP numbers: m * 2^(-f_i) (eq. 1)."""
    m = jnp.asarray(m)
    scales = jnp.asarray([2.0 ** (-fk) for fk in vp.f], dtype)
    return m.astype(dtype) * scales[i]


def float_to_vp(x, fxp: FXPFormat, vp: VPFormat, rounding: str = "nearest"):
    """Real -> FXP(W,F) -> VP(M,f); the paper's ingestion pipeline."""
    from .fxp import fxp_quantize

    return fxp2vp(fxp_quantize(x, fxp, rounding), fxp, vp)
