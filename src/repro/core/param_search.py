"""Monte-Carlo VP parameter selection (paper Sec. II-D).

"The optimal parameters are determined for each signal individually using
Monte-Carlo simulations to ensure that the precision loss is negligible for
the target application.  In general, we set max(f) = F ... and min(f) such
that W - F = M - min(f)."

Given samples of a signal (already in, or quantized to, a reference
FXP(W, F) grid), we search:

  * the exponent list `f` for fixed (M, E): endpoints pinned by the Sec. II-D
    rules, interior entries chosen by exhaustive/greedy MSE minimization over
    the samples;
  * the smallest significand width M meeting an NMSE target.
"""
from __future__ import annotations

import itertools
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .formats import FXPFormat, VPFormat
from .fxp import fxp_quantize
from .convert import fxp2vp, vp_to_float


def vp_nmse(samples: np.ndarray, fxp: FXPFormat, vp: VPFormat) -> float:
    """NMSE of representing `samples` in VP(M, f) via the FXP(W,F) grid."""
    import jax.numpy as jnp

    x = np.asarray(samples, np.float64).ravel()
    raw = np.asarray(fxp_quantize(x.astype(np.float32), fxp))
    m, i = fxp2vp(raw, fxp, vp)
    xq = np.asarray(vp_to_float(m, i, vp, jnp.float64))
    num = float(np.mean((xq - x) ** 2))
    den = float(np.mean(x**2)) + 1e-300
    return num / den


def candidate_lists(fxp: FXPFormat, M: int, E: int) -> Sequence[Tuple[int, ...]]:
    """All descending exponent lists with Sec. II-D endpoint rules."""
    K = 1 << E
    top = fxp.F                    # max(f) = F
    bot = M - (fxp.W - fxp.F)      # W - F = M - min(f)
    if bot > top:
        raise ValueError(f"infeasible: M={M} too large for {fxp} (bot {bot} > top {top})")
    if K == 1:
        return [(top,)]
    if K == 2:
        return [(top, bot)]
    interior = list(range(bot + 1, top))
    lists = []
    for combo in itertools.combinations(interior, K - 2):
        lists.append(tuple(sorted((top, bot) + combo, reverse=True)))
    return lists


def search_exponent_list(
    samples: np.ndarray,
    fxp: FXPFormat,
    M: int,
    E: int,
    max_exhaustive: int = 20000,
    seed: int = 0,
) -> Tuple[VPFormat, float]:
    """Best exponent list for fixed (M, E) by MSE over the samples.

    Exhaustive when the candidate count is small; otherwise greedy forward
    selection (add the interior entry that most reduces MSE, K-2 times).
    Returns (format, nmse).
    """
    cands = candidate_lists(fxp, M, E)
    if len(cands) <= max_exhaustive:
        best, best_err = None, math.inf
        for f in cands:
            err = vp_nmse(samples, fxp, VPFormat(M, f))
            if err < best_err:
                best, best_err = f, err
        return VPFormat(M, best), best_err
    # Greedy forward selection.
    K = 1 << E
    top, bot = fxp.F, M - (fxp.W - fxp.F)
    chosen = [top, bot]
    pool = [v for v in range(bot + 1, top)]
    while len(chosen) < K:
        best_v, best_err = None, math.inf
        for v in pool:
            f = tuple(sorted(chosen + [v], reverse=True))
            # Pad to a power of two by duplicating nothing — evaluate on the
            # partial list only if it is a power of two; otherwise rank by
            # the padded list with the worst-case duplicate removed.
            if len(f) & (len(f) - 1):
                f = f + (f[-1],) * (2 ** math.ceil(math.log2(len(f))) - len(f))
                f = tuple(sorted(f, reverse=True))
            err = vp_nmse(samples, fxp, VPFormat(M, f))
            if err < best_err:
                best_v, best_err = v, err
        chosen.append(best_v)
        pool.remove(best_v)
    f = tuple(sorted(chosen, reverse=True))
    return VPFormat(M, f), vp_nmse(samples, fxp, VPFormat(M, f))


def search_min_M(
    samples: np.ndarray,
    fxp: FXPFormat,
    E: int,
    nmse_target: float,
    M_range: Tuple[int, int] = (4, 16),
) -> Optional[Tuple[VPFormat, float]]:
    """Smallest M whose best exponent list meets `nmse_target`."""
    for M in range(M_range[0], M_range[1] + 1):
        try:
            fmt, err = search_exponent_list(samples, fxp, M, E)
        except ValueError:
            continue
        if err <= nmse_target:
            return fmt, err
    return None
