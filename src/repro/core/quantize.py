"""Tensor-level VP quantization API.

Pipeline (paper Sec. II-A): real -> FXP(W, F) -> VP(M, f).  This module
packages that pipeline for ML tensors:

  * `vp_quantize` / `vp_dequantize`: bit-exact VPTensor round trip.
  * `vp_fake_quant` + `vp_fake_quant_ste`: quantize-dequantize in one float
    graph (for accuracy sims and QAT; STE passes gradients through).
  * per-channel format selection for weight matrices.
  * `block_vp_quantize`: the TPU-native block-VP variant — one exponent index
    per block of elements (the VP analogue of BFP, still with an arbitrary
    exponent list), enabling int8 MXU matmuls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .formats import FXPFormat, VPFormat
from .fxp import fxp_quantize
from .convert import fxp2vp, vp_to_float
from .packing import dequant_words, pack_vp
from .vp_tensor import VPTensor, significand_dtype


def vp_quantize(x, fxp: FXPFormat, vp: VPFormat, rounding: str = "nearest") -> VPTensor:
    """Real tensor -> VPTensor through the FXP(W,F) grid."""
    raw = fxp_quantize(x, fxp, rounding)
    m, i = fxp2vp(raw, fxp, vp)
    return VPTensor(
        m=m.astype(significand_dtype(vp.M)),
        i=i.astype(jnp.uint8),
        fmt=vp,
        fxp=fxp,
    )


def vp_dequantize(t: VPTensor, dtype=jnp.float32) -> jax.Array:
    return t.to_float(dtype)


def vp_fake_quant(x, fxp: FXPFormat, vp: VPFormat, rounding: str = "nearest"):
    """Quantize-dequantize: the VP-representable value nearest-ish to x.

    ('nearest-ish': FXP rounds to nearest; the FXP2VP bit-window then
    truncates dropped LSBs, exactly like the hardware.)"""
    raw = fxp_quantize(x, fxp, rounding)
    m, i = fxp2vp(raw, fxp, vp)
    return vp_to_float(m, i, vp, jnp.asarray(x).dtype)


@jax.custom_vjp
def _ste(x, y):
    """Forward y, backward identity onto x."""
    return y


def _ste_fwd(x, y):
    return y, None


def _ste_bwd(_, g):
    return g, None


_ste.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def _ste_clipped(x, y, lo, hi):
    """Forward y; backward identity onto x INSIDE [lo, hi], zero outside."""
    return y


def _ste_clipped_fwd(x, y, lo, hi):
    return y, (x, lo, hi)


def _ste_clipped_bwd(res, g):
    x, lo, hi = res
    inside = jnp.logical_and(x >= lo, x <= hi)
    return jnp.where(inside, g, 0).astype(g.dtype), None, None, None


_ste_clipped.defvjp(_ste_clipped_fwd, _ste_clipped_bwd)


def vp_fake_quant_ste(x, fxp: FXPFormat, vp: VPFormat,
                      clip_grad: bool = False):
    """QAT straight-through estimator around `vp_fake_quant`.

    ``clip_grad=False`` is the classic STE (gradient passes everywhere —
    the historical behaviour, kept as the default so existing fake-quant
    graphs are unchanged).  ``clip_grad=True`` zeroes the gradient where
    x saturated the FXP(W, F) envelope — those elements moved to the clip
    rail, their quantizer Jacobian really is 0, and letting gradient
    through drags saturated weights further out of range.
    """
    y = vp_fake_quant(x, fxp, vp)
    if clip_grad:
        return _ste_clipped(
            x, y,
            jnp.asarray(fxp.min, jnp.asarray(x).dtype),
            jnp.asarray(fxp.max, jnp.asarray(x).dtype))
    return _ste(x, y)


# ---------------------------------------------------------------------------
# Packed-word tensor codec (shared by gradient compression and optimizer
# moment storage — lives here, below both, to avoid an optim <-> train
# import cycle)
# ---------------------------------------------------------------------------

def vp_pack_tensor(x, fxp: FXPFormat, vp: VPFormat):
    """Real tensor (any rank, any float dtype) -> (packed words, scale).

    The memory codec behind VP-packed gradient compression
    (`train.compression`) and packed optimizer moments
    (`optim.optimizer`): a per-tensor POWER-OF-TWO scale (exact under VP
    semantics — dividing by 2^k only shifts exponents, it never rounds)
    brings max|x| into (-1, 1], then real -> FXP(W, F) -> VP(M, f) ->
    `core.packing` words at `vp.storage_bits` bits per element.  Returns
    (words, f32 scalar scale); an all-zero tensor gets scale 1.0.
    """
    xf = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))))
    scale = jnp.where(amax > 0, s, 1.0).astype(jnp.float32)
    raw = fxp_quantize(xf / scale, fxp)
    m, i = fxp2vp(raw, fxp, vp)
    return pack_vp(m, i, vp), scale


def vp_unpack_tensor(w, scale, vp: VPFormat, dtype=jnp.float32):
    """Invert `vp_pack_tensor`: (words, scale) -> real tensor."""
    return dequant_words(w, vp, dtype) * scale.astype(dtype)


# ---------------------------------------------------------------------------
# Per-channel formats for weight matrices
# ---------------------------------------------------------------------------

def per_channel_fxp_scales(w: jax.Array, W: int, axis: int = 0):
    """Power-of-two per-channel F so each channel fits FXP(W, F).

    Returns int32 F per channel along `axis`'s complement (reduce over
    `axis`).  Power-of-two scales keep the VP semantics exact."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    # F = W-1-ceil(log2(amax)); amax<=0 -> F = W-1
    f = jnp.where(
        amax > 0,
        (W - 1) - jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))),
        W - 1,
    )
    return f.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Block VP (beyond-paper, TPU-native): shared exponent index per block
# ---------------------------------------------------------------------------

def block_vp_quantize(
    x: jax.Array,
    fxp: FXPFormat,
    vp: VPFormat,
    block: int,
    axis: int = -1,
) -> Tuple[jax.Array, jax.Array]:
    """Quantize with ONE exponent index per `block` contiguous elements.

    The shared index for a block is the per-element FXP2VP index of the
    block's largest-magnitude element (the element needing the smallest
    fractional length) — every element in the block is then representable
    without overflow at that fractional length, mirroring BFP's max-exponent
    rule but over the arbitrary VP exponent list.

    Returns (m, i_block): significands shaped like x, indices with the
    blocked axis reduced by `block`.
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    if n % block:
        raise ValueError(f"axis size {n} not divisible by block {block}")
    raw = fxp_quantize(x, fxp)
    # Per-element index, then max over each block (larger index = smaller
    # fractional length since f is descending).
    _, i_elt = fxp2vp(raw, fxp, vp)
    shp = list(x.shape)
    shp[axis: axis + 1] = [n // block, block]
    i_blk = jnp.max(i_elt.reshape(shp), axis=axis + 1)
    # Re-quantize every element at the block's fractional length.
    i_full = jnp.repeat(i_blk, block, axis=axis)
    m = jnp.zeros_like(raw)
    for k in range(vp.K):
        s_k = fxp.F - vp.f[k]
        m_k = jnp.right_shift(raw, s_k) if s_k >= 0 else jnp.left_shift(raw, -s_k)
        m = jnp.where(i_full == k, m_k, m)
    m = jnp.clip(m, vp.raw_min, vp.raw_max)
    return m.astype(significand_dtype(vp.M)), i_blk.astype(jnp.uint8)


def block_vp_dequantize(m, i_blk, vp: VPFormat, block: int, axis: int = -1,
                        dtype=jnp.float32):
    axis = axis % m.ndim
    scales = jnp.asarray([2.0 ** (-fk) for fk in vp.f], dtype)
    s = scales[i_blk.astype(jnp.int32)]
    s = jnp.repeat(s, block, axis=axis)
    return m.astype(dtype) * s
