"""Gate-level area/power model for the paper's VLSI comparisons.

The paper reports post-layout 22nm numbers (Fig. 11, Sec. V): B-VP saves ~20%
area and 10-14% power vs B-FXP, B-FXP is ~25% larger than A-FXP, and a
custom-FLP CMAC array is ~3.4x the area of the VP CMAC array.  Silicon
cannot be re-measured here; this module reproduces the comparisons with a
transparent unit-gate model (standard GE accounting: NAND2 = 1 GE).

Only RATIOS between designs are meaningful; the single multiplier constant
is shared by all designs, so ratios are calibration-free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from .formats import FXPFormat, VPFormat, product_format

# Unit-gate costs (GE), standard cell-library accounting.
FA = 5.0          # full adder
HA = 3.0          # half adder
AND = 1.0
XNOR = 2.0
MUX_BIT = 2.0     # 2:1 mux per bit (AOI-based datapath mux)
FF = 4.5          # flip-flop per bit
INV = 0.5


def adder_area(W: int) -> float:
    """Ripple/compact CLA adder, W bits."""
    return FA * W


def multiplier_area(Wa: int, Wb: int) -> float:
    """Signed (Baugh-Wooley) array multiplier Wa x Wb.

    PP generation Wa*Wb AND gates + reduction tree ~ (Wa*Wb - Wa - Wb) FAs
    + final (Wa+Wb)-bit adder.
    """
    pp = AND * Wa * Wb
    red = FA * max(Wa * Wb - Wa - Wb, 0)
    final = adder_area(Wa + Wb)
    return pp + red + final


def mux_area(W: int, K: int) -> float:
    """K-way W-bit select.

    The converter muxes select among SHIFTED copies of one word, so they
    synthesize as a log2(K)-stage barrel structure (not a flat K-1 mux
    chain); datapath compilers exploit this.
    """
    if K <= 1:
        return 0.0
    return MUX_BIT * W * math.ceil(math.log2(K))


def eq_check_area(bits: int) -> float:
    """All-equal detector over `bits` bits: (bits-1) XNORs + AND tree."""
    if bits <= 1:
        return 0.0
    return XNOR * (bits - 1) + AND * (bits - 2 if bits > 2 else 0)


def lod_area(K: int) -> float:
    """Leading-one detector over K check bits -> log2(K)-bit index."""
    return 3.0 * K


def fxp2vp_area(fxp: FXPFormat, vp: VPFormat) -> float:
    """Fig. 3: K MSB-equality checks + LOD + K-way M-bit significand mux."""
    total = 0.0
    for fk in vp.f:
        s_k = fxp.F - fk
        win = fxp.W - (vp.M + s_k - 1)  # bits [W-1 : M+s_k-1]
        total += eq_check_area(max(win, 0))
    total += lod_area(vp.K)
    total += mux_area(vp.M, vp.K)
    return total


def vp2fxp_area(vp: VPFormat, fxp: FXPFormat) -> float:
    """Fig. 5: shifts are wiring; K-way W-bit mux dominates."""
    return mux_area(fxp.W, vp.K)


def barrel_shifter_area(W: int) -> float:
    return MUX_BIT * W * max(math.ceil(math.log2(max(W, 2))), 1)


def flp_mult_area(Wm: int, We: int) -> float:
    """Custom (non-IEEE, no denormals/NaN) FLP multiplier.

    Beyond the significand multiplier: exponent add + bias, 1-bit
    normalization, and round-to-nearest with guard/sticky (sticky OR-tree
    over Wm low product bits + incrementer + overflow exponent fixup).
    Literature half-precision-class FLP multipliers land near 2.5-3x the
    bare significand multiplier; this composition reproduces that.
    """
    g = 3  # guard/round/sticky datapath widening
    return (
        multiplier_area(Wm, Wm)
        + 2 * adder_area(We)               # exponent add + bias/overflow fixup
        + mux_area(Wm + g, 2)              # 1-bit normalize shift
        + AND * Wm                         # sticky OR tree
        + adder_area(Wm + 1)               # rounding incrementer
        + FF * (Wm + We)                   # 1 GHz pipeline stage
    )


def flp_adder_area(Wm: int, We: int) -> float:
    """Custom FLP adder: the component that makes FLP MACs expensive.

    Swap + alignment barrel + effective-subtract negate + wide (guarded)
    add + leading-zero anticipation + normalization barrel + round + exp
    update, plus a pipeline stage to make timing at 1 GHz.  Unit-gate
    totals reproduce published ~1.2-1.5 kGE half-precision-class adders.
    """
    g = 3
    Wd = Wm + g
    return (
        adder_area(We)                     # exponent difference
        + mux_area(2 * Wd, 2)              # operand swap
        + barrel_shifter_area(Wd)          # alignment shifter
        + AND * Wm                         # sticky collection
        + XNOR * Wd + adder_area(Wd)       # effective-subtract negate (XOR+cin)
        + adder_area(Wd + 1)               # significand add
        + 6.0 * Wd                         # leading-zero anticipator
        + barrel_shifter_area(Wd)          # normalization shifter
        + adder_area(Wm + 1)               # round incrementer
        + 2 * adder_area(We)               # exponent update / clamp
        + FF * (Wm + We + g)               # 1 GHz pipeline stage
    )


# ---------------------------------------------------------------------------
# Design specs (Table I) and hierarchical areas
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MVMSpec:
    """One equalizer design: U DOTP units x B complex multipliers."""

    name: str
    B: int
    U: int
    # FXP formats of the two operand streams (post-quantization).
    y_fxp: FXPFormat
    w_fxp: FXPFormat
    # VP formats (None => pure-FXP design).
    y_vp: Optional[VPFormat] = None
    w_vp: Optional[VPFormat] = None
    cspade: bool = False

    @property
    def is_vp(self) -> bool:
        return self.y_vp is not None


def _rm_operands(spec: MVMSpec) -> Tuple[int, int]:
    """Real-multiplier operand widths."""
    if spec.is_vp:
        return spec.y_vp.M, spec.w_vp.M
    return spec.y_fxp.W, spec.w_fxp.W


def _product_fxp(spec: MVMSpec) -> FXPFormat:
    """FXP format carried into the adder tree."""
    if spec.is_vp:
        p = product_format(spec.y_vp, spec.w_vp)
        # Integer bits to hold the largest product, fraction = max(f_p).
        frac = max(p.f)
        max_val = (2 ** (p.M - 1)) * 2.0 ** (-min(p.f))
        int_bits = max(1, math.ceil(math.log2(max_val + 1)))
        return FXPFormat(int_bits + frac + 1, frac)
    return FXPFormat(spec.y_fxp.W + spec.w_fxp.W,
                     spec.y_fxp.F + spec.w_fxp.F)


def cm_area(spec: MVMSpec) -> Dict[str, float]:
    """Complex multiplier (Fig. 10): 4 RMs + 2 adders (+ VP2FXP, CSPADE)."""
    wa, wb = _rm_operands(spec)
    prod = _product_fxp(spec)
    rm = 4 * multiplier_area(wa, wb)
    add = 2 * adder_area(prod.W + 1)
    conv = 4 * vp2fxp_area(product_format(spec.y_vp, spec.w_vp), prod) if spec.is_vp else 0.0
    cspade = 0.0
    if spec.cspade:
        # Threshold comparators on |re|+|im| of both operands + muting gates.
        cspade = 2 * adder_area(max(spec.y_fxp.W, spec.w_fxp.W)) + 4 * AND * (wa + wb)
    return {"rm": rm, "cm_add": add, "conv": conv, "cspade": cspade}


def dotp_area(spec: MVMSpec) -> Dict[str, float]:
    """One dot-product unit: B CMs + pipelined complex adder tree."""
    acc = _product_fxp(spec).W + math.ceil(math.log2(spec.B))
    parts = {k: spec.B * v for k, v in cm_area(spec).items()}
    # (B-1) complex adders = 2(B-1) real adders + pipeline FFs every 2 levels.
    parts["tree_add"] = 2 * (spec.B - 1) * adder_area(acc)
    levels = math.ceil(math.log2(spec.B))
    parts["pipe_ff"] = 2 * acc * spec.B * FF * (levels // 2) / 2
    # Weight-register file: B complex weights per DOTP.
    parts["w_reg"] = 2 * spec.B * spec.w_fxp.W * FF
    return parts


def mvm_area(spec: MVMSpec) -> Dict[str, float]:
    """Full MVM engine: U DOTPs + input FXP2VP converters (VP design)."""
    parts = {k: spec.U * v for k, v in dotp_area(spec).items()}
    if spec.is_vp:
        # One FXP2VP pair (y-path + W-path) per real/imag input port (Fig 9c).
        per_port = fxp2vp_area(spec.y_fxp, spec.y_vp) + fxp2vp_area(spec.w_fxp, spec.w_vp)
        parts["conv"] = parts.get("conv", 0.0) + 2 * spec.B * per_port
    return parts


def total(parts: Dict[str, float]) -> float:
    return sum(parts.values())


# ---------------------------------------------------------------------------
# Power: P ~ area x activity, with CSPADE muting on the multipliers
# ---------------------------------------------------------------------------

# Relative switching-activity priors per component class.  Multiplier
# glitching is high per active cycle, but registers/clock switch every
# cycle; these priors are shared by ALL designs (ratios calibration-free).
ACTIVITY = {
    "rm": 0.55,
    "cm_add": 0.55,
    "conv": 0.45,
    "cspade": 0.9,
    "tree_add": 0.6,
    "pipe_ff": 1.0,
    "w_reg": 0.12,     # weights reload only once per coherence block
}


def mvm_power(spec: MVMSpec, muting_rate: float = 0.0,
              power_savings: bool = True) -> Dict[str, float]:
    """Relative dynamic power per component.

    `muting_rate`: fraction of partial products muted by CSPADE (measured
    from channel stimuli); only multipliers (and their product adders/
    converters) see the activity reduction, matching Sec. V-A.
    """
    parts = mvm_area(spec)
    out = {}
    for k, a in parts.items():
        act = ACTIVITY.get(k, 0.5)
        if k in ("rm", "cm_add", "conv") and spec.cspade and power_savings:
            act *= (1.0 - muting_rate)
        out[k] = a * act
    # Clock-tree/network power: switches every cycle regardless of data
    # activity, proportional to the sequential area it drives.
    out["clock"] = 0.6 * (parts.get("pipe_ff", 0.0) + parts.get("w_reg", 0.0))
    return out


# ---------------------------------------------------------------------------
# Sec. V-B: CMAC array, VP vs custom FLP
# ---------------------------------------------------------------------------

def vp_cmac_array_area(spec: MVMSpec) -> float:
    """U CSPADE CMACs: 1 CM + complex accumulator each (+ input converters)."""
    acc = _product_fxp(spec).W + math.ceil(math.log2(spec.B))
    cm = total(cm_area(spec))
    per_cmac = cm + 2 * adder_area(acc) + 2 * acc * FF
    conv_in = 2 * (fxp2vp_area(spec.y_fxp, spec.y_vp)
                   + fxp2vp_area(spec.w_fxp, spec.w_vp)) if spec.is_vp else 0.0
    return spec.U * per_cmac + conv_in


def flp_cmac_array_area(U: int, Wm: int = 10, We: int = 4) -> float:
    """Custom FLP(1 sign + 9-bit mantissa + 4-bit exp) CMAC array (Sec. V-B).

    Wm includes the sign+mantissa significand datapath width (1+9).
    Complex MAC: 4 FLP mults + 2 FLP adds (cross terms) + 2 FLP accumulators.
    """
    cm = 4 * flp_mult_area(Wm, We) + 2 * flp_adder_area(Wm + 1, We)
    acc = 2 * flp_adder_area(Wm + 3, We) + 2 * (Wm + We) * FF
    return U * (cm + acc)


# ---------------------------------------------------------------------------
# The paper's three designs (Table I)
# ---------------------------------------------------------------------------

def paper_designs(B: int = 64, U: int = 8) -> Dict[str, MVMSpec]:
    return {
        "A-FXP": MVMSpec(
            "A-FXP", B, U,
            y_fxp=FXPFormat(7, 1), w_fxp=FXPFormat(11, 10), cspade=False),
        "B-FXP": MVMSpec(
            "B-FXP", B, U,
            y_fxp=FXPFormat(9, 1), w_fxp=FXPFormat(12, 11), cspade=True),
        "B-VP": MVMSpec(
            "B-VP", B, U,
            y_fxp=FXPFormat(9, 1), w_fxp=FXPFormat(12, 11),
            y_vp=VPFormat(7, (1, -1)), w_vp=VPFormat(7, (11, 9, 7, 6)),
            cspade=True),
    }
