"""Number-format descriptors for FXP and VP numbers.

The paper (Sec. II) defines:
  FXP(W, F): W-bit two's-complement fixed point with F fractional bits.
  VP(M, f):  M-bit two's-complement significand `m` plus an E-bit exponent
             *index* `i` into the exponent list `f` (fractional-length
             options, sorted descending).  Value: x = m * 2**(-f_i).

Formats are static (hashable, usable as jit static args / pytree aux data).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FXPFormat:
    """FXP(W, F): W-bit two's complement, F fractional bits."""

    W: int
    F: int

    def __post_init__(self):
        if self.W < 2:
            raise ValueError(f"FXP width must be >= 2, got W={self.W}")

    # Raw (integer) significand range.
    @property
    def raw_min(self) -> int:
        return -(1 << (self.W - 1))

    @property
    def raw_max(self) -> int:
        return (1 << (self.W - 1)) - 1

    # Real-value range and resolution.
    @property
    def scale(self) -> float:
        return 2.0 ** (-self.F)

    @property
    def min(self) -> float:
        return self.raw_min * self.scale

    @property
    def max(self) -> float:
        return self.raw_max * self.scale

    def __repr__(self) -> str:
        return f"FXP({self.W},{self.F})"


@dataclasses.dataclass(frozen=True)
class VPFormat:
    """VP(M, f): M-bit significand + index into exponent list `f`.

    `f` is the tuple of fractional-length options, sorted descending
    (f_0 >= f_1 >= ... >= f_{K-1}); K = |f| must be a power of two.
    """

    M: int
    f: Tuple[int, ...]

    def __post_init__(self):
        f = tuple(int(v) for v in self.f)
        object.__setattr__(self, "f", f)
        if self.M < 2:
            raise ValueError(f"VP significand must be >= 2 bits, got M={self.M}")
        if len(f) < 1 or (len(f) & (len(f) - 1)) != 0:
            raise ValueError(f"|f| must be a power of two, got {len(f)}")
        if any(f[k] < f[k + 1] for k in range(len(f) - 1)):
            raise ValueError(f"exponent list must be sorted descending, got {f}")

    @property
    def K(self) -> int:
        """Number of exponent options, 2**E."""
        return len(self.f)

    @property
    def E(self) -> int:
        """Exponent-index bitwidth."""
        return int(math.log2(len(self.f)))

    @property
    def raw_min(self) -> int:
        return -(1 << (self.M - 1))

    @property
    def raw_max(self) -> int:
        return (1 << (self.M - 1)) - 1

    @property
    def max_f(self) -> int:
        return self.f[0]

    @property
    def min_f(self) -> int:
        return self.f[-1]

    @property
    def span(self) -> int:
        """Exponent spread max f - min f: the bit headroom a coarse-grid
        value needs when re-expressed on the finest grid 2^-max_f (the
        quantity that drives accumulator bit growth — see
        `repro.analysis.bitwidth`)."""
        return self.max_f - self.min_f

    @property
    def bits_per_element(self) -> float:
        """Information content per element: significand + index bits."""
        return self.M + self.E

    @property
    def storage_bits(self) -> int:
        """HBM bits per element in the PACKED word layout (core.packing).

        Sign + significand + exponent index bit-pack into one int8 when
        M + E <= 8 (e.g. VP(7,[1,-1]): 7 + 1 = 8), one int16 when <= 16
        (VP(7,[11,9,7,6]): 7 + 2 = 9), else int32 — versus 16 bits
        minimum for the two-plane (int8 m + uint8 i) layout.
        """
        bits = self.M + self.E
        for width in (8, 16, 32):
            if bits <= width:
                return width
        raise ValueError(f"M + E = {bits} exceeds the widest packed word")

    @property
    def max(self) -> float:
        """Largest representable magnitude (positive side)."""
        return self.raw_max * 2.0 ** (-self.min_f)

    @property
    def resolution(self) -> float:
        """Finest representable step (at the largest fractional length)."""
        return 2.0 ** (-self.max_f)

    def value(self, m: int, i: int) -> float:
        """Real value of (significand, index) — eq. (1)."""
        return m * 2.0 ** (-self.f[i])

    def __repr__(self) -> str:
        return f"VP({self.M},{list(self.f)})"


def product_format(a: VPFormat, b: VPFormat) -> VPFormat:
    """Exponent list / significand width of a VP*VP product (Sec. II-B).

    The product exponent list is the pairwise sum of the operand lists in
    index-concatenation order ((i_a << E_b) | i_b); it is built OFFLINE and
    handed to the VP2FXP converter — the multiplier itself never adds
    exponents.  The significand product of M_a x M_b two's-complement inputs
    fits in (M_a + M_b - 1) bits for every input pair EXCEPT the single
    extreme case (-2^(Ma-1)) * (-2^(Mb-1)) = +2^(Ma+Mb-2), which exceeds
    the (Ma+Mb-1)-bit signed maximum 2^(Ma+Mb-2)-1 by one (the paper's
    Sec. II-B width claim, with the caveat made explicit —
    `repro.analysis.bitwidth.product_interval` proves the exact interval).
    M here records the paper's multiplier width; nothing in the runtime
    path truncates to it — `vp_mul` computes products exactly in int32
    and `vp2fxp` shifts/clips on the TARGET format only, so the one-off
    case stays exact end to end.

    The pairwise-sum list is generally NOT sorted descending (it is sorted
    within each i_a-block); product VP numbers are only ever consumed by
    VP2FXP, which does not require ordering, so we bypass the descending
    check here via direct construction.
    """
    fp = tuple(fa + fb for fa in a.f for fb in b.f)
    fmt = object.__new__(VPFormat)
    object.__setattr__(fmt, "M", a.M + b.M - 1)
    object.__setattr__(fmt, "f", fp)
    return fmt


def default_vp_format(fxp: FXPFormat, M: int, E: int) -> VPFormat:
    """Default parameter rule of Sec. II-D.

    max(f) = F (full resolution for small numbers) and
    W - F = M - min(f) (enough integer bits for the largest numbers), with
    the remaining 2^E - 2 entries spread as evenly as possible in between.
    """
    K = 1 << E
    top, bot = fxp.F, M - (fxp.W - fxp.F)
    if K == 1:
        return VPFormat(M, (top,))
    # Evenly spaced, descending, endpoints pinned.
    step = (top - bot) / (K - 1)
    f = sorted({int(round(top - k * step)) for k in range(K)}, reverse=True)
    # Rounding may collide entries; repair by walking down.
    while len(f) < K:
        for v in range(top, bot - (K - len(f)) - 1, -1):
            if v not in f:
                f.append(v)
                break
        else:
            f.append(f[-1] - 1)
        f = sorted(set(f), reverse=True)
    return VPFormat(M, tuple(f[:K]))
