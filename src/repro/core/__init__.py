"""Core VP number-format library (the paper's contribution, in JAX).

Public API:
  FXPFormat, VPFormat, product_format, default_vp_format
  fxp_quantize, fxp_to_float
  fxp2vp, vp2fxp, vp_to_float, float_to_vp
  vp_mul, vp_mul_to_fxp, product_scale_lut
  VPTensor, vp_quantize, vp_dequantize, vp_fake_quant, vp_fake_quant_ste
  block_vp_quantize, block_vp_dequantize
  param_search (module), cost_model (module)
"""
from .formats import FXPFormat, VPFormat, product_format, default_vp_format
from .fxp import (
    fxp_quantize,
    fxp_to_float,
    fxp_saturate,
    fxp_quantize_value,
    choose_fxp_fraction,
)
from .convert import fxp2vp, fxp2vp_bitwindow, vp2fxp, vp_to_float, float_to_vp
from .vp_math import vp_mul, vp_mul_to_fxp, product_scale_lut
from .vp_tensor import VPTensor, pack_indices, unpack_indices, significand_dtype
from .packing import pack_vp, unpack_vp, storage_dtype, dequant_words
from .quantize import (
    vp_quantize,
    vp_dequantize,
    vp_fake_quant,
    vp_fake_quant_ste,
    block_vp_quantize,
    block_vp_dequantize,
    per_channel_fxp_scales,
)
from . import param_search, cost_model

__all__ = [
    "FXPFormat", "VPFormat", "product_format", "default_vp_format",
    "fxp_quantize", "fxp_to_float", "fxp_saturate", "fxp_quantize_value",
    "choose_fxp_fraction",
    "fxp2vp", "fxp2vp_bitwindow", "vp2fxp", "vp_to_float", "float_to_vp",
    "vp_mul", "vp_mul_to_fxp", "product_scale_lut",
    "VPTensor", "pack_indices", "unpack_indices", "significand_dtype",
    "pack_vp", "unpack_vp", "storage_dtype", "dequant_words",
    "vp_quantize", "vp_dequantize", "vp_fake_quant", "vp_fake_quant_ste",
    "block_vp_quantize", "block_vp_dequantize", "per_channel_fxp_scales",
    "param_search", "cost_model",
]
