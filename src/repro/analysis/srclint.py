"""Minimal AST source lint: the offline subset of the CI ruff job.

The container has no ruff/pyflakes, but the CI `static-analysis` job
pip-installs a pinned ruff — so anything ruff would flag must be
catchable LOCALLY before push.  This module reimplements exactly the
rules the CI selects, nothing more:

  SL-F401    an imported name never used in the module (matches ruff
             F401; `__init__.py` re-export files are exempt, as in the
             ruff per-file-ignores).
  SL-ASSERT  an `assert` statement under `src/repro/launch/`: launch
             scripts validate RUNTIME conditions (finite logits, arg
             combinations), and asserts vanish under `python -O` —
             kernel-internal invariant asserts elsewhere are fine.
  SL-SYNTAX  a file that does not parse (ruff E999).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List


def _imported_names(tree: ast.AST) -> Dict[str, int]:
    """name -> first lineno for every binding an import creates."""
    names: Dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                names.setdefault(bound, node.lineno)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                names.setdefault(a.asname or a.name, node.lineno)
    return names


def _used_names(tree: ast.AST) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
        elif (isinstance(node, ast.Constant)
              and isinstance(node.value, str)):
            # `__all__` entries and string annotations reference by name.
            used.add(node.value)
    return used


def lint_file(path: str, rel: str) -> List[Dict[str, str]]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [{"rule": "SL-SYNTAX", "where": f"{rel}:{e.lineno}",
                 "detail": str(e)}]
    findings: List[Dict[str, str]] = []
    if os.path.basename(path) != "__init__.py":
        used = _used_names(tree)
        for name, lineno in sorted(
                _imported_names(tree).items(), key=lambda kv: kv[1]):
            if name not in used:
                findings.append({
                    "rule": "SL-F401", "where": f"{rel}:{lineno}",
                    "detail": f"imported name `{name}` is never used"})
    if f"{os.sep}launch{os.sep}" in path or rel.startswith("launch/"):
        for node in ast.walk(tree):
            if isinstance(node, ast.Assert):
                findings.append({
                    "rule": "SL-ASSERT", "where": f"{rel}:{node.lineno}",
                    "detail": "assert in a launch script vanishes under "
                              "`python -O` — raise an explicit error"})
    return findings


def _py_files(root: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def lint_tree(root: str) -> List[Dict[str, str]]:
    """Lint every .py under `root` (the `src/` tree in the CLI)."""
    findings: List[Dict[str, str]] = []
    for path in _py_files(root):
        rel = os.path.relpath(path, root)
        findings.extend(lint_file(path, rel))
    return findings
