"""Static contract checking for the VP kernel stack.

The paper's value proposition is a set of bit-level invariants — an M-bit
two's-complement significand, an E-bit index into a descending exponent
list, products that must fit the accumulator without wraparound (Sec. II /
Table I) — that the rest of this repo enforces only dynamically, by
golden-parity tests on the shapes we happened to test.  This package
proves them statically:

  bitwidth    interval / bit-growth abstract interpretation over
              VPFormat / FXPFormat: quantize -> pack -> unpack ->
              multiply -> K-dim accumulate, with max-safe-K certificates
              per (format pair, accumulator dtype)
  contracts   the fail-fast layer `kernels/ops.py` calls at op
              construction (cached, raises VPContractError with the
              analyzer's explanation instead of silently corrupting)
  vmem        a per-kernel VMEM footprint model checked against the TPU
              budget; `kernels/autotune.py` uses it to prune infeasible
              candidate tilings BEFORE timing them
  jaxpr_lint  trace registered kernel ops and model forwards and lint the
              jaxprs for hot-path hazards (f64 creep, full-weight f32
              materialization on a packed path, O(vocab)/step gathers)
  srclint     AST-level source lint (unused imports, bare asserts
              guarding runtime invariants in launch code)
  rules       the rule registry + findings baseline behind
              `python -m repro.analysis`

Import discipline: `bitwidth` / `contracts` / `vmem` depend only on
`repro.core` so `repro.kernels` can import them without cycles;
`jaxpr_lint` (which imports kernels and models) is only pulled in by the
CLI / `rules` at run time.
"""
from .bitwidth import (  # noqa: F401
    Interval,
    MatmulProof,
    analyze_matmul,
    significand_interval,
    product_interval,
    max_safe_k,
    check_pack_fields,
    check_scale_exponents,
    check_quantize_shifts,
)
from .contracts import (  # noqa: F401
    VPContractError,
    require_format_serviceable,
    require_quant_safe,
    require_int_accum_safe,
)
from .vmem import (  # noqa: F401
    vmem_budget_bytes,
    kernel_vmem_bytes,
    vmem_feasible,
)
from .rules import Finding, Severity  # noqa: F401
