"""Bitwidth / overflow proofs for VP and FXP datapaths.

Abstract interpretation over the *formats*, not the tensors: every
quantity a kernel can produce from a VP(M, f) or FXP(W, F) operand lives
in a statically known integer interval on a statically known power-of-two
grid, so bit growth through quantize -> pack -> unpack -> multiply ->
K-dim accumulate is provable offline, for every shape at once.

The model (paper Sec. II):

  * a quantized element is an integer significand m in
    [-2^(M-1), 2^(M-1)-1] times a power-of-two scale 2^-f_i, f_i drawn
    from the static exponent list;
  * a product of two elements is an integer m_a * m_b on the grid
    2^-(f_a + f_b).  It fits M_a + M_b - 1 signed bits — the paper's
    multiplier-width claim — for every input pair EXCEPT min * min,
    whose +2^(Ma+Mb-2) needs the full M_a + M_b bits (interval
    arithmetic below proves both halves; `core.formats.product_format`
    documents the same caveat);
  * a K-term dot product accumulates K such products.  Expressed on the
    FINEST product grid 2^-(max f_a + max f_b), every partial sum is an
    integer of magnitude <= K * max|m_a m_b| * 2^(span_a + span_b) where
    span = max f - min f (coarse-grid products are left-shifted onto the
    fine grid).

Accumulator verdicts derived from that integer:

  int32 / int16 accumulators (the block-VP int8 MXU path) WRAP when the
  raw significand sum exceeds the type: safe iff
  K * max|m_a m_b| <= 2^(bits-1) - 1.

  float accumulators (every dequant-then-MXU kernel) cannot wrap, but
  the paper's exact-MAC property only survives while every partial sum
  is exactly representable: safe iff the fine-grid integer above fits
  the mantissa (2^24 for f32).  Beyond that K the kernel still computes
  a correctly-rounded result — the analyzer reports the exactness
  horizon, it does not forbid the regime (the parity suites pin it at
  1e-6-class tolerances).

Both bounds are TIGHT: `tests/test_analysis.py` brute-forces random and
exhaustive worst cases against them (no false "safe" verdicts, and the
worst case achieves the predicted bound).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from repro.core.formats import FXPFormat, VPFormat
from repro.core.packing import WORD_LUT_MAX_BITS

Format = Union[FXPFormat, VPFormat]

# f32: 1 sign, 8 exponent, 23 mantissa bits -> integers up to 2^24 exact,
# biased exponents of normals in [1, 254].
F32_MANTISSA_BITS = 24
F32_MIN_BIASED_EXP = 1
F32_MAX_BIASED_EXP = 254


# ---------------------------------------------------------------------------
# Integer intervals
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed integer interval [lo, hi] (the abstract domain)."""

    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @property
    def mag(self) -> int:
        """Largest absolute value in the interval."""
        return max(abs(self.lo), abs(self.hi))

    @property
    def signed_bits(self) -> int:
        """Width of the smallest two's-complement type holding every
        value: bits such that [-2^(b-1), 2^(b-1)-1] covers [lo, hi]."""
        b = 1
        while self.lo < -(1 << (b - 1)) or self.hi > (1 << (b - 1)) - 1:
            b += 1
        return b

    def mul(self, other: "Interval") -> "Interval":
        """Exact interval of the elementwise product."""
        c = (self.lo * other.lo, self.lo * other.hi,
             self.hi * other.lo, self.hi * other.hi)
        return Interval(min(c), max(c))

    def scale(self, k: int) -> "Interval":
        """Interval of a K-term sum of values drawn from this interval."""
        if k < 0:
            raise ValueError(f"negative accumulation depth K={k}")
        return Interval(self.lo * k, self.hi * k)

    def shift_left(self, s: int) -> "Interval":
        return Interval(self.lo << s, self.hi << s)

    def __repr__(self) -> str:
        return f"[{self.lo}, {self.hi}]"


def significand_interval(fmt: Format) -> Interval:
    """Raw-significand interval of a format (post-quantize: the cascade
    clips to exactly this range, `core.convert`/`substrate` pin it)."""
    return Interval(fmt.raw_min, fmt.raw_max)


def product_interval(a: Format, b: Format) -> Interval:
    """Interval of one raw significand product m_a * m_b.

    Its `signed_bits` is M_a + M_b: the single extreme case
    (-2^(Ma-1)) * (-2^(Mb-1)) = +2^(Ma+Mb-2) exceeds the
    (Ma+Mb-1)-bit signed maximum by one, so the paper's Sec. II-B
    "M_a + M_b - 1 bits" multiplier-width claim holds for every product
    EXCEPT min * min — a caveat this analyzer surfaced in the claim as
    previously documented by `core.formats.product_format` (harmless at
    runtime: `vp_mul` computes products in int32 and nothing truncates
    to the product format's M; `tests/test_analysis.py` pins both
    halves of the corrected claim).
    """
    return significand_interval(a).mul(significand_interval(b))


def _span(fmt: Format) -> int:
    """Exponent spread of a format: max f - min f (0 for FXP, whose
    scale is a single static 2^-F)."""
    if isinstance(fmt, VPFormat):
        return fmt.span
    return 0


def _width(fmt: Format) -> int:
    return fmt.M if isinstance(fmt, VPFormat) else fmt.W


# ---------------------------------------------------------------------------
# Accumulation proofs
# ---------------------------------------------------------------------------

def _accum_limit(accum: str) -> Tuple[int, bool]:
    """(max representable magnitude, is_float) of an accumulator dtype."""
    if accum in ("int32", "int16", "int8", "int64"):
        bits = int(accum[3:])
        return (1 << (bits - 1)) - 1, False
    if accum == "float32":
        return 1 << F32_MANTISSA_BITS, True
    if accum == "bfloat16":
        return 1 << 8, True
    if accum == "float64":
        return 1 << 53, True
    raise ValueError(f"unknown accumulator dtype {accum!r}")


def max_safe_k(a: Format, b: Format, accum: str = "float32") -> int:
    """Largest accumulation depth K with a safety certificate.

    int accumulators: no two's-complement wraparound of the raw
    significand sum.  float accumulators: every partial sum of
    fine-grid product integers stays exactly representable (the paper's
    exact-MAC property).  0 means even a single product violates the
    bound.
    """
    limit, is_float = _accum_limit(accum)
    per_product = product_interval(a, b).mag
    if is_float:
        # Products land on grids 2^-(f_a + f_b); on the finest grid a
        # coarse product is left-shifted by up to span_a + span_b bits.
        per_product <<= _span(a) + _span(b)
    if per_product == 0:
        return limit
    return limit // per_product


@dataclasses.dataclass(frozen=True)
class MatmulProof:
    """The full certificate for one (format pair, K, accumulator)."""

    a: Format
    b: Format
    K: int
    accum: str
    product_bits: int          # signed bits of one significand product
    product_exact_f32: bool    # single products exact on an f32 MXU
    sum_interval: Interval     # raw significand-sum interval at depth K
    fine_grid_bits: int        # signed bits of the fine-grid sum integer
    max_safe_k: int            # exactness / no-wrap horizon
    safe: bool                 # K <= max_safe_k
    wraps: bool                # int accumulator AND K > max_safe_k
    reasons: Tuple[str, ...]

    def explain(self) -> str:
        head = (f"{self.a!r} x {self.b!r} @ K={self.K} into {self.accum}: "
                f"{'SAFE' if self.safe else 'UNSAFE'}")
        return "\n".join([head] + [f"  - {r}" for r in self.reasons])


def analyze_matmul(
    a: Format, b: Format, K: int, accum: str = "float32",
) -> MatmulProof:
    """Prove (or refute) that a K-deep dot product of a x b quantized
    elements cannot wrap / lose exactness in the given accumulator."""
    prod = product_interval(a, b)
    sum_iv = prod.scale(K)
    span = _span(a) + _span(b)
    fine_bits = sum_iv.shift_left(span).signed_bits
    limit, is_float = _accum_limit(accum)
    k_max = max_safe_k(a, b, accum)
    safe = K <= k_max
    wraps = (not is_float) and not safe
    product_exact_f32 = prod.mag <= (1 << F32_MANTISSA_BITS)

    reasons: List[str] = [
        f"significand product in {prod} "
        f"({prod.signed_bits} = M_a + M_b signed bits; all but "
        f"min*min fit {prod.signed_bits - 1})",
        f"raw sum over K={K} in {sum_iv} ({sum_iv.signed_bits} bits)",
    ]
    if is_float:
        reasons.append(
            f"fine-grid sum integer needs {fine_bits} bits "
            f"(exponent spans {_span(a)} + {_span(b)}); exact in {accum} "
            f"up to {limit:#x}")
        reasons.append(
            f"exact accumulation horizon: K <= {k_max}"
            + ("" if safe else
               f"; beyond it partial sums round (no wraparound — float "
               f"accumulators saturate gracefully)"))
    else:
        reasons.append(
            f"{accum} holds magnitudes <= {limit:#x}; "
            f"no-wraparound horizon: K <= {k_max}")
        if wraps:
            reasons.append(
                f"K={K} OVERFLOWS: worst-case sum magnitude "
                f"{sum_iv.mag:#x} exceeds {limit:#x} — two's-complement "
                f"wraparound, silently wrong results")
    if not product_exact_f32:
        reasons.append(
            f"single products reach magnitude {prod.mag:#x}: not "
            f"exactly representable on an f32 MXU "
            f"(M_a + M_b - 2 = {_width(a) + _width(b) - 2} > "
            f"{F32_MANTISSA_BITS})")
    return MatmulProof(
        a=a, b=b, K=K, accum=accum,
        product_bits=prod.signed_bits,
        product_exact_f32=product_exact_f32,
        sum_interval=sum_iv,
        fine_grid_bits=fine_bits,
        max_safe_k=k_max,
        safe=safe,
        wraps=wraps,
        reasons=tuple(reasons),
    )


# ---------------------------------------------------------------------------
# Field / scale / shift checks (pack, dequant, quantize cascades)
# ---------------------------------------------------------------------------

def check_pack_fields(fmt: VPFormat) -> List[str]:
    """Prove the packed-word layout (`core.packing`) cannot truncate.

    The word is (m << E) | i: the significand needs M bits (sign
    included), the index E bits, and both must fit `storage_bits`.
    Returns a list of violations (empty = proven safe).
    """
    problems: List[str] = []
    bits = fmt.M + fmt.E
    try:
        storage = fmt.storage_bits
    except ValueError as e:
        return [f"{fmt!r}: {e}"]
    if bits > storage:
        problems.append(
            f"{fmt!r}: M + E = {bits} information bits exceed the "
            f"{storage}-bit packed word — pack_vp would truncate the "
            f"significand's top bits")
    if significand_interval(fmt).signed_bits > fmt.M:
        problems.append(
            f"{fmt!r}: significand interval "
            f"{significand_interval(fmt)} does not fit M={fmt.M} bits")
    if fmt.K > (1 << fmt.E):
        problems.append(
            f"{fmt!r}: {fmt.K} exponent options exceed the E={fmt.E}-bit "
            f"index field")
    return problems


def word_lut_entries(fmt: VPFormat) -> Optional[int]:
    """Size of the offline whole-word dequant LUT when the format admits
    it (`core.packing.dequant_words`), else None."""
    bits = fmt.M + fmt.E
    return (1 << bits) if bits <= WORD_LUT_MAX_BITS else None


def check_scale_exponents(fmt: VPFormat) -> List[str]:
    """Prove every dequant scale 2^-f_i is an f32 NORMAL.

    Both in-kernel scale paths require it: the bit-assembled path writes
    (127 - f_i) << 23 straight into the exponent field, and the select
    chain materializes 2.0**-f_i as an f32 constant — a biased exponent
    outside [1, 254] means denormal/zero/inf scales and silently
    corrupted dequants.  Returns violations (empty = proven safe).
    """
    problems: List[str] = []
    for fv in fmt.f:
        biased = 127 - fv
        if not (F32_MIN_BIASED_EXP <= biased <= F32_MAX_BIASED_EXP):
            problems.append(
                f"{fmt!r}: scale 2^-{fv} has biased f32 exponent "
                f"{biased}, outside the normal range "
                f"[{F32_MIN_BIASED_EXP}, {F32_MAX_BIASED_EXP}] — the "
                f"dequant scale degenerates to "
                f"{'zero/denormal' if biased < 1 else 'inf'}")
    return problems


def check_quantize_shifts(fxp: FXPFormat, vp: VPFormat) -> List[str]:
    """Prove the Fig.-3 quantize cascade's shifts cannot overflow int32.

    For exponent option k the cascade computes m_k = raw << (f_k - F)
    when f_k > F (`substrate.quantize_cascade`); raw carries up to W
    signed bits, so the shifted value needs W + f_k - F bits and an
    int32 left shift wraps beyond 32 — the in-range test then sees a
    wrapped value and can select a corrupt (m, i).  Returns violations.
    """
    problems: List[str] = []
    raw_bits = significand_interval(fxp).signed_bits
    for fv in vp.f:
        s = fxp.F - fv
        if s < 0 and raw_bits + (-s) > 32:
            problems.append(
                f"{fxp!r} -> {vp!r}: option f={fv} left-shifts the "
                f"{raw_bits}-bit raw value by {-s} bits "
                f"({raw_bits - s} > 32) — int32 shift wraparound inside "
                f"the quantize cascade's range test")
    return problems


def check_format(fmt: Format) -> List[str]:
    """All single-format static checks (pack fields + scale exponents)."""
    if isinstance(fmt, FXPFormat):
        return []
    return check_pack_fields(fmt) + check_scale_exponents(fmt)


def safe_k_table(
    pairs: Sequence[Tuple[str, Format, Format]],
    accums: Sequence[str] = ("float32", "int32"),
) -> List[dict]:
    """Max-safe-K certificates for a set of named format pairs (the
    CLI's Table-I report; README quotes it)."""
    rows = []
    for name, a, b in pairs:
        row = {
            "pair": name,
            "a": repr(a),
            "b": repr(b),
            "product_bits": product_interval(a, b).signed_bits,
        }
        for accum in accums:
            row[f"max_safe_k_{accum}"] = max_safe_k(a, b, accum)
        rows.append(row)
    return rows


def brute_force_worst_sum(
    a: Format, b: Format, K: int, fine_grid: bool = False,
) -> int:
    """EXACT worst-case |sum| of K products, by construction.

    The worst case of a sum of independent products is K times the worst
    single product (every term can simultaneously take the extreme
    value).  With `fine_grid`, products are expressed on the finest
    product grid — each coarse product shifted by its exponent headroom;
    the extreme shift and the extreme product co-occur at (raw_min *
    raw_min, f = min_f).  Used by the soundness tests as an independent
    oracle against `max_safe_k` / `analyze_matmul`.
    """
    worst = 0
    shifts_a = ([a.max_f - fv for fv in a.f]
                if isinstance(a, VPFormat) else [0])
    shifts_b = ([b.max_f - fv for fv in b.f]
                if isinstance(b, VPFormat) else [0])
    for ma in (a.raw_min, a.raw_max):
        for mb in (b.raw_min, b.raw_max):
            for sa in (shifts_a if fine_grid else [0]):
                for sb in (shifts_b if fine_grid else [0]):
                    worst = max(worst, abs(ma * mb) << (sa + sb))
    return worst * K
