"""Hot-path jaxpr linting: trace serving forwards, scan the IR for hazards.

`jax.make_jaxpr` over the model zoo's prefill / decode entry points (and
over the registered kernel ops) yields the exact primitive graph XLA
will compile — including every `pallas_call` when the trace runs under
`substrate.force_backend("interpret")`, which pins dispatch to the
Pallas path on any host so the lint sees the SERVING graph rather than
the pure-jnp ref oracles (whose full-tensor dequants are correct for an
oracle but would be serving-path findings).

Rules (severities are assigned by `analysis.rules`):

  JX-F64    a float64/complex128 value anywhere in the graph.  Nothing
            in this codebase wants doubles; one leaked `np.float64`
            scalar silently doubles bandwidth on its whole subtree (or
            crashes under jax's default x64-disabled config elsewhere).
  JX-WMAT   a float tensor with EXACTLY the shape of an integer weight
            leaf: the packed/planes weight was fully dequantized into an
            f32 matrix in HBM — the materialization the packed kernel
            path exists to avoid.  Not scanned inside pallas_call
            bodies, whose per-TILE dequants in VMEM are the design.
  JX-VOCAB  a float (vocab, d)-shaped tensor in a DECODE step: an
            O(vocab) dequant/gather per generated token (e.g. an
            embedding table dequantized before `jnp.take`); the packed
            layout gathers rows first, making this O(tokens * d).
  JX-JIT    a public `*_ref` oracle in `kernels.ref` that is not
            jit-wrapped: eager per-call dispatch cascades (the PR-2
            decode regression) — checked structurally, no trace needed.
  JX-SHGATH inside a shard_map body, an integer `all_gather` (packed
            weight words reassembled across the tensor axis) followed by
            a float tensor of exactly the gathered shape: the full
            UNSHARDED weight was dequantized on every device after the
            gather — sharding moved the bytes but bought no memory.
            The column/ring modes in `parallel.shard_ops` never do this
            (outputs resp. per-chunk tiles travel, not the whole
            weight); the `gather` baseline mode is the pattern flagged.
  JX-BWDMAT in a BACKWARD trace over the packed datapath, a float
            tensor of exactly a packed weight's shape produced by
            anything other than `dot_general` or a `pallas_call`: the
            VJP fell back to dequantize-then-autodiff, materializing the
            f32 weight plane the custom backward kernels
            (`kernels.vp_bwd_matmul`) exist to avoid.  dL/dW is
            legitimately weight-shaped, hence the producer exemptions
            (a contraction or a kernel launch stages tiles only).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Iterator, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# `*_ref` callables that are deliberately NOT jit-wrapped (mask builders
# and helpers called at trace time inside an enclosing jit, where a
# nested jit would only add dispatch overhead).
REF_JIT_EXCEPTIONS = frozenset({
    "tile_activity",
    "cspade_tile_masks",
    "cspade_tile_masks_batched",
    "_decode_attention_core",
})

# Below this element count a full-shape float match is ignored: tiny
# tensors (norm gains, scales) can coincide with tiny weight shapes.
_WMAT_MIN_ELEMS = 2048
_VOCAB_MIN = 32


def _subjaxprs(eqn) -> Iterator[Tuple[Any, bool]]:
    """Yield (jaxpr, entered_pallas) for every sub-jaxpr riding an eqn's
    params (scan/cond bodies, custom_vjp calls, pallas kernel bodies)."""
    is_pallas = eqn.primitive.name == "pallas_call"
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if isinstance(item, jax.core.ClosedJaxpr):
                yield item.jaxpr, is_pallas
            elif hasattr(item, "eqns") and hasattr(item, "outvars"):
                yield item, is_pallas


def iter_eqns(jaxpr, in_pallas: bool = False) -> Iterator[Tuple[Any, bool]]:
    """Depth-first walk over every eqn in a (closed) jaxpr, tagging
    whether the eqn sits inside a pallas_call kernel body."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, in_pallas
        for sub, entered in _subjaxprs(eqn):
            yield from iter_eqns(sub, in_pallas or entered)


def _finding(rule: str, where: str, detail: str) -> Dict[str, str]:
    return {"rule": rule, "where": where, "detail": detail}


def int_weight_shapes(params) -> Set[Tuple[int, ...]]:
    """Shapes of quantized weight storage: every integer-dtype leaf with
    >= 2 dims, plus the per-layer shapes of stacked leaves (scanned
    groups see one layer's slice inside the scan body)."""
    shapes: Set[Tuple[int, ...]] = set()
    for leaf in jax.tree_util.tree_leaves(params):
        if not hasattr(leaf, "dtype") or not hasattr(leaf, "shape"):
            continue
        if not jnp.issubdtype(leaf.dtype, jnp.integer):
            continue
        if leaf.ndim < 2:
            continue
        shapes.add(tuple(leaf.shape))
        for lead in range(1, leaf.ndim - 1):
            shapes.add(tuple(leaf.shape[lead:]))
    return shapes


def lint_traced(
    jaxpr,
    weight_shapes: Sequence[Tuple[int, ...]] = (),
    vocab: Optional[int] = None,
    decode: bool = False,
    where: str = "",
) -> List[Dict[str, str]]:
    """Scan one traced graph for JX-F64 / JX-WMAT / JX-VOCAB."""
    findings: List[Dict[str, str]] = []
    wshapes = {tuple(s) for s in weight_shapes
               if int(np.prod(s)) >= _WMAT_MIN_ELEMS}
    seen: Set[Tuple[str, Tuple[int, ...]]] = set()
    for eqn, in_pallas in iter_eqns(jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            shape = tuple(getattr(aval, "shape", ()))
            if dtype is None:
                continue
            if dtype in (jnp.float64, jnp.complex128):
                key = ("f64", shape)
                if key not in seen:
                    seen.add(key)
                    findings.append(_finding(
                        "JX-F64", where,
                        f"{eqn.primitive.name} produces {dtype} {shape}"))
            if in_pallas or not jnp.issubdtype(dtype, jnp.floating):
                continue
            if shape in wshapes:
                key = ("wmat", shape)
                if key not in seen:
                    seen.add(key)
                    findings.append(_finding(
                        "JX-WMAT", where,
                        f"{eqn.primitive.name} materializes a float "
                        f"{shape} tensor matching a quantized weight "
                        f"leaf — full-weight dequant in HBM"))
            if (decode and vocab and vocab >= _VOCAB_MIN
                    and len(shape) >= 2 and shape[0] == vocab
                    and int(np.prod(shape[1:])) > 1):
                key = ("vocab", shape)
                if key not in seen:
                    seen.add(key)
                    findings.append(_finding(
                        "JX-VOCAB", where,
                        f"{eqn.primitive.name} produces a float {shape} "
                        f"tensor spanning the whole vocab in a decode "
                        f"step — O(vocab) work per generated token"))
    return findings


def _shard_map_bodies(jaxpr) -> Iterator[Any]:
    """Yield the body jaxpr of every shard_map eqn, at any nesting depth
    outside of one (shard_map does not nest in this codebase)."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        if "shard_map" in eqn.primitive.name:
            for sub, _ in _subjaxprs(eqn):
                yield sub
        else:
            for sub, _ in _subjaxprs(eqn):
                yield from _shard_map_bodies(sub)


def lint_sharded_traced(jaxpr, where: str = "") -> List[Dict[str, str]]:
    """JX-SHGATH over every shard_map body in a traced graph.

    Structural, so the verdict is mesh-size independent: an integer
    `all_gather` outvar (>= `_WMAT_MIN_ELEMS` elements) records its
    shape; any LATER float outvar of the identical shape in the same
    body is the full gathered weight dequantized in HBM.  Float matches
    inside pallas_call bodies are ignored (per-tile VMEM dequants are
    the design), so trace on the ref backend, where the full dequant is
    a visible jnp op.
    """
    findings: List[Dict[str, str]] = []
    seen: Set[Tuple[str, Tuple[int, ...]]] = set()
    for body in _shard_map_bodies(jaxpr):
        gathered: Set[Tuple[int, ...]] = set()
        for eqn, in_pallas in iter_eqns(body):
            is_gather = eqn.primitive.name == "all_gather"
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                dtype = getattr(aval, "dtype", None)
                shape = tuple(getattr(aval, "shape", ()))
                if dtype is None or int(np.prod(shape)) < _WMAT_MIN_ELEMS:
                    continue
                if is_gather and jnp.issubdtype(dtype, jnp.integer):
                    gathered.add(shape)
                elif (not in_pallas and shape in gathered
                      and jnp.issubdtype(dtype, jnp.floating)):
                    key = (where, shape)
                    if key not in seen:
                        seen.add(key)
                        findings.append(_finding(
                            "JX-SHGATH", where,
                            f"{eqn.primitive.name} materializes a float "
                            f"{shape} tensor matching an all-gathered "
                            f"integer shape inside a shard_map body — "
                            f"the full unsharded weight was dequantized "
                            f"on every device after the gather"))
    return findings


# Producers allowed to emit weight-shaped floats in a backward trace:
# a contraction IS the weight gradient, and a kernel launch's HBM output
# (dL/dW from `vp_matmul_dw_pallas`) stages tiles on chip only.  The
# call-like wrappers merely FORWARD a sub-jaxpr's result — `iter_eqns`
# descends into their bodies, so the true producer inside is still
# linted (a jitted dequant chain is flagged on its elementwise eqns; a
# jitted backward kernel is exempt on its pallas_call).
_BWD_LEGIT_PRODUCERS = frozenset({
    "dot_general", "pallas_call",
    "pjit", "closed_call", "core_call", "remat", "remat2",
    "custom_vjp_call", "custom_vjp_call_jaxpr", "custom_jvp_call",
})


def lint_bwd_traced(
    jaxpr,
    weight_shapes: Sequence[Tuple[int, ...]] = (),
    where: str = "",
) -> List[Dict[str, str]]:
    """JX-BWDMAT over one BACKWARD trace (a `jax.grad` jaxpr).

    Any float outvar with exactly a packed-weight shape whose producer
    is not in `_BWD_LEGIT_PRODUCERS` means the VJP dequantized the full
    weight plane (autodiff through `dequant_words`) instead of running
    the packed backward kernel.  Eqns inside pallas_call bodies are
    exempt — on the interpret backend tiles clamp to the full (small)
    test shape, and per-tile VMEM dequants are the design.
    """
    findings: List[Dict[str, str]] = []
    wshapes = {tuple(s) for s in weight_shapes
               if int(np.prod(s)) >= _WMAT_MIN_ELEMS}
    seen: Set[Tuple[str, Tuple[int, ...]]] = set()
    for eqn, in_pallas in iter_eqns(jaxpr):
        if in_pallas or eqn.primitive.name in _BWD_LEGIT_PRODUCERS:
            continue
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            shape = tuple(getattr(aval, "shape", ()))
            if dtype is None or shape not in wshapes:
                continue
            if not jnp.issubdtype(dtype, jnp.floating):
                continue
            key = (where, shape)
            if key not in seen:
                seen.add(key)
                findings.append(_finding(
                    "JX-BWDMAT", where,
                    f"{eqn.primitive.name} materializes a float {shape} "
                    f"tensor matching a packed weight in a backward "
                    f"trace — the VJP dequantized the full weight plane "
                    f"instead of running the packed backward kernel"))
    return findings


def lint_ref_jit() -> List[Dict[str, str]]:
    """JX-JIT: every public `*_ref` oracle must be jit-wrapped."""
    from repro.kernels import ref

    findings = []
    for name in dir(ref):
        if not name.endswith("_ref") or name in REF_JIT_EXCEPTIONS:
            continue
        fn = getattr(ref, name)
        if not callable(fn):
            continue
        # jax.jit wrappers expose .lower / .trace; plain functions don't.
        if not hasattr(fn, "lower"):
            findings.append(_finding(
                "JX-JIT", f"kernels/ref.py::{name}",
                "ref oracle is not jit-wrapped: every call re-dispatches "
                "its op cascade eagerly (the PR-2 decode regression "
                "pattern)"))
    return findings


# ---------------------------------------------------------------------------
# Model-zoo tracing
# ---------------------------------------------------------------------------

def model_traces(cfg, layout: str = "packed"):
    """Trace one model config's serving entry points.

    Returns a list of (name, jaxpr, decode?) plus the quantized-weight
    shape set.  Params are built and quantized on the default backend
    (cheap ref math); the TRACES run under
    `force_backend("interpret")` so the graphs contain the pallas_call
    launches of the serving path.  Tracing never executes the kernels.
    """
    from repro.kernels import substrate
    from repro.models import model as M

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    qparams = M.quantize_params(params, cfg, layout=layout)
    caches = M.init_cache(cfg, B=1, max_len=32)
    tokens = jnp.zeros((1, 8), jnp.int32)
    token = jnp.zeros((1, 1), jnp.int32)
    wshapes = int_weight_shapes(qparams)

    extra = None
    if cfg.family == "encdec":
        enc = jnp.zeros((1, 8, cfg.d_model), M.model_dtype(cfg))
        extra = M._cross_kv(qparams, enc, cfg)

    traces = []
    with substrate.force_backend("interpret"):
        prefill_jaxpr = jax.make_jaxpr(
            functools.partial(
                lambda p, t, c, x: M.prefill(p, t, c, cfg, patches=x)))(
            qparams, tokens, caches, extra)
        traces.append(("prefill", prefill_jaxpr, False))
        decode_jaxpr = jax.make_jaxpr(
            lambda p, t, c, x: M.decode_step(p, t, c, cfg, cross_kv=x))(
            qparams, token, caches, extra)
        traces.append(("decode", decode_jaxpr, True))
    return traces, wshapes


def lint_model(cfg, name: str = "", layout: str = "packed"):
    """All jaxpr rules over one model config's prefill + decode."""
    traces, wshapes = model_traces(cfg, layout=layout)
    findings: List[Dict[str, str]] = []
    for stage, jaxpr, decode in traces:
        findings.extend(lint_traced(
            jaxpr, weight_shapes=wshapes, vocab=cfg.vocab,
            decode=decode, where=f"{name or cfg.family}:{stage}"))
    return findings


def lint_kernel_ops(pairs) -> List[Dict[str, str]]:
    """JX-F64 over the registered kernel ops' traced graphs.

    `pairs`: [(name, callable-of-no-args)] where the callable runs one
    op at a representative shape; the trace runs on the interpret
    backend so the pallas_call launches are in-graph.
    """
    from repro.kernels import substrate

    findings: List[Dict[str, str]] = []
    with substrate.force_backend("interpret"):
        for name, thunk in pairs:
            jaxpr = jax.make_jaxpr(thunk)()
            findings.extend(lint_traced(jaxpr, where=f"ops.{name}"))
    return findings
