"""Fail-fast kernel contracts: the analyzer wired into op construction.

`kernels/ops.py` calls these at every op entry.  Each check is an
`lru_cache`d function of hashable static data (formats, tile sizes), so
the steady-state cost is one dict lookup per call — but the FIRST call
with an unsafe combination raises `VPContractError` carrying the
bitwidth analyzer's explanation, instead of letting the kernel silently
wrap an accumulator, emit denormal/inf dequant scales, or truncate
packed fields.

Severity policy (mirrors `analysis.rules`):

  * hard errors (raise): conditions that produce silently WRONG numbers
    on some input — scale exponents outside the f32 normal range,
    quantize-cascade shift wraparound, packed-field truncation, and
    integer-accumulator overflow on the block-VP int8 MXU path;
  * not errors: float-accumulator exactness horizons.  K beyond
    `max_safe_k(..., "float32")` rounds (1e-6-class, pinned by the
    parity suites) but cannot wrap — the CLI reports the horizon, ops
    stay usable at every K.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

from repro.core.formats import FXPFormat, VPFormat
from . import bitwidth

Format = Union[FXPFormat, VPFormat]


class VPContractError(ValueError):
    """A statically-provable kernel-contract violation (carries the
    analyzer's explanation)."""


def _raise(problems, what: str):
    if problems:
        raise VPContractError(
            f"static contract violation in {what}:\n  "
            + "\n  ".join(problems)
            + "\n(proved by repro.analysis.bitwidth — run "
            "`python -m repro.analysis` for the full report)")


@functools.lru_cache(maxsize=None)
def require_format_serviceable(fmt: Format, what: str = "kernel op") -> bool:
    """Hard contract for any op that dequantizes `fmt`: packed fields
    fit the storage word and every 2^-f_i scale is an f32 normal."""
    if isinstance(fmt, VPFormat):
        _raise(bitwidth.check_pack_fields(fmt)
               + bitwidth.check_scale_exponents(fmt), what)
    return True


@functools.lru_cache(maxsize=None)
def require_quant_safe(fxp: FXPFormat, vp: VPFormat,
                       what: str = "vp_quant") -> bool:
    """Hard contract for the quantize cascade: no int32 shift
    wraparound inside the Fig.-3 range tests, plus the dequant-side
    format contract (quant ops emit planes someone will dequantize)."""
    require_format_serviceable(vp, what)
    _raise(bitwidth.check_quantize_shifts(fxp, vp), what)
    return True


@functools.lru_cache(maxsize=None)
def require_int_accum_safe(
    a: Format, b: Format, depth: int,
    accum: str = "int32", what: str = "block_vp_matmul",
) -> bool:
    """Hard contract for integer-accumulator matmuls: a `depth`-term
    raw-significand dot product cannot wrap the accumulator.

    `depth` is the number of products accumulated per integer partial
    sum — the k-TILE size for the block-VP kernel (each tile's int32
    MXU sum is rescaled to f32 before crossing tiles), not the full K.
    """
    proof = bitwidth.analyze_matmul(a, b, depth, accum)
    if proof.wraps:
        raise VPContractError(
            f"static contract violation in {what}:\n{proof.explain()}")
    return True


@functools.lru_cache(maxsize=None)
def require_vmem_feasible(kernel: str, blocks, formats, shape,
                          what: str = "kernel op") -> bool:
    """Hard contract for TPU-native launches: the resolved tiling must
    fit the modeled VMEM working set.  The model is a LOWER bound
    (`analysis.vmem`), so anything it rejects would fail at Mosaic
    lowering anyway — raising here turns a cryptic lowering crash into
    the analyzer's byte accounting.

    Under shard_map, `shape` at the op entry is the per-shard LOCAL
    operand shape, so the contract naturally reasons about the tile
    each device actually stages — a tiling that only fits because the
    mesh shrank the operand passes; one whose local tile still
    overflows fails before launch.
    """
    from . import vmem
    ok, need = vmem.vmem_feasible(kernel, tuple(blocks), formats, shape)
    if not ok:
        raise VPContractError(
            f"static contract violation in {what}: tiling "
            f"{tuple(blocks)} at shape {tuple(shape)} needs {need} bytes "
            f"of VMEM > budget {vmem.vmem_budget_bytes()} "
            f"(model: repro.analysis.vmem — a launch would fail at "
            f"Mosaic lowering)")
    return True


def float_exactness_horizon(a: Format, b: Format) -> int:
    """Max K with exact f32 accumulation (informational, never raises)."""
    return bitwidth.max_safe_k(a, b, "float32")


def check_formats(*fmts: Optional[Format], what: str = "kernel op") -> None:
    """Convenience: run the serviceability contract over several formats
    (None entries skipped) — the one-liner ops.py uses."""
    for f in fmts:
        if f is not None:
            require_format_serviceable(f, what)
