"""Rule registry, severity policy, and the full analysis run.

Severities:

  error  statically provable silent corruption: packed-field truncation,
         dequant scales outside the f32 normal range, quantize-cascade
         shift wraparound, int-accumulator overflow at a registered
         depth, a default kernel tiling over the VMEM budget, float64 in
         a serving graph, asserts guarding runtime conditions in launch
         scripts.
  warn   costs performance or robustness but computes correct numbers:
         full-weight f32 materialization on the packed path, O(vocab)
         decode work, un-jitted ref oracles, unused imports, persisted
         autotune cache entries over the VMEM budget (they fail at
         lowering, costing a crash-then-retune, not wrong numbers).
  info   reporting only: f32 exact-accumulation horizons per format
         pair.  Models legitimately accumulate K = d_model >> horizon;
         beyond it sums are correctly ROUNDED (1e-6-class, pinned by the
         parity suites), never wrapped — so this must not fail CI.

The CLI (`python -m repro.analysis`) fails on any error/warn finding not
in the committed baseline (`ANALYSIS_BASELINE.json`); info findings are
always reported, never fatal.  The baseline keys findings by
`rule|where` so detail wording can improve without churn.

Import discipline: this module imports only `repro.core` + sibling
analysis modules at module level, so `kernels.autotune` can import the
`analysis` package without a cycle; kernels/models/mimo are pulled in
lazily inside the check functions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.formats import FXPFormat, VPFormat
from . import bitwidth, srclint, vmem

Severity = str  # "error" | "warn" | "info"

RULES: Dict[str, Tuple[Severity, str]] = {
    "BW-PACK": ("error", "packed-word field truncation"),
    "BW-SCALE": ("error", "dequant scale outside f32 normal range"),
    "BW-SHIFT": ("error", "quantize-cascade int32 shift wraparound"),
    "BW-INT": ("error", "integer accumulator overflow"),
    "BW-F32K": ("info", "f32 exact-accumulation horizon"),
    "VM-BUDGET": ("error", "default kernel tiling exceeds VMEM budget"),
    "VM-CACHE": ("warn", "persisted autotune entry exceeds VMEM budget"),
    "JX-F64": ("error", "float64/complex128 in a serving graph"),
    "JX-WMAT": ("warn", "full-weight float materialization"),
    "JX-VOCAB": ("warn", "O(vocab) work per decode step"),
    "JX-JIT": ("warn", "ref oracle not jit-wrapped"),
    "JX-SHGATH": ("warn",
                  "full unsharded weight materialized after a shard_map "
                  "gather"),
    "JX-BWDMAT": ("warn",
                  "full-weight float materialization in a backward "
                  "trace"),
    "SL-F401": ("warn", "unused import"),
    "SL-ASSERT": ("error", "assert guarding a runtime condition"),
    "SL-SYNTAX": ("error", "file does not parse"),
}

_SEV_ORDER = {"error": 0, "warn": 1, "info": 2}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    where: str
    detail: str

    @property
    def severity(self) -> Severity:
        return RULES[self.rule][0]

    @property
    def key(self) -> str:
        return f"{self.rule}|{self.where}"

    def __str__(self) -> str:
        return f"[{self.severity:5s}] {self.rule} {self.where}: {self.detail}"


def _from_dicts(ds: Sequence[dict]) -> List[Finding]:
    return [Finding(d["rule"], d["where"], d["detail"]) for d in ds]


# ---------------------------------------------------------------------------
# The format universe under analysis
# ---------------------------------------------------------------------------

def analysis_formats():
    """(named format pairs, quantize pairs, block depth) covering every
    format the repo registers: Table-I MIMO specs + the model zoo's
    canonical serving formats."""
    from repro.configs.base import QuantConfig
    from repro.mimo.equalizer import table1_specs
    from repro.models.layers import canonical_formats

    pairs: List[Tuple[str, object, object]] = []
    quant_pairs: List[Tuple[str, FXPFormat, VPFormat]] = []
    for spec in table1_specs():
        if spec.is_vp:
            pairs.append((f"table1:{spec.name}", spec.y_vp, spec.w_vp))
            quant_pairs.append((f"table1:{spec.name}:y",
                                spec.y_fxp, spec.y_vp))
            quant_pairs.append((f"table1:{spec.name}:w",
                                spec.w_fxp, spec.w_vp))
        else:
            pairs.append((f"table1:{spec.name}", spec.y_fxp, spec.w_fxp))
    q = QuantConfig(mode="vp")
    fxp, vp = canonical_formats(q)
    pairs.append(("zoo:canonical", vp, vp))
    quant_pairs.append(("zoo:canonical", fxp, vp))
    return pairs, quant_pairs, QuantConfig().block


def check_bitwidth() -> List[Finding]:
    """BW-*: pack/scale/shift/accumulator proofs over every registered
    format, plus the f32 exactness horizons (info)."""
    pairs, quant_pairs, depth = analysis_formats()
    findings: List[Finding] = []
    seen_fmts = []
    for _, a, b in pairs:
        for f in (a, b):
            if isinstance(f, VPFormat) and f not in seen_fmts:
                seen_fmts.append(f)
    for fmt in seen_fmts:
        for msg in bitwidth.check_pack_fields(fmt):
            findings.append(Finding("BW-PACK", f"format:{fmt!r}", msg))
        for msg in bitwidth.check_scale_exponents(fmt):
            findings.append(Finding("BW-SCALE", f"format:{fmt!r}", msg))
    for name, fxp, vp in quant_pairs:
        for msg in bitwidth.check_quantize_shifts(fxp, vp):
            findings.append(Finding("BW-SHIFT", f"quant:{name}", msg))
    # The block-VP int8 MXU path accumulates `depth` products in int32
    # per k-tile (kernels/vp_block_matmul.py).
    for name, a, b in pairs:
        proof = bitwidth.analyze_matmul(a, b, depth, "int32")
        if proof.wraps:
            findings.append(Finding(
                "BW-INT", f"block_vp:{name}@K{depth}", proof.explain()))
    for row in bitwidth.safe_k_table(pairs):
        findings.append(Finding(
            "BW-F32K", f"pair:{row['pair']}",
            f"{row['a']} x {row['b']}: product {row['product_bits']} "
            f"bits; exact-f32 K <= {row['max_safe_k_float32']}, "
            f"int32 no-wrap K <= {row['max_safe_k_int32']}"))
    return findings


# ---------------------------------------------------------------------------
# VMEM rules
# ---------------------------------------------------------------------------

# Representative serving shapes: skinny decode, prefill, square.
_MATMUL_SHAPES = ((8, 4096, 4096), (2048, 4096, 4096), (4096, 4096, 4096))


def check_vmem_defaults() -> List[Finding]:
    """VM-BUDGET: the tiling `resolve_blocks` launches WITHOUT a cache
    entry (native-floored heuristic) must fit the budget for every
    registered kernel at representative serving shapes."""
    from repro.configs.base import QuantConfig
    from repro.kernels import autotune
    from repro.models.layers import canonical_formats

    _, vp = canonical_formats(QuantConfig(mode="vp"))
    findings: List[Finding] = []
    budget = vmem.vmem_budget_bytes()
    kernels = (
        ("vp_matmul", (vp, vp)),
        ("vp_matmul_packed", (vp, vp)),
        ("vp_dequant_matmul", (vp,)),
        ("vp_matmul_dx", (vp,)),
        ("vp_matmul_dw", (vp,)),
        ("vp_quant_matmul", (vp, vp)),
        (f"block_vp_matmul_bk{QuantConfig().block}", (vp, vp)),
    )
    for kernel, formats in kernels:
        for shape in _MATMUL_SHAPES:
            blocks = autotune._native_floor(
                autotune.heuristic_blocks(*shape))
            ok, need = vmem.vmem_feasible(
                kernel, blocks, formats, shape, budget=budget)
            if not ok:
                findings.append(Finding(
                    "VM-BUDGET", f"{kernel}@{'x'.join(map(str, shape))}",
                    f"default tiling {blocks} needs {need} bytes "
                    f"> budget {budget}"))
    # Attention defaults: decode (B, Smax, KV, dh, window, rolling) and
    # prefill (B, H, KV, dh, Sq, Sk, window) with the heuristic chunking.
    attn = (
        ("vp_decode_attention", (8, 4096, 8, 128, 0, 0), (8, 256, 1),
         (vp,)),
        ("flash_prefill", (2, 32, 8, 128, 4096, 4096, 0), (128, 256, 1),
         ()),
    )
    for kernel, shape, blocks, formats in attn:
        ok, need = vmem.vmem_feasible(
            kernel, blocks, formats, shape, budget=budget)
        if not ok:
            findings.append(Finding(
                "VM-BUDGET", f"{kernel}@{'x'.join(map(str, shape))}",
                f"default chunking {blocks} needs {need} bytes "
                f"> budget {budget}"))
    return findings


_FMT_RE = re.compile(
    r"VP\((\d+),\[([^\]]*)\]\)|FXP\((\d+),(-?\d+)\)")


def _parse_formats(s: str) -> List[object]:
    out: List[object] = []
    for m in _FMT_RE.finditer(s):
        if m.group(1) is not None:
            f = tuple(int(v) for v in m.group(2).split(",") if v.strip())
            out.append(VPFormat(int(m.group(1)), f))
        else:
            out.append(FXPFormat(int(m.group(3)), int(m.group(4))))
    return out


_MESH_SEG_RE = re.compile(r"^[a-z]+(\d+)\.([A-Z])$")

# Which logical dim a mesh-key shard spec letter shards: matmul dims
# counted from the END of the shape (shapes are (..., M, K, N)),
# attention batch/sequence dims from the front ((B, S, ...)).
_MESH_SPEC_DIM_FROM_END = {"N": 1, "K": 2, "M": 3}
_MESH_SPEC_DIM_ABS = {"B": 0, "S": 1}


def _mesh_key_shards(seg: str, rank: int):
    """Shard counts for a `mesh=<axis><size>.<spec>` cache-key segment
    (`autotune.mesh_desc`), or None for single-device / unrecognized
    segments — the audit then models the unsharded launch, which is
    conservative (per-shard operands are never larger)."""
    m = _MESH_SEG_RE.match(seg)
    if not m:
        return None
    size, spec = int(m.group(1)), m.group(2)
    if spec in _MESH_SPEC_DIM_ABS:
        dim = _MESH_SPEC_DIM_ABS[spec]
    elif spec in _MESH_SPEC_DIM_FROM_END:
        dim = rank - _MESH_SPEC_DIM_FROM_END[spec]
    else:
        return None
    if not 0 <= dim < rank or size <= 1:
        return None
    shards = [1] * rank
    shards[dim] = size
    return tuple(shards)


def check_vmem_cache() -> List[Finding]:
    """VM-CACHE: audit every persisted autotune entry against the budget
    (a stale or foreign-budget entry fails at lowering on launch)."""
    from repro.kernels import autotune

    path = autotune.cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return []
    findings: List[Finding] = []
    for key, blocks in sorted(data.items()):
        parts = key.split("|")
        if len(parts) not in (4, 5) or len(blocks) != 3:
            continue
        kernel, dims, fmts = parts[:3]
        try:
            shape = [int(x) for x in dims.split("x")]
        except ValueError:
            continue
        shards = None
        if len(parts) == 5 and parts[4].startswith("mesh="):
            shards = _mesh_key_shards(parts[4][len("mesh="):], len(shape))
        ok, need = vmem.vmem_feasible(
            kernel, tuple(blocks), _parse_formats(fmts), shape,
            shards=shards)
        if not ok:
            findings.append(Finding(
                "VM-CACHE", key,
                f"cached tiling {tuple(blocks)} needs {need} bytes "
                f"> budget {vmem.vmem_budget_bytes()}"))
    return findings


# ---------------------------------------------------------------------------
# Jaxpr rules
# ---------------------------------------------------------------------------

def _op_thunks():
    """Tiny representative launches of every registered kernel op, for
    trace-level linting (never executed)."""
    import jax.numpy as jnp

    from repro.configs.base import QuantConfig
    from repro.core.packing import storage_dtype
    from repro.kernels import ops
    from repro.models.layers import canonical_formats

    fxp, vp = canonical_formats(QuantConfig(mode="vp"))
    wdt = storage_dtype(vp)
    m = jnp.zeros((16, 16), jnp.int8)
    i = jnp.zeros((16, 16), jnp.uint8)
    w = jnp.zeros((16, 16), wdt)
    x = jnp.zeros((16, 16), jnp.float32)
    q4 = jnp.zeros((1, 1, 4, 32), jnp.float32)
    kv = jnp.zeros((1, 64, 2, 32), wdt)
    sc = jnp.zeros((1, 64, 1, 1), jnp.float32)
    ln = jnp.zeros((1,), jnp.int32)
    qp = jnp.zeros((1, 16, 4, 32), jnp.float32)
    kp = jnp.zeros((1, 16, 2, 32), jnp.float32)
    return (
        ("vp_quant", lambda: ops.vp_quant(x, fxp, vp, packed=True)),
        ("vp_dequant", lambda: ops.vp_dequant(w, None, vp)),
        ("vp_matmul", lambda: ops.vp_matmul(m, i, m, i, vp, vp)),
        ("vp_matmul_packed",
         lambda: ops.vp_matmul(w, None, w, None, vp, vp)),
        ("vp_dequant_matmul",
         lambda: ops.vp_dequant_matmul(x, w, vp)),
        ("vp_matmul_dx", lambda: ops.vp_matmul_dx(x, w, vp)),
        ("vp_matmul_dw", lambda: ops.vp_matmul_dw(w, x, vp)),
        ("vp_quant_matmul",
         lambda: ops.vp_quant_matmul(x, x, fxp, vp, fxp, vp)),
        ("block_vp_matmul",
         lambda: ops.block_vp_matmul(
             jnp.zeros((16, 256), jnp.int8), jnp.zeros((16, 1), jnp.uint8),
             jnp.zeros((256, 16), jnp.int8), jnp.zeros((1, 16), jnp.uint8),
             vp, vp, bk=256)),
        ("vp_decode_attention",
         lambda: ops.vp_decode_attention(q4, kv, kv, sc, sc, ln, vp)),
        ("flash_prefill", lambda: ops.flash_prefill(qp, kp, kp)),
    )


def check_jaxpr_ops() -> List[Finding]:
    from . import jaxpr_lint

    return _from_dicts(jaxpr_lint.lint_kernel_ops(_op_thunks()))


def check_ref_jit() -> List[Finding]:
    from . import jaxpr_lint

    return _from_dicts(jaxpr_lint.lint_ref_jit())


def check_backward() -> List[Finding]:
    """JX-BWDMAT over the packed-datapath gradient trace.

    Traces `jax.grad` through `vp_dequant_matmul` (packed pretrained
    weights — the serving fine-tune path) under
    `force_backend("interpret")` so the pallas backward launches are
    in-graph; any full-weight-shaped float outside a dot_general /
    pallas_call means the VJP fell back to dequantize-then-autodiff.
    The activation dims are chosen NOT to collide with the weight shape
    so activation/cotangent floats can never alias a weight match.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.base import QuantConfig
    from repro.core.packing import storage_dtype
    from repro.kernels import ops, substrate
    from repro.models.layers import canonical_formats
    from . import jaxpr_lint

    _, vp = canonical_formats(QuantConfig(mode="vp"))
    w = jnp.zeros((64, 64), storage_dtype(vp))
    x = jnp.zeros((8, 64), jnp.float32)

    fxp, _ = canonical_formats(QuantConfig(mode="vp"))
    b = jnp.zeros((64, 64), jnp.float32)

    def loss(x):
        return ops.vp_dequant_matmul(x, w, vp).sum()

    def ste_loss(x, b):
        return ops.vp_quant_matmul(x, b, fxp, vp, fxp, vp).sum()

    findings: List[Finding] = []
    with substrate.force_backend("interpret"):
        for name, jaxpr in (
            ("vp_dequant_matmul", jax.make_jaxpr(jax.grad(loss))(x)),
            ("vp_quant_matmul",
             jax.make_jaxpr(jax.grad(ste_loss, argnums=(0, 1)))(x, b)),
        ):
            findings.extend(_from_dicts(jaxpr_lint.lint_bwd_traced(
                jaxpr, weight_shapes=[(64, 64)], where=f"bwd:{name}")))
    return findings


def check_models(archs: Optional[Sequence[str]] = None) -> List[Finding]:
    """JX-* over the model zoo's serving traces (smoke configs, VP-packed
    quantization with a packed KV cache — the full kernel-backed path)."""
    import dataclasses as dc

    from repro.configs.base import QuantConfig
    from repro.configs.registry import ARCH_NAMES, get_smoke_config
    from . import jaxpr_lint

    q = QuantConfig(mode="vp", quantize_kv_cache=True, kv_layout="packed")
    findings: List[Finding] = []
    for arch in (archs if archs is not None else ARCH_NAMES):
        cfg = get_smoke_config(arch, quant=q)
        if cfg.family in ("ssm", "hybrid"):
            # SSM caches are float state, not KV tensors.
            cfg = dc.replace(cfg, quant=dc.replace(
                q, quantize_kv_cache=False))
        findings.extend(_from_dicts(
            jaxpr_lint.lint_model(cfg, name=arch)))
    return findings


def check_sharded() -> List[Finding]:
    """JX-SHGATH over the shard_map'd serving forwards.

    Traces `parallel.shard_ops.sharded_forward_fns` (one dense arch, one
    MoE arch) on a best-effort mesh over however many devices the
    platform exposes — the rule is structural (int all_gather then a
    float of the gathered shape INSIDE the body), so the verdict does
    not depend on the mesh size.  Traced on the ref backend, where a
    full post-gather dequant is a visible jnp op; the sharded serving
    path gathers outputs/head slices only, so this stays clean.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.base import QuantConfig
    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import best_effort_mesh
    from . import jaxpr_lint

    from repro.models import model as M
    from repro.parallel import shard_ops

    q = QuantConfig(mode="vp", quantize_kv_cache=True, kv_layout="packed")
    mesh = best_effort_mesh()
    findings: List[Finding] = []
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = get_smoke_config(arch, quant=q)
        params = M.quantize_params(
            M.init_params(jax.random.PRNGKey(0), cfg), cfg, layout="packed")
        try:
            prefill_fn, decode_fn = shard_ops.sharded_forward_fns(
                params, cfg, mesh)
        except shard_ops.ShardSpecError:
            continue  # smoke dims not divisible by this device count
        caches = M.init_cache(cfg, B=1, max_len=32)
        tokens = jnp.zeros((1, 8), jnp.int32)
        token = jnp.zeros((1, 1), jnp.int32)
        for stage, jaxpr in (
            ("prefill", jax.make_jaxpr(prefill_fn)(params, tokens, caches)),
            ("decode", jax.make_jaxpr(decode_fn)(params, token, caches)),
        ):
            findings.extend(_from_dicts(jaxpr_lint.lint_sharded_traced(
                jaxpr, where=f"sharded:{arch}:{stage}")))
    return findings


# ---------------------------------------------------------------------------
# Source lint + assembly
# ---------------------------------------------------------------------------

def _src_root() -> str:
    # .../src/repro/analysis/rules.py -> .../src/repro
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check_sources() -> List[Finding]:
    return _from_dicts(srclint.lint_tree(_src_root()))


def run_all(
    archs: Optional[Sequence[str]] = None,
    models: bool = True,
) -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(check_bitwidth())
    findings.extend(check_vmem_defaults())
    findings.extend(check_vmem_cache())
    findings.extend(check_sources())
    findings.extend(check_ref_jit())
    findings.extend(check_jaxpr_ops())
    findings.extend(check_backward())
    if models:
        findings.extend(check_models(archs))
        findings.extend(check_sharded())
    findings.sort(key=lambda f: (_SEV_ORDER[f.severity], f.rule, f.where))
    return findings


def default_baseline_path() -> str:
    # repo root = parent of src/
    return os.path.join(
        os.path.dirname(os.path.dirname(_src_root())),
        "ANALYSIS_BASELINE.json")


def load_baseline(path: str) -> List[str]:
    try:
        with open(path) as f:
            data = json.load(f)
        return list(data.get("accepted", []))
    except (OSError, ValueError):
        return []


def unbaselined(findings: Sequence[Finding],
                baseline: Sequence[str]) -> List[Finding]:
    """Error/warn findings not covered by the baseline (the CI gate)."""
    accepted = set(baseline)
    return [f for f in findings
            if f.severity != "info" and f.key not in accepted]
