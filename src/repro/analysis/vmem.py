"""Per-kernel VMEM footprint model, checked against the TPU budget.

Every Pallas kernel in `repro.kernels` stages block-spec tiles plus VMEM
scratch on chip; a candidate tiling whose working set exceeds the ~16 MB
per-core VMEM fails to lower (Mosaic "not enough VMEM"-class errors) —
previously discovered only by TIMING the candidate inside
`autotune.tune` and letting it lose.  This module computes the footprint
statically from the same quantities the launch uses (block shapes,
operand dtypes, scratch shapes), so:

  * `kernels/autotune.py` prunes infeasible candidates BEFORE timing
    (shorter tuning runs, and a class of Mosaic failures never launches);
  * the `python -m repro.analysis` VM rules verify the default/native
    tilings of every registered kernel and every persisted autotune
    cache entry against the budget.

The model counts, per operand and output, tile_bytes x 2 (Pallas
double-buffers pipelined tiles), scratch once, and the in-kernel f32
dequant temporaries the kernel bodies materialize.  It is deliberately a
LOWER bound — compiler-internal spills and fusions are not modeled — so
a candidate it rejects is certainly infeasible, while one it admits may
still lose in `tune` the old way (by failing to lower).  Never the
reverse: the model must not over-prune, which the soundness tests pin by
checking it admits every tiling the kernel suite actually launches.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import FXPFormat, VPFormat
from repro.core.packing import storage_dtype
from repro.core.vp_tensor import significand_dtype

# Per-core VMEM on contemporary TPUs (v4/v5 class): ~16 MiB.
_DEFAULT_BUDGET = 16 * 1024 * 1024
_ENV_VAR = "REPRO_VMEM_BUDGET_BYTES"

# Online-softmax scratch rows are lane-broadcast to the TPU lane count
# (kernels/vp_attention._LANES).
_LANES = 128
_F32 = 4


def vmem_budget_bytes() -> int:
    """The VMEM budget (env override `REPRO_VMEM_BUDGET_BYTES`)."""
    env = os.environ.get(_ENV_VAR)
    return int(env) if env else _DEFAULT_BUDGET


def _itemsize(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def _plane_bytes(fmt: VPFormat) -> int:
    """Bytes/element of the two-plane layout (significand + uint8 index)."""
    return _itemsize(significand_dtype(fmt.M)) + 1


def _word_bytes(fmt: VPFormat) -> int:
    """Bytes/element of the packed-word layout."""
    return _itemsize(storage_dtype(fmt))


def _vp(formats: Sequence, idx: int) -> Optional[VPFormat]:
    fs = [f for f in formats if isinstance(f, (VPFormat, FXPFormat))]
    if idx < len(fs) and isinstance(fs[idx], VPFormat):
        return fs[idx]
    return None


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _shard_shape(shape, shards):
    """Per-shard logical shape: each dim ceil-divided by its shard count."""
    if shape is None or shards is None:
        return shape
    if len(shards) != len(shape):
        raise ValueError(
            f"shards {tuple(shards)} must match shape rank {tuple(shape)}")
    return tuple(-(-int(d) // max(1, int(s)))
                 for d, s in zip(shape, shards))


def kernel_vmem_bytes(
    kernel: str,
    blocks: Tuple[int, int, int],
    formats: Sequence = (),
    shape: Optional[Sequence[int]] = None,
    shards: Optional[Sequence[int]] = None,
) -> Optional[int]:
    """Static VMEM working set of one kernel launch, or None if this
    kernel's layout is not modeled (unknown kernels are never pruned).

    `kernel`, `blocks`, `formats`, `shape` are exactly the values the
    autotune cache keys carry, so the autotuner can consult the model
    with what it already has in hand.

    `shards` (same rank as `shape`) divides the logical shape by the
    mesh-shard counts first: under shard_map each device launches on its
    LOCAL operand, so tiles clamp to the per-shard dims (the same
    power-of-two clamp `heuristic_blocks` applies) — a tiling that only
    fits on-chip BECAUSE the mesh shrank the operand is admitted, and
    one whose per-shard tile still overflows is rejected.
    """
    bm, bk, bn = int(blocks[0]), int(blocks[1]), int(blocks[2])
    if shards is not None and shape is not None:
        # Per-shard launch: the resolver re-clamps tiles to the LOCAL
        # operand (`heuristic_blocks`' power-of-two clamp), so the model
        # evaluates the tile that actually launches on each device —
        # never the single-device tile a shard could not even stage.
        shape = _shard_shape(shape, shards)
        if "attention" in kernel or "prefill" in kernel:
            if len(shape) >= 2:  # blocks[1] tiles the (sharded) seq dim
                bk = min(bk, _pow2_at_least(int(shape[1])))
        elif len(shape) >= 3:
            m, k, n = (int(d) for d in shape[-3:])
            bm = min(bm, _pow2_at_least(m))
            bk = min(bk, _pow2_at_least(k))
            bn = min(bn, _pow2_at_least(n))
    base = kernel.split("_bk")[0] if kernel.startswith(
        "block_vp_matmul") else kernel
    batched = "batched" in base
    base = base.replace("_batched", "")

    if base in ("vp_matmul", "vp_matmul_packed"):
        a_fmt, b_fmt = _vp(formats, 0), _vp(formats, 1)
        if a_fmt is None or b_fmt is None:
            return None
        if base.endswith("_packed"):
            in_bytes = bm * bk * _word_bytes(a_fmt) \
                + bk * bn * _word_bytes(b_fmt)
        else:
            in_bytes = bm * bk * _plane_bytes(a_fmt) \
                + bk * bn * _plane_bytes(b_fmt)
        temps = (bm * bk + bk * bn) * _F32          # dequantized tiles
        out = bm * bn * _F32
        scratch = bm * bn * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "vp_dequant_matmul":
        w_fmt = _vp(formats, 0)
        if w_fmt is None:
            return None
        in_bytes = bm * bk * _F32 + bk * bn * _word_bytes(w_fmt)
        temps = bk * bn * _F32                       # dequantized W tile
        out = bm * bn * _F32
        scratch = bm * bn * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "vp_matmul_dx":
        # g (bm, bn) f32 and packed-w (bk, bn) tiles in, (bm, bk) out
        # with an f32 accumulator scratch; the dequantized w tile is the
        # only temp (kernels/vp_bwd_matmul._vp_matmul_dx_kernel).
        w_fmt = _vp(formats, 0)
        if w_fmt is None:
            return None
        in_bytes = bm * bn * _F32 + bk * bn * _word_bytes(w_fmt)
        temps = bk * bn * _F32                       # dequantized W tile
        out = bm * bk * _F32
        scratch = bm * bk * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "vp_matmul_dw":
        # packed-a (bm, bk) and g (bm, bn) f32 tiles in, (bk, bn) out
        # with an f32 accumulator scratch; temp = dequantized a tile.
        a_fmt = _vp(formats, 0)
        if a_fmt is None:
            return None
        in_bytes = bm * bk * _word_bytes(a_fmt) + bm * bn * _F32
        temps = bm * bk * _F32                       # dequantized A tile
        out = bk * bn * _F32
        scratch = bk * bn * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "vp_quant_matmul":
        # Float operands in, quantize-dequantize cascade in-register:
        # int32 (m, i) intermediates per operand tile + the f32 results.
        in_bytes = (bm * bk + bk * bn) * _F32
        temps = (bm * bk + bk * bn) * _F32
        out = bm * bn * _F32
        scratch = bm * bn * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "block_vp_matmul":
        in_bytes = bm * bk + bk * bn + bm + bn       # int8 planes + indices
        temps = bm * bn * 4 + (bm + bn) * _F32       # int32 MXU tile, scales
        out = bm * bn * _F32
        scratch = bm * bn * _F32
        return 2 * in_bytes + 2 * out + scratch + temps

    if base == "vp_decode_attention":
        fmt = _vp(formats, 0)
        if fmt is None or shape is None or len(shape) < 4:
            return None
        dh = int(shape[3])
        bs = bk                                      # seq tile = blocks[1]
        rows = 8                                     # Gp floor (lower bound)
        cache = 2 * bs * dh * _word_bytes(fmt)       # K and V word tiles
        scales = 2 * bs * _F32
        q = rows * dh * _F32
        temps = 2 * bs * dh * _F32                   # dequantized K, V
        out = rows * dh * _F32
        scratch = (2 * rows * _LANES + rows * dh) * _F32
        return 2 * (cache + scales + q) + 2 * out + scratch + temps

    if base == "flash_prefill":
        if shape is None or len(shape) < 4:
            return None
        dh = int(shape[3])
        bq, bkv = bm, bk                             # blocks = (bq, bk, 1)
        in_bytes = (bq + 2 * bkv) * dh * _F32
        out = bq * dh * _F32
        scratch = (2 * bq * _LANES + bq * dh) * _F32
        temps = bq * bkv * _F32                      # scores tile
        return 2 * in_bytes + 2 * out + scratch + temps

    del batched  # per-tile footprint is batch-independent (leading 1)
    return None


def vmem_feasible(
    kernel: str,
    blocks: Tuple[int, int, int],
    formats: Sequence = (),
    shape: Optional[Sequence[int]] = None,
    budget: Optional[int] = None,
    shards: Optional[Sequence[int]] = None,
) -> Tuple[bool, Optional[int]]:
    """(fits, modeled bytes).  Unmodeled kernels report (True, None) —
    the autotuner must never prune what it cannot reason about."""
    need = kernel_vmem_bytes(kernel, blocks, formats, shape, shards=shards)
    if need is None:
        return True, None
    budget = vmem_budget_bytes() if budget is None else budget
    return need <= budget, need
