"""`python -m repro.analysis`: run every static check, gate on baseline.

Exit status 0 when every error/warn finding is covered by the committed
baseline (`ANALYSIS_BASELINE.json`); 1 otherwise.  Info findings (f32
exactness horizons) are reported but never fatal.

  python -m repro.analysis                     # full run (all archs)
  python -m repro.analysis --archs llama3-8b   # one model's traces
  python -m repro.analysis --no-models         # skip model tracing
  python -m repro.analysis --update-baseline   # accept current findings
  python -m repro.analysis --json out.json     # machine-readable report
"""
from __future__ import annotations

import argparse
import json
import sys

from . import bitwidth, rules


def _print_safe_k_table() -> None:
    pairs, _, depth = rules.analysis_formats()
    print(f"\nMax safe accumulation depth K per format pair "
          f"(block-VP int32 tile depth = {depth}):")
    print(f"  {'pair':18s} {'a':16s} {'b':18s} "
          f"{'exact-f32 K':>12s} {'int32 K':>12s}")
    for row in bitwidth.safe_k_table(pairs):
        print(f"  {row['pair']:18s} {row['a']:16s} {row['b']:18s} "
              f"{row['max_safe_k_float32']:>12d} "
              f"{row['max_safe_k_int32']:>12d}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker for the VP kernel stack")
    p.add_argument("--archs", default=None,
                   help="comma-separated arch subset to trace "
                        "(default: all)")
    p.add_argument("--no-models", action="store_true",
                   help="skip the model-zoo jaxpr traces")
    p.add_argument("--baseline", default=rules.default_baseline_path())
    p.add_argument("--update-baseline", action="store_true",
                   help="write the current error/warn findings as the "
                        "accepted baseline")
    p.add_argument("--json", dest="json_out", default=None,
                   help="write the full findings list as JSON")
    args = p.parse_args(argv)

    archs = [a for a in args.archs.split(",") if a] if args.archs else None
    findings = rules.run_all(archs=archs, models=not args.no_models)

    by_sev = {"error": [], "warn": [], "info": []}
    for f in findings:
        by_sev[f.severity].append(f)
    for sev in ("error", "warn", "info"):
        for f in by_sev[sev]:
            print(f)
    _print_safe_k_table()

    if args.json_out:
        with open(args.json_out, "w") as fp:
            json.dump([dataclass_dict(f) for f in findings], fp, indent=1)

    if args.update_baseline:
        accepted = sorted({f.key for f in findings
                           if f.severity != "info"})
        doc = {"accepted": accepted}
        try:  # keep human-written justification notes across rewrites
            with open(args.baseline) as fp:
                notes = json.load(fp).get("notes")
            if notes:
                doc["notes"] = notes
        except (OSError, ValueError):
            pass
        with open(args.baseline, "w") as fp:
            json.dump(doc, fp, indent=1)
            fp.write("\n")
        print(f"\nbaseline updated: {len(accepted)} accepted finding(s) "
              f"-> {args.baseline}")
        return 0

    baseline = rules.load_baseline(args.baseline)
    bad = rules.unbaselined(findings, baseline)
    n_err = len(by_sev["error"])
    n_warn = len(by_sev["warn"])
    print(f"\n{n_err} error(s), {n_warn} warning(s), "
          f"{len(by_sev['info'])} info; "
          f"{len(bad)} not in baseline ({len(baseline)} accepted)")
    if bad:
        print("non-baselined findings (fix them, or accept with "
              "--update-baseline):")
        for f in bad:
            print(f"  {f}")
        return 1
    return 0


def dataclass_dict(f: rules.Finding) -> dict:
    return {"rule": f.rule, "severity": f.severity,
            "where": f.where, "detail": f.detail}


if __name__ == "__main__":
    sys.exit(main())
