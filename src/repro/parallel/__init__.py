from . import sharding
