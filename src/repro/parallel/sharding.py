"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod
("pod" is pure data parallelism).  Rules are name+shape based:

  * embeddings / lm_head: vocab sharded over "model";
  * attention projections: head dim over "model" IF the head count divides
    the model-axis size, else replicated (qwen2 14H, whisper 6H — noted in
    DESIGN.md; the MLP still shards, so TP remains useful);
  * MLP: column-parallel in, row-parallel out;
  * MoE experts: expert axis over "model" when E % tp == 0 (qwen3-moe),
    else d_ff over "model" (mixtral: 8e < 16 devices);
  * Mamba2 / RWKV6: d_inner-style dims over "model" when divisible;
  * batch dims over ("pod", "data").

Activation entry points get explicit constraints; GSPMD propagates the
rest from the weight shardings.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Wideband OFDM: subcarrier-axis data parallelism (mimo/ofdm.py)
# ---------------------------------------------------------------------------

def subcarrier_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh with a single "sc" (subcarrier) axis.

    The wideband equalizer is embarrassingly parallel across subcarriers
    (independent per-subcarrier MVM batches), so the fleet layout is pure
    data parallelism over the band: each device owns a contiguous slab of
    subcarriers and runs the batched VP kernel on its slab.
    """
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), ("sc",))


def shard_over_subcarriers(fn, mesh: Optional[Mesh] = None,
                           n_subcarriers: Optional[int] = None):
    """shard_map `fn` over the leading subcarrier axis of its args.

    `fn` maps (S_local, ...) arrays to (S_local, ...) arrays (the flat
    wideband path in mimo/ofdm.py).  Inputs/outputs are sharded over the
    mesh's "sc" axis; every other dim is replicated.  When the subcarrier
    count does not divide the mesh (or the mesh is a single device) this
    degrades gracefully to running `fn` unsharded — callers never need a
    divisibility check on the serving path.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    if mesh is None:
        mesh = subcarrier_mesh()
    n_dev = mesh.shape["sc"]
    if n_dev == 1 or (n_subcarriers is not None and n_subcarriers % n_dev):
        return fn
    spec = PartitionSpec("sc")
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _spec_for_path(path: str, leaf, cfg: ModelConfig, tp: int,
                   fsdp: Optional[str] = "data") -> P:
    """PartitionSpec for one parameter leaf (path = '/'-joined keys).

    2D layout: tensor-parallel dim over "model" + FSDP dim over "data"
    (weights are ZeRO-3-style gathered per layer; optimizer state inherits
    the same specs).  `fsdp=None` disables the data-axis dimension (small
    models / pure-TP serving).
    """
    name = path.split("/")[-1]
    # Quantized leaves ("m", "i_packed", "i_blk", packed serving words)
    # inherit the spec of their parent weight via the SAME rules keyed on
    # the parent name.
    parent = path.split("/")[-2] if "/" in path else ""
    if name in ("m", "i_packed", "i_blk", "w_packed"):
        name = parent
    elif name in ("scale", "b") or leaf.ndim <= 1:
        return P()
    nd = leaf.ndim
    in_groups = path.startswith("groups/")
    H, KV, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    F = fsdp  # alias

    def with_stack(spec: P) -> P:
        if in_groups and nd == len(spec) + 1:
            return P(None, *spec)
        return spec

    if name == "embed":
        # vocab over "model" only: FSDP-sharding d makes the token gather
        # all-gather the ENTIRE table (5.6 GB f32 for gemma3) every step.
        return P("model", None)
    if name == "lm_head":
        # NO FSDP on d_in: a data-sharded contraction dim against batch-
        # sharded activations makes GSPMD emit partial-sum logit
        # all-reduces (10 GB/layer observed); vocab sharding alone keeps
        # the largest lm_head at ~176 MB/device.
        return P(None, "model")
    if name == "patch_proj":
        return P(F, "model")
    if name == "wq":
        return with_stack(P(F, "model") if _div(H, tp) else P(F, None))
    if name in ("wk", "wv"):
        return with_stack(P(F, "model") if _div(KV, tp) else P(F, None))
    if name == "wo":
        return with_stack(P("model", F) if _div(H, tp) else P(None, F))
    if name in ("w_gate", "w_up", "w_down"):
        is_expert = cfg.n_experts and nd - (1 if in_groups else 0) == 3
        if is_expert:
            if _div(cfg.n_experts, tp):   # true EP (qwen3-moe)
                return with_stack(P("model", F, None))
            # few big experts (mixtral): TP over d_ff + FSDP over d
            if name == "w_down":
                return with_stack(P(None, "model", F))
            return with_stack(P(None, F, "model"))
        if name == "w_down":
            return with_stack(P("model", F))
        return with_stack(P(F, "model"))
    if name == "w_router":
        return with_stack(P(F, None))
    if name == "w_in":   # whisper gelu mlp in
        return with_stack(P(F, "model"))
    if name == "w_out":  # whisper mlp out / mamba out-proj
        return with_stack(P("model", F))
    # Mamba2: d_inner over "model" (heads divide), d over FSDP
    if name in ("w_z", "w_x"):
        return with_stack(
            P(F, "model") if _div(cfg.ssm_nheads, tp) else P(F, None))
    if name in ("w_bc", "w_dt"):
        return with_stack(P(F, None))
    if name == "conv_w":
        return with_stack(P(None, None))
    # RWKV6 (2560 -> 40 heads, not divisible by 16: TP replicated, FSDP
    # still shards the d_in dim so params/optimizer fit)
    if name in ("w_r", "w_k", "w_v", "w_g", "w_o"):
        rh = d // 64
        return with_stack(P(F, "model") if _div(rh, tp) else P(F, None))
    if name == "w_ck":
        return with_stack(P(F, "model"))
    if name == "w_cv":
        return with_stack(P("model", F))
    if name == "w_cr":
        return with_stack(P(F, None))
    if name in ("w_dec_a", "w_dec_b"):
        return with_stack(P(F, None))
    # everything else (norms, biases, scalars): replicated
    return P()


def param_shardings(params, cfg: ModelConfig, mesh: Mesh,
                    fsdp: bool = True):
    """NamedSharding tree matching the params tree.

    fsdp=True shards the non-TP weight dim over "data" (ZeRO-3); disable
    for small models where replication is cheaper than the gathers.
    Sharded dims that do not divide evenly fall back to replicated.
    """
    tp = tp_size(mesh)
    fs = "data" if fsdp else None
    axis_sizes = dict(mesh.shape)

    def fix(spec_names, shape):
        """Drop axis assignments that don't divide the dim evenly."""
        out = []
        for dim, ax in zip(shape, spec_names):
            if ax is None:
                out.append(None)
            else:
                size = (axis_sizes[ax] if isinstance(ax, str)
                        else int(np.prod([axis_sizes[a] for a in ax])))
                out.append(ax if dim % size == 0 else None)
        return out

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
        spec = _spec_for_path(path, node, cfg, tp, fs)
        names = list(spec) + [None] * (node.ndim - len(spec))
        names = fix(names[: node.ndim], node.shape)
        return NamedSharding(mesh, P(*names))

    def walk_top(node):
        out = {}
        for k, v in node.items():
            if k == "groups":
                out[k] = [walk(g, "groups") for g in v]
            else:
                out[k] = walk(v, k)
        return out

    return walk_top(params)


def batch_shardings(batch, mesh: Mesh):
    """Shard leading batch dim over (pod,)+data (replicate if too small)."""
    ax = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ax]))

    def leaf(x):
        first = ax if x.shape and x.shape[0] % n == 0 else None
        return NamedSharding(mesh, P(first, *([None] * (max(x.ndim, 1) - 1))))

    return jax.tree_util.tree_map(leaf, batch)


def cache_shardings(caches, cfg: ModelConfig, mesh: Mesh,
                    seq_axes=None):
    """Decode-cache sharding: (group-stack, B, S, KV, dh).

    B over data(+pod) when divisible.  KV heads over "model" when
    divisible; for cells whose cache would blow past HBM, `seq_axes`
    shards the SEQUENCE dim instead (e.g. ("model",) or ("data","model")
    for batch-1 long-context decode) — GSPMD then emits the distributed
    flash-decode combine for the masked softmax.
    """
    tp = tp_size(mesh)
    ax = batch_axes(mesh)
    axis_sizes = dict(mesh.shape)
    kv_div = _div(cfg.n_kv_heads, tp)

    def leaf_spec(path, x):
        name = path.split("/")[-1]
        nb = int(np.prod([axis_sizes[a] for a in ax]))
        bax = ax if x.ndim > 1 and x.shape[1] % nb == 0 else None
        if name in ("k", "v", "k_m", "k_i", "v_m", "v_i"):
            if seq_axes:
                nseq = int(np.prod([axis_sizes[a] for a in seq_axes]))
                seq = seq_axes if x.shape[2] % nseq == 0 else None
                return P(None, bax, seq, None, None)
            head_ax = "model" if kv_div else None
            return P(None, bax, None, head_ax, None)
        if name in ("k_s", "v_s"):
            seq = seq_axes if seq_axes else None
            return P(None, bax, seq, None, None)
        if name == "len":
            return P(None, bax)
        if name == "s":      # rwkv state (L, B, H, N, N)
            return P(None, bax, None, None, None)
        if name == "h":      # mamba state (L, B, H, P, N)
            hspec = "model" if _div(cfg.ssm_nheads, tp) else None
            return P(None, bax, hspec, None, None)
        if name == "conv":
            return P(None, bax, None, None)
        if name in ("last_tm", "last_cm"):
            return P(None, bax, None)
        return P(*([None] * x.ndim))

    def walk(node, path=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
        if node is None:
            return None
        spec = leaf_spec(path, node)
        names = list(spec)[: node.ndim]
        names += [None] * (node.ndim - len(names))
        return NamedSharding(mesh, P(*names))

    return walk(caches)
