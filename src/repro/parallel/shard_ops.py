"""Mesh-native execution of the packed VP datapath under shard_map.

The paper's packed words earn their keep twice on a mesh: the same
narrow int8/int16 words that halve HBM traffic also halve (or quarter)
COLLECTIVE bytes versus f32, so tensor-parallel shards exchange packed
words and dequantize after the gather, in-tile.  Three weight-sharded
execution modes, all bit-identical to the single-device oracle on the
ref backend (every collective here is a pure concatenation — no
cross-device reduction ever touches a float accumulation order):

  column  local dequant-matmul on the weight shard, then all-gather the
          OUTPUT activations.  The serving default: for decode the
          activation plane (M x N/tp floats) is far smaller than the
          weight shard, so this moves the fewest bytes.
  gather  all-gather the PACKED weight words, then one full dequant-
          matmul.  Moves int words (2-4x fewer bytes than f32 weights)
          but materializes the full unsharded weight on every device —
          the anti-pattern `analysis.jaxpr_lint` JX-SHGATH flags; kept
          as the non-overlapped baseline the sweep driver times.
  ring    collective matmul: per step, dequant-matmul the resident
          packed chunk into its owner's output columns, while ppermute
          rotates the NEXT packed chunk around the mesh.  Communication
          is packed words AND it hides behind compute; the full f32
          weight never exists on any device.

The datapath is trainable under the same mesh: `sharded_matmul_dx`
reduces dL/dx across the tensor axis (one psum, or a ring
reduce-scatter of row chunks overlapped with the per-chunk packed-word
backward kernels), `sharded_matmul_dw` computes each shard's weight
gradient purely locally, and `dp_compress_reduce` runs the
error-feedback gradient codec before the data-axis mean.

`shard_param_specs` places a whole quantized param tree for the model-
level wrappers: every quantized weight leaf shards its OUTPUT (last)
dim over the tensor axis, stacked MoE expert leaves shard their expert
axis instead (expert parallelism), scales/norms/biases/router stay
replicated.  `qdot`/`embed_lookup`/`moe_block` then all-gather their
local outputs when `QuantConfig.tp_axis` is set, so full-model prefill
and decode run under shard_map with no other model changes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, QuantConfig
from repro.kernels import autotune
from repro.kernels import ops as kops

MODES = ("column", "gather", "ring")

# Quantized-leaf member arrays whose trailing dim is the OUTPUT dim
# (every storage layout `quantize_weight` emits keeps d_out last).
_WEIGHT_MEMBERS = ("w_packed", "m", "w", "i_packed", "i_blk")


class ShardSpecError(ValueError):
    """A param tree cannot be placed on the requested tensor axis."""


# ---------------------------------------------------------------------------
# Op-level sharded execution (call INSIDE shard_map)
# ---------------------------------------------------------------------------

def sharded_dequant_matmul(x, w_packed, fmt, *, axis: str = "model",
                           mode: str = "ring", out_dtype=None):
    """x (M, K) replicated, w_packed (K, N/tp) local -> (M, N) replicated.

    Must run inside shard_map over `axis`.  All three modes return the
    bit-exact single-device result on the ref backend: `column`/`ring`
    compute each output column block from the same dequantized words in
    the same contraction order as the full matmul, and `gather`
    reassembles the identical full weight before one full matmul.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}: {mode!r}")
    tp = jax.lax.psum(1, axis)
    if mode == "column":
        with autotune.mesh_scope(f"{axis}{tp}.N"):
            y = kops.vp_dequant_matmul(x, w_packed, fmt, out_dtype=out_dtype)
        return jax.lax.all_gather(y, axis, axis=1, tiled=True)
    if mode == "gather":
        # The matmul runs on the REASSEMBLED full weight, so its tiling
        # geometry equals the single-device launch: no mesh scope.
        w_full = jax.lax.all_gather(w_packed, axis, axis=1, tiled=True)
        return kops.vp_dequant_matmul(x, w_full, fmt, out_dtype=out_dtype)
    # ring: overlap per-chunk dequant-matmul with the packed-word rotate.
    idx = jax.lax.axis_index(axis)
    n_loc = w_packed.shape[1]
    dtype = out_dtype if out_dtype is not None else x.dtype
    y = jnp.zeros((x.shape[0], n_loc * tp), dtype)
    chunk = w_packed
    perm = [(i, (i - 1) % tp) for i in range(tp)]
    with autotune.mesh_scope(f"{axis}{tp}.N"):
        for step in range(tp):
            owner = (idx + step) % tp
            y_loc = kops.vp_dequant_matmul(x, chunk, fmt,
                                           out_dtype=out_dtype)
            y = jax.lax.dynamic_update_slice(y, y_loc, (0, owner * n_loc))
            if step < tp - 1:
                chunk = jax.lax.ppermute(chunk, axis, perm=perm)
    return y


def sharded_matmul_dx(g, w_packed, fmt, *, axis: str = "model",
                      mode: str = "psum", out_dtype=jnp.float32,
                      gather: bool = True):
    """Backward of the column-sharded forward: dL/dx from a REPLICATED
    output cotangent g (M, N) and the LOCAL packed weight shard
    w_packed (K, N/tp) -> dx (M, K).  Must run inside shard_map.

    A shard owns N/tp output columns, so its contribution to dx is
    g[:, own cols] @ dequant(w_loc)^T — the packed-word backward kernel
    (`kernels.ops.vp_matmul_dx`); the f32 weight plane never exists on
    any device, mirroring the forward modes.

      psum  local partial dx, then one all-reduce of M*K floats.  The
            simple baseline (the backward analogue of `gather`).
      ring  reduce-scatter: dx is chunked along M; each step computes
            the partial for ONE rotating chunk while the accumulating
            buffer ppermutes around the mesh, so after tp steps device i
            holds its fully-reduced (M/tp, K) chunk — tp-fold fewer
            collective bytes, hidden behind the per-chunk kernels.
            `gather=True` all-gathers the chunks back to a replicated
            dx; False leaves dx row-sharded (ZeRO-style consumers).

    psum and ring add the same tp partials in different orders, so the
    modes agree to f32 reduction tolerance (each is deterministic on its
    own) — unlike the forward modes, which are concatenation-exact.
    """
    if mode not in ("psum", "ring"):
        raise ValueError(f"mode must be 'psum' or 'ring': {mode!r}")
    tp = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    n_loc = w_packed.shape[1]
    g_loc = jax.lax.dynamic_slice_in_dim(g, idx * n_loc, n_loc, axis=1)
    if mode == "psum":
        with autotune.mesh_scope(f"{axis}{tp}.N"):
            dx = kops.vp_matmul_dx(g_loc, w_packed, fmt,
                                   out_dtype=out_dtype)
        return jax.lax.psum(dx, axis)
    m = g.shape[0]
    if m % tp:
        raise ShardSpecError(
            f"ring dx reduce-scatter chunks the batch dim: M={m} is not "
            f"divisible by tensor-parallel size {tp}")
    m_loc = m // tp

    def chunk_term(c):
        g_c = jax.lax.dynamic_slice_in_dim(g_loc, c * m_loc, m_loc, axis=0)
        return kops.vp_matmul_dx(g_c, w_packed, fmt, out_dtype=out_dtype)

    # Invariant: after step s, device i's buf holds
    # sum_{d=i..i+s} T_d(chunk (i+1+s) % tp), where T_d(c) is device d's
    # partial for chunk c — so after tp-1 steps buf is chunk i, fully
    # reduced.  Same rotation as the forward ring.
    perm = [(i, (i - 1) % tp) for i in range(tp)]
    with autotune.mesh_scope(f"{axis}{tp}.N"):
        buf = chunk_term((idx + 1) % tp)
        for s in range(1, tp):
            buf = jax.lax.ppermute(buf, axis, perm=perm) \
                + chunk_term((idx + 1 + s) % tp)
    if gather:
        return jax.lax.all_gather(buf, axis, axis=0, tiled=True)
    return buf


def sharded_matmul_dw(a_w, g, fmt, *, axis: str = "model",
                      out_dtype=jnp.float32):
    """dL/dW shard for the column-sharded weight: dequant(a_w)^T @
    g[:, own cols] -> (K, N/tp).  Must run inside shard_map.

    Entirely LOCAL — each device's weight shard is touched only by its
    own output columns, so the weight gradient needs no tensor-axis
    collective at all (the DP-axis reduction is `dp_compress_reduce`).
    The packed residual a_w rides HBM at storage_bits per element.
    """
    tp = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    n_loc = g.shape[1] // tp
    g_loc = jax.lax.dynamic_slice_in_dim(g, idx * n_loc, n_loc, axis=1)
    with autotune.mesh_scope(f"{axis}{tp}.N"):
        return kops.vp_matmul_dw(a_w, g_loc, fmt, out_dtype=out_dtype)


def dp_compress_reduce(grads, state, *, axis: str = "data", config=None):
    """Error-feedback compressed data-parallel gradient mean.

    Must run inside shard_map over `axis`.  Each DP rank quantizes its
    LOCAL gradient tree (int8 or packed VP words per
    `CompressionConfig.codec`) carrying the residual in `state`; what
    crosses the wire is the reduction of the DEQUANTIZED planes —
    modeling the reduce-scatter-of-words fleets run, with the residual
    keeping SGD convergence (the compressor is a contraction).  Returns
    (mean grads, new state); per-rank residuals stay rank-local.
    """
    # Imported here: train.compression is a training-side module and
    # this one is imported by serving paths (no train deps at import).
    from repro.train.compression import (CompressionConfig,
                                         compress_decompress)

    if config is None:
        config = CompressionConfig()
    dp = jax.lax.psum(1, axis)
    deq, new_state = compress_decompress(grads, state, config)
    reduced = jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axis) / dp, deq)
    return reduced, new_state


def sharded_decode_attention(q, k_w, v_w, k_s, v_s, lengths, fmt, *,
                             axis: str = "model", mode: str = "seq",
                             window: Optional[int] = None,
                             rolling: bool = False):
    """Packed-KV decode attention under shard_map over `axis`.

    mode "seq":   caches sharded along the sequence dim (axis 1) — the
                  paged-KV layout; the shards are all-gathered as PACKED
                  words (+ their pow2 scales) and the unchanged op runs
                  on the reassembled cache.  The collective moves
                  storage_bits-per-element words, never f32 planes.
    mode "heads": q sharded along H, caches along KV — GQA groups are
                  independent, so each shard attends locally and the
                  outputs concatenate along the head dim.  No cache
                  collective at all.
    Both are bit-identical to the single-device op (concatenation-only
    collectives; softmax/contraction orders are untouched per position
    resp. per head group).
    """
    if mode == "seq":
        k_w = jax.lax.all_gather(k_w, axis, axis=1, tiled=True)
        v_w = jax.lax.all_gather(v_w, axis, axis=1, tiled=True)
        k_s = jax.lax.all_gather(k_s, axis, axis=1, tiled=True)
        v_s = jax.lax.all_gather(v_s, axis, axis=1, tiled=True)
        return kops.vp_decode_attention(q, k_w, v_w, k_s, v_s, lengths,
                                        fmt, window=window, rolling=rolling)
    if mode == "heads":
        tp = jax.lax.psum(1, axis)
        with autotune.mesh_scope(f"{axis}{tp}.H"):
            out = kops.vp_decode_attention(q, k_w, v_w, k_s, v_s, lengths,
                                           fmt, window=window,
                                           rolling=rolling)
        return jax.lax.all_gather(out, axis, axis=2, tiled=True)
    raise ValueError(f"mode must be 'seq' or 'heads': {mode!r}")


def sharded_flash_prefill(q, k, v, *, axis: str = "model",
                          pattern: str = "causal",
                          window: Optional[int] = None):
    """Flash prefill with q sharded along H and k/v along KV (axis 2).

    GQA head groups never interact, so the per-shard flash pass equals
    the corresponding head slice of the full pass bit-for-bit; outputs
    concatenate along the head dim.
    """
    from repro.models.attention import flash_attention

    tp = jax.lax.psum(1, axis)
    with autotune.mesh_scope(f"{axis}{tp}.H"):
        out = flash_attention(q, k, v, pattern=pattern, window=window)
    return jax.lax.all_gather(out, axis, axis=2, tiled=True)


# ---------------------------------------------------------------------------
# Param-tree placement
# ---------------------------------------------------------------------------

def _is_quant_leaf(node) -> bool:
    return isinstance(node, dict) and any(
        k in node for k in _WEIGHT_MEMBERS) and not any(
        isinstance(v, (dict, list)) for v in node.values())


def _leaf_specs(node: dict, path: str, axis: str, tp: int,
                expert: bool) -> dict:
    """Specs for one quantized leaf-dict (the `quantize_weight` output).

    Plain / layer-stacked weights ((d_in, d_out) or (L, d_in, d_out))
    shard d_out — the LAST dim of every storage member.  Expert-stacked
    MoE weights ((E, d_in, d_out) or (L, E, d_in, d_out), recognized by
    the sibling `w_router`) shard the expert axis (ndim-3) instead:
    expert parallelism keeps each expert's column dims whole, so the
    group-local dispatch math is untouched.
    """
    out = {}
    for k, v in node.items():
        if k in _WEIGHT_MEMBERS:
            dim = v.ndim - 3 if expert else v.ndim - 1
            if v.shape[dim] % tp:
                raise ShardSpecError(
                    f"{path}.{k}: dim {dim} of shape {tuple(v.shape)} is "
                    f"not divisible by tensor-parallel size {tp}; pick a "
                    f"mesh whose '{axis}' axis divides every quantized "
                    f"{'expert count' if expert else 'output dim'}")
            spec = [None] * v.ndim
            spec[dim] = axis
            out[k] = P(*spec)
        elif k == "scale" and expert:
            # per-expert scales ride the expert axis: (L, E) / (E,)
            out[k] = P(*([None] * (v.ndim - 1) + [axis]))
        else:
            out[k] = P()
    return out


def shard_param_specs(params, cfg: ModelConfig, *, axis: str = "model",
                      tp: int):
    """PartitionSpec tree mirroring a (quantized) param tree.

    Quantized leaf-dicts shard per `_leaf_specs`; every float leaf
    (norms, biases, router weights, unquantized models) is replicated —
    routing and layernorm math must be identical on every shard for the
    gathered outputs to be bit-exact.  Raises ShardSpecError with the
    offending path when a weight dim does not divide by `tp`.
    """
    if tp < 1:
        raise ShardSpecError(f"tensor-parallel size must be >= 1: {tp}")

    def walk(node, path, expert_ctx=False):
        if _is_quant_leaf(node):
            return _leaf_specs(node, path, axis, tp, expert_ctx) if tp > 1 \
                else {k: P() for k in node}
        if isinstance(node, dict):
            has_router = "w_router" in node
            return {k: walk(v, f"{path}.{k}" if path else k,
                            has_router and k in ("w_gate", "w_up", "w_down"))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, f"{path}[{i}]") for i, v in enumerate(node)]
        return P()

    return walk(params, "")


def tp_quant(q: QuantConfig, axis: str = "model") -> QuantConfig:
    """The QuantConfig the shard_map'd forward runs under."""
    return dataclasses.replace(q, tp_axis=axis)


def tp_size(mesh, axis: str = "model") -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def batch_dim_specs(tree, axis: str, dim: int):
    """Per-leaf specs sharding `dim` over `axis` (cache/view trees)."""
    return jax.tree_util.tree_map(
        lambda v: P(*[axis if d == dim else None
                      for d in range(v.ndim)]) if v.ndim > dim else P(),
        tree)


# ---------------------------------------------------------------------------
# Full-model shard_map wrappers
# ---------------------------------------------------------------------------

def sharded_forward_fns(params, cfg: ModelConfig, mesh, *,
                        axis: str = "model", data_axis: Optional[str] = None):
    """(prefill_fn, decode_fn) running the model under shard_map.

    Both take the SAME arguments as `models.prefill` / `decode_step`
    minus cfg; params must be placed per `shard_param_specs` (jit will
    reshard automatically if they are not).  Activations, caches and
    logits are replicated over the tensor axis; when `data_axis` is
    given the decode batch dim shards over it (the caller guarantees
    divisibility — serving buckets are powers of two).
    """
    from repro.models import model as M

    specs = shard_param_specs(params, cfg, axis=axis,
                              tp=tp_size(mesh, axis))
    cfg_sh = dataclasses.replace(cfg, quant=tp_quant(cfg.quant, axis))

    def prefill_body(p, tokens, caches, patches):
        return M.prefill(p, tokens, caches, cfg_sh, patches=patches)

    def chunk_body(p, tokens, caches, patches):
        return M.prefill(p, tokens, caches, cfg_sh, patches=patches,
                         chunked=True)

    def decode_body(p, token, caches, cross_kv):
        return M.decode_step(p, token, caches, cfg_sh, cross_kv=cross_kv)

    def wrap(body, example_caches=None, batch_sharded=False):
        if batch_sharded and data_axis is not None:
            cache_spec = batch_dim_specs(example_caches, data_axis, 1)
            arg_spec = P(data_axis)
            out0 = P(data_axis)
        else:
            cache_spec = jax.tree_util.tree_map(
                lambda _: P(), example_caches) if example_caches is not None \
                else P()
            arg_spec = P()
            out0 = P()
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, arg_spec, cache_spec, P()),
            out_specs=(out0, cache_spec), check_rep=False)

    def prefill_fn(p, tokens, caches, patches=None, chunked=False):
        body = chunk_body if chunked else prefill_body
        return wrap(body, caches)(p, tokens, caches, patches)

    def decode_fn(p, token, caches, cross_kv=None, batch_sharded=False):
        return wrap(decode_body, caches, batch_sharded=batch_sharded)(
            p, token, caches, cross_kv)

    return prefill_fn, decode_fn


def place_params(params, cfg: ModelConfig, mesh, *, axis: str = "model"):
    """device_put the param tree onto the mesh per `shard_param_specs`."""
    from jax.sharding import NamedSharding

    specs = shard_param_specs(params, cfg, axis=axis,
                              tp=tp_size(mesh, axis))
    return jax.tree_util.tree_map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        params, specs)
