"""gemma3-27b [dense]: 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  Every 6th layer is global
full attention; local layers use a 1024-token sliding window.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144,
    local_global_period=6, local_window=1024,
    rope_theta=1e6, remat="full",
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    local_global_period=3, local_window=8, dtype="float32",
)
