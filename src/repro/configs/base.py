"""Model configuration schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How the VP technique is applied to the model's matmuls.

    mode:
      none      - bf16/f32 baseline (the paper's "FLP" analogue)
      fxp       - plain int8 fixed-point weights (the FXP baseline)
      vp        - paper-faithful per-element VP weights (int8 significand +
                  2-bit index planes; dequant-on-load)
      vp_block  - beyond-paper block-VP (shared index per weight block ->
                  int8 MXU matmuls + LUT scales)
    """
    mode: str = "none"
    M: int = 7
    E: int = 2
    W: int = 12                    # FXP proxy grid width
    block: int = 256               # vp_block index granularity
    quantize_kv_cache: bool = False  # VP-quantized KV cache (decode lever)
    kv_layout: str = "packed"      # VP KV-cache storage: "packed" words
                                   # (kernel-consumed) | "planes" (legacy
                                   # two-plane jnp-dequant golden baseline)
    act_mode: str = "none"         # activation quantization (none | vp)
    qat_mode: str = "fake"         # QAT weight path when training float
                                   # masters under mode="vp":
                                   # "fake" = legacy fake-quant STE in the
                                   # float graph; "packed" = quantize to
                                   # packed words + run the packed Pallas
                                   # serving kernel fwd AND the packed-word
                                   # backward kernels (kernels.ops
                                   # .vp_qat_matmul) — training numerics
                                   # == serving numerics
    tp_axis: Optional[str] = None  # set ONLY inside a shard_map'd forward:
                                   # weight matmuls see tensor-parallel
                                   # last-dim shards and all-gather their
                                   # output along this mesh axis (see
                                   # parallel.shard_ops.shard_param_specs
                                   # for the matching placement rule)

    def __post_init__(self):
        assert self.mode in ("none", "fxp", "vp", "vp_block"), self.mode
        assert self.kv_layout in ("packed", "planes"), self.kv_layout
        assert self.qat_mode in ("fake", "packed"), self.qat_mode


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    # Attention pattern
    sliding_window: Optional[int] = None      # SWA (mixtral)
    local_global_period: int = 0              # gemma3: every Nth layer global
    local_window: int = 1024                  # local-attention window
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    # RWKV6
    rwkv: bool = False
    # Hybrid (zamba2): one SHARED attention block applied every N ssm layers
    shared_attn_period: int = 0
    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    # VLM
    n_patches: int = 0
    # Numerics / training
    dtype: str = "bfloat16"
    quant: QuantConfig = QuantConfig()
    remat: str = "none"            # none | full | dots  (act checkpointing)
    loss_chunk: int = 1024         # chunked cross-entropy seq block
    # Distribution hints (set by the launcher, not the arch files):
    seq_shard: bool = False        # Megatron-style sequence-parallel
                                   # residual stream over "model"
    mesh_batch_axes: Tuple[str, ...] = ("data",)
    mesh_axis_sizes: Tuple[Tuple[str, int], ...] = ()  # set by launcher

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * hd * nh + 2 * d * hd * nkv + hd * nh * d
        mlp = 3 * d * dff
        if self.n_experts:
            mlp = self.n_experts * 3 * d * dff + d * self.n_experts
        ssm = 0
        if self.family in ("ssm", "hybrid") and not self.rwkv:
            di, ns, nh_s = self.d_inner, self.ssm_state, self.ssm_nheads
            ssm = d * (2 * di + 2 * ns + nh_s) + di * d + di  # in/out proj etc
        if self.rwkv:
            ssm = 6 * d * d + 2 * d * dff + d * dff  # R,K,V,G,O,decay + FFN
        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += ssm + (2 * d * dff + d * dff if self.rwkv else 0)
            if self.rwkv:
                per_layer = 2 * d + ssm
        elif self.family == "hybrid":
            per_layer += ssm
        else:
            per_layer += attn + mlp
        total = self.n_layers * per_layer + 2 * v * d + d
        if self.family == "hybrid" and self.shared_attn_period:
            total += attn + 3 * d * dff  # the shared block (counted once)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + 2 * d)  # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        dense_mlp = self.n_experts * 3 * d * dff
        active_mlp = self.experts_per_token * 3 * d * dff
        return int(self.param_count() - self.n_layers * (dense_mlp - active_mlp))
