"""internvl2-1b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2/qwen2-style LM backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
[arXiv:2404.16821; hf].  256 visual patches per image.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, qkv_bias=True,
    n_patches=256, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, qkv_bias=True, n_patches=8, dtype="float32",
)
