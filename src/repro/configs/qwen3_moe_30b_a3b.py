"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8.

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, qk_norm=True,
    n_experts=128, experts_per_token=8,
    rope_theta=1e6, remat="full",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=48, vocab=256, qk_norm=True,
    n_experts=8, experts_per_token=2, dtype="float32",
)
