"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified].  Shared attention block applied every 6
Mamba2 layers (Zamba2's weight-shared transformer block).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    shared_attn_period=6,
    remat="full",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=32, ssm_chunk=8,
    shared_attn_period=2, dtype="float32",
)
