"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", rwkv=True,
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536,
    remat="full",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", rwkv=True,
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=192, vocab=256, dtype="float32",
)
