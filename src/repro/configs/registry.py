"""Architecture registry: exact assigned configs + reduced smoke variants.

Every entry is from the assignment table (public literature; see inline
source tags).  `get_config(name)` returns the FULL config (dry-run only —
never allocated on CPU); `get_smoke_config(name)` returns a reduced
same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .base import ModelConfig, QuantConfig
from . import (
    zamba2_7b, rwkv6_3b, whisper_tiny, qwen2_0_5b, qwen3_0_6b,
    stablelm_12b, gemma3_27b, internvl2_1b, qwen3_moe_30b_a3b, mixtral_8x22b,
)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "rwkv6-3b": rwkv6_3b,
    "whisper-tiny": whisper_tiny,
    "qwen2-0.5b": qwen2_0_5b,
    "qwen3-0.6b": qwen3_0_6b,
    "stablelm-12b": stablelm_12b,
    "gemma3-27b": gemma3_27b,
    "internvl2-1b": internvl2_1b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "mixtral-8x22b": mixtral_8x22b,
}

ARCH_NAMES = tuple(_MODULES)

# (arch, shape) cells where long_500k applies (sub-quadratic decode):
LONG_CONTEXT_ARCHS = ("zamba2-7b", "rwkv6-3b", "mixtral-8x22b")

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(name: str, quant: Optional[QuantConfig] = None) -> ModelConfig:
    cfg = _MODULES[name].CONFIG
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=quant)
    return cfg


def get_smoke_config(name: str, quant: Optional[QuantConfig] = None
                     ) -> ModelConfig:
    cfg = _MODULES[name].SMOKE
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=quant)
    return cfg


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  long_500k only for sub-quadratic
    archs (full-attention skips documented in DESIGN.md)."""
    out = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                if include_skipped:
                    out.append((arch, shape, "SKIP: full attention at 500k "
                                "is not sub-quadratic"))
                continue
            out.append((arch, shape) if not include_skipped
                       else (arch, shape, "run"))
    return out
