from .base import ModelConfig, QuantConfig
