"""whisper-tiny [audio]: enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356;
unverified].  Encoder source length 1500 frames.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    encoder_layers=4, encoder_seq=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    encoder_layers=2, encoder_seq=16, dtype="float32",
)
