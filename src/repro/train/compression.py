"""Error-feedback gradient compression (DP-axis bandwidth saver).

Each gradient leaf is quantized with a per-leaf scale BEFORE the
data-parallel all-reduce; the quantization residual is carried in the
compressor state and added back next step (error feedback), which keeps
SGD convergence (the compressor is a contraction).

Two codecs (`CompressionConfig.codec`):

  * ``int8`` — the original linear quantizer: scale = amax/127, one int8
    per element.  Uniform resolution across the leaf.
  * ``vp`` — the paper's format applied to gradients, the high-dynamic-
    range case it exists for: each leaf is packed into ACTUAL VP words
    (`core.quantize.vp_pack_tensor` -> `core.packing` layout,
    `storage_bits` bits/element) with a per-leaf pow2 scale.  Small
    gradient entries keep `M` significant bits instead of vanishing under
    one global step size; what crosses the DP wire is the packed word
    plane + one f32 scale (`parallel.shard_ops.dp_compress_reduce`).

Both codecs carry f32 error feedback, so the compressor state layout is
codec-independent (and checkpoints interchangeably).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FXPFormat, VPFormat, default_vp_format
from repro.core.quantize import vp_pack_tensor, vp_unpack_tensor


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Gradient codec selection.  M/E/W only apply to codec="vp"."""
    codec: str = "int8"
    M: int = 7                     # VP significand bits (incl. sign)
    E: int = 2                     # VP exponent-index bits
    W: int = 12                    # FXP proxy grid width

    def __post_init__(self):
        if self.codec not in ("int8", "vp"):
            raise ValueError(
                f"unknown gradient codec {self.codec!r}; "
                f"pick 'int8' or 'vp'")

    def formats(self) -> Tuple[FXPFormat, VPFormat]:
        """The (FXP, VP) pair the vp codec quantizes through — same
        construction as `models.layers.canonical_formats`."""
        fxp = FXPFormat(self.W, self.W - 1)
        return fxp, default_vp_format(fxp, self.M, self.E)


def init_compressor_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf_int8(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def _compress_leaf_vp(g, err, fxp: FXPFormat, vp: VPFormat):
    g = g.astype(jnp.float32) + err
    words, scale = vp_pack_tensor(g, fxp, vp)
    deq = vp_unpack_tensor(words, scale, vp, jnp.float32)
    return deq, g - deq


def _check_structs(grads, state):
    """Fail loudly on mismatched trees — a silent zip-truncate here pairs
    gradients with the WRONG error leaves and corrupts feedback forever."""
    gdef = jax.tree_util.tree_structure(grads)
    sdef = jax.tree_util.tree_structure(state)
    if gdef == sdef:
        return
    gpaths = [jax.tree_util.keystr(p) for p, _ in
              jax.tree_util.tree_flatten_with_path(grads)[0]]
    spaths = [jax.tree_util.keystr(p) for p, _ in
              jax.tree_util.tree_flatten_with_path(state)[0]]
    only_g = [p for p in gpaths if p not in set(spaths)]
    only_s = [p for p in spaths if p not in set(gpaths)]
    raise ValueError(
        "compress_decompress: gradient tree and compressor state differ "
        f"in structure. Leaves only in grads: {only_g or 'none'}; leaves "
        f"only in state: {only_s or 'none'}. Rebuild the state with "
        "init_compressor_state(params) after any parameter-tree change.")


def compress_decompress(grads, state,
                        config: CompressionConfig = CompressionConfig(),
                        ) -> Tuple[Any, Any]:
    """Quantize-dequantize every leaf with error feedback.

    Under pjit the compressed representation (int8, or packed VP words +
    scale) is what crosses the DP axis (XLA reduces the dequantized
    values; on real fleets this pairs with reduce-scatter of the words —
    `parallel.shard_ops.dp_compress_reduce` models exactly that)."""
    if state is None:
        state = init_compressor_state(grads)
    _check_structs(grads, state)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(state)
    if config.codec == "vp":
        fxp, vp = config.formats()
        outs = [_compress_leaf_vp(g, e, fxp, vp)
                for g, e in zip(flat_g, flat_e)]
    else:
        outs = [_compress_leaf_int8(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
