"""Error-feedback int8 gradient compression (DP-axis bandwidth saver).

Each gradient leaf is quantized to int8 with a per-leaf scale BEFORE the
data-parallel all-reduce; the quantization residual is carried in the
compressor state and added back next step (error feedback), which keeps
SGD convergence (the compressor is a contraction).  Interestingly this is
the VP idea applied to gradients: high-dynamic-range values, short
significand, scale recovered from side information.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_compressor_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def compress_decompress(grads, state) -> Tuple[Any, Any]:
    """Quantize-dequantize every leaf with error feedback.

    Under pjit the int8 representation is what crosses the DP axis (XLA
    reduces the dequantized values; on real fleets this pairs with
    reduce-scatter in int8 — here we model the numerics exactly)."""
    if state is None:
        state = init_compressor_state(grads)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(state)
    outs = [_compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
