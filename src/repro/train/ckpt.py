"""Fault-tolerant checkpointing: atomic, async, resumable.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, plus <dir>/LATEST
(written via temp-file + os.replace, so a crash mid-write can never
corrupt an existing checkpoint).  Arrays are saved host-side (fully
addressable); restore reshards onto the current mesh — which is how
ELASTIC restarts work: a checkpoint taken on 512 devices restores onto
any mesh whose axes divide the array shapes.

Async mode hands the device->host copy + serialization to a background
thread; `wait()` joins before the next save (single outstanding save).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint on disk fails its manifest checksums (bit rot,
    torn write on a non-atomic filesystem, operator error)."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_with_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten_with_paths(v, f"{prefix}/{i}"))
    elif tree is None:
        pass
    else:
        out[prefix] = tree
    return out


def _unflatten_like(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten_like(v, flat, f"{prefix}/{i}")
               for i, v in enumerate(template)]
        if isinstance(template, tuple):
            # NamedTuples (OptState(step, mu, nu), ...) construct from
            # POSITIONAL fields — type(template)(seq) handed the whole
            # list to the first field and raised TypeError on the rest.
            if hasattr(template, "_fields"):
                return type(template)(*seq)
            return type(template)(seq)
        return seq
    if template is None:
        return None
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._sweep_stale_tmp()

    def _sweep_stale_tmp(self):
        """Remove temp artifacts orphaned by a crashed/killed save.

        A save that dies between mkdir and os.replace leaves
        `.tmp_step_<N>_<pid>` (and possibly `.LATEST.tmp`) behind forever
        — nothing else ever touches them, and on restart-heavy fleets
        they accumulate one dead weight-sized directory per crash.  A new
        manager owns the directory (restarts reuse the path, the dead
        writer's pid is gone), so anything matching the temp pattern at
        construction time is garbage by definition.  Completed
        checkpoints (`step_<N>` with manifest) are never touched.
        """
        for name in os.listdir(self.dir):
            path = os.path.join(self.dir, name)
            if name.startswith(".tmp_step_"):
                shutil.rmtree(path, ignore_errors=True)
            elif name == ".LATEST.tmp":
                # the pointer temp is a FILE, not a directory
                try:
                    os.remove(path)
                except OSError:
                    pass

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None):
        """state: pytree of arrays. extra: JSON-serializable metadata
        (data-pipeline position, RNG, mesh shape...)."""
        flat = _flatten_with_paths(state)
        # device->host copy happens here (synchronously cheap on CPU,
        # overlapped DMA on TPU); serialization goes to the worker thread.
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "time": time.time(),
                "extra": extra or {},
                "n_arrays": len(host),
                "bytes": int(sum(a.nbytes for a in host.values())),
                # per-file integrity: restore verifies these before
                # trusting the arrays (manifest.json itself is implicitly
                # covered — a torn manifest fails json.load)
                "files": {"arrays.npz":
                          _sha256(os.path.join(tmp, "arrays.npz"))},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            # atomic LATEST pointer
            ptr_tmp = os.path.join(self.dir, ".LATEST.tmp")
            with open(ptr_tmp, "w") as f:
                f.write(str(step))
            os.replace(ptr_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                # only completed checkpoints (manifest present)
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                s = int(f.read().strip())
            if s in self.all_steps():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> None:
        """Check a checkpoint's files against its manifest checksums.

        Raises `CheckpointCorruptError` on any mismatch or missing file.
        Pre-checksum manifests (no "files" key) verify trivially —
        restores of old checkpoints keep working, they just get no
        integrity guarantee.
        """
        path = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"step {step}: unreadable manifest ({e})") from e
        for name, want in manifest.get("files", {}).items():
            fpath = os.path.join(path, name)
            if not os.path.exists(fpath):
                raise CheckpointCorruptError(
                    f"step {step}: missing file {name}")
            got = _sha256(fpath)
            if got != want:
                raise CheckpointCorruptError(
                    f"step {step}: checksum mismatch on {name} "
                    f"(manifest {want[:12]}…, disk {got[:12]}…)")

    def restore(self, step: int, template, shardings=None,
                verify: bool = True):
        """Restore into the structure of `template`, placing shards onto
        the current mesh via `shardings` (elastic re-mesh restore).
        `verify` checks manifest checksums first and raises
        `CheckpointCorruptError` instead of loading corrupt arrays."""
        self.wait()
        if verify:
            self.verify(step)
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest

    def restore_latest(self, template, shardings=None):
        """Restore the newest INTACT checkpoint, walking past corrupt
        ones (newest -> oldest).  Returns (tree, manifest, step), or
        None if no intact checkpoint exists.  This is the resume path:
        one rotted checkpoint costs `ckpt_every` steps of recompute, not
        the whole run."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                tree, manifest = self.restore(step, template, shardings,
                                              verify=True)
                return tree, manifest, step
            except CheckpointCorruptError:
                continue
        return None
