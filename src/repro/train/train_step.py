"""Training / serving step builders (the functions the launcher jits).

`make_train_step` supports gradient (micro)accumulation: the global batch
is split into microbatches scanned sequentially; the parameter update
happens once per step.  With DP-sharded microbatches XLA overlaps the
gradient all-reduce of microbatch i with the compute of i+1 (the standard
latency-hiding pattern).  Optional error-feedback int8 gradient
compression plugs into the DP reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import loss_fn, decode_step, prefill
from repro.optim.optimizer import OptConfig, OptState, apply_updates
from .compression import CompressionConfig, compress_decompress


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1,
                    compress_grads: bool = False,
                    qat: Optional[QuantConfig] = None):
    """Returns train_step(params, opt_state, batch[, cmp_state]) ->
    (params, opt_state, metrics[, cmp_state]).

    `qat` threads a QuantConfig into the loss so float master weights are
    fine-tuned INTO a VP format: every qdot sees `train=True` with that
    quant config — `qat_mode="fake"` runs the legacy fake-quant STE in
    the float graph, `qat_mode="packed"` quantizes to packed words and
    runs the packed Pallas serving kernel with the packed-word custom-VJP
    backward (`kernels.ops.vp_qat_matmul`), so the fine-tune optimizes
    exactly the numerics serving will execute.
    """
    if qat is not None:
        cfg = dataclasses.replace(cfg, quant=qat)
    # `compress_grads` accepts a bare bool (legacy int8 codec) or a
    # CompressionConfig picking the codec ("vp" = packed-word gradients).
    cmp_cfg = (compress_grads
               if isinstance(compress_grads, CompressionConfig)
               else CompressionConfig())

    def grad_one(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb, cfg, True)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch,
                   cmp_state=None):
        if microbatches == 1:
            loss, metrics, grads = grad_one(params, batch)
        else:
            # Shapes are static under trace, so this fails at jit/trace
            # time with the actual numbers instead of an opaque reshape
            # error from `split` mid-scan.
            for path, leaf in jax.tree_util.tree_flatten_with_path(batch)[0]:
                if leaf.shape[0] % microbatches:
                    raise ValueError(
                        f"batch leaf {jax.tree_util.keystr(path)} has "
                        f"leading (global batch) dim {leaf.shape[0]}, not "
                        f"divisible by microbatches={microbatches}; pick a "
                        f"microbatch count that divides the batch")

            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grad_one(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, (loss, metrics)

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, stacked) = jax.lax.scan(body, zero, mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = losses.mean()
            # Per-microbatch aux metrics (load_balance, router_z, ...)
            # used to be discarded here; average them over the scan axis
            # so the metric dict matches the microbatches=1 path.
            metrics = jax.tree_util.tree_map(
                lambda m: m.mean(axis=0), stacked)
        if compress_grads:
            grads, cmp_state = compress_decompress(grads, cmp_state, cmp_cfg)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        if compress_grads:
            return params, opt_state, metrics, cmp_state
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """One batched decode step: (params, token, caches) -> (logits, caches).

    encdec models additionally take precomputed cross K/V."""

    if cfg.family == "encdec":
        def serve_step(params, token, caches, cross_kv):
            return decode_step(params, token, caches, cfg, cross_kv=cross_kv)
        return serve_step

    def serve_step(params, token, caches):
        return decode_step(params, token, caches, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, caches, extra=None):
        return prefill(params, tokens, caches, cfg, patches=extra)
    return prefill_step
