"""Training / serving step builders (the functions the launcher jits).

`make_train_step` supports gradient (micro)accumulation: the global batch
is split into microbatches scanned sequentially; the parameter update
happens once per step.  With DP-sharded microbatches XLA overlaps the
gradient all-reduce of microbatch i with the compute of i+1 (the standard
latency-hiding pattern).  Optional error-feedback int8 gradient
compression plugs into the DP reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn, decode_step, prefill
from repro.optim.optimizer import OptConfig, OptState, apply_updates
from .compression import compress_decompress


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch[, cmp_state]) ->
    (params, opt_state, metrics[, cmp_state])."""

    def grad_one(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb, cfg, True)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch,
                   cmp_state=None):
        if microbatches == 1:
            loss, metrics, grads = grad_one(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grad_one(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, loss

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, zero, mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = {"ce": loss}
        if compress_grads:
            grads, cmp_state = compress_decompress(grads, cmp_state)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        if compress_grads:
            return params, opt_state, metrics, cmp_state
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """One batched decode step: (params, token, caches) -> (logits, caches).

    encdec models additionally take precomputed cross K/V."""

    if cfg.family == "encdec":
        def serve_step(params, token, caches, cross_kv):
            return decode_step(params, token, caches, cfg, cross_kv=cross_kv)
        return serve_step

    def serve_step(params, token, caches):
        return decode_step(params, token, caches, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, caches, extra=None):
        return prefill(params, tokens, caches, cfg, patches=extra)
    return prefill_step
