"""Fault-tolerance driver: heartbeats, straggler mitigation, elastic re-mesh.

On a real fleet each host runs this controller around the training loop;
here the same logic is driven by a simulated host set (tests inject
failures/stragglers).  The mechanisms:

  * HEARTBEATS: every host stamps a monotonic heartbeat each step; the
    controller declares a host dead after `dead_after` missed beats.
  * STRAGGLER MITIGATION: per-step durations are tracked with an EMA; a
    host consistently slower than `straggler_factor` x median is marked a
    straggler and excluded at the next elastic boundary (on TPU pods the
    usual cause is a flaky HBM/ICI link).
  * ELASTIC RE-MESH: when the healthy-host set changes, pick the largest
    (pods, data, model) mesh that (a) fits the survivors, (b) keeps the
    model axis intact (TP must not shrink below what the weights need),
    and restart from the latest checkpoint — `CheckpointManager.restore`
    reshards host-side arrays onto the new mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    step_ema: Optional[float] = None
    missed: int = 0
    alive: bool = True
    straggler: bool = False


@dataclasses.dataclass(frozen=True)
class FTConfig:
    dead_after: int = 3           # missed heartbeats before eviction
    straggler_factor: float = 2.0
    ema: float = 0.8
    min_hosts: int = 1


class FaultToleranceController:
    def __init__(self, n_hosts: int, cfg: FTConfig = FTConfig()):
        self.cfg = cfg
        self.hosts: Dict[int, HostState] = {
            i: HostState(i) for i in range(n_hosts)}
        self.generation = 0           # bumps on every elastic transition

    # ---- signals ------------------------------------------------------
    def heartbeat(self, host_id: int, step_duration: float,
                  now: Optional[float] = None):
        h = self.hosts[host_id]
        h.last_beat = time.monotonic() if now is None else now
        h.missed = 0
        if h.step_ema is None:
            h.step_ema = step_duration
        else:
            h.step_ema = (self.cfg.ema * h.step_ema
                          + (1 - self.cfg.ema) * step_duration)

    def tick(self):
        """One controller round: age heartbeats, classify hosts."""
        alive = [h for h in self.hosts.values() if h.alive]
        for h in alive:
            h.missed += 1
            if h.missed > self.cfg.dead_after:
                h.alive = False
        # straggler detection against the median EMA of live hosts
        emas = sorted(h.step_ema for h in alive
                      if h.alive and h.step_ema is not None)
        if emas:
            median = emas[len(emas) // 2]
            for h in alive:
                if h.alive and h.step_ema is not None:
                    h.straggler = h.step_ema > self.cfg.straggler_factor * median
        return self.healthy()

    def healthy(self) -> List[int]:
        return [i for i, h in self.hosts.items()
                if h.alive and not h.straggler]

    def topology_changed(self, previous: List[int]) -> bool:
        return set(previous) != set(self.healthy())

    # ---- elastic re-mesh ---------------------------------------------
    def propose_mesh(self, chips_per_host: int, model_axis: int,
                     multi_pod_hosts: Optional[int] = None
                     ) -> Tuple[int, int, int]:
        """Largest (pods, data, model) using the healthy hosts.

        Keeps `model_axis` fixed (weight shards must fit); data axis is the
        largest value such that pods*data*model <= healthy chips, power-of-
        two-friendly by truncation to the largest divisor.
        """
        n = len(self.healthy()) * chips_per_host
        if n < model_axis:
            raise RuntimeError(
                f"elastic: only {n} chips healthy, need >= {model_axis}")
        usable = n // model_axis           # data-parallel replicas
        if multi_pod_hosts:
            pods = max(1, usable // multi_pod_hosts)
        else:
            pods = 1
        data = usable // pods
        # largest power of two <= data (keeps collectives balanced)
        data = 1 << (data.bit_length() - 1)
        self.generation += 1
        return (pods, data, model_axis)


def run_with_restarts(train_loop, max_restarts: int = 3):
    """Crash-containment wrapper: rerun `train_loop` (which resumes from
    the latest checkpoint) until it completes or exhausts restarts."""
    for attempt in range(max_restarts + 1):
        try:
            return train_loop(attempt)
        except RuntimeError as e:            # simulated node failure
            if attempt == max_restarts:
                raise
    return None
