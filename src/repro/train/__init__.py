from .train_step import make_train_step, make_serve_step, make_prefill_step
from .ckpt import CheckpointCorruptError, CheckpointManager
from .ft import FaultToleranceController, FTConfig, run_with_restarts
from .compression import compress_decompress, init_compressor_state
