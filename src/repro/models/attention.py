"""Attention: GQA with RoPE/qk-norm, flash-style chunked softmax, KV cache.

Training/prefill use a "pair-scan flash" implementation: the (q-chunk,
k-chunk) pairs that can contribute under the mask (causal triangle, local
band, or full rectangle) are enumerated STATICALLY, and a single lax.scan
walks the pair list carrying running (max, denom, acc).  This gives
  * bounded peak memory (one q-chunk x k-chunk score block at a time),
  * exact mask-aware FLOPs (no wasted upper-triangle compute),
  * one compiled body regardless of sequence length.

Decode attends a single query against the (optionally VP-quantized) cache.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import FXPFormat, VPFormat, default_vp_format
from repro.kernels import ref as kref
from .layers import qdot, rms_norm, rope

NEG_INF = -1e30


def _pick_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of s that is <= target."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _chunk_pairs(n_q: int, n_k: int, pattern: str, window_chunks: int):
    """Static list of contributing (qi, ki) chunk pairs."""
    pairs = []
    for qi in range(n_q):
        for ki in range(n_k):
            if pattern == "causal" and ki > qi:
                continue
            if pattern == "local" and (ki > qi or qi - ki > window_chunks):
                continue
            pairs.append((qi, ki))
    return pairs


def flash_attention(
    q, k, v,
    pattern: str = "causal",
    window: Optional[int] = None,
    chunk: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """q (B, Sq, H, dh), k/v (B, Sk, KV, dh) -> (B, Sq, H, dh).

    GQA: H must be a multiple of KV; k/v heads are repeated logically via
    reshape (no materialized repeat).
    pattern: causal | local (banded causal) | full (encoder/cross).
    Causal/local require Sq == Sk.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else dh ** -0.5
    c = _pick_chunk(Sq, chunk)
    ck = _pick_chunk(Sk, chunk)
    if pattern in ("causal", "local"):
        assert Sq == Sk
        ck = c
    nq, nk = Sq // c, Sk // ck
    wc = max(1, (window or Sq) // c) if pattern == "local" else nk
    pairs = _chunk_pairs(nq, nk, pattern, wc)
    pair_arr = jnp.asarray(pairs, jnp.int32)  # (P, 2)

    # Layout: (B, KV, G, nq, c, dh) for q; (B, KV, nk, ck, dh) for k/v.
    qr = q.reshape(B, Sq, KV, G, dh).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, KV, G, nq, c, dh) * scale
    kr = k.transpose(0, 2, 1, 3).reshape(B, KV, nk, ck, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B, KV, nk, ck, dh)

    q_off = jnp.arange(c, dtype=jnp.int32)
    k_off = jnp.arange(ck, dtype=jnp.int32)

    def step(carry, pair):
        m, l, acc = carry                        # running stats per q pos
        qi, ki = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qr, qi, axis=3, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, ki, axis=2, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, ki, axis=2, keepdims=False)
        # scores (B, KV, G, c, ck) — operands stay bf16 (halves the
        # SP-gather bytes), accumulation in f32 (MXU-native)
        s = jnp.einsum(
            "bkgqd,bkcd->bkgqc", qb, kb,
            preferred_element_type=jnp.float32)
        if pattern in ("causal", "local"):
            q_pos = qi * c + q_off[:, None]
            k_pos = ki * ck + k_off[None, :]
            mask = k_pos <= q_pos
            if pattern == "local" and window:
                mask &= q_pos - k_pos < window
            s = jnp.where(mask, s, NEG_INF)
        # online softmax update for q chunk qi
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 3, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 3, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 3, keepdims=False)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_old, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.sum(p, axis=-1)
        a_new = a_old * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 3)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 3)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 3)
        return (m, l, acc), None

    init = (
        jnp.full((B, KV, G, nq, c), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G, nq, c), jnp.float32),
        jnp.zeros((B, KV, G, nq, c, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, pair_arr)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, KV, G, Sq, dh).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (optionally VP-quantized) + decode attention
# ---------------------------------------------------------------------------

def kv_cache_formats(q: QuantConfig):
    fxp = FXPFormat(q.W, q.W - 1)
    vp = default_vp_format(fxp, q.M, q.E)
    return fxp, vp


def quantize_kv(x, q: QuantConfig):
    """bf16 KV block -> (int8 significand, PACKED uint8 index) planes +
    pow2 scale: 8 + E bits/element of cache traffic instead of 16.

    The E-bit exponent indices pack 8//E per byte along the head dim;
    per-position pow2 scale keeps VP exactness."""
    from repro.core.vp_tensor import pack_indices

    fxp, vp = kv_cache_formats(q)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1),
                   keepdims=True)
    s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))))
    m, i = kref.vp_quant_ref(x.astype(jnp.float32) / s, fxp, vp)
    if vp.E and x.shape[-1] % (8 // vp.E) == 0:
        i = pack_indices(i, vp.E)
    return m, i, s.astype(jnp.float32)


def dequantize_kv(m, i, s, q: QuantConfig, dtype):
    from repro.core.vp_tensor import unpack_indices

    _, vp = kv_cache_formats(q)
    if i.shape[-1] != m.shape[-1]:
        i = unpack_indices(i, vp.E, m.shape[-1])
    return (kref.vp_dequant_ref(m, i, vp, jnp.float32) * s).astype(dtype)


def decode_attention(
    q, k_cache, v_cache, cache_len,
    window: Optional[int] = None,
    rolling: bool = False,
) -> jax.Array:
    """Single-token decode: q (B, 1, H, dh), caches (B, Smax, KV, dh).

    Masks positions >= cache_len (and outside the sliding window if given).
    `rolling`: the buffer IS the window (SWA ring buffer) — every slot
    written so far is valid, no window masking by absolute position.
    """
    B, _, H, dh = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    kr = k_cache.transpose(0, 2, 1, 3).astype(jnp.float32)
    vr = v_cache.transpose(0, 2, 1, 3).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qr, kr)
    pos = jnp.arange(Smax)[None, :]
    if rolling:
        valid = pos < jnp.minimum(cache_len, Smax)[:, None]
    else:
        valid = pos < cache_len[:, None]
        if window:
            valid &= pos >= (cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, vr)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + norms + rope + flash/decode)
# ---------------------------------------------------------------------------

def attn_block(
    x, params, cfg: ModelConfig,
    positions,
    pattern: str,
    window: Optional[int],
    cache: Optional[dict] = None,
    train: bool = False,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Self/cross attention block.

    cache: {"k": (B, Smax, KV, dh)[ or VP planes], "v": ..., "len": (B,)}
    -> returns (out, new_cache).  kv_override supplies precomputed
    encoder K/V for cross-attention.
    """
    q_cfg = cfg.quant
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    qp = qdot(x, params["wq"], q_cfg, train)
    if params.get("bq") is not None:
        qp = qp + params["bq"].astype(qp.dtype)
    qp = qp.reshape(*x.shape[:-1], H, dh)

    if kv_override is None:
        kp = qdot(x, params["wk"], q_cfg, train)
        vp_ = qdot(x, params["wv"], q_cfg, train)
        if params.get("bk") is not None:
            kp = kp + params["bk"].astype(kp.dtype)
            vp_ = vp_ + params["bv"].astype(vp_.dtype)
        kp = kp.reshape(*x.shape[:-1], KV, dh)
        vp_ = vp_.reshape(*x.shape[:-1], KV, dh)
    else:
        kp, vp_ = kv_override

    if cfg.qk_norm:
        qp = rms_norm(qp, params["q_norm"])
        kp = rms_norm(kp, params["k_norm"]) if kv_override is None else kp

    if positions is not None and kv_override is None:
        qp = rope(qp, positions, cfg.rope_theta)
        kp = rope(kp, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_override is None and x.shape[1] > 1:
        # PREFILL: full causal pass over the prompt, then write all S
        # positions into the cache in one shot.
        S = x.shape[1]
        smax = (cache["k"] if "k" in cache else cache["k_m"]).shape[1]
        out = flash_attention(qp, kp, vp_, pattern=pattern, window=window)
        kw, vw = kp, vp_
        if S > smax:  # ring buffer shorter than prompt: keep the tail,
            # arranged so slot j holds position p with p % smax == j (the
            # decode writer uses len % smax).
            kw = jnp.roll(kp[:, -smax:], S % smax, axis=1)
            vw = jnp.roll(vp_[:, -smax:], S % smax, axis=1)
        pad = smax - kw.shape[1]
        if pad:
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if "k_m" in cache:
            m_k, i_k, s_k = quantize_kv(kw, q_cfg)
            m_v, i_v, s_v = quantize_kv(vw, q_cfg)
            new_cache = dict(
                k_m=m_k, k_i=i_k, k_s=s_k, v_m=m_v, v_i=i_v, v_s=s_v,
                len=cache["len"] + S)
        else:
            new_cache = dict(k=kw.astype(cache["k"].dtype),
                             v=vw.astype(cache["v"].dtype),
                             len=cache["len"] + S)
        out = out.reshape(*x.shape[:-1], H * dh)
        return qdot(out, params["wo"], q_cfg, train), new_cache
    if cache is not None and kv_override is None:
        # Decode: append this step's K/V.  A buffer no longer than the
        # sliding window acts as a ring buffer (long-context SWA decode).
        smax = (cache["k"] if "k" in cache else cache["k_m"]).shape[1]
        rolling = window is not None and smax <= window
        idx = cache["len"]  # (B,)
        widx = idx % smax if rolling else idx
        upd = lambda buf, val: jax.vmap(
            lambda b, v, j: jax.lax.dynamic_update_slice_in_dim(
                b, v, j, axis=0))(buf, val, widx)
        if "k_m" in cache:  # VP-quantized cache
            m_k, i_k, s_k = quantize_kv(kp, q_cfg)
            m_v, i_v, s_v = quantize_kv(vp_, q_cfg)
            new_cache = dict(
                k_m=upd(cache["k_m"], m_k), k_i=upd(cache["k_i"], i_k),
                k_s=upd(cache["k_s"], s_k),
                v_m=upd(cache["v_m"], m_v), v_i=upd(cache["v_i"], i_v),
                v_s=upd(cache["v_s"], s_v),
                len=idx + kp.shape[1],
            )
            k_full = dequantize_kv(
                new_cache["k_m"], new_cache["k_i"], new_cache["k_s"],
                q_cfg, kp.dtype)
            v_full = dequantize_kv(
                new_cache["v_m"], new_cache["v_i"], new_cache["v_s"],
                q_cfg, vp_.dtype)
        else:
            new_cache = dict(
                k=upd(cache["k"], kp), v=upd(cache["v"], vp_),
                len=idx + kp.shape[1],
            )
            k_full, v_full = new_cache["k"], new_cache["v"]
        out = decode_attention(
            qp, k_full, v_full, new_cache["len"], window, rolling=rolling)
    elif kv_override is not None:
        if qp.shape[1] == 1:
            # Cross-attention during decode: full-length source.
            src_len = jnp.full((B,), kp.shape[1], jnp.int32)
            out = decode_attention(qp, kp, vp_, src_len)
        else:
            out = flash_attention(qp, kp, vp_, pattern="full")
    else:
        out = flash_attention(qp, kp, vp_, pattern=pattern, window=window)

    out = out.reshape(*x.shape[:-1], H * dh)
    out = qdot(out, params["wo"], q_cfg, train)
    return out, new_cache
