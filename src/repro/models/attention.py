"""Attention: GQA with RoPE/qk-norm, flash-style chunked softmax, KV cache.

Training/prefill use a "pair-scan flash" implementation: the (q-chunk,
k-chunk) pairs that can contribute under the mask (causal triangle, local
band, or full rectangle) are enumerated STATICALLY, and a single lax.scan
walks the pair list carrying running (max, denom, acc).  This gives
  * bounded peak memory (one q-chunk x k-chunk score block at a time),
  * exact mask-aware FLOPs (no wasted upper-triangle compute),
  * one compiled body regardless of sequence length.

Decode attends a single query against the (optionally VP-quantized) cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import FXPFormat, default_vp_format
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import substrate as ksub
from repro.kernels.autotune import _pow2_at_least
from .layers import qdot, rms_norm, rope

NEG_INF = -1e30


def _chunk_and_pad(s: int, target: int = 512):
    """Chunk size and padded length for a sequence of length s.

    The chunk is the largest power of two <= target that is needed to
    cover s; s pads up to the next chunk multiple (pad < chunk, masked
    in the kernel).  The old policy demanded an exact DIVISOR of s, so a
    prime length (e.g. 509) degraded to chunk=1 and a scan over s^2
    singleton pairs.
    """
    c = min(target, _pow2_at_least(max(s, 1)))
    return c, s + (-s) % c


def _chunk_pairs(n_q: int, n_k: int, pattern: str, window_chunks: int):
    """Static list of contributing (qi, ki) chunk pairs."""
    pairs = []
    for qi in range(n_q):
        for ki in range(n_k):
            if pattern == "causal" and ki > qi:
                continue
            if pattern == "local" and (ki > qi or qi - ki > window_chunks):
                continue
            pairs.append((qi, ki))
    return pairs


def flash_attention(
    q, k, v,
    pattern: str = "causal",
    window: Optional[int] = None,
    chunk: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """q (B, Sq, H, dh), k/v (B, Sk, KV, dh) -> (B, Sq, H, dh).

    GQA: H must be a multiple of KV; k/v heads are repeated logically via
    reshape (no materialized repeat).
    pattern: causal | local (banded causal) | full (encoder/cross).
    Causal/local require Sq == Sk.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if scale is None and ksub.resolve_backend(None) == "native":
        # Kernel backend: one fused flash pallas_call (q-chunk x k-chunk
        # online softmax, diagonal/window tiles skipped) replaces the
        # lax.scan pair-walk.
        return kops.flash_prefill(q, k, v, pattern=pattern, window=window)
    scale = scale if scale is not None else dh ** -0.5
    c, sqp = _chunk_and_pad(Sq, chunk)
    ck, skp = _chunk_and_pad(Sk, chunk)
    if pattern in ("causal", "local"):
        assert Sq == Sk
        ck, skp = c, sqp
    nq, nk = sqp // c, skp // ck
    wc = max(1, (window or sqp) // c) if pattern == "local" else nk
    pairs = _chunk_pairs(nq, nk, pattern, wc)
    pair_arr = jnp.asarray(pairs, jnp.int32)  # (P, 2)

    if sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, sqp - Sq), (0, 0), (0, 0)))
    if skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp - Sk), (0, 0), (0, 0)))

    # Layout: (B, KV, G, nq, c, dh) for q; (B, KV, nk, ck, dh) for k/v.
    qr = q.reshape(B, sqp, KV, G, dh).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, KV, G, nq, c, dh) * scale
    kr = k.transpose(0, 2, 1, 3).reshape(B, KV, nk, ck, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B, KV, nk, ck, dh)

    q_off = jnp.arange(c, dtype=jnp.int32)
    k_off = jnp.arange(ck, dtype=jnp.int32)

    def step(carry, pair):
        m, l, acc = carry                        # running stats per q pos
        qi, ki = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qr, qi, axis=3, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, ki, axis=2, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, ki, axis=2, keepdims=False)
        # scores (B, KV, G, c, ck) — operands stay bf16 (halves the
        # SP-gather bytes), accumulation in f32 (MXU-native)
        s = jnp.einsum(
            "bkgqd,bkcd->bkgqc", qb, kb,
            preferred_element_type=jnp.float32)
        q_pos = qi * c + q_off[:, None]
        k_pos = ki * ck + k_off[None, :]
        if pattern in ("causal", "local"):
            mask = k_pos <= q_pos
            if pattern == "local" and window:
                mask &= q_pos - k_pos < window
            if skp != Sk:
                mask &= k_pos < Sk
            s = jnp.where(mask, s, NEG_INF)
        elif skp != Sk:
            s = jnp.where(k_pos < Sk, s, NEG_INF)
        # online softmax update for q chunk qi
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 3, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 3, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 3, keepdims=False)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_old, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.sum(p, axis=-1)
        a_new = a_old * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 3)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 3)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 3)
        return (m, l, acc), None

    init = (
        jnp.full((B, KV, G, nq, c), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G, nq, c), jnp.float32),
        jnp.zeros((B, KV, G, nq, c, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, pair_arr)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, KV, G, sqp, dh).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, sqp, H, dh)[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (optionally VP-quantized) + decode attention
# ---------------------------------------------------------------------------

def kv_cache_formats(q: QuantConfig):
    fxp = FXPFormat(q.W, q.W - 1)
    vp = default_vp_format(fxp, q.M, q.E)
    return fxp, vp


def _kv_scale(x):
    """Per-position pow2 scale: smallest 2^n >= max|x| over (KV, dh)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1),
                   keepdims=True)
    return jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))))


def quantize_kv(x, q: QuantConfig, layout: str = "packed"):
    """bf16 KV block (B, S, KV, dh) -> VP storage + per-position pow2
    scale.

    layout "packed" (default): ONE packed VP word per element
    (`core.packing`: sign + significand + exponent index,
    `vp.storage_bits` bits) -> (w, s).  This is the layout the
    decode-attention kernel consumes directly — no per-step index
    unpacking, no two-plane HBM reads.
    layout "planes": the legacy (int8 significand, bit-packed uint8
    index) planes -> (m, i, s), kept as the golden jnp oracle the
    packed path is pinned against.
    """
    fxp, vp = kv_cache_formats(q)
    s = _kv_scale(x)
    xn = x.astype(jnp.float32) / s
    if layout == "packed":
        return kref.vp_quant_packed_ref(xn, fxp, vp), s.astype(jnp.float32)
    from repro.core.vp_tensor import pack_indices

    m, i = kref.vp_quant_ref(xn, fxp, vp)
    if vp.E and x.shape[-1] % (8 // vp.E) == 0:
        i = pack_indices(i, vp.E)
    return m, i, s.astype(jnp.float32)


def dequantize_kv(m, i, s, q: QuantConfig, dtype):
    """Planes cache -> reals (the legacy whole-cache jnp dequant)."""
    from repro.core.vp_tensor import unpack_indices

    _, vp = kv_cache_formats(q)
    if i.shape[-1] != m.shape[-1]:
        i = unpack_indices(i, vp.E, m.shape[-1])
    return (kref.vp_dequant_ref(m, i, vp, jnp.float32) * s).astype(dtype)


def dequantize_kv_packed(w, s, q: QuantConfig, dtype):
    """Packed-word cache -> reals (offline whole-word LUT, bit-identical
    to `dequantize_kv` on the planes it packs)."""
    from repro.core.packing import dequant_words

    _, vp = kv_cache_formats(q)
    return (dequant_words(w, vp, jnp.float32) * s).astype(dtype)


def decode_attention(
    q, k_cache, v_cache, cache_len,
    window: Optional[int] = None,
    rolling: bool = False,
) -> jax.Array:
    """Single-token decode: q (B, 1, H, dh), caches (B, Smax, KV, dh).

    Masks positions >= cache_len (and outside the sliding window if given).
    `rolling`: the buffer IS the window (SWA ring buffer) — every slot
    written so far is valid, no window masking by absolute position.
    When a non-rolling `window` bounds the valid span and Smax is
    statically larger, the cache is sliced to the window before the
    einsum (O(window) scores instead of O(Smax) — see
    `kernels.ref._decode_attention_core`, the shared implementation).
    """
    return kref.decode_attention_ref(q, k_cache, v_cache, cache_len,
                                     window=window, rolling=rolling)


# ---------------------------------------------------------------------------
# Full attention block (projections + norms + rope + flash/decode)
# ---------------------------------------------------------------------------

def _cache_buf(cache: dict):
    """The key buffer of any cache layout (float / planes / packed)."""
    for key in ("k", "k_m", "k_w"):
        if key in cache:
            return cache[key]
    raise KeyError(f"unrecognized KV cache layout: {sorted(cache)}")


def _chunked_prefill_attention(qp, k_all, v_all, offset, hist_len: int):
    """Causal attention of a prompt chunk against cache history + itself.

    qp (B, S, H, dh) is the chunk's queries; k_all/v_all (B, hist_len+S,
    KV, dh) are the dequantized cache history concatenated with the
    chunk's own K/V.  offset (B,) is the valid history span: history
    position t contributes iff t < offset, chunk position c iff c <= s
    (intra-chunk causality).  Everything past offset is masked to
    NEG_INF, so garbage in unwritten cache slots cannot leak.
    """
    B, S, H, dh = qp.shape
    KV = k_all.shape[2]
    G = H // KV
    qr = qp.reshape(B, S, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qr,
                        k_all.astype(jnp.float32))
    t = jnp.arange(k_all.shape[1], dtype=jnp.int32)[None, None, :]
    s_idx = jnp.arange(S, dtype=jnp.int32)[None, :, None]
    mask = (t < offset[:, None, None]) | \
        ((t >= hist_len) & (t - hist_len <= s_idx))      # (B, S, T)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgst,btkd->bskgd", p / jnp.maximum(l, 1e-30),
                     v_all.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(qp.dtype)


def attn_block(
    x, params, cfg: ModelConfig,
    positions,
    pattern: str,
    window: Optional[int],
    cache: Optional[dict] = None,
    train: bool = False,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    chunked: bool = False,
):
    """Self/cross attention block.

    cache: {"k": (B, Smax, KV, dh) floats, "v": ..., "len": (B,)} — or
    the VP-quantized layouts: packed words {"k_w", "k_s", "v_w", "v_s"}
    (kernel-consumed, default) / legacy planes {"k_m", "k_i", "k_s", ...}
    -> returns (out, new_cache).  kv_override supplies precomputed
    encoder K/V for cross-attention.

    chunked: the multi-token input is a prompt CHUNK appended at offset
    `cache["len"]` (continuous-batching prefill) rather than the start
    of an empty cache — the chunk attends to the already-written history
    plus itself, and its K/V are written at the offset.  Full-causal
    caches only (a rolling ring's chunk writes would need wraparound
    bookkeeping no caller exercises).
    """
    q_cfg = cfg.quant
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    qp = qdot(x, params["wq"], q_cfg, train)
    if params.get("bq") is not None:
        qp = qp + params["bq"].astype(qp.dtype)
    qp = qp.reshape(*x.shape[:-1], H, dh)

    if kv_override is None:
        kp = qdot(x, params["wk"], q_cfg, train)
        vp_ = qdot(x, params["wv"], q_cfg, train)
        if params.get("bk") is not None:
            kp = kp + params["bk"].astype(kp.dtype)
            vp_ = vp_ + params["bv"].astype(vp_.dtype)
        kp = kp.reshape(*x.shape[:-1], KV, dh)
        vp_ = vp_.reshape(*x.shape[:-1], KV, dh)
    else:
        kp, vp_ = kv_override

    if cfg.qk_norm:
        qp = rms_norm(qp, params["q_norm"])
        kp = rms_norm(kp, params["k_norm"]) if kv_override is None else kp

    if positions is not None and kv_override is None:
        qp = rope(qp, positions, cfg.rope_theta)
        kp = rope(kp, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_override is None and x.shape[1] > 1 \
            and chunked:
        # CHUNKED PREFILL: this chunk's queries attend to the valid
        # cache history (dequantized once per chunk — O(chunk * hist)
        # like any prefill, unlike the per-token decode path which never
        # dequantizes the whole cache) plus the chunk itself; the
        # chunk's K/V append at offset `len` via the same per-position
        # quantization the one-shot path uses.
        if window is not None:
            raise NotImplementedError(
                "chunked prefill over rolling/windowed caches is not "
                "implemented; use whole-prompt prefill")
        S = x.shape[1]
        smax = _cache_buf(cache).shape[1]
        idx = cache["len"]  # (B,)
        if "k_w" in cache:
            k_hist = dequantize_kv_packed(cache["k_w"], cache["k_s"],
                                          q_cfg, kp.dtype)
            v_hist = dequantize_kv_packed(cache["v_w"], cache["v_s"],
                                          q_cfg, vp_.dtype)
        elif "k_m" in cache:
            k_hist = dequantize_kv(cache["k_m"], cache["k_i"],
                                   cache["k_s"], q_cfg, kp.dtype)
            v_hist = dequantize_kv(cache["v_m"], cache["v_i"],
                                   cache["v_s"], q_cfg, vp_.dtype)
        else:
            k_hist, v_hist = cache["k"].astype(kp.dtype), \
                cache["v"].astype(vp_.dtype)
        k_all = jnp.concatenate([k_hist, kp.astype(k_hist.dtype)], axis=1)
        v_all = jnp.concatenate([v_hist, vp_.astype(v_hist.dtype)], axis=1)
        out = _chunked_prefill_attention(qp, k_all, v_all, idx, smax)
        upd = lambda buf, val: jax.vmap(
            lambda b, v, j: jax.lax.dynamic_update_slice_in_dim(
                b, v, j, axis=0))(buf, val, idx)
        if "k_w" in cache:
            w_k, s_k = quantize_kv(kp, q_cfg)
            w_v, s_v = quantize_kv(vp_, q_cfg)
            new_cache = dict(
                k_w=upd(cache["k_w"], w_k), k_s=upd(cache["k_s"], s_k),
                v_w=upd(cache["v_w"], w_v), v_s=upd(cache["v_s"], s_v),
                len=idx + S)
        elif "k_m" in cache:
            m_k, i_k, s_k = quantize_kv(kp, q_cfg, layout="planes")
            m_v, i_v, s_v = quantize_kv(vp_, q_cfg, layout="planes")
            new_cache = dict(
                k_m=upd(cache["k_m"], m_k), k_i=upd(cache["k_i"], i_k),
                k_s=upd(cache["k_s"], s_k),
                v_m=upd(cache["v_m"], m_v), v_i=upd(cache["v_i"], i_v),
                v_s=upd(cache["v_s"], s_v), len=idx + S)
        else:
            new_cache = dict(k=upd(cache["k"], kp.astype(cache["k"].dtype)),
                             v=upd(cache["v"], vp_.astype(cache["v"].dtype)),
                             len=idx + S)
        out = out.reshape(*x.shape[:-1], H * dh)
        return qdot(out, params["wo"], q_cfg, train), new_cache
    if cache is not None and kv_override is None and x.shape[1] > 1:
        # PREFILL: full causal pass over the prompt, then write all S
        # positions into the cache in one shot.
        S = x.shape[1]
        smax = _cache_buf(cache).shape[1]
        out = flash_attention(qp, kp, vp_, pattern=pattern, window=window)
        kw, vw = kp, vp_
        if S > smax:  # ring buffer shorter than prompt: keep the tail,
            # arranged so slot j holds position p with p % smax == j (the
            # decode writer uses len % smax).
            kw = jnp.roll(kp[:, -smax:], S % smax, axis=1)
            vw = jnp.roll(vp_[:, -smax:], S % smax, axis=1)
        pad = smax - kw.shape[1]
        if pad:
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if "k_w" in cache:  # packed-word VP cache (kernel layout)
            w_k, s_k = quantize_kv(kw, q_cfg)
            w_v, s_v = quantize_kv(vw, q_cfg)
            new_cache = dict(k_w=w_k, k_s=s_k, v_w=w_v, v_s=s_v,
                             len=cache["len"] + S)
        elif "k_m" in cache:
            m_k, i_k, s_k = quantize_kv(kw, q_cfg, layout="planes")
            m_v, i_v, s_v = quantize_kv(vw, q_cfg, layout="planes")
            new_cache = dict(
                k_m=m_k, k_i=i_k, k_s=s_k, v_m=m_v, v_i=i_v, v_s=s_v,
                len=cache["len"] + S)
        else:
            new_cache = dict(k=kw.astype(cache["k"].dtype),
                             v=vw.astype(cache["v"].dtype),
                             len=cache["len"] + S)
        out = out.reshape(*x.shape[:-1], H * dh)
        return qdot(out, params["wo"], q_cfg, train), new_cache
    if cache is not None and kv_override is None:
        # Decode: append this step's K/V.  A buffer no longer than the
        # sliding window acts as a ring buffer (long-context SWA decode).
        smax = _cache_buf(cache).shape[1]
        rolling = window is not None and smax <= window
        idx = cache["len"]  # (B,)
        widx = idx % smax if rolling else idx
        upd = lambda buf, val: jax.vmap(
            lambda b, v, j: jax.lax.dynamic_update_slice_in_dim(
                b, v, j, axis=0))(buf, val, widx)
        if "k_w" in cache:
            # Packed-word VP cache: the words go straight to the
            # decode-attention kernel op — unpack + bit-assembled pow2
            # scale happen in-tile, and seq tiles outside the valid span
            # are skipped.  The whole cache is never dequantized in XLA.
            w_k, s_k = quantize_kv(kp, q_cfg)
            w_v, s_v = quantize_kv(vp_, q_cfg)
            new_cache = dict(
                k_w=upd(cache["k_w"], w_k), k_s=upd(cache["k_s"], s_k),
                v_w=upd(cache["v_w"], w_v), v_s=upd(cache["v_s"], s_v),
                len=idx + kp.shape[1],
            )
            _, vp_fmt = kv_cache_formats(q_cfg)
            out = kops.vp_decode_attention(
                qp, new_cache["k_w"], new_cache["v_w"],
                new_cache["k_s"], new_cache["v_s"], new_cache["len"],
                vp_fmt, window=window, rolling=rolling)
        else:
            if "k_m" in cache:  # legacy planes VP cache (golden baseline)
                m_k, i_k, s_k = quantize_kv(kp, q_cfg, layout="planes")
                m_v, i_v, s_v = quantize_kv(vp_, q_cfg, layout="planes")
                new_cache = dict(
                    k_m=upd(cache["k_m"], m_k), k_i=upd(cache["k_i"], i_k),
                    k_s=upd(cache["k_s"], s_k),
                    v_m=upd(cache["v_m"], m_v), v_i=upd(cache["v_i"], i_v),
                    v_s=upd(cache["v_s"], s_v),
                    len=idx + kp.shape[1],
                )
                k_full = dequantize_kv(
                    new_cache["k_m"], new_cache["k_i"], new_cache["k_s"],
                    q_cfg, kp.dtype)
                v_full = dequantize_kv(
                    new_cache["v_m"], new_cache["v_i"], new_cache["v_s"],
                    q_cfg, vp_.dtype)
            else:
                new_cache = dict(
                    k=upd(cache["k"], kp), v=upd(cache["v"], vp_),
                    len=idx + kp.shape[1],
                )
                k_full, v_full = new_cache["k"], new_cache["v"]
            out = decode_attention(
                qp, k_full, v_full, new_cache["len"], window,
                rolling=rolling)
    elif kv_override is not None:
        if qp.shape[1] == 1:
            # Cross-attention during decode: full-length source.
            src_len = jnp.full((B,), kp.shape[1], jnp.int32)
            out = decode_attention(qp, kp, vp_, src_len)
        else:
            out = flash_attention(qp, kp, vp_, pattern="full")
    else:
        out = flash_attention(qp, kp, vp_, pattern=pattern, window=window)

    out = out.reshape(*x.shape[:-1], H * dh)
    out = qdot(out, params["wo"], q_cfg, train)
    return out, new_cache
