"""Mixture-of-Experts with top-k token-choice routing (sort-based dispatch).

The dispatch is the sort-based capacity form used by production systems:
tokens' (token, expert) assignments are sorted by expert, positions within
each expert computed by a cumulative count, entries beyond the per-expert
capacity dropped, and tokens scattered into an (E, C, d) buffer.  Under
pjit with the expert axis sharded over "model", the gather/scatter lowers
to the expected all-to-all pattern (expert parallelism).

Aux losses: load-balance (Switch-style) + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from .layers import qdot


def router_probs(x, w_router, q: QuantConfig):
    """Softmax router logits in f32 (T, E)."""
    logits = qdot(x.astype(jnp.float32), w_router, QuantConfig("none"))
    return logits, jax.nn.softmax(logits, axis=-1)


def _moe_group_count(T: int, target: int = 4096) -> int:
    """Number of dispatch groups: ~`target` tokens each, dividing T."""
    g = max(1, T // target)
    while T % g:
        g -= 1
    return g


def moe_block(
    x, params, cfg: ModelConfig,
    capacity_factor: float = 1.25,
    train: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, S, d) -> (B, S, d), aux losses.

    params: w_router (d, E); experts {w_gate, w_up, w_down} stacked (E, ...).

    Dispatch is GROUP-LOCAL (MaxText-style): tokens are split into groups
    of ~4k, each group sorts its own (token, expert) assignments and fills
    a per-group per-expert capacity buffer.  Groups shard over the
    data/sequence axes, so the sort never crosses devices; the expert
    einsum against EP-sharded weights produces the all-to-all.
    """
    q = cfg.quant
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    G = _moe_group_count(T)
    Tg = T // G
    xt = x.reshape(G, Tg, d)

    logits, probs = router_probs(xt, params["w_router"], q)   # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (over the full router distribution) ----
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32),
        axis=(0, 1))
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }

    # ---- group-local sort-based dispatch with capacity ----
    C = int(max(1, round(Tg * k / E * capacity_factor)))
    flat_expert = expert_idx.reshape(G, Tg * k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    flat_gate = gate_vals.reshape(G, Tg * k)
    order = jnp.argsort(flat_expert, axis=-1)                 # per group
    se = jnp.take_along_axis(flat_expert, order, -1)
    stok = jnp.take_along_axis(flat_token, order, -1)
    sg = jnp.take_along_axis(flat_gate, order, -1)
    # position within expert = index - start offset of that expert
    one_hot_counts = jax.vmap(
        lambda e: jnp.bincount(e, length=E))(se)              # (G, E)
    starts = jnp.cumsum(one_hot_counts, -1) - one_hot_counts
    pos = jnp.arange(Tg * k)[None] - jnp.take_along_axis(starts, se, -1)
    keep = pos < C

    def scatter_group(xg, se_g, stok_g, pos_g, keep_g):
        buf = jnp.zeros((E, C, d), x.dtype)
        vals = jnp.where(keep_g[:, None], xg[stok_g], 0).astype(x.dtype)
        return buf.at[se_g, jnp.where(keep_g, pos_g, 0)].add(vals)

    buf = jax.vmap(scatter_group)(xt, se, stok, pos, keep)    # (G, E, C, d)

    # ---- expert FFNs: einsum over EP-sharded weights ----
    def ffn(h):  # h (G, E_local, C, d)
        g = jnp.einsum("gecd,edf->gecf", h, _w(params["w_gate"], q, h.dtype))
        u = jnp.einsum("gecd,edf->gecf", h, _w(params["w_up"], q, h.dtype))
        act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        return jnp.einsum("gecf,efd->gecd", act,
                          _w(params["w_down"], q, h.dtype))

    E_local = params["w_gate"]["w_packed"].shape[0] \
        if isinstance(params["w_gate"], dict) and "w_packed" in params["w_gate"] \
        else (params["w_gate"]["m"].shape[0]
              if isinstance(params["w_gate"], dict)
              else params["w_gate"].shape[0])
    if q.tp_axis is not None and E_local < E:
        # Expert parallelism under shard_map: routing/dispatch above ran
        # replicated, so every shard holds the full (G, E, C, d) buffer;
        # each shard runs only its resident experts and the outputs
        # reassemble by all-gather along the expert axis.  Per-expert
        # FFNs are independent, so the concatenation is bit-exact
        # against the all-experts einsum.
        idx = jax.lax.axis_index(q.tp_axis)
        buf_local = jax.lax.dynamic_slice_in_dim(
            buf, idx * E_local, E_local, axis=1)
        out_buf = jax.lax.all_gather(
            ffn(buf_local), q.tp_axis, axis=1, tiled=True)
    else:
        out_buf = ffn(buf)

    # ---- combine: gather back and weight by gates ----
    def combine_group(ob, se_g, stok_g, pos_g, keep_g, sg_g):
        gathered = ob[se_g, jnp.where(keep_g, pos_g, 0)]      # (Tg*k, d)
        gathered = jnp.where(keep_g[:, None], gathered, 0.0)
        out = jnp.zeros((Tg, d), jnp.float32)
        return out.at[stok_g].add(
            gathered.astype(jnp.float32) * sg_g[:, None])

    combined = jax.vmap(combine_group)(out_buf, se, stok, pos, keep, sg)
    return combined.reshape(B, S, d).astype(x.dtype), aux


def _w(wq, q: QuantConfig, dtype):
    """Materialize a (possibly quantized) stacked expert weight for einsum."""
    if not isinstance(wq, dict):
        return wq.astype(dtype)
    if "w_packed" in wq:      # packed VP words (kernel serving layout)
        # dequant_words is elementwise over any rank — no per-expert vmap
        # needed (unlike the i_packed branch, whose index unpack is
        # axis-dependent).
        from .layers import canonical_formats
        from repro.core.packing import dequant_words
        _, vp = canonical_formats(q)
        scale = jnp.asarray(wq["scale"], dtype).reshape(
            (-1,) + (1,) * (wq["w_packed"].ndim - 1))
        return dequant_words(wq["w_packed"], vp, dtype) * scale
    scale = jnp.asarray(wq["scale"], dtype).reshape(
        (-1,) + (1,) * (wq["m"].ndim - 1))
    if "i_packed" in wq:      # per-element VP planes
        from .layers import _dequant_vp_weight
        return jax.vmap(
            lambda m, i: _dequant_vp_weight(
                {"m": m, "i_packed": i,
                 "scale": jnp.ones((), jnp.float32)}, q, dtype)
        )(wq["m"], wq["i_packed"]) * scale
    if "i_blk" in wq:         # block VP
        from .layers import canonical_formats
        from repro.core import block_vp_dequantize
        _, vp = canonical_formats(q)
        deq = jax.vmap(
            lambda m, i: block_vp_dequantize(m, i, vp, q.block, axis=0,
                                             dtype=dtype)
        )(wq["m"], wq["i_blk"])
        return deq * scale
    return wq["m"].astype(dtype) * scale
