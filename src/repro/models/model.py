"""Model assembly for every assigned architecture.

A model is (init_params, loss_fn / prefill / decode_step) driven purely by
ModelConfig.  Layers are SCANNED in homogeneous groups so the HLO contains
one body per distinct layer pattern regardless of depth:

  dense GQA        : one group of n_layers x [causal]
  gemma3 5:1       : groups of [5 x local, 1 x global] + tail locals
  mixtral SWA+MoE  : n_layers x [swa + moe]
  qwen3-moe        : n_layers x [causal + moe]
  rwkv6            : n_layers x [time_mix + channel_mix]
  zamba2 hybrid    : groups of [6 x mamba2] with ONE SHARED attention block
                     applied between groups (weight sharing — the shared
                     params live outside the scan)
  whisper enc-dec  : encoder stack (full attn) + decoder stack (causal
                     self-attn + cross-attn)
  internvl2 (vlm)  : patch-embedding stub prepended to token embeddings,
                     then the dense LM stack

Caches for decode are stacked per group; `quantize_params` converts float
weights to the configured serving representation (VP planes etc.).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import qdot, rms_norm, layer_norm, embed_lookup, quantize_weight
from .attention import attn_block
from .mlp import swiglu, gelu_mlp
from .moe import moe_block
from .mamba2 import mamba2_block, mamba2_dims, D_CONV
from .rwkv6 import rwkv6_time_mix, rwkv6_channel_mix, HEAD_DIM as RWKV_HEAD


def model_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Layer grouping (static plan of scanned groups)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerGroup:
    repeats: int
    patterns: Tuple[str, ...]   # per sub-layer: causal|local|global|swa|
                                # moe|moe_swa|mamba|rwkv|shared_attn


def layer_groups(cfg: ModelConfig) -> List[LayerGroup]:
    if cfg.family == "hybrid":
        per = cfg.shared_attn_period
        n_full, tail = divmod(cfg.n_layers, per)
        groups = []
        if n_full:
            groups.append(LayerGroup(n_full, ("mamba",) * per + ("shared_attn",)))
        if tail:
            groups.append(LayerGroup(1, ("mamba",) * tail))
        return groups
    if cfg.family == "ssm" and cfg.rwkv:
        return [LayerGroup(cfg.n_layers, ("rwkv",))]
    if cfg.local_global_period:
        per = cfg.local_global_period
        n_full, tail = divmod(cfg.n_layers, per)
        groups = []
        if n_full:
            groups.append(
                LayerGroup(n_full, ("local",) * (per - 1) + ("global",)))
        if tail:
            groups.append(LayerGroup(1, ("local",) * tail))
        return groups
    if cfg.family == "moe":
        pat = "moe_swa" if cfg.sliding_window else "moe"
        return [LayerGroup(cfg.n_layers, (pat,))]
    pat = "swa" if cfg.sliding_window else "causal"
    return [LayerGroup(cfg.n_layers, (pat,))]


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(key, cfg: ModelConfig, dtype, cross: bool = False):
    H, KV, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * dh), dtype),
        "wk": _dense_init(ks[1], (d, KV * dh), dtype),
        "wv": _dense_init(ks[2], (d, KV * dh), dtype),
        "wo": _dense_init(ks[3], (H * dh, d), dtype,
                          scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((KV * dh,), dtype)
        p["bv"] = jnp.zeros((KV * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _mlp_params(key, cfg: ModelConfig, dtype, gelu: bool = False):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if gelu:
        return {
            "w_in": _dense_init(ks[0], (d, ff), dtype),
            "b_in": jnp.zeros((ff,), dtype),
            "w_out": _dense_init(ks[1], (ff, d), dtype),
            "b_out": jnp.zeros((d,), dtype),
        }
    return {
        "w_gate": _dense_init(ks[0], (d, ff), dtype),
        "w_up": _dense_init(ks[1], (d, ff), dtype),
        "w_down": _dense_init(ks[2], (ff, d), dtype,
                              scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def _moe_params(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "w_router": _dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, ff), dtype),
        "w_up": _dense_init(ks[2], (E, d, ff), dtype),
        "w_down": _dense_init(ks[3], (E, ff, d), dtype,
                              scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def _mamba_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, n, h, p_, conv_dim, proj_dim = mamba2_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_z": _dense_init(ks[0], (d, di), dtype),
        "w_x": _dense_init(ks[1], (d, di), dtype),
        "w_bc": _dense_init(ks[2], (d, 2 * n), dtype),
        "w_dt": _dense_init(ks[3], (d, h), dtype),
        "conv_w": _dense_init(ks[4], (D_CONV, conv_dim), jnp.float32, 0.2),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.zeros((di,), jnp.float32),
        "w_out": _dense_init(ks[5], (di, d), dtype,
                             scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def _rwkv_params(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    lora = max(32, d // 16)
    ks = jax.random.split(key, 10)
    p = {
        "w_r": _dense_init(ks[0], (d, d), dtype),
        "w_k": _dense_init(ks[1], (d, d), dtype),
        "w_v": _dense_init(ks[2], (d, d), dtype),
        "w_g": _dense_init(ks[3], (d, d), dtype),
        "w_o": _dense_init(ks[4], (d, d), dtype,
                           scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
        "w_dec_a": _dense_init(ks[5], (d, lora), jnp.float32),
        "w_dec_b": _dense_init(ks[6], (lora, d), jnp.float32),
        "w_dec0": jnp.full((d,), 0.0, jnp.float32),
        "u_bonus": jnp.zeros((d // RWKV_HEAD, RWKV_HEAD), jnp.float32),
        "ln_x": jnp.zeros((d,), jnp.float32),
        "w_ck": _dense_init(ks[7], (d, ff), dtype),
        "w_cv": _dense_init(ks[8], (ff, d), dtype,
                            scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
        "w_cr": _dense_init(ks[9], (d, d), dtype),
    }
    for name in ("r", "k", "v", "g", "w"):
        p[f"mu_{name}"] = jnp.full((d,), 0.5, jnp.float32)
    p["mu_ck"] = jnp.full((d,), 0.5, jnp.float32)
    p["mu_cr"] = jnp.full((d,), 0.5, jnp.float32)
    p["ln1"] = jnp.zeros((d,), jnp.float32)
    p["ln2"] = jnp.zeros((d,), jnp.float32)
    return p


def _sublayer_params(key, cfg: ModelConfig, pattern: str, dtype):
    if pattern == "rwkv":
        return _rwkv_params(key, cfg, dtype)
    if pattern == "mamba":
        p = _mamba_params(key, cfg, dtype)
        p["ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return p
    if pattern in ("moe", "moe_swa"):
        k1, k2 = jax.random.split(key)
        return {
            "attn": _attn_params(k1, cfg, dtype),
            "moe": _moe_params(k2, cfg, dtype),
            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    # plain attention + dense mlp
    k1, k2 = jax.random.split(key)
    return {
        "attn": _attn_params(k1, cfg, dtype),
        "mlp": _mlp_params(k2, cfg, dtype),
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = model_dtype(cfg)
    keys = jax.random.split(key, 16)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": _dense_init(keys[0], (cfg.vocab, d), dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "lm_head": _dense_init(keys[1], (d, cfg.vocab), dtype),
    }
    groups = []
    for gi, group in enumerate(layer_groups(cfg)):
        gkey = jax.random.fold_in(keys[2], gi)
        sub = {}
        for j, pattern in enumerate(group.patterns):
            if pattern == "shared_attn":
                continue  # lives outside the scan (weight sharing)
            jkeys = jax.random.split(jax.random.fold_in(gkey, j),
                                     group.repeats)
            stacked = jax.vmap(
                lambda k: _sublayer_params(k, cfg, pattern, dtype))(jkeys)
            sub[f"sub{j}"] = stacked
        groups.append(sub)
    params["groups"] = groups
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[3])
        params["shared_attn"] = {
            "attn": _attn_params(k1, cfg, dtype),
            "mlp": _mlp_params(k2, cfg, dtype),
            "ln1": jnp.zeros((d,), jnp.float32),
            "ln2": jnp.zeros((d,), jnp.float32),
        }
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: {
                "attn": _attn_params(k, cfg, dtype),
                "mlp": _mlp_params(jax.random.fold_in(k, 1), cfg, dtype,
                                   gelu=True),
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
            })(enc_keys)
        dec_keys = jax.random.split(keys[5], cfg.n_layers)
        params["cross"] = jax.vmap(
            lambda k: {
                "attn": _attn_params(k, cfg, dtype, cross=True),
                "ln_g": jnp.ones((d,), jnp.float32),
                "ln_b": jnp.zeros((d,), jnp.float32),
            })(dec_keys)
        params["enc_ln_g"] = jnp.ones((d,), jnp.float32)
        params["enc_ln_b"] = jnp.zeros((d,), jnp.float32)
    if cfg.family == "vlm":
        # modality-frontend STUB projection (patch embeds arrive precomputed)
        params["patch_proj"] = _dense_init(keys[6], (d, d), dtype)
    return params


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------

def _sp_gather(x, cfg: ModelConfig):
    """Megatron-SP gather point: attention/MLP consume the FULL sequence.

    The residual stream is pinned seq-sharded between layers
    (`_maybe_shard_seq`); right before each block we pin the post-norm
    tensor seq-UNsharded, so GSPMD emits one all-gather(seq) here and one
    reduce-scatter at the next residual pin — instead of resolving the
    (seq x weight) double-"model"-sharding by all-gathering full weight
    matrices inside the layer scan (462 MB f32 per matmul observed).
    """
    if not cfg.seq_shard or x.ndim != 3 or not cfg.mesh_axis_sizes:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(cfg.mesh_axis_sizes)
    nb = 1
    for a in cfg.mesh_batch_axes:
        nb *= sizes.get(a, 1)
    bax = cfg.mesh_batch_axes if nb > 1 and x.shape[0] % nb == 0 else None
    return jax.lax.with_sharding_constraint(x, P(bax, None, None))


def _apply_sublayer(x, p, cfg: ModelConfig, pattern: str, positions,
                    cache=None, train: bool = False, chunked: bool = False):
    """Returns (x, new_cache, aux) — aux is (2,) f32 [load_balance, z]."""
    zero_aux = jnp.zeros((2,), jnp.float32)
    if pattern == "rwkv":
        h, st1 = rwkv6_time_mix(
            rms_norm(x, p["ln1"]), p, cfg, state=cache, train=train)
        x = x + h
        h, st2 = rwkv6_channel_mix(
            rms_norm(x, p["ln2"]), p, cfg, state=cache, train=train)
        x = x + h
        new_cache = None if cache is None else {**st1, **st2}
        return x, new_cache, zero_aux
    if pattern == "mamba":
        h, st = mamba2_block(rms_norm(x, p["ln"]), p, cfg,
                             state=cache, train=train)
        return x + h, st, zero_aux
    # attention-based sub-layers
    pat, window = {
        "causal": ("causal", None),
        "global": ("causal", None),
        "local": ("local", cfg.local_window),
        "swa": ("local", cfg.sliding_window),
        "moe": ("causal", None),
        "moe_swa": ("local", cfg.sliding_window),
        "shared_attn": ("causal", None),
    }[pattern]
    h, new_cache = attn_block(
        _sp_gather(rms_norm(x, p["ln1"]), cfg), p["attn"], cfg, positions,
        pattern=pat, window=window, cache=cache, train=train,
        chunked=chunked)
    x = x + h
    aux = zero_aux
    if pattern in ("moe", "moe_swa"):
        h, aux_d = moe_block(_sp_gather(rms_norm(x, p["ln2"]), cfg),
                             p["moe"], cfg, train=train)
        aux = jnp.stack([aux_d["load_balance"], aux_d["router_z"]])
    else:
        h = swiglu(_sp_gather(rms_norm(x, p["ln2"]), cfg), p["mlp"],
                   cfg.quant, train)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _maybe_shard_seq(x, cfg: ModelConfig):
    """Pin the residual stream's layout between layers.

    (1) batch stays sharded over the data axes — CRITICAL under FSDP
    weight sharding: without this, GSPMD may choose partial-sum matmuls
    that ALL-REDUCE full activations (10 GB/layer observed) instead of
    all-gathering weights (19 MB/layer);
    (2) with cfg.seq_shard, the sequence dim additionally shards over
    "model" (Megatron-SP) — GSPMD inserts the all-gather/reduce-scatter
    pairs around attention/MLP.
    No-op outside a mesh context (CPU unit tests).
    """
    if x.ndim != 3 or not cfg.mesh_axis_sizes:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(cfg.mesh_axis_sizes)
    nb = 1
    for a in cfg.mesh_batch_axes:
        nb *= sizes.get(a, 1)
    if nb <= 1:
        return x
    bax = cfg.mesh_batch_axes if x.shape[0] % nb == 0 else None
    sax = None
    if cfg.seq_shard and x.shape[1] >= 64 \
            and x.shape[1] % sizes.get("model", 1) == 0:
        sax = "model"
    if bax is None and sax is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(bax, sax, None))


def _scan_group(x, group_params, cfg, patterns, positions, shared=None,
                caches=None, train=False, chunked=False):
    """Scan a homogeneous group of layers.

    group_params: {"sub{j}": stacked-params} (leading axis = repeats).
    caches: matching stacked cache pytree or None.
    Returns (x, new_caches, aux_sum).
    """
    def body(carry, per_layer):
        h = carry
        h = _maybe_shard_seq(h, cfg)
        p_layer, cache_layer = per_layer
        new_caches = {}
        aux_acc = jnp.zeros((2,), jnp.float32)
        for j, pattern in enumerate(patterns):
            p_sub = shared if pattern == "shared_attn" else p_layer[f"sub{j}"]
            c_in = None if cache_layer is None else cache_layer.get(f"sub{j}")
            h, c_out, aux = _apply_sublayer(
                h, p_sub, cfg, pattern, positions, cache=c_in, train=train,
                chunked=chunked)
            aux_acc = aux_acc + aux
            if c_in is not None:
                new_caches[f"sub{j}"] = c_out
        return h, (new_caches if new_caches else None, aux_acc)

    n_rep = jax.tree_util.tree_leaves(group_params)[0].shape[0]
    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if caches is None:
        caches_xs = None
    else:
        caches_xs = caches
    x, (new_caches, aux) = jax.lax.scan(body, x, (group_params, caches_xs))
    return x, new_caches, aux.sum(0)


def sinusoid_pos(s: int, d: int, dtype):
    """Whisper-style sinusoidal positions (S, d)."""
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / max(d // 2 - 1, 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _encoder_forward(params, frames, cfg: ModelConfig, train=False):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    x = frames + sinusoid_pos(frames.shape[1], cfg.d_model, frames.dtype)

    def body(h, p):
        a, _ = attn_block(
            layer_norm(h, p["ln1_g"], p["ln1_b"]), p["attn"], cfg,
            positions=None, pattern="full", window=None, train=train)
        h = h + a
        m = gelu_mlp(layer_norm(h, p["ln2_g"], p["ln2_b"]), p["mlp"],
                     cfg.quant, train)
        return h + m, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layer_norm(x, params["enc_ln_g"], params["enc_ln_b"])


def _cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    B, S, _ = enc_out.shape

    def per_layer(p):
        k = qdot(enc_out, p["attn"]["wk"], cfg.quant).reshape(B, S, KV, dh)
        v = qdot(enc_out, p["attn"]["wv"], cfg.quant).reshape(B, S, KV, dh)
        return k, v

    return jax.vmap(per_layer)(params["cross"])  # (L, B, S, KV, dh) x2


def _decoder_backbone(params, x, cfg: ModelConfig, positions, cross_kv,
                      caches=None, train=False):
    """Whisper decoder: scanned [self-attn, cross-attn, mlp] layers."""
    group = params["groups"][0]

    def body(h, inp):
        p_layer, (ck, cv), cache_layer = inp
        p = p_layer["sub0"]
        c_in = None if cache_layer is None else cache_layer["self"]
        a, c_out = attn_block(
            rms_norm(h, p["ln1"]), p["attn"], cfg, positions,
            pattern="causal", window=None, cache=c_in, train=train)
        h = h + a
        pc = p_layer["cross"]
        a, _ = attn_block(
            layer_norm(h, pc["ln_g"], pc["ln_b"]), pc["attn"], cfg,
            positions=None, pattern="full", window=None,
            kv_override=(ck, cv), train=train)
        h = h + a
        m = swiglu(rms_norm(h, p["ln2"]), p["mlp"], cfg.quant, train)
        new_cache = None if c_in is None else {"self": c_out}
        return h + m, new_cache

    layer_params = {"sub0": group["sub0"], "cross": params["cross"]}
    x, new_caches = jax.lax.scan(body, x, (layer_params, cross_kv, caches))
    return x, new_caches


def _lm_backbone(params, x, cfg: ModelConfig, positions, caches=None,
                 train=False, chunked=False):
    """Run all scanned groups.  caches: list aligned with groups or None."""
    shared = params.get("shared_attn")
    new_caches = []
    aux = jnp.zeros((2,), jnp.float32)
    for gi, group in enumerate(layer_groups(cfg)):
        c_in = None if caches is None else caches[gi]
        x, c_out, a = _scan_group(
            x, params["groups"][gi], cfg, group.patterns, positions,
            shared=shared, caches=c_in, train=train, chunked=chunked)
        new_caches.append(c_out)
        aux = aux + a
    return x, new_caches, aux


def chunked_cross_entropy(hidden, lm_head, labels, cfg: ModelConfig,
                          chunk: int = 1024):
    """Mean CE over valid labels (-1 = ignore), logits in f32, computed in
    sequence chunks so the (B, S, V) logits tensor never materializes."""
    q = cfg.quant
    B, S, d = hidden.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c

    def body(carry, inp):
        tot, cnt = carry
        h_c, y_c = inp                      # (B, c, d), (B, c)
        h_c = _maybe_shard_seq(h_c, dataclasses.replace(
            cfg, seq_shard=False))
        logits = qdot(h_c, lm_head, q).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], -1)[..., 0]
        valid = (y_c >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    hs = hidden.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, n, c).transpose(1, 0, 2)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ys))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, train: bool = True):
    """batch: {"tokens" (B,S), "labels" (B,S)} + family-specific stubs:
    encdec: "frames" (B, S_enc, d); vlm: "patches" (B, P, d)."""
    dtype = model_dtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_lookup(tokens, params["embed"], cfg.quant, train).astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    labels = batch["labels"]

    if cfg.family == "encdec":
        enc = _encoder_forward(params, batch["frames"].astype(dtype), cfg,
                               train)
        x = x + sinusoid_pos(S, cfg.d_model, dtype)
        ck, cv = _cross_kv(params, enc, cfg)
        x, _ = _decoder_backbone(params, x, cfg, None, (ck, cv), train=train)
        aux = jnp.zeros((2,), jnp.float32)
    else:
        if cfg.family == "vlm":
            patches = qdot(batch["patches"].astype(dtype),
                           params["patch_proj"], cfg.quant, train)
            x = jnp.concatenate([patches, x], axis=1)
            P = patches.shape[1]
            S = S + P
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            labels = jnp.concatenate(
                [jnp.full((B, P), -1, labels.dtype), labels], axis=1)
        x, _, aux = _lm_backbone(params, x, cfg, positions, train=train)

    x = rms_norm(x, params["final_norm"])
    ce = chunked_cross_entropy(x, params["lm_head"], labels, cfg,
                               cfg.loss_chunk)
    loss = ce + 0.01 * aux[0] + 1e-3 * aux[1]
    return loss, {"ce": ce, "load_balance": aux[0], "router_z": aux[1]}


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------

def _attn_cache(cfg: ModelConfig, reps, B, max_len, dtype, window=None):
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    buf_len = min(max_len, window) if window else max_len
    if cfg.quant.quantize_kv_cache:
        if cfg.quant.kv_layout == "packed":
            # ONE packed VP word per element (`core.packing`), consumed
            # directly by the decode-attention kernel — no per-step
            # index unpacking, no two-plane reads.
            from repro.core.packing import storage_dtype
            from .attention import kv_cache_formats

            _, vp = kv_cache_formats(cfg.quant)
            wdt = storage_dtype(vp)
            return dict(
                k_w=jnp.zeros((reps, B, buf_len, KV, dh), wdt),
                k_s=jnp.zeros((reps, B, buf_len, 1, 1), jnp.float32),
                v_w=jnp.zeros((reps, B, buf_len, KV, dh), wdt),
                v_s=jnp.zeros((reps, B, buf_len, 1, 1), jnp.float32),
                len=jnp.zeros((reps, B), jnp.int32),
            )
        E = cfg.quant.E
        per = 8 // E if E else 1
        dh_i = dh // per if (E and dh % per == 0) else dh
        return dict(
            k_m=jnp.zeros((reps, B, buf_len, KV, dh), jnp.int8),
            k_i=jnp.zeros((reps, B, buf_len, KV, dh_i), jnp.uint8),
            k_s=jnp.zeros((reps, B, buf_len, 1, 1), jnp.float32),
            v_m=jnp.zeros((reps, B, buf_len, KV, dh), jnp.int8),
            v_i=jnp.zeros((reps, B, buf_len, KV, dh_i), jnp.uint8),
            v_s=jnp.zeros((reps, B, buf_len, 1, 1), jnp.float32),
            len=jnp.zeros((reps, B), jnp.int32),
        )
    return dict(
        k=jnp.zeros((reps, B, buf_len, KV, dh), dtype),
        v=jnp.zeros((reps, B, buf_len, KV, dh), dtype),
        len=jnp.zeros((reps, B), jnp.int32),
    )


def init_cache(cfg: ModelConfig, B: int, max_len: int):
    """Decode caches, stacked per group (leading axis = group repeats)."""
    dtype = model_dtype(cfg)
    d = cfg.d_model
    caches = []
    for group in layer_groups(cfg):
        g = {}
        for j, pattern in enumerate(group.patterns):
            reps = group.repeats
            if pattern == "rwkv":
                H, N = d // RWKV_HEAD, RWKV_HEAD
                g[f"sub{j}"] = dict(
                    s=jnp.zeros((reps, B, H, N, N), jnp.float32),
                    last_tm=jnp.zeros((reps, B, d), dtype),
                    last_cm=jnp.zeros((reps, B, d), dtype),
                )
            elif pattern == "mamba":
                di, n, h, p_, conv_dim, _ = mamba2_dims(cfg)
                g[f"sub{j}"] = dict(
                    h=jnp.zeros((reps, B, h, p_, n), jnp.float32),
                    conv=jnp.zeros((reps, B, D_CONV - 1, conv_dim), dtype),
                )
            else:
                window = (cfg.sliding_window
                          if pattern in ("swa", "moe_swa")
                          else (cfg.local_window
                                if pattern == "local" else None))
                g[f"sub{j}"] = _attn_cache(cfg, reps, B, max_len, dtype,
                                           window)
        caches.append(g)
    if cfg.family == "encdec":
        caches = [{"self": _attn_cache(cfg, cfg.n_layers, B, max_len, dtype)}]
    return caches


def decode_step(params, token, caches, cfg: ModelConfig,
                cross_kv=None):
    """One decode step: token (B, 1) -> (logits (B, V), new caches)."""
    dtype = model_dtype(cfg)
    B = token.shape[0]
    x = embed_lookup(token, params["embed"], cfg.quant).astype(dtype)
    if cfg.family == "encdec":
        from .attention import _cache_buf
        self_c = caches[0]["self"]
        pos_len = self_c["len"][0]                       # (B,)
        max_pos = _cache_buf(self_c).shape[2]
        sin = sinusoid_pos(max_pos, cfg.d_model, dtype)  # (Smax, d)
        x = x + jnp.take(sin, jnp.clip(pos_len, 0, max_pos - 1),
                         axis=0)[:, None]
        x, new_caches = _decoder_backbone(
            params, x, cfg, None, cross_kv, caches=caches[0], train=False)
        new_caches = [new_caches]
    else:
        pos = _decode_positions(caches, cfg)
        x, new_caches, _ = _lm_backbone(params, x, cfg, pos, caches=caches)
    x = rms_norm(x, params["final_norm"])
    logits = qdot(x[:, 0], params["lm_head"], cfg.quant)
    return logits.astype(jnp.float32), new_caches


def _decode_positions(caches, cfg):
    """Current absolute position per batch element from any attn cache."""
    for g in caches:
        if g is None:
            continue
        for sub in g.values():
            if isinstance(sub, dict) and "len" in sub:
                return sub["len"][0][:, None]
    # SSM-only model: positions unused (no rope) — return zeros
    first = jax.tree_util.tree_leaves(caches)[0]
    B = first.shape[1]
    return jnp.zeros((B, 1), jnp.int32)


def prefill(params, tokens, caches, cfg: ModelConfig, patches=None,
            chunked=False):
    """Prefill the caches with a full prompt — ONE batched causal pass.

    Attention layers write all S key/values into their caches; SSM layers
    run the chunked scan and keep the final state.  Returns
    (last-position logits (B, V), filled caches).

    chunked: `tokens` is a prompt CHUNK continuing already-prefilled
    caches (continuous batching) — positions offset by the cache length,
    attention layers append at that offset, SSM states carry forward.
    """
    dtype = model_dtype(cfg)
    B, S = tokens.shape
    if chunked and cfg.family == "encdec":
        raise ValueError("chunked prefill is not supported for encdec")
    x = embed_lookup(tokens, params["embed"], cfg.quant).astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if chunked:
        positions = _decode_positions(caches, cfg) + positions
    if cfg.family == "vlm" and patches is not None:
        pp = qdot(patches.astype(dtype), params["patch_proj"], cfg.quant)
        x = jnp.concatenate([pp, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.family == "encdec":
        # decoder-prompt prefill: frames must already be encoded; the
        # caller passes cross_kv via `patches` (reused slot).
        cross_kv = patches
        x = x + sinusoid_pos(S, cfg.d_model, dtype)
        x, dec_caches = _decoder_backbone(
            params, x, cfg, None, cross_kv, caches=caches[0], train=False)
        x = rms_norm(x, params["final_norm"])
        logits = qdot(x[:, -1], params["lm_head"], cfg.quant)
        return logits.astype(jnp.float32), [dec_caches]
    x, new_caches, _ = _lm_backbone(params, x, cfg, positions, caches=caches,
                                    chunked=chunked)
    x = rms_norm(x, params["final_norm"])
    logits = qdot(x[:, -1], params["lm_head"], cfg.quant)
    return logits.astype(jnp.float32), new_caches


def quantize_params(params, cfg: ModelConfig, layout: str = "packed"):
    """Export-time transform: float weights -> serving representation.

    `layout` (VP modes only) picks the storage the serving path consumes:
    "packed" (default) emits ONE packed VP word per element — the layout
    the Pallas `vp_dequant_matmul` kernel reads directly in `qdot`;
    "planes" emits the legacy two-plane layout dequantized in jnp (the
    golden baseline the cross-arch parity suite pins the kernel against).
    """
    if cfg.quant.mode == "none":
        return params
    QUANT_KEYS = {
        "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_in", "w_out",
        "w_r", "w_k", "w_v", "w_g", "w_o", "w_ck", "w_cv", "w_cr",
        "w_z", "w_x", "w_bc", "w_dt",
        "embed", "lm_head", "patch_proj",
    }

    def qw(w):
        return quantize_weight(w, cfg.quant, layout=layout)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in QUANT_KEYS and isinstance(v, jax.Array):
                    if v.ndim == 2:
                        out[k] = qw(v)
                    elif v.ndim == 3:  # stacked (L or E, d_in, d_out)
                        out[k] = jax.vmap(qw)(v)
                    elif v.ndim == 4:  # layer- AND expert-stacked MoE
                        out[k] = jax.vmap(jax.vmap(qw))(v)
                    else:
                        out[k] = v
                elif isinstance(v, (dict, list)):
                    out[k] = walk(v)
                else:
                    out[k] = v
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)
