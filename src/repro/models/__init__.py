"""Architecture zoo: all assigned model families, VP-quantizable end to end."""
from .model import (
    init_params, loss_fn, prefill, decode_step, init_cache,
    quantize_params, layer_groups, model_dtype,
)
from . import layers, attention, mlp, moe, mamba2, rwkv6, model

__all__ = [
    "init_params", "loss_fn", "prefill", "decode_step", "init_cache",
    "quantize_params", "layer_groups", "model_dtype",
    "layers", "attention", "mlp", "moe", "mamba2", "rwkv6", "model",
]
