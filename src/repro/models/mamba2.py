"""Mamba2 (SSD) layer: chunked state-space-dual scan for training/prefill,
O(1)-state recurrence for decode.

Structure follows the Mamba2 block: fused input projection producing
(z, x, B, C, dt), short causal depthwise conv over (x, B, C), per-head
scalar decay A, SSD with headdim P and state N, skip D, gated RMSNorm,
output projection.  All projections route through `qdot` (VP-quantizable).

The chunked SSD is numerically safe by construction: every exponential is
of a NON-POSITIVE cumulative-decay difference (scalar per-head decay), so
factors live in (0, 1].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import qdot, rms_norm

D_CONV = 4  # short-conv width


def mamba2_dims(cfg: ModelConfig):
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_nheads
    p = cfg.ssm_headdim
    conv_dim = di + 2 * n
    proj_dim = 2 * di + 2 * n + h
    return di, n, h, p, conv_dim, proj_dim


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, C), w (D_CONV, C)."""
    pad = jnp.pad(x, ((0, 0), (D_CONV - 1, 0), (0, 0)))
    out = sum(
        pad[:, t: t + x.shape[1], :] * w[t][None, None, :]
        for t in range(D_CONV))
    return out + b[None, None, :]


def _ssd_chunked(xdt, dA, b, c, chunk: int, h0=None):
    """Chunked SSD scan.

    xdt (B, S, H, P) inputs pre-multiplied by dt; dA (B, S, H) per-head log
    decay increments (<= 0); b/c (B, S, N) (single SSM group).
    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    while S % Q:       # largest divisor of S <= chunk
        Q -= 1
    nc = S // Q

    xdt = xdt.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dA = dA.reshape(B, nc, Q, H).astype(jnp.float32)
    b = b.reshape(B, nc, Q, N).astype(jnp.float32)
    c = c.reshape(B, nc, Q, N).astype(jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def step(h, inp):
        x_c, dA_c, b_c, c_c = inp
        cum = jnp.cumsum(dA_c, axis=1)                      # (B, Q, H)
        # inter-chunk: y1[t] = exp(cum_t) * C_t . h
        y1 = jnp.einsum("bqn,bhpn->bqhp", c_c, h) * jnp.exp(cum)[..., None]
        # intra-chunk
        g = jnp.einsum("bqn,bkn->bqk", c_c, b_c)            # (B, Q, Q)
        ldec = jnp.exp(
            jnp.where(tri[None, :, :, None],
                      cum[:, :, None, :] - cum[:, None, :, :], -jnp.inf))
        y2 = jnp.einsum("bqk,bqkh,bkhp->bqhp", g, ldec, x_c)
        # state update
        dec_rem = jnp.exp(cum[:, -1:, :] - cum)             # (B, Q, H)
        h = (h * jnp.exp(cum[:, -1])[:, :, None, None]
             + jnp.einsum("bqn,bqhp,bqh->bhpn", b_c, x_c, dec_rem))
        return h, y1 + y2

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_fin, ys = jax.lax.scan(
        step, h0,
        (xdt.transpose(1, 0, 2, 3, 4), dA.transpose(1, 0, 2, 3),
         b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, h_fin


def mamba2_block(
    x, params, cfg: ModelConfig,
    state: Optional[dict] = None,
    train: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """x (B, S, d) -> (B, S, d).  `state` (decode): {"h", "conv"}."""
    q = cfg.quant
    B, S, d = x.shape
    di, n, h, p, conv_dim, _ = mamba2_dims(cfg)

    # Separate projections (instead of one fused w_in) so TP sharding of
    # the d_inner dimension never crosses the z/x/B/C/dt boundaries.
    z = qdot(x, params["w_z"], q, train)
    xin = qdot(x, params["w_x"], q, train)
    bc = qdot(x, params["w_bc"], q, train)
    dt = qdot(x, params["w_dt"], q, train)
    b, c = jnp.split(bc, [n], axis=-1)
    xbc = jnp.concatenate([xin, b, c], axis=-1)

    new_state = None
    prefill = state is not None and S > 1
    if state is None or prefill:
        xbc_raw = xbc
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        if prefill:
            tail = xbc_raw[:, -(D_CONV - 1):]
            pad = (D_CONV - 1) - tail.shape[1]
            if pad:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_conv = tail
    else:
        # decode: roll the conv cache (B, D_CONV-1, conv_dim)
        hist = jnp.concatenate([state["conv"], xbc], axis=1)
        xbc = (jnp.einsum(
            "btc,tc->bc", hist, params["conv_w"]) + params["conv_b"])[:, None]
        new_conv = hist[:, 1:]
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, b, c = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"])          # (B, S, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # (H,)
    dA = dt * a                                              # <= 0
    xh = xin.reshape(B, S, h, p).astype(jnp.float32)
    xdt = xh * dt[..., None]

    if state is None or prefill:
        h0 = state["h"] if prefill else None
        y, h_fin = _ssd_chunked(xdt, dA, b, c, cfg.ssm_chunk, h0=h0)
        if prefill:
            new_state = {"h": h_fin, "conv": new_conv}
    else:
        # single-step recurrence
        h_prev = state["h"]
        dec = jnp.exp(dA[:, 0])                              # (B, H)
        h_fin = (h_prev * dec[..., None, None]
                 + jnp.einsum("bn,bhp->bhpn", b[:, 0], xdt[:, 0]))
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0], h_fin)[:, None]
        new_state = {"h": h_fin, "conv": new_conv}

    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["out_norm"])
    return qdot(y, params["w_out"], q, train), new_state
