"""RWKV6 ("Finch") layer: data-dependent per-channel decay linear attention.

TimeMix: token-shift lerp -> R/K/V/G projections + low-rank data-dependent
decay w_t = exp(-exp(w0 + tanh(x W_a) W_b)); per-head WKV recurrence
  S_t = diag(w_t) S_{t-1} + k_t (x) v_t
  y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
computed CHUNKWISE (chunk 16) so all exponentials stay within f32 range:
log-decays are clamped to [-LOG_W_MIN, ~0], so the largest positive
exponent is chunk * LOG_W_MIN = 64 -> exp() ~ 6e27 < f32 max.

ChannelMix: token-shift + squared-ReLU FFN with receptance gate.
All projections route through `qdot` (VP-quantizable).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import qdot, rms_norm

CHUNK = 16
LOG_W_MIN = 4.0  # decay clamp: log w in [-4, -1e-4]
HEAD_DIM = 64


def _token_shift(x, last=None):
    """x_{t-1} stream; `last` (B, 1, d) carries x_{t-1} across steps."""
    if last is not None:
        return jnp.concatenate([last, x[:, :-1]], axis=1)
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _wkv_chunked(r, k, v, logw, u, s0=None):
    """Chunked WKV6.  r/k/v/logw (B, S, H, N); u (H, N).

    Returns (y (B, S, H, N), s_final (B, H, N, N))."""
    B, S, H, N = r.shape
    Q = min(CHUNK, S)
    while S % Q:       # largest divisor of S <= CHUNK
        Q -= 1
    nc = S // Q
    f32 = jnp.float32
    r, k, v, logw = (t.reshape(B, nc, Q, H, N).astype(f32)
                     for t in (r, k, v, logw))
    tri_strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    eye = jnp.eye(Q, dtype=bool)

    def step(s, inp):
        r_c, k_c, v_c, lw_c = inp                     # (B, Q, H, N)
        cum = jnp.cumsum(lw_c, axis=1)                # inclusive
        ecum = cum - lw_c                             # exclusive
        r_dec = r_c * jnp.exp(ecum)                   # bounded <= |r|
        k_grow = k_c * jnp.exp(-cum)                  # bounded by exp(Q*4)
        # inter-chunk: y1[t] = (r_t * exp(ecum_t)) . S
        y1 = jnp.einsum("bqhn,bhnp->bqhp", r_dec, s)
        # intra-chunk: strict-lower attention + diagonal bonus
        att = jnp.einsum("bqhn,bkhn->bqkh", r_dec, k_grow)
        att = jnp.where(tri_strict[None, :, :, None], att, 0.0)
        diag = jnp.einsum("bqhn,bqhn->bqh", r_c * u[None, None], k_c)
        att = att + diag[:, :, None, :] * eye[None, :, :, None]
        y2 = jnp.einsum("bqkh,bkhp->bqhp", att, v_c)
        # state update
        dec_all = jnp.exp(cum[:, -1])                 # (B, H, N)
        k_rem = k_c * jnp.exp(cum[:, -1:] - cum)      # (B, Q, H, N)
        s = (s * dec_all[..., None]
             + jnp.einsum("bqhn,bqhp->bhnp", k_rem, v_c))
        return s, y1 + y2

    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), f32)
    s_fin, ys = jax.lax.scan(
        step, s0, tuple(t.transpose(1, 0, 2, 3, 4) for t in (r, k, v, logw)))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N), s_fin


def rwkv6_time_mix(
    x, params, cfg: ModelConfig,
    state: Optional[dict] = None,
    train: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """x (B, S, d) -> (B, S, d).  state (decode): {"s", "last"}."""
    q = cfg.quant
    B, S, d = x.shape
    H, N = d // HEAD_DIM, HEAD_DIM

    last = state["last_tm"] if state is not None else None
    xx = _token_shift(x, last[:, None] if last is not None else None) - x
    mix = lambda name: x + xx * params[f"mu_{name}"][None, None, :]

    r = qdot(mix("r"), params["w_r"], q, train).reshape(B, S, H, N)
    k = qdot(mix("k"), params["w_k"], q, train).reshape(B, S, H, N)
    v = qdot(mix("v"), params["w_v"], q, train).reshape(B, S, H, N)
    g = qdot(mix("g"), params["w_g"], q, train)
    # data-dependent decay (low-rank)
    wlora = jnp.tanh(mix("w") @ params["w_dec_a"]) @ params["w_dec_b"]
    logw = -jnp.exp(
        params["w_dec0"][None, None, :] + wlora.astype(jnp.float32))
    logw = jnp.clip(logw, -LOG_W_MIN, -1e-4).reshape(B, S, H, N)

    if state is None or S > 1:
        s0 = state["s"] if state is not None else None
        y, s_fin = _wkv_chunked(r, k, v, logw, params["u_bonus"], s0=s0)
        new_state = (None if state is None
                     else {"s": s_fin, "last_tm": x[:, -1]})
    else:
        s_prev = state["s"]
        r1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        lw1 = logw[:, 0]
        y = (jnp.einsum("bhn,bhnp->bhp", r1, s_prev)
             + jnp.einsum("bhn,bhn,bhp->bhp",
                          r1 * params["u_bonus"][None], k1, v1))[:, None]
        s_fin = (s_prev * jnp.exp(lw1)[..., None]
                 + jnp.einsum("bhn,bhp->bhnp", k1, v1))
        new_state = {"s": s_fin, "last_tm": x[:, -1]}

    # per-head groupnorm (normalize each head's N channels), then gate
    y4 = y.reshape(B, S, H, N)
    y4 = rms_norm(y4, params["ln_x"].reshape(H, N))
    y = y4.reshape(B, S, d)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return qdot(y.astype(x.dtype), params["w_o"], q, train), new_state


def rwkv6_channel_mix(
    x, params, cfg: ModelConfig,
    state: Optional[dict] = None,
    train: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    q = cfg.quant
    last = state["last_cm"] if state is not None else None
    xx = _token_shift(x, last[:, None] if last is not None else None) - x
    xk = x + xx * params["mu_ck"][None, None, :]
    xr = x + xx * params["mu_cr"][None, None, :]
    kk = qdot(xk, params["w_ck"], q, train)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    rr = jax.nn.sigmoid(
        qdot(xr, params["w_cr"], q, train).astype(jnp.float32)).astype(x.dtype)
    out = rr * qdot(kk, params["w_cv"], q, train)
    new_state = {"last_cm": x[:, -1]} if state is not None else None
    return out, new_state
