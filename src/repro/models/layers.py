"""Quantization-aware building blocks.

Every weight matmul in every architecture routes through `qdot`, which
dispatches on the model's QuantConfig:

  none      x @ W                          (bf16/f32 baseline)
  fxp       x @ (int8 W * 2^-F)            (plain fixed-point baseline)
  vp        vp_dequant_matmul(x, Wpacked)  (paper-faithful, kernel-backed:
                                            ONE packed VP word per weight
                                            in the param pytree, consumed
                                            directly by the Pallas kernel —
                                            unpack + pow2 scale in-tile, no
                                            f32 weight matrix in HBM.  The
                                            legacy layout="planes" two-plane
                                            jnp-dequant path is kept as the
                                            golden parity baseline.)
  vp_block  block_vp_matmul(xq, Wq)        (beyond-paper: int8 MXU matmuls,
                                            LUT scales; activations are
                                            dynamically block-VP quantized;
                                            non-tileable weights fall back
                                            to per-element packed VP)

Training uses float master weights with an STE fake-quant (QAT); the
quantized representations are produced by `quantize_params` at
serving/checkpoint-export time.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import (
    FXPFormat, default_vp_format, vp_fake_quant_ste, block_vp_quantize, block_vp_dequantize,
)
from repro.core.packing import dequant_words
from repro.core.vp_tensor import pack_indices, unpack_indices
from repro.configs.base import QuantConfig
from repro.kernels import ops as kops


# Canonical quantization grid: weights are pre-normalized to (-1, 1) by a
# power-of-two per-tensor scale, then quantized on this fixed grid.  Static
# formats keep VP semantics exact and jit-friendly.
def canonical_formats(q: QuantConfig):
    fxp = FXPFormat(q.W, q.W - 1)
    vp = default_vp_format(fxp, q.M, q.E)
    return fxp, vp


def _pow2_scale(w) -> jax.Array:
    """Smallest power of two >= max|w| (keeps normalized w in (-1, 1)).

    An all-zero tensor has no magnitude to normalize: the clamp floor
    used to leak through the log2 and produce a spurious ~2^-100 scale
    (harmless numerically — 0/s is still 0 — but it poisoned recorded
    scales and divided activations by a denormal-adjacent constant).
    Zero tensors get scale 1.0 (still a power of two, still exact).
    """
    amax = jnp.max(jnp.abs(w))
    s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))))
    return jnp.where(amax > 0, s, 1.0)


# ---------------------------------------------------------------------------
# Weight quantization (export-time transform)
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array, q: QuantConfig,
                    layout: str = "packed") -> Dict[str, jax.Array]:
    """Convert a float weight matrix (d_in, d_out) to its serving form.

    `layout` selects the VP storage the serving path consumes:
      "packed"  one packed VP word per element (`core.packing`,
                `vp.storage_bits` bits/param) — the layout the Pallas
                `vp_dequant_matmul` kernel reads directly; the DEFAULT.
      "planes"  the legacy two-plane layout (int8 significand + bit-packed
                index plane), dequantized in jnp — kept as the golden
                baseline the parity suite pins the kernel path against.
    """
    fxp, vp = canonical_formats(q)
    if q.mode == "none":
        return {"w": w}
    s = _pow2_scale(w)
    wn = w / s
    if q.mode == "fxp":
        m = jnp.clip(jnp.round(wn * 127.0), -128, 127).astype(jnp.int8)
        return {"m": m, "scale": (s / 127.0).astype(jnp.float32)}
    if q.mode == "vp":
        if layout == "packed":
            wp = kops.vp_quant(wn.astype(jnp.float32), fxp, vp, packed=True)
            return {"w_packed": wp, "scale": s.astype(jnp.float32)}
        m, i = kops.vp_quant(wn.astype(jnp.float32), fxp, vp)
        d_in = w.shape[0]
        pad = (-d_in) % (8 // vp.E) if vp.E else 0
        if pad:
            i = jnp.pad(i, ((0, pad),) + ((0, 0),) * (w.ndim - 1))
        ip = pack_indices(jnp.moveaxis(i, 0, -1), vp.E)
        return {
            "m": m,
            "i_packed": jnp.moveaxis(ip, -1, 0),
            "scale": s.astype(jnp.float32),
        }
    if q.mode == "vp_block":
        if w.shape[0] % q.block:
            # contraction dim not tileable (e.g. embedding tables indexed
            # by vocab): fall back to per-element VP
            return quantize_weight(
                w, dataclasses_replace_mode(q, "vp"), layout=layout)
        m, i_blk = block_vp_quantize(
            wn.astype(jnp.float32), fxp, vp, block=q.block, axis=0)
        return {"m": m, "i_blk": i_blk, "scale": s.astype(jnp.float32)}
    raise ValueError(q.mode)


def dataclasses_replace_mode(q: QuantConfig, mode: str) -> QuantConfig:
    import dataclasses

    return dataclasses.replace(q, mode=mode)


# ---------------------------------------------------------------------------
# The quantization-aware matmul
# ---------------------------------------------------------------------------

def _dequant_vp_weight(wq: Dict[str, jax.Array], q: QuantConfig, dtype):
    fxp, vp = canonical_formats(q)
    m = wq["m"]
    d_in = m.shape[0]
    per = 8 // vp.E if vp.E else 1
    ip = jnp.moveaxis(wq["i_packed"], 0, -1)
    i = unpack_indices(ip, vp.E, ip.shape[-1] * per)
    i = jnp.moveaxis(i, -1, 0)[:d_in]
    scales = jnp.asarray([2.0 ** (-fk) for fk in vp.f], dtype)
    return m.astype(dtype) * scales[i.astype(jnp.int32)] * wq["scale"].astype(dtype)


def _dequant_vp_packed(w_packed: jax.Array, scale, q: QuantConfig, dtype):
    """Packed VP words -> real weights (jnp; for gather-style consumers).

    The matmul path never calls this — `qdot` hands the packed words to
    the kernel op — but embedding lookups and stacked-expert einsums need
    real values; `core.packing.dequant_words` picks the offline word-LUT
    gather (or shift+mask for wide formats), bit-identical either way."""
    _, vp = canonical_formats(q)
    return dequant_words(w_packed, vp, dtype) * jnp.asarray(scale, dtype)


def qdot(x: jax.Array, wq: Any, q: QuantConfig,
         train: bool = False) -> jax.Array:
    """x (..., d_in) @ W (d_in, d_out) under the quantization mode.

    `wq` is a float array (training / mode none) or the dict produced by
    `quantize_weight` (serving).

    Under shard_map with `q.tp_axis` set, quantized weight dicts are the
    tensor-parallel LAST-DIM shards placed by
    `parallel.shard_ops.shard_param_specs`: the local matmul produces the
    local output columns and the full activation is reassembled by an
    all-gather — a pure column concatenation, so the result is bit-exact
    against the unsharded matmul (each column block sees the identical
    contraction order).  Float weights (mode none / the router) are
    replicated and need no collective.
    """
    out = _qdot_local(x, wq, q, train)
    if q.tp_axis is not None and isinstance(wq, dict):
        out = jax.lax.all_gather(out, q.tp_axis, axis=out.ndim - 1,
                                 tiled=True)
    return out


def _qdot_local(x: jax.Array, wq: Any, q: QuantConfig,
                train: bool = False) -> jax.Array:
    dtype = x.dtype
    if isinstance(wq, jax.Array) or not isinstance(wq, dict):
        w = wq
        if train and q.mode in ("vp", "vp_block"):
            fxp, vp = canonical_formats(q)
            s = _pow2_scale(jax.lax.stop_gradient(w))
            if q.qat_mode == "packed" and w.ndim == 2:
                # Packed QAT: quantize the float master to packed words
                # and run the packed serving kernel forward AND backward
                # (custom VJP: dx by the transposed packed-word kernel,
                # dW = x^T g under STE) — training numerics == serving.
                # The pow2 scale commutes exactly with the contraction.
                lead = x.shape[:-1]
                x2 = x.reshape(-1, x.shape[-1]).astype(dtype)
                out = kops.vp_qat_matmul(x2, w / s, fxp, vp)
                out = out.astype(dtype) * s.astype(dtype)
                return out.reshape(*lead, -1)
            w = vp_fake_quant_ste(w / s, fxp, vp) * s
        return jnp.dot(x, w.astype(dtype))
    if q.mode == "none":
        return jnp.dot(x, wq["w"].astype(dtype))
    if q.mode == "fxp":
        w = wq["m"].astype(dtype) * wq["scale"].astype(dtype)
        return jnp.dot(x, w)
    if q.mode in ("vp", "vp_block") and (
            "w_packed" in wq or "i_packed" in wq):
        # Per-element VP serving: the "vp" mode proper, or a "vp_block"
        # weight whose contraction dim was not block-tileable (the
        # quantize_weight fallback).  Dispatch is on the dict KEYS.
        if "w_packed" in wq:
            # Kernel-backed path: the packed words go straight to the
            # Pallas kernel (unpack + bit-assembled scale in-tile); the
            # per-tensor pow2 scale commutes exactly with the contraction.
            _, vp = canonical_formats(q)
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            out = kops.vp_dequant_matmul(
                x2, wq["w_packed"], vp, out_dtype=dtype)
            out = out * wq["scale"].astype(dtype)
            return out.reshape(*lead, -1)
        w = _dequant_vp_weight(wq, q, dtype)
        return jnp.dot(x, w)
    if q.mode == "vp_block":
        fxp, vp = canonical_formats(q)
        lead = x.shape[:-1]
        d_in = x.shape[-1]
        x2 = x.reshape(-1, d_in).astype(jnp.float32)
        # Dynamic per-tensor pow2 scale for activations, then block-VP.
        sa = _pow2_scale(jax.lax.stop_gradient(x2))
        a_m, a_i = block_vp_quantize(x2 / sa, fxp, vp, block=q.block, axis=-1)
        out = kops.block_vp_matmul(
            a_m, a_i, wq["m"], wq["i_blk"], vp, vp, bk=q.block,
            blocks=None)
        out = out * (sa * wq["scale"]).astype(out.dtype)
        return out.reshape(*lead, -1).astype(dtype)
    raise ValueError(q.mode)


def qdense(x, params: Dict[str, Any], q: QuantConfig, train: bool = False):
    """Dense layer: params = {"w": array-or-quantdict, "b": optional}."""
    y = qdot(x, params["w"], q, train)
    if params.get("b") is not None:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms / positional encodings / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """Rotary embedding: x (..., S, H, dh), positions (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions (..., S) -> angles (..., S, 1, half) broadcasting over heads
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(tokens, table, q: QuantConfig, train: bool = False):
    """Token embedding; table may be quantized like any other weight.

    Dispatches on the dict KEYS (a vp_block model may carry a per-element
    VP embedding when the vocab is not tileable).

    Under `q.tp_axis` a quantized table is sharded along d_model (its
    last dim); the row gather + dequant run on the local columns and the
    embedding reassembles by all-gather (bit-exact concatenation)."""
    if isinstance(table, dict) and q.tp_axis is not None:
        out = _embed_local(tokens, table, q)
        return jax.lax.all_gather(out, q.tp_axis, axis=out.ndim - 1,
                                  tiled=True)
    return _embed_local(tokens, table, q)


def _embed_local(tokens, table, q: QuantConfig):
    if isinstance(table, dict):
        if "w_packed" in table:
            # Gather the PACKED rows first, then dequantize just those:
            # O(tokens * d) unpack work instead of O(vocab * d) — the
            # packed layout makes the embedding the cheapest quant path.
            rows = jnp.take(table["w_packed"], tokens, axis=0)
            return _dequant_vp_packed(rows, table["scale"], q, jnp.float32)
        if "i_packed" in table:
            w = _dequant_vp_weight(table, q, jnp.float32)
        elif "i_blk" in table:
            _, vp = canonical_formats(q)
            w = block_vp_dequantize(
                table["m"], table["i_blk"], vp, q.block, axis=0,
                dtype=jnp.float32) * table["scale"]
        else:
            w = table["m"].astype(jnp.float32) * table["scale"]
        return jnp.take(w, tokens, axis=0)
    return jnp.take(table, tokens, axis=0)
