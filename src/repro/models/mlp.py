"""Feed-forward blocks: SwiGLU (LM default) and GELU (whisper-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from .layers import qdot


def swiglu(x, params, q: QuantConfig, train: bool = False):
    """params: w_gate (d, ff), w_up (d, ff), w_down (ff, d)."""
    g = qdot(x, params["w_gate"], q, train)
    u = qdot(x, params["w_up"], q, train)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return qdot(h, params["w_down"], q, train)


def gelu_mlp(x, params, q: QuantConfig, train: bool = False):
    """params: w_in (d, ff), b_in, w_out (ff, d), b_out."""
    h = qdot(x, params["w_in"], q, train) + params["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qdot(h, params["w_out"], q, train) + params["b_out"].astype(x.dtype)
