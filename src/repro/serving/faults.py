"""Deterministic fault injection (chaos harness) for the serving engine.

Production traffic punishes an engine in ways a clean benchmark never
does: a request quantizes to the edge of its VP format and emits NaN
logits, an HBM word takes a bit flip, a co-tenant grabs the page pool,
a device enqueue transiently fails, a step straggles.  The paper's whole
premise is operating near the edge of a format's dynamic range, so
overflow/NaN escapes from the packed path are an *expected operating
condition* — this module makes every such condition reproducible.

A `FaultPlan` is a list of fault events the engine consults at fixed
hook points.  Every event is host-side and deterministic (keyed on
request ids, token counts, and the injected clock), so a chaos run
replays identically and the chaos suite can assert bit-identical tokens
for every UNAFFECTED request against the fault-free run.

Fault classes:

  * `LogitPoison`     — overwrite one request's host-side logits with
                        NaN/Inf after the jitted step returns.  The
                        device computation is untouched, so co-resident
                        slots stay bit-identical; the engine's per-slot
                        finite check then quarantines only the victim.
  * `KVBitFlip`       — XOR one bit of one packed KV word inside a page
                        OWNED by the victim request (via
                        `kernels.paged.flip_bit`).  Silent corruption:
                        VP dequant of any word pattern is finite, so no
                        check fires — the chaos suite instead proves the
                        corruption never escapes the owning request's
                        pages.
  * `PagePressure`    — temporarily withhold free pages from the
                        allocator (an HBM co-tenant spike): admissions
                        back up, the bounded submit queue sheds.
  * `TransientFault`  — fail a prefill/decode dispatch before it runs
                        (`TransientComputeError`); the engine retries
                        with backoff charged to the clock.
  * `SlowStep`        — charge extra seconds to the virtual clock at a
                        chosen time (a straggling step); deadlines and
                        SLOs must keep being honored.

Counters for everything injected land on `engine.stats`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class TransientComputeError(RuntimeError):
    """A dispatch failed transiently; the caller may retry."""


@dataclasses.dataclass(frozen=True)
class LogitPoison:
    """Poison request `rid`'s logits once it has `after_tokens` tokens.

    `phase` selects the hook ("prefill" fires on the unit that completes
    the prompt; "decode" on decode steps).  `times` bounds how many
    engine passes get poisoned — a retried request sails through once
    the budget is spent, which is how retry-then-succeed scenarios are
    scripted.
    """
    rid: int
    phase: str = "decode"           # "prefill" | "decode"
    after_tokens: int = 0
    value: float = math.nan
    times: int = 1_000_000          # effectively "always"


@dataclasses.dataclass(frozen=True)
class KVBitFlip:
    """Flip `bit` of the word at (`page_index`, `offset`) of `rid`'s
    pages, in pool buffer `buf` (default: first pooled buffer), once the
    request's prompt is committed."""
    rid: int
    page_index: int = 0
    offset: int = 0
    bit: int = 0
    buf: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class PagePressure:
    """Withhold up to `pages` free pages during [`at`, `release`)."""
    at: float
    release: float
    pages: int


@dataclasses.dataclass(frozen=True)
class TransientFault:
    """Fail the next `times` dispatches of `kind` ("prefill" targets
    request `rid`; "decode" fails the whole batched step — rid ignored)."""
    kind: str = "decode"            # "prefill" | "decode"
    rid: Optional[int] = None
    times: int = 1


@dataclasses.dataclass(frozen=True)
class SlowStep:
    """Charge `extra_s` virtual seconds at the first step with
    `now >= at` (a straggling dispatch / preemption by a co-tenant)."""
    at: float
    extra_s: float


class FaultPlan:
    """A deterministic schedule of fault events, consumed by the engine.

    Construction takes any mix of the event dataclasses above.  The plan
    carries its own mutable consumption state; `reset()` rearms every
    event for a fresh wave.
    """

    def __init__(self, events: Sequence = ()):
        self.poisons: List[LogitPoison] = []
        self.flips: List[KVBitFlip] = []
        self.pressure: List[PagePressure] = []
        self.transients: List[TransientFault] = []
        self.slow: List[SlowStep] = []
        for ev in events:
            if isinstance(ev, LogitPoison):
                self.poisons.append(ev)
            elif isinstance(ev, KVBitFlip):
                self.flips.append(ev)
            elif isinstance(ev, PagePressure):
                self.pressure.append(ev)
            elif isinstance(ev, TransientFault):
                self.transients.append(ev)
            elif isinstance(ev, SlowStep):
                self.slow.append(ev)
            else:
                raise TypeError(f"unknown fault event {ev!r}")
        self.reset()

    def reset(self) -> None:
        """Rearm every event (held pages must have been released —
        i.e. call between engine waves, not mid-run)."""
        self._poison_used: Dict[int, int] = {}
        self._flip_done: set = set()
        self._transient_used: Dict[int, int] = {}
        self._slow_done: set = set()
        # id -> (spec, held page ids); pages outstanding only mid-spike
        self._held: Dict[int, Tuple[PagePressure, List[int]]] = {}

    # -- engine hook: once per engine iteration -----------------------------

    def on_step(self, engine) -> None:
        """Advance time-keyed faults: engage/release page-pressure
        spikes and charge slow-step stalls."""
        now = engine.clock.now()
        for i, spec in enumerate(self.slow):
            if i not in self._slow_done and now >= spec.at:
                self._slow_done.add(i)
                if hasattr(engine.clock, "tick"):
                    engine.clock.tick(spec.extra_s)
                else:  # wall clock: model the stall as a sleep-through
                    engine.clock.wait_until(now + spec.extra_s)
                engine.stats["fault_slow_steps"] += 1
        for i, spec in enumerate(self.pressure):
            held = self._held.get(i)
            if held is None and now >= spec.at and now < spec.release:
                pages = engine.kv.reserve_pages(spec.pages)
                self._held[i] = (spec, pages)
                engine.stats["fault_page_spikes"] += 1
            elif held is not None and now >= spec.release:
                engine.kv.release_pages(held[1])
                self._held[i] = (spec, [])
                if not held[1]:
                    pass  # already drained
        # fully-released spikes keep an empty entry so they never rearm

    def next_event(self, now: float) -> Optional[float]:
        """Earliest future time a time-keyed fault changes state — the
        engine waits for this when otherwise stalled (e.g. a spike holds
        every page the waiting request needs)."""
        times = []
        for i, spec in enumerate(self.pressure):
            held = self._held.get(i)
            if held is None and spec.at > now:
                times.append(spec.at)
            elif held is not None and held[1] and spec.release > now:
                times.append(spec.release)
        for i, spec in enumerate(self.slow):
            if i not in self._slow_done and spec.at > now:
                times.append(spec.at)
        return min(times) if times else None

    def release_all(self, engine) -> None:
        """Return any still-held pages (end-of-run conservation)."""
        for i, (spec, pages) in list(self._held.items()):
            if pages:
                engine.kv.release_pages(pages)
                self._held[i] = (spec, [])

    # -- engine hook: dispatch failures -------------------------------------

    def take_transient(self, kind: str, rid: Optional[int]) -> bool:
        """True if this dispatch should fail (consumes one failure)."""
        for i, spec in enumerate(self.transients):
            if spec.kind != kind:
                continue
            if kind == "prefill" and spec.rid is not None and spec.rid != rid:
                continue
            used = self._transient_used.get(i, 0)
            if used < spec.times:
                self._transient_used[i] = used + 1
                return True
        return False

    # -- engine hook: host-side logit poisoning -----------------------------

    def poison(self, phase: str, rid: int, n_tokens: int,
               logits: np.ndarray) -> Optional[np.ndarray]:
        """Poisoned copy of `logits` if an event matches, else None.

        Host-side only: the device computation (and every other slot's
        logits) is untouched.
        """
        for i, spec in enumerate(self.poisons):
            if spec.rid != rid or spec.phase != phase:
                continue
            if n_tokens < spec.after_tokens:
                continue
            used = self._poison_used.get(i, 0)
            if used >= spec.times:
                continue
            self._poison_used[i] = used + 1
            out = np.array(logits, copy=True)
            out.flat[0] = spec.value
            return out
        return None

    # -- engine hook: cache corruption --------------------------------------

    def kv_flips(self, rid: int) -> List[KVBitFlip]:
        """Un-consumed bit flips targeting `rid` (consumed once each)."""
        out = []
        for i, spec in enumerate(self.flips):
            if spec.rid == rid and i not in self._flip_done:
                self._flip_done.add(i)
                out.append(spec)
        return out

    @property
    def holding_pages(self) -> int:
        return sum(len(p) for _, p in self._held.values())
