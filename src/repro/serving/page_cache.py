"""Paged packed-KV cache: page pools, block tables, free-list admission.

The static driver's `init_cache(cfg, B, max_len)` allocates every
request a contiguous `(B, max_len)` cache slice for its whole lifetime —
admission means re-allocating (and copying) the batch.  This module
replaces the SEQUENCE axis of every full-causal attention cache with a
pool of fixed-size pages plus one per-slot block table:

    pool      (reps, n_pages, page_size, *tail)   per cache buffer
    block_table (max_slots, pages_per_slot) int32  SHARED by all pools
    lengths   (max_slots,) int32                   valid span per slot

One free list allocates PAGE GROUPS: page id `p` addresses the p-th page
of every pool simultaneously (all attention layers advance in lockstep,
so one block-table row serves the whole model — the vLLM block-table
layout).  Admission pops `ceil((prompt+gen)/page_size)` ids; eviction
pushes them back.  The packed VP words inside pages are never copied or
dequantized by either operation.

What stays DENSE (per-slot rows, not pages):

  * rolling / sliding-window ring buffers — their size is bounded by the
    window, so paging buys nothing, and the ring arithmetic
    (`len % smax`) needs a contiguous buffer;
  * SSM states (mamba h/conv, rwkv s/last) — fixed-size per slot.

Page 0 is reserved as the dummy page (masked writes land there, nothing
reads it); the free list hands out ids 1..n_pages-1.  `n_pages` is sized
from the HBM byte budget when given, so "how many requests fit" is a
byte question answered at construction, not an OOM at admission.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import paged
from repro.models import init_cache, layer_groups

# Buffer kinds -------------------------------------------------------------
PAGED = "paged"      # full-causal attention cache: seq axis -> pages
DENSE = "dense"      # rolling/SWA ring buffer: per-slot dense rows
STATE = "state"      # SSM state: per-slot, no seq axis


@dataclasses.dataclass(frozen=True)
class SubSpec:
    """Static plan of one sub-layer's cache storage."""
    gi: int                 # layer-group index
    sub: str                # key inside the group dict ("sub0", ...)
    pattern: str
    kind: str               # PAGED | DENSE | STATE
    window: Optional[int]
    buf_len: int            # seq-buffer length (0 for STATE)
    reps: int
    # (name, tail_shape, dtype) per buffer; tail = dims after the seq
    # axis (PAGED/DENSE) or after the slot axis (STATE).  "len" excluded.
    bufs: Tuple[Tuple[str, Tuple[int, ...], Any], ...]

    @property
    def has_len(self) -> bool:
        return self.kind in (PAGED, DENSE)


def _pattern_window(cfg: ModelConfig, pattern: str) -> Optional[int]:
    if pattern in ("swa", "moe_swa"):
        return cfg.sliding_window
    if pattern == "local":
        return cfg.local_window
    return None


def plan_cache(cfg: ModelConfig, capacity: int) -> List[SubSpec]:
    """Classify every sub-layer cache: paged, dense ring, or SSM state.

    Uses `init_cache` itself (via eval_shape — no allocation) as the
    single source of truth for buffer names/shapes/dtypes, so any cache
    layout the model zoo grows is picked up without touching this file.
    """
    if cfg.family == "encdec":
        raise ValueError(
            "paged serving does not support encoder-decoder models (the "
            "cross-attention source is request-specific; use the static "
            "driver)")
    tmpl = jax.eval_shape(lambda: init_cache(cfg, 1, capacity))
    specs: List[SubSpec] = []
    for gi, group in enumerate(layer_groups(cfg)):
        for j, pattern in enumerate(group.patterns):
            sub = f"sub{j}"
            entry = tmpl[gi][sub]
            if pattern in ("mamba", "rwkv"):
                kind, window, buf_len = STATE, None, 0
                bufs = tuple(
                    (name, tuple(a.shape[2:]), a.dtype)
                    for name, a in sorted(entry.items()))
            else:
                window = _pattern_window(cfg, pattern)
                # A windowed buffer is a rolling ring (buf_len <= window
                # always holds — see `_attn_cache`): keep it dense.
                kind = DENSE if window is not None else PAGED
                names = sorted(n for n in entry if n != "len")
                buf_len = int(entry[names[0]].shape[2])
                bufs = tuple(
                    (name, tuple(entry[name].shape[3:]), entry[name].dtype)
                    for name in names)
            specs.append(SubSpec(
                gi=gi, sub=sub, pattern=pattern, kind=kind, window=window,
                buf_len=buf_len, reps=group.repeats, bufs=bufs))
    return specs


def buf_key(spec: SubSpec, name: str) -> str:
    return f"g{spec.gi}.{spec.sub}.{name}"


def page_group_bytes(specs: List[SubSpec], page_size: int) -> int:
    """HBM bytes one page id costs across ALL pools (the admission unit)."""
    total = 0
    for spec in specs:
        if spec.kind != PAGED:
            continue
        for _, tail, dtype in spec.bufs:
            total += spec.reps * page_size * int(np.prod(tail, dtype=np.int64)
                                                 or 1) * np.dtype(dtype).itemsize
    return int(total)


class PagedKVCache:
    """Page pools + block table + free list for one serving engine.

    Device state (updated functionally by the runner's jitted calls):
      pools        {buf_key: (reps, n_pages, page_size, *tail)}
      dense        {buf_key: (reps, max_slots, ...)}  ring buffers + states
      block_table  (max_slots, pages_per_slot) int32
      lengths      (max_slots,) int32

    Host state: the free-page list and per-slot page ownership.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, capacity: int,
                 page_size: int, n_pages: Optional[int] = None,
                 hbm_budget_bytes: Optional[int] = None):
        if capacity % page_size:
            raise ValueError(
                f"capacity {capacity} must be a multiple of page_size "
                f"{page_size}")
        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.capacity = int(capacity)
        self.page_size = int(page_size)
        self.pages_per_slot = capacity // page_size
        self.specs = plan_cache(cfg, capacity)
        self.group_count = len(layer_groups(cfg))
        self.bytes_per_page = page_group_bytes(self.specs, page_size)

        want = 1 + self.max_slots * self.pages_per_slot  # fully committed
        if n_pages is None:
            n_pages = want
            if hbm_budget_bytes is not None and self.bytes_per_page:
                n_pages = min(
                    n_pages, 1 + hbm_budget_bytes // self.bytes_per_page)
        if self.has_paged and n_pages < 1 + self.pages_per_slot:
            raise ValueError(
                f"page budget too small: {n_pages} pages "
                f"({self.bytes_per_page} B each) cannot hold even one "
                f"request of {self.pages_per_slot} pages + the dummy page")
        self.n_pages = int(n_pages)

        self.pools: Dict[str, jax.Array] = {}
        self.dense: Dict[str, jax.Array] = {}
        for spec in self.specs:
            for name, tail, dtype in spec.bufs:
                k = buf_key(spec, name)
                if spec.kind == PAGED:
                    self.pools[k] = jnp.zeros(
                        (spec.reps, self.n_pages, page_size) + tail, dtype)
                elif spec.kind == DENSE:
                    self.dense[k] = jnp.zeros(
                        (spec.reps, max_slots, spec.buf_len) + tail, dtype)
                else:
                    self.dense[k] = jnp.zeros(
                        (spec.reps, max_slots) + tail, dtype)
        self.block_table = jnp.zeros(
            (max_slots, self.pages_per_slot), jnp.int32)
        self.lengths = jnp.zeros((max_slots,), jnp.int32)

        # Host-side allocator: LIFO free list over page ids 1..n_pages-1.
        self.free_pages: List[int] = list(range(self.n_pages - 1, 0, -1))
        self.slot_pages: Dict[int, List[int]] = {}
        self.free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._reserved: set = set()   # withheld by reserve_pages

    # -- capacity queries ---------------------------------------------------

    @property
    def has_paged(self) -> bool:
        return any(s.kind == PAGED for s in self.specs)

    def pages_needed(self, total_len: int) -> int:
        if not self.has_paged:
            return 0
        return math.ceil(total_len / self.page_size)

    def can_admit(self, total_len: int) -> bool:
        if total_len > self.capacity:
            raise ValueError(
                f"request needs {total_len} positions > engine capacity "
                f"{self.capacity}")
        return bool(self.free_slots) \
            and self.pages_needed(total_len) <= len(self.free_pages)

    def hbm_bytes(self) -> int:
        """Bytes of pool + dense cache storage actually allocated."""
        return int(sum(
            a.size * a.dtype.itemsize
            for a in list(self.pools.values()) + list(self.dense.values())))

    # -- admission / eviction ----------------------------------------------

    def alloc(self, total_len: int) -> int:
        """Claim a slot + pages for a request of `total_len` positions.

        Returns the slot id.  The slot's dense rows are zeroed (a fresh
        request must not see the previous tenant's ring/SSM state); its
        PAGES are handed over as-is — page contents are garbage until
        written, and every read is masked by `lengths`, which the
        no-aliasing property tests pin by poisoning free pages.
        """
        n = self.pages_needed(total_len)
        if not self.free_slots or n > len(self.free_pages):
            raise RuntimeError("alloc called without can_admit")
        slot = self.free_slots.pop()
        pages = [self.free_pages.pop() for _ in range(n)]
        self.slot_pages[slot] = pages
        row = np.zeros((self.pages_per_slot,), np.int32)
        row[:n] = pages
        self.block_table = self.block_table.at[slot].set(jnp.asarray(row))
        self.lengths = self.lengths.at[slot].set(0)
        for spec in self.specs:
            if spec.kind == PAGED:
                continue
            for name, _, _ in spec.bufs:
                k = buf_key(spec, name)
                self.dense[k] = self.dense[k].at[:, slot].set(0)
        return slot

    def free(self, slot: int) -> None:
        """Evict a request: return its pages to the free list.

        Metadata-only — no page contents move.  The block-table row is
        zeroed (points at the dummy page) so a stale row can never alias
        a page's next owner.
        """
        pages = self.slot_pages.pop(slot, [])
        self.free_pages.extend(reversed(pages))
        self.free_slots.append(slot)
        self.block_table = self.block_table.at[slot].set(0)
        self.lengths = self.lengths.at[slot].set(0)

    # -- external pressure (fault injection / co-tenant reservations) -------

    def reserve_pages(self, n: int) -> List[int]:
        """Withhold up to `n` free pages from the allocator (an HBM
        pressure spike: admissions back up while the reservation holds).
        Returns the withheld ids — the caller MUST hand them back to
        `release_pages` unchanged; conservation is checked there."""
        take = min(int(n), len(self.free_pages))
        held = [self.free_pages.pop() for _ in range(take)]
        self._reserved.update(held)
        return held

    def release_pages(self, pages: List[int]) -> None:
        """Return pages withheld by `reserve_pages`."""
        for p in pages:
            if p not in self._reserved:
                raise ValueError(f"page {p} was not reserved")
            self._reserved.discard(p)
        self.free_pages.extend(reversed(pages))

    # -- invariants (chaos-suite assertions) --------------------------------

    def check_conservation(self) -> None:
        """Every page id 1..n_pages-1 is free, reserved, or owned by
        exactly one slot — raises on any leak, double-free or aliasing.
        Quarantine, preemption, timeout and shed paths all promise this.
        """
        free = list(self.free_pages)
        if len(set(free)) != len(free):
            raise AssertionError("duplicate ids on the free list")
        owned: Dict[int, int] = {}
        for slot, pages in self.slot_pages.items():
            for p in pages:
                if p in owned:
                    raise AssertionError(
                        f"page {p} owned by slots {owned[p]} and {slot}")
                owned[p] = slot
        seen = set(free) | set(owned) | set(self._reserved)
        want = set(range(1, self.n_pages))
        if seen != want:
            leaked = sorted(want - seen)
            extra = sorted(seen - want)
            raise AssertionError(
                f"free-list conservation violated: leaked={leaked} "
                f"extra={extra}")
        if 0 in seen:
            raise AssertionError("dummy page 0 entered circulation")

    def page0_fingerprint(self) -> Dict[str, bytes]:
        """Host bytes of page 0 in every pool — nothing may ever READ
        page 0, and outside masked dummy writes nothing meaningful may
        depend on it; chaos tests snapshot it around faulted runs."""
        return {k: np.asarray(pool[:, 0]).tobytes()
                for k, pool in self.pools.items()}

    # -- debug/test helpers -------------------------------------------------

    def gather_slot(self, key: str, slot: int) -> jax.Array:
        """One slot's contiguous view of one pooled buffer (tests)."""
        bt = self.block_table[slot][None]
        return paged.gather_pages(self.pools[key], bt)[:, 0]
