"""Continuous-batching VP serving: paged cache, scheduler, runner,
engine, plus the PR-10 resilience layer (fault injection, SLO classes,
graceful degradation)."""
from .page_cache import PagedKVCache, SubSpec, plan_cache, page_group_bytes
from .scheduler import Request, RunningRequest, Scheduler, SLOClass, \
    SLO_CLASSES, VirtualClock, WallClock
from .runner import ModelRunner, oracle_generate, supports_chunked
from .engine import ServingEngine
from .faults import FaultPlan, KVBitFlip, LogitPoison, PagePressure, \
    SlowStep, TransientComputeError, TransientFault

__all__ = [
    "PagedKVCache", "SubSpec", "plan_cache", "page_group_bytes",
    "Request", "RunningRequest", "Scheduler", "SLOClass", "SLO_CLASSES",
    "VirtualClock", "WallClock",
    "ModelRunner", "oracle_generate", "supports_chunked", "ServingEngine",
    "FaultPlan", "KVBitFlip", "LogitPoison", "PagePressure", "SlowStep",
    "TransientComputeError", "TransientFault",
]
