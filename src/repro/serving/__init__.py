"""Continuous-batching VP serving: paged cache, scheduler, runner, engine."""
from .page_cache import PagedKVCache, SubSpec, plan_cache, page_group_bytes
from .scheduler import Request, RunningRequest, Scheduler, VirtualClock, \
    WallClock
from .runner import ModelRunner, supports_chunked
from .engine import ServingEngine

__all__ = [
    "PagedKVCache", "SubSpec", "plan_cache", "page_group_bytes",
    "Request", "RunningRequest", "Scheduler", "VirtualClock", "WallClock",
    "ModelRunner", "supports_chunked", "ServingEngine",
]
