"""Request lifecycle + admission scheduling for the paged engine.

The scheduler is deliberately host-side and deterministic: given the
same arrival trace it makes the same admission/eviction decisions, so
engine-vs-static parity tests can replay exact schedules.  Time comes
from an injected clock —

  * `WallClock`   — `time.perf_counter` based (NEVER `time.time()`: an
    NTP step mid-run would skew every latency/throughput number, which
    is exactly the bug the static driver's reports had);
  * `VirtualClock` — advances only when told, so benchmarks can replay
    a Poisson arrival trace deterministically and tests never sleep.

Admission is FIFO head-of-line: a request is admitted when a slot is
free AND the free list holds every page the request could EVER need
(`ceil((prompt + max_new_tokens) / page_size)`).  Reserving the full
page budget up front means an admitted request can never deadlock the
engine mid-generation — eviction happens only at completion, never as
preemption, so no cache state is ever recomputed.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence


class WallClock:
    """Monotonic real time; `wait_until` sleeps through idle gaps."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class VirtualClock:
    """Deterministic clock for benchmarks/tests.

    `tick(dt)` accounts measured compute time; `wait_until` jumps over
    idle gaps instantly.  Arrival traces replay identically across runs.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def tick(self, dt: float) -> None:
        self._t += max(0.0, dt)

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)


@dataclasses.dataclass
class Request:
    """One submitted generation request (immutable intent)."""
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_time: float = 0.0

    @property
    def total_len(self) -> int:
        # Prompt positions + cache growth during generation.  The final
        # sampled token is returned but never written to the cache, so
        # the cache span is prompt + (gen - 1) + the prefill position
        # itself; budgeting prompt + gen is the safe upper bound.
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class RunningRequest:
    """Engine-side state of an admitted request."""
    req: Request
    slot: int
    admitted_time: float
    prefill_pos: int = 0            # prompt positions already committed
    tokens: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= len(self.req.prompt)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.req.max_new_tokens


class Scheduler:
    """FIFO continuous-batching scheduler over a `PagedKVCache`.

    Owns the waiting queue and the running set; the engine asks it
    "admit whom?", "whose prefill next?", "who decodes?" each iteration.
    """

    def __init__(self, kv, max_slots: Optional[int] = None):
        self.kv = kv
        self.max_slots = max_slots if max_slots is not None else kv.max_slots
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, RunningRequest] = {}   # slot -> state
        self._rid = itertools.count()

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival_time: float = 0.0) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=int(max_new_tokens),
                      arrival_time=float(arrival_time))
        self.waiting.append(req)
        return req

    def admit(self, now: float) -> List[RunningRequest]:
        """Head-of-line FIFO admission under slot + page budget.

        Strict FIFO: if the head doesn't fit, nothing behind it jumps
        the queue (no starvation of long requests).
        """
        admitted = []
        while self.waiting:
            head = self.waiting[0]
            if head.arrival_time > now:
                break
            if len(self.running) >= self.max_slots:
                break
            if not self.kv.can_admit(head.total_len):
                break
            self.waiting.popleft()
            slot = self.kv.alloc(head.total_len)
            run = RunningRequest(req=head, slot=slot, admitted_time=now)
            self.running[slot] = run
            admitted.append(run)
        return admitted

    def next_prefill(self) -> Optional[RunningRequest]:
        """Oldest admitted request with prompt positions still uncommitted."""
        cands = [r for r in self.running.values() if not r.prefill_done]
        if not cands:
            return None
        return min(cands, key=lambda r: r.req.rid)

    def decoding(self) -> List[RunningRequest]:
        """Requests with a committed prompt and generation still to do."""
        return sorted(
            (r for r in self.running.values()
             if r.prefill_done and not r.done),
            key=lambda r: r.slot)

    def finish(self, run: RunningRequest, now: float) -> None:
        run.finish_time = now
        self.kv.free(run.slot)
        del self.running[run.slot]

    def next_arrival(self) -> Optional[float]:
        if not self.waiting:
            return None
        return min(r.arrival_time for r in self.waiting)

    @property
    def idle(self) -> bool:
        return not self.running and not self.waiting
