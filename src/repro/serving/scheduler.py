"""Request lifecycle + admission scheduling for the paged engine.

The scheduler is deliberately host-side and deterministic: given the
same arrival trace it makes the same admission/eviction decisions, so
engine-vs-static parity tests can replay exact schedules.  Time comes
from an injected clock —

  * `WallClock`   — `time.perf_counter` based (NEVER `time.time()`: an
    NTP step mid-run would skew every latency/throughput number, which
    is exactly the bug the static driver's reports had);
  * `VirtualClock` — advances only when told, so benchmarks can replay
    a Poisson arrival trace deterministically and tests never sleep.

Admission policies:

  * `fifo` (default) — head-of-line: a request is admitted when a slot
    is free AND the free list holds every page it could EVER need
    (`ceil((prompt + max_new_tokens) / page_size)`).  If the head does
    not fit, nothing behind it jumps the queue (no starvation of long
    requests).  Reserving the full page budget up front means an
    admitted request can never deadlock the engine mid-generation.
  * `edf` — earliest-deadline-first over the ARRIVED queue: requests
    carry absolute deadlines (explicit, or derived from an `SLOClass`),
    and the tightest deadline admits first.  With `preempt=True`, a
    deadline-bearing request that cannot fit may evict the running
    request with the LATEST deadline (strictly later than its own):
    eviction is free-list metadata (no cache copies), the victim's
    generated tokens are parked in `progress`, and re-admission
    re-prefills `prompt + generated` — the re-prefilled cache holds
    exactly the positions a continuous run would, so generation
    continues where it left off.

`expire(now)` enforces deadlines as timeouts: a waiting or running
request past its deadline is cancelled with FULL page reclamation and
reported to the engine for a `timeout` outcome.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple


class WallClock:
    """Monotonic real time; `wait_until` sleeps through idle gaps."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class VirtualClock:
    """Deterministic clock for benchmarks/tests.

    `tick(dt)` accounts measured compute time; `wait_until` jumps over
    idle gaps instantly.  Arrival traces replay identically across runs.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def tick(self, dt: float) -> None:
        self._t += max(0.0, dt)

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service-level objective class: TTFT/TPOT targets plus a
    relative completion deadline.  `deadline_for` turns the class into
    the absolute deadline the EDF policy and `expire` enforce."""
    name: str
    ttft_s: float = math.inf        # time to first token
    tpot_s: float = math.inf        # time per output token (after first)
    deadline_s: float = math.inf    # arrival -> completion budget

    def deadline_for(self, arrival: float, max_new_tokens: int
                     ) -> Optional[float]:
        budget = min(self.deadline_s,
                     self.ttft_s + self.tpot_s * max(0, max_new_tokens - 1))
        return arrival + budget if math.isfinite(budget) else None

    def met(self, ttft: Optional[float], tpot: Optional[float]) -> bool:
        if ttft is None:
            return False
        if ttft > self.ttft_s:
            return False
        return tpot is None or tpot <= self.tpot_s


# Presets for the CLI / benchmarks (seconds are virtual-clock seconds in
# deterministic runs, so these are traffic-mix knobs, not hardware facts).
SLO_CLASSES = {
    "interactive": SLOClass("interactive", ttft_s=0.5, tpot_s=0.1),
    "standard": SLOClass("standard", ttft_s=2.0, tpot_s=0.5),
    "batch": SLOClass("batch"),
}


@dataclasses.dataclass
class Request:
    """One submitted generation request (immutable intent)."""
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    deadline: Optional[float] = None     # absolute; None = never expires
    slo: Optional[SLOClass] = None

    @property
    def total_len(self) -> int:
        # Prompt positions + cache growth during generation.  The final
        # sampled token is returned but never written to the cache, so
        # the cache span is prompt + (gen - 1) + the prefill position
        # itself; budgeting prompt + gen is the safe upper bound.
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class Progress:
    """Generation state parked across a preemption."""
    tokens: List[int]
    first_token_time: Optional[float]
    retries: int
    preemptions: int


@dataclasses.dataclass
class RunningRequest:
    """Engine-side state of an admitted request."""
    req: Request
    slot: int
    admitted_time: Optional[float]
    prefill_pos: int = 0            # source positions already committed
    tokens: List[int] = dataclasses.field(default_factory=list)
    resumed: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    retries: int = 0
    preemptions: int = 0
    quarantines: int = 0
    outcome: Optional[str] = None   # None while live; set at retirement

    @property
    def prefill_source(self) -> List[int]:
        """Positions the prefill must commit: the prompt, plus any
        tokens generated before a preemption (re-prefilling them
        rebuilds the exact cache a continuous run would hold)."""
        return list(self.req.prompt) + self.resumed

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= len(self.req.prompt) + len(self.resumed)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.req.max_new_tokens


class Scheduler:
    """Admission scheduler over a `PagedKVCache` (FIFO or EDF).

    Owns the waiting queue and the running set; the engine asks it
    "admit whom?", "whose prefill next?", "who decodes?", "who expired?"
    each iteration.
    """

    def __init__(self, kv, max_slots: Optional[int] = None,
                 policy: str = "fifo", preempt: bool = False):
        if policy not in ("fifo", "edf"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.kv = kv
        self.max_slots = max_slots if max_slots is not None else kv.max_slots
        self.policy = policy
        self.preempt = bool(preempt)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, RunningRequest] = {}   # slot -> state
        self.progress: Dict[int, Progress] = {}        # rid -> parked state
        self.preempted_log: List[Request] = []         # drained by engine
        self._rid = itertools.count()

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival_time: float = 0.0,
               deadline: Optional[float] = None,
               slo: Optional[SLOClass] = None) -> Request:
        if deadline is None and slo is not None:
            deadline = slo.deadline_for(float(arrival_time),
                                        int(max_new_tokens))
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=int(max_new_tokens),
                      arrival_time=float(arrival_time),
                      deadline=deadline, slo=slo)
        self.waiting.append(req)
        return req

    # -- admission ----------------------------------------------------------

    def _candidate(self, now: float) -> Optional[Request]:
        """Next request admission should consider, per policy."""
        if self.policy == "fifo":
            head = self.waiting[0] if self.waiting else None
            return head if head and head.arrival_time <= now else None
        arrived = [r for r in self.waiting if r.arrival_time <= now]
        if not arrived:
            return None
        return min(arrived, key=lambda r: (
            r.deadline if r.deadline is not None else math.inf,
            r.arrival_time, r.rid))

    def _fits(self, req: Request) -> bool:
        return len(self.running) < self.max_slots \
            and self.kv.can_admit(req.total_len)

    def _preempt_for(self, cand: Request) -> bool:
        """Evict latest-deadline decoding victims until `cand` fits.
        Only a candidate WITH a deadline may preempt, and only victims
        with strictly later (or no) deadlines are eligible."""
        if cand.deadline is None:
            return self._fits(cand)
        while not self._fits(cand):
            victims = [r for r in self.running.values()
                       if r.prefill_done and not r.done]
            victims = [r for r in victims
                       if (r.req.deadline is None
                           or r.req.deadline > cand.deadline)]
            if not victims:
                return False
            victim = max(victims, key=lambda r: (
                r.req.deadline if r.req.deadline is not None else math.inf,
                r.req.rid))
            self._park(victim)
        return True

    def _park(self, run: RunningRequest) -> None:
        """Preempt-by-eviction: pages release as free-list metadata,
        generated tokens park in `progress`, the request requeues."""
        self.progress[run.req.rid] = Progress(
            tokens=list(run.tokens),
            first_token_time=run.first_token_time,
            retries=run.retries, preemptions=run.preemptions + 1)
        self.kv.free(run.slot)
        del self.running[run.slot]
        self.waiting.append(run.req)
        self.preempted_log.append(run.req)

    def admit(self, now: float) -> List[RunningRequest]:
        """Admit requests under slot + page budget, per policy."""
        admitted = []
        while True:
            cand = self._candidate(now)
            if cand is None:
                break
            if not self._fits(cand):
                if not (self.policy == "edf" and self.preempt
                        and self._preempt_for(cand)):
                    break
            self.waiting.remove(cand)
            slot = self.kv.alloc(cand.total_len)
            run = RunningRequest(req=cand, slot=slot, admitted_time=now)
            prog = self.progress.pop(cand.rid, None)
            if prog is not None:
                run.resumed = list(prog.tokens)
                run.tokens = list(prog.tokens)
                run.first_token_time = prog.first_token_time
                run.retries = prog.retries
                run.preemptions = prog.preemptions
            self.running[slot] = run
            admitted.append(run)
        return admitted

    # -- queries ------------------------------------------------------------

    def next_prefill(self) -> Optional[RunningRequest]:
        """Oldest admitted request with source positions still uncommitted."""
        cands = [r for r in self.running.values() if not r.prefill_done]
        if not cands:
            return None
        return min(cands, key=lambda r: r.req.rid)

    def decoding(self) -> List[RunningRequest]:
        """Requests with a committed prompt and generation still to do."""
        return sorted(
            (r for r in self.running.values()
             if r.prefill_done and not r.done),
            key=lambda r: r.slot)

    def next_arrival(self) -> Optional[float]:
        if not self.waiting:
            return None
        return min(r.arrival_time for r in self.waiting)

    def next_deadline(self) -> Optional[float]:
        dls = [r.deadline for r in self.waiting if r.deadline is not None]
        dls += [r.req.deadline for r in self.running.values()
                if r.req.deadline is not None]
        return min(dls) if dls else None

    @property
    def idle(self) -> bool:
        return not self.running and not self.waiting

    # -- retirement / cancellation ------------------------------------------

    def finish(self, run: RunningRequest, now: float) -> None:
        run.finish_time = now
        self.kv.free(run.slot)
        del self.running[run.slot]

    def cancel(self, run: RunningRequest) -> None:
        """Remove a running request WITHOUT a finish record (quarantine
        or timeout): full page reclamation, no cache copies."""
        self.kv.free(run.slot)
        del self.running[run.slot]
        self.progress.pop(run.req.rid, None)

    def requeue(self, req: Request) -> None:
        """Resubmit a cancelled request for a fresh attempt (quarantine
        retry: progress intentionally NOT retained — the retry re-runs
        from scratch so a poisoned prefix is not trusted)."""
        self.progress.pop(req.rid, None)
        self.waiting.append(req)

    def expire(self, now: float) -> List[Tuple[str, object]]:
        """Cancel every waiting/running request past its deadline.

        Returns ("waiting", Request) / ("running", RunningRequest) pairs
        for the engine to record as `timeout` outcomes.  Pages of
        running victims reclaim fully; parked progress is dropped.
        """
        out: List[Tuple[str, object]] = []
        for req in [r for r in self.waiting
                    if r.deadline is not None and now > r.deadline]:
            self.waiting.remove(req)
            self.progress.pop(req.rid, None)
            out.append(("waiting", req))
        for run in [r for r in self.running.values()
                    if r.req.deadline is not None
                    and now > r.req.deadline]:
            self.cancel(run)
            out.append(("running", run))
        return out
