"""Continuous-batching serving engine over the paged packed-KV cache.

One engine iteration interleaves BOTH kinds of work:

    ingest arrivals -> FIFO admission (slot + full page budget reserved)
    one PREFILL unit  — the oldest admitted request's whole prompt, or
                        its next chunk when `prefill_chunk` is set
    one DECODE step   — every request with a committed prompt, batched
                        through one jitted `decode_batch` call at a
                        power-of-two slot bucket
    retire completions — pages return to the free list (metadata only)

so new requests reach their first token without draining the running
batch, and running requests never stall behind a long prompt for more
than one prefill unit.  All numbers the engine reports come from the
injected clock (`perf_counter`-backed wall clock by default, virtual
clock for deterministic benchmarks) — never `time.time()`.

Budgets: `hbm_budget_bytes` sizes the page pool (admission is then a
free-list question), and at construction the engine consults the PR-6
`analysis.vmem` model to verify the packed decode-attention working set
at full capacity fits on-chip — a config that could never lower fails
fast here, not minutes into a traffic run.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.vmem import vmem_feasible
from repro.configs.base import ModelConfig
from repro.models.attention import kv_cache_formats
from .page_cache import PagedKVCache
from .runner import ModelRunner, supports_chunked
from .scheduler import Request, RunningRequest, Scheduler, WallClock


class ServingEngine:
    """Paged continuous-batching engine for one model.

    Parameters mirror the static driver where they overlap; the engine
    additions are the paging geometry (`max_slots` concurrent requests,
    `capacity` positions per request, `page_size` positions per page)
    and the budgets.  `temperature=0` decodes greedily (the parity
    mode); `prefill_chunk` enables chunked prefill for full-causal
    models.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int,
                 capacity: int, page_size: int,
                 prefill_chunk: Optional[int] = None,
                 decode_lookahead: int = 1,
                 temperature: float = 0.0, seed: int = 0,
                 clock=None, check_finite: bool = False,
                 n_pages: Optional[int] = None,
                 hbm_budget_bytes: Optional[int] = None,
                 mesh=None):
        if decode_lookahead < 1:
            raise ValueError("decode_lookahead must be >= 1")
        self.mesh = mesh
        if mesh is not None:
            # Shard the weights over the mesh up front (packed words
            # along d_out over "model", MoE experts over their expert
            # axis); the runner then serves tensor-parallel, plus
            # data-parallel over slot buckets that divide "data".
            from repro.parallel import shard_ops
            params = shard_ops.place_params(params, cfg, mesh)
        self.params = params
        self.cfg = cfg
        self.kv = PagedKVCache(cfg, max_slots=max_slots, capacity=capacity,
                               page_size=page_size, n_pages=n_pages,
                               hbm_budget_bytes=hbm_budget_bytes)
        if prefill_chunk is not None and not supports_chunked(self.kv.specs):
            raise ValueError(
                "chunked prefill requires all attention layers to be "
                "full-causal (windowed layers keep rolling ring buffers "
                "whose write offsets the chunk path does not implement); "
                "use whole-prompt prefill for this config")
        self.prefill_chunk = prefill_chunk
        self.decode_lookahead = int(decode_lookahead)
        self.runner = ModelRunner(cfg, self.kv, temperature=temperature,
                                  mesh=mesh)
        self.scheduler = Scheduler(self.kv)
        self.clock = clock if clock is not None else WallClock()
        self.check_finite = bool(check_finite)
        self._key = jax.random.PRNGKey(seed)
        self._step = 0
        self.finished: List[RunningRequest] = []
        self._check_vmem()

    def _check_vmem(self) -> None:
        """Fail fast if the packed decode-attention working set at full
        slot capacity cannot fit VMEM for even the smallest seq tile."""
        q = self.cfg.quant
        if not (q.quantize_kv_cache and q.kv_layout == "packed"):
            return
        _, vp = kv_cache_formats(q)
        shape = (self.kv.max_slots, self.kv.capacity,
                 self.cfg.n_kv_heads, self.cfg.head_dim)
        shards = None
        if self.mesh is not None:
            # Data-parallel decode shards the slot-batch dim, so each
            # device stages only its slice of the working set.
            from repro.parallel import shard_ops
            dp = shard_ops.tp_size(self.mesh, "data")
            if dp > 1:
                shards = (dp, 1, 1, 1)
        fits, need = vmem_feasible(
            "vp_decode_attention", (128, min(128, self.kv.capacity), 1),
            (vp,), shape, shards=shards)
        if not fits:
            raise ValueError(
                f"decode-attention working set ({need} B) exceeds the "
                f"VMEM budget at capacity {self.kv.capacity}; shrink "
                f"capacity/max_slots or raise REPRO_VMEM_BUDGET_BYTES")

    # -- request API --------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival_time: float = 0.0) -> Request:
        return self.scheduler.submit(prompt, max_new_tokens, arrival_time)

    # -- internals ----------------------------------------------------------

    def _next_key(self):
        """Fresh fold per compute unit — except greedy decoding, where
        `_sample` never consumes the key: there the fold would be two
        eager device dispatches per step bought for nothing."""
        if self.runner.temperature == 0:
            return self._key
        self._step += 1
        return jax.random.fold_in(self._key, self._step)

    def _timed(self, fn, *args):
        """Run one jitted step to completion and charge its wall time to
        a virtual clock (wall clocks advance on their own)."""
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        if hasattr(self.clock, "tick"):
            self.clock.tick(time.perf_counter() - t0)
        return out

    def _require_finite(self, logits, what: str) -> None:
        if not self.check_finite:
            return
        if not bool(np.isfinite(np.asarray(logits)).all()):
            raise FloatingPointError(
                f"non-finite logits in {what} (quantization overflow or "
                f"bad cache read)")

    def _prefill_unit(self, run: RunningRequest) -> None:
        """Commit one prefill unit for `run`: the whole prompt, or the
        next `prefill_chunk` positions.  The unit that commits the final
        prompt position also yields the request's first generated token."""
        prompt = run.req.prompt
        if self.prefill_chunk is None:
            tok, logits = self._timed(
                self.runner.prefill_commit, self.params,
                jnp.asarray(prompt, jnp.int32), run.slot, self._next_key())
            run.prefill_pos = len(prompt)
        else:
            c = min(self.prefill_chunk, len(prompt) - run.prefill_pos)
            chunk = prompt[run.prefill_pos:run.prefill_pos + c]
            tok, logits = self._timed(
                self.runner.chunk_prefill_commit, self.params,
                jnp.asarray(chunk, jnp.int32), run.slot, self._next_key())
            run.prefill_pos += c
        self._require_finite(logits, f"prefill rid={run.req.rid}")
        if run.prefill_done:
            run.tokens.append(int(tok[0, 0]))
            run.first_token_time = self.clock.now()

    def _lookahead(self, runs: List[RunningRequest]) -> int:
        """Fused steps this batch can run: bounded by the configured
        run-ahead and by every slot's cache headroom (a run-ahead past a
        request's token budget only wastes the tail — admission already
        guarantees the budgeted span fits, so headroom clamping keeps
        over-generation inside the slot's reserved pages).  Restricted
        to {1, decode_lookahead} so the compile cache stays one entry
        per bucket, not one per headroom value."""
        if self.decode_lookahead == 1:
            return 1
        headroom = min(
            self.kv.capacity
            - (len(r.req.prompt) + len(r.tokens) - 1) for r in runs)
        return self.decode_lookahead \
            if headroom >= self.decode_lookahead else 1

    def _decode_once(self, runs: List[RunningRequest]) -> None:
        slot_tokens = {r.slot: r.tokens[-1] for r in runs}
        out = self._timed(self.runner.decode_batch, self.params,
                          slot_tokens, self._next_key(),
                          self._lookahead(runs))
        by_slot = {r.slot: r for r in runs}
        for slot, (toks, logits) in out.items():
            self._require_finite(logits, f"decode slot={slot}")
            run = by_slot[slot]
            run.tokens.extend(toks)
            # run-ahead may overshoot the budget; the overshoot was
            # decoded into the slot's own reserved pages (freed at
            # retire) and is dropped from the transcript here.
            del run.tokens[run.req.max_new_tokens:]

    def _retire(self) -> None:
        now = self.clock.now()
        for run in [r for r in self.scheduler.running.values() if r.done]:
            self.scheduler.finish(run, now)
            self.finished.append(run)

    # -- main loop ----------------------------------------------------------

    def step(self) -> bool:
        """One engine iteration; returns False when fully idle."""
        sched = self.scheduler
        sched.admit(self.clock.now())
        did = False
        run = sched.next_prefill()
        if run is not None:
            self._prefill_unit(run)
            did = True
        decoding = sched.decoding()
        if decoding:
            self._decode_once(decoding)
            did = True
        self._retire()
        if did:
            return True
        nxt = sched.next_arrival()
        if nxt is None:
            return not sched.idle
        self.clock.wait_until(nxt)
        return True

    def run(self) -> List[Dict]:
        """Serve until every submitted request completes; returns
        per-request records (tokens + timing) sorted by request id."""
        while self.step():
            pass
        recs = []
        for run in sorted(self.finished, key=lambda r: r.req.rid):
            recs.append({
                "rid": run.req.rid,
                "prompt_len": len(run.req.prompt),
                "tokens": list(run.tokens),
                "arrival_time": run.req.arrival_time,
                "admitted_time": run.admitted_time,
                "first_token_time": run.first_token_time,
                "finish_time": run.finish_time,
            })
        return recs
