"""Continuous-batching serving engine over the paged packed-KV cache.

One engine iteration interleaves BOTH kinds of work:

    fault hooks + deadline expiry -> admission (FIFO or EDF, optional
                        preemption-by-eviction) -> queue backpressure
    one PREFILL unit  — the oldest admitted request's whole prompt, or
                        its next chunk when `prefill_chunk` is set
    one DECODE step   — every request with a committed prompt, batched
                        through one jitted `decode_batch` call at a
                        power-of-two slot bucket
    retire completions — pages return to the free list (metadata only)

so new requests reach their first token without draining the running
batch, and running requests never stall behind a long prompt for more
than one prefill unit.  All numbers the engine reports come from the
injected clock (`perf_counter`-backed wall clock by default, virtual
clock for deterministic benchmarks) — never `time.time()`.

Budgets: `hbm_budget_bytes` sizes the page pool (admission is then a
free-list question), and at construction the engine consults the PR-6
`analysis.vmem` model to verify the packed decode-attention working set
at full capacity fits on-chip — a config that could never lower fails
fast here, not minutes into a traffic run.

RESILIENCE (PR 10).  The paper's premise is operating near the edge of
a format's dynamic range, so overflow/NaN escapes from the packed path
are an expected operating condition to contain, not a fatal invariant
violation:

  * per-slot finite check — a non-finite logit quarantines ONLY the
    offending request (`on_nonfinite="quarantine"`); surviving slots
    continue bit-identically (the poisoned slot only ever wrote its own
    reserved pages).  `"raise"` keeps the legacy all-or-nothing
    `FloatingPointError` for smoke drivers that want a hard stop.
  * retry with backoff — transient dispatch failures (`FaultPlan`
    injection or real enqueue hiccups surfaced as
    `TransientComputeError`) charge an exponential backoff to the clock
    and retry; a request that keeps failing is quarantined.
  * graceful degradation — a repeatedly-quarantined request re-runs on
    the static golden-baseline path (`runner.oracle_generate`, dense
    cache, optionally separately-quantized `degrade_params`) and is
    flagged `degraded` instead of dropped.
  * bounded submit queue — arrivals that find `max_queue` requests
    already waiting are shed (`shed` outcome) instead of growing the
    queue without bound under HBM pressure.
  * deadlines/SLOs — see `scheduler`; expiry cancels with full page
    reclamation (`timeout` outcome).

Per-request outcomes land on the `run()` records
(`ok|retried|quarantined|degraded|timeout|shed`) and aggregate counters
on `engine.stats`; the chaos suite (tests/test_chaos.py) drives every
fault class against these contracts.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.vmem import vmem_feasible
from repro.configs.base import ModelConfig
from repro.models.attention import kv_cache_formats
from .faults import FaultPlan, TransientComputeError
from .page_cache import PAGED, PagedKVCache, buf_key
from .runner import ModelRunner, oracle_generate, supports_chunked
from .scheduler import Request, RunningRequest, Scheduler, SLOClass, \
    WallClock


class ServingEngine:
    """Paged continuous-batching engine for one model.

    Parameters mirror the static driver where they overlap; the engine
    additions are the paging geometry (`max_slots` concurrent requests,
    `capacity` positions per request, `page_size` positions per page)
    and the budgets.  `temperature=0` decodes greedily (the parity
    mode); `prefill_chunk` enables chunked prefill for full-causal
    models.

    Resilience knobs (all default OFF / legacy-equivalent):
      policy="fifo"|"edf", preempt, max_queue, check_finite +
      on_nonfinite ("quarantine"|"raise"), max_retries/retry_backoff_s,
      degrade/degrade_after/degrade_params, faults (a `FaultPlan`).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int,
                 capacity: int, page_size: int,
                 prefill_chunk: Optional[int] = None,
                 decode_lookahead: int = 1,
                 temperature: float = 0.0, seed: int = 0,
                 clock=None, check_finite: bool = False,
                 n_pages: Optional[int] = None,
                 hbm_budget_bytes: Optional[int] = None,
                 mesh=None,
                 policy: str = "fifo", preempt: bool = False,
                 max_queue: Optional[int] = None,
                 on_nonfinite: str = "quarantine",
                 max_retries: int = 2, retry_backoff_s: float = 0.005,
                 degrade: bool = False, degrade_after: int = 2,
                 degrade_params=None,
                 faults: Optional[FaultPlan] = None):
        if decode_lookahead < 1:
            raise ValueError("decode_lookahead must be >= 1")
        if on_nonfinite not in ("quarantine", "raise"):
            raise ValueError(
                f"on_nonfinite must be 'quarantine' or 'raise', "
                f"got {on_nonfinite!r}")
        self.mesh = mesh
        if mesh is not None:
            # Shard the weights over the mesh up front (packed words
            # along d_out over "model", MoE experts over their expert
            # axis); the runner then serves tensor-parallel, plus
            # data-parallel over slot buckets that divide "data".
            from repro.parallel import shard_ops
            params = shard_ops.place_params(params, cfg, mesh)
        self.params = params
        self.cfg = cfg
        self.kv = PagedKVCache(cfg, max_slots=max_slots, capacity=capacity,
                               page_size=page_size, n_pages=n_pages,
                               hbm_budget_bytes=hbm_budget_bytes)
        if prefill_chunk is not None and not supports_chunked(self.kv.specs):
            raise ValueError(
                "chunked prefill requires all attention layers to be "
                "full-causal (windowed layers keep rolling ring buffers "
                "whose write offsets the chunk path does not implement); "
                "use whole-prompt prefill for this config")
        self.prefill_chunk = prefill_chunk
        self.decode_lookahead = int(decode_lookahead)
        self.runner = ModelRunner(cfg, self.kv, temperature=temperature,
                                  mesh=mesh)
        self.scheduler = Scheduler(self.kv, policy=policy, preempt=preempt)
        self.clock = clock if clock is not None else WallClock()
        self.check_finite = bool(check_finite)
        self.on_nonfinite = on_nonfinite
        self.max_queue = max_queue
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.degrade = bool(degrade)
        self.degrade_after = int(degrade_after)
        self.degrade_params = degrade_params
        self.faults = faults
        self.stats = collections.Counter()
        self._quarantine_counts: Dict[int, int] = {}
        self._decode_fail_streak = 0
        self._key = jax.random.PRNGKey(seed)
        self._step = 0
        self.finished: List[RunningRequest] = []
        self._check_vmem()

    def _check_vmem(self) -> None:
        """Fail fast if the packed decode-attention working set at full
        slot capacity cannot fit VMEM for even the smallest seq tile."""
        q = self.cfg.quant
        if not (q.quantize_kv_cache and q.kv_layout == "packed"):
            return
        _, vp = kv_cache_formats(q)
        shape = (self.kv.max_slots, self.kv.capacity,
                 self.cfg.n_kv_heads, self.cfg.head_dim)
        shards = None
        if self.mesh is not None:
            # Data-parallel decode shards the slot-batch dim, so each
            # device stages only its slice of the working set.
            from repro.parallel import shard_ops
            dp = shard_ops.tp_size(self.mesh, "data")
            if dp > 1:
                shards = (dp, 1, 1, 1)
        fits, need = vmem_feasible(
            "vp_decode_attention", (128, min(128, self.kv.capacity), 1),
            (vp,), shape, shards=shards)
        if not fits:
            raise ValueError(
                f"decode-attention working set ({need} B) exceeds the "
                f"VMEM budget at capacity {self.kv.capacity}; shrink "
                f"capacity/max_slots or raise REPRO_VMEM_BUDGET_BYTES")

    # -- request API --------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival_time: float = 0.0,
               deadline: Optional[float] = None,
               slo: Optional[SLOClass] = None) -> Request:
        self.stats["submitted"] += 1
        return self.scheduler.submit(prompt, max_new_tokens, arrival_time,
                                     deadline=deadline, slo=slo)

    # -- internals ----------------------------------------------------------

    def _next_key(self):
        """Fresh fold per compute unit — except greedy decoding, where
        `_sample` never consumes the key: there the fold would be two
        eager device dispatches per step bought for nothing."""
        if self.runner.temperature == 0:
            return self._key
        self._step += 1
        return jax.random.fold_in(self._key, self._step)

    def _timed(self, fn, *args):
        """Run one jitted step to completion and charge its wall time to
        a virtual clock (wall clocks advance on their own)."""
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        if hasattr(self.clock, "tick"):
            self.clock.tick(time.perf_counter() - t0)
        return out

    def _charge(self, seconds: float) -> None:
        """Charge non-compute time (backoff, stalls) to the clock."""
        if hasattr(self.clock, "tick"):
            self.clock.tick(seconds)
        else:
            self.clock.wait_until(self.clock.now() + seconds)

    # -- fault containment --------------------------------------------------

    def _screen(self, phase: str, run: RunningRequest, logits) -> bool:
        """Per-slot health screen on one request's host logits.

        Applies any scheduled fault-plan poison (host-side only — the
        device computation and every co-resident slot are untouched),
        then the finite check.  Returns True if the request is healthy;
        False means the caller must quarantine it.  `"raise"` mode keeps
        the legacy all-or-nothing FloatingPointError.
        """
        arr = None
        if self.faults is not None:
            arr = np.asarray(logits)
            poisoned = self.faults.poison(phase, run.req.rid,
                                          len(run.tokens), arr)
            if poisoned is not None:
                arr = poisoned
                self.stats["fault_logit_poisons"] += 1
        if not self.check_finite:
            return True
        if arr is None:
            arr = np.asarray(logits)
        if bool(np.isfinite(arr).all()):
            return True
        if self.on_nonfinite == "raise":
            raise FloatingPointError(
                f"non-finite logits in {phase} rid={run.req.rid} "
                f"(quantization overflow or bad cache read)")
        return False

    def _quarantine(self, run: RunningRequest, where: str) -> None:
        """Contain one poisoned request: cancel it (full page
        reclamation, co-resident slots untouched), then requeue for a
        fresh attempt or degrade to the golden-baseline path."""
        rid = run.req.rid
        count = self._quarantine_counts.get(rid, 0) + 1
        self._quarantine_counts[rid] = count
        run.quarantines = count
        self.stats["quarantine_events"] += 1
        self.scheduler.cancel(run)
        if self.degrade and count >= self.degrade_after:
            self._degrade(run)
        elif self.degrade:
            # fresh retry on the fast path (a transient overflow may not
            # recur); the poisoned transcript is not trusted or resumed
            run.tokens = []
            self.scheduler.requeue(run.req)
            self.stats["quarantine_requeues"] += 1
        else:
            run.outcome = "quarantined"
            run.tokens = []
            run.finish_time = self.clock.now()
            self.finished.append(run)
            self.stats["quarantined"] += 1

    def _degrade(self, run: RunningRequest) -> None:
        """Re-run a repeatedly-quarantined request on the static
        golden-baseline path (dense cache, PR-4 oracle; greedy) and flag
        it — the answer arrives late and slow, but it arrives."""
        t0 = time.perf_counter()
        params = self.degrade_params if self.degrade_params is not None \
            else self.params
        toks = oracle_generate(params, self.cfg, run.req.prompt,
                               run.req.max_new_tokens, self.kv.capacity)
        self._charge(time.perf_counter() - t0)
        run.tokens = toks
        run.outcome = "degraded"
        run.finish_time = self.clock.now()
        if run.first_token_time is None:
            run.first_token_time = run.finish_time
        self.finished.append(run)
        self.stats["degraded"] += 1

    def _transient_failure(self, run: RunningRequest, what: str) -> None:
        """One failed dispatch: exponential backoff charged to the
        clock; persistent failure quarantines the request."""
        run.retries += 1
        self.stats["transient_faults"] += 1
        self._charge(self.retry_backoff_s * (2 ** (run.retries - 1)))
        if run.retries > self.max_retries:
            self._quarantine(run, f"{what} retries exhausted")

    def _apply_kv_flips(self, run: RunningRequest) -> None:
        """Apply scheduled bit flips inside this request's OWN pages
        (silent HBM corruption; must never escape the page's owner)."""
        from repro.kernels import paged
        for spec in self.faults.kv_flips(run.req.rid):
            keys = sorted(
                buf_key(s, name) for s in self.kv.specs if s.kind == PAGED
                for name, _, _ in s.bufs)
            if not keys:
                continue
            key = spec.buf if spec.buf is not None else keys[0]
            pages = self.kv.slot_pages.get(run.slot, [])
            if not pages:
                continue
            page = pages[spec.page_index % len(pages)]
            self.kv.pools[key] = paged.flip_bit(
                self.kv.pools[key], page,
                spec.offset % self.kv.page_size, spec.bit)
            self.stats["fault_kv_bit_flips"] += 1

    def _shed(self, now: float) -> None:
        """Bounded-queue backpressure: arrivals that find `max_queue`
        requests already waiting are rejected (newest first — they
        found the queue full), not silently parked forever."""
        if self.max_queue is None:
            return
        sched = self.scheduler
        arrived = [r for r in sched.waiting if r.arrival_time <= now]
        while len(arrived) > self.max_queue:
            victim = max(arrived, key=lambda r: (r.arrival_time, r.rid))
            arrived.remove(victim)
            sched.waiting.remove(victim)
            sched.progress.pop(victim.rid, None)
            run = RunningRequest(req=victim, slot=-1, admitted_time=None)
            run.outcome = "shed"
            run.finish_time = now
            self.finished.append(run)
            self.stats["shed"] += 1

    def _record_timeouts(self, expired, now: float) -> None:
        for where, item in expired:
            run = item if where == "running" else \
                RunningRequest(req=item, slot=-1, admitted_time=None)
            run.outcome = "timeout"
            run.finish_time = now
            self.finished.append(run)
            self.stats["timeout"] += 1

    # -- compute units ------------------------------------------------------

    def _prefill_unit(self, run: RunningRequest) -> None:
        """Commit one prefill unit for `run`: the whole source (prompt
        plus any preemption-resumed tokens), or the next `prefill_chunk`
        positions.  The unit that commits the final source position also
        yields the request's next generated token."""
        if self.faults is not None and \
                self.faults.take_transient("prefill", run.req.rid):
            self._transient_failure(run, "prefill")
            return
        src = run.prefill_source
        try:
            if self.prefill_chunk is None:
                tok, logits = self._timed(
                    self.runner.prefill_commit, self.params,
                    jnp.asarray(src, jnp.int32), run.slot, self._next_key())
                run.prefill_pos = len(src)
            else:
                c = min(self.prefill_chunk, len(src) - run.prefill_pos)
                chunk = src[run.prefill_pos:run.prefill_pos + c]
                tok, logits = self._timed(
                    self.runner.chunk_prefill_commit, self.params,
                    jnp.asarray(chunk, jnp.int32), run.slot,
                    self._next_key())
                run.prefill_pos += c
        except TransientComputeError:
            self._transient_failure(run, "prefill")
            return
        if self.faults is not None and run.prefill_done:
            self._apply_kv_flips(run)
        # "prefill"-phase poison fires only on the unit that completes
        # the prompt; intermediate chunks still get the finite check.
        phase = "prefill" if run.prefill_done else "prefill_chunk"
        if not self._screen(phase, run, logits):
            self._quarantine(run, "prefill")
            return
        if run.prefill_done:
            run.tokens.append(int(tok[0, 0]))
            if run.first_token_time is None:
                run.first_token_time = self.clock.now()

    def _lookahead(self, runs: List[RunningRequest]) -> int:
        """Fused steps this batch can run: bounded by the configured
        run-ahead and by every slot's cache headroom (a run-ahead past a
        request's token budget only wastes the tail — admission already
        guarantees the budgeted span fits, so headroom clamping keeps
        over-generation inside the slot's reserved pages).  Restricted
        to {1, decode_lookahead} so the compile cache stays one entry
        per bucket, not one per headroom value."""
        if self.decode_lookahead == 1:
            return 1
        headroom = min(
            self.kv.capacity
            - (len(r.prefill_source) + len(r.tokens)
               - len(r.resumed) - 1) for r in runs)
        return self.decode_lookahead \
            if headroom >= self.decode_lookahead else 1

    def _decode_once(self, runs: List[RunningRequest]) -> None:
        if self.faults is not None and \
                self.faults.take_transient("decode", None):
            # whole-step dispatch failure: nothing committed, the same
            # batch retries next iteration after a charged backoff
            self.stats["transient_faults"] += 1
            self._decode_fail_streak += 1
            for r in runs:
                r.retries += 1
            self._charge(self.retry_backoff_s
                         * (2 ** (self._decode_fail_streak - 1)))
            if self._decode_fail_streak > self.max_retries:
                raise RuntimeError(
                    f"decode step failed {self._decode_fail_streak} "
                    f"consecutive times; giving up")
            return
        slot_tokens = {r.slot: r.tokens[-1] for r in runs}
        try:
            out = self._timed(self.runner.decode_batch, self.params,
                              slot_tokens, self._next_key(),
                              self._lookahead(runs))
        except TransientComputeError:
            self.stats["transient_faults"] += 1
            self._decode_fail_streak += 1
            for r in runs:
                r.retries += 1
            self._charge(self.retry_backoff_s
                         * (2 ** (self._decode_fail_streak - 1)))
            return
        self._decode_fail_streak = 0
        by_slot = {r.slot: r for r in runs}
        for slot, (toks, logits) in out.items():
            run = by_slot[slot]
            if not self._screen("decode", run, logits):
                # quarantine ONLY this slot: its garbage lived in its
                # own reserved pages (freed by cancel); every other
                # slot's logits came off the same jitted call untouched
                self._quarantine(run, "decode")
                continue
            run.tokens.extend(toks)
            # run-ahead may overshoot the budget; the overshoot was
            # decoded into the slot's own reserved pages (freed at
            # retire) and is dropped from the transcript here.
            del run.tokens[run.req.max_new_tokens:]

    def _retire(self) -> None:
        now = self.clock.now()
        for run in [r for r in self.scheduler.running.values() if r.done]:
            self.scheduler.finish(run, now)
            run.outcome = "retried" if run.retries > 0 else "ok"
            self.stats[run.outcome] += 1
            self.finished.append(run)

    # -- main loop ----------------------------------------------------------

    def step(self) -> bool:
        """One engine iteration; returns False when fully idle."""
        sched = self.scheduler
        if self.faults is not None:
            self.faults.on_step(self)
        now = self.clock.now()
        self._record_timeouts(sched.expire(now), now)
        sched.admit(now)
        if sched.preempted_log:
            self.stats["preemptions"] += len(sched.preempted_log)
            sched.preempted_log.clear()
        self._shed(now)
        did = False
        run = sched.next_prefill()
        if run is not None:
            self._prefill_unit(run)
            did = True
        decoding = sched.decoding()
        if decoding:
            self._decode_once(decoding)
            did = True
        self._retire()
        if did:
            return True
        if sched.idle:
            return False
        # Nothing computable now: advance the clock to the next event —
        # an arrival, a deadline expiry, or a fault-plan state change
        # (e.g. a page-pressure spike releasing the pages the waiting
        # head needs).
        now = self.clock.now()
        events = []
        nxt = sched.next_arrival()
        if nxt is not None and nxt > now:
            events.append(nxt)
        dl = sched.next_deadline()
        if dl is not None and dl > now:
            events.append(dl + 1e-9)   # expiry is strict `now > deadline`
        if self.faults is not None:
            t = self.faults.next_event(now)
            if t is not None and t > now:
                events.append(t)
        if events:
            self.clock.wait_until(min(events))
            return True
        raise RuntimeError(
            "engine stalled: requests are waiting but cannot be admitted "
            "and no future event (arrival, deadline, fault release) can "
            "unblock them")

    def run(self) -> List[Dict]:
        """Serve until every submitted request reaches a terminal
        outcome; returns per-request records (tokens + timing +
        outcome) sorted by request id."""
        while self.step():
            pass
        if self.faults is not None:
            self.faults.release_all(self)
        recs = []
        for run in sorted(self.finished, key=lambda r: r.req.rid):
            req = run.req
            n = len(run.tokens)
            ttft = None if run.first_token_time is None \
                else run.first_token_time - req.arrival_time
            tpot = None
            if run.first_token_time is not None and n > 1 \
                    and run.finish_time is not None:
                tpot = (run.finish_time - run.first_token_time) / (n - 1)
            deadline_met = run.outcome in ("ok", "retried") and (
                req.deadline is None
                or (run.finish_time is not None
                    and run.finish_time <= req.deadline))
            recs.append({
                "rid": req.rid,
                "prompt_len": len(req.prompt),
                "tokens": list(run.tokens),
                "arrival_time": req.arrival_time,
                "admitted_time": run.admitted_time,
                "first_token_time": run.first_token_time,
                "finish_time": run.finish_time,
                "outcome": run.outcome or "ok",
                "deadline": req.deadline,
                "slo": req.slo.name if req.slo is not None else None,
                "ttft_s": ttft,
                "tpot_s": tpot,
                "deadline_met": deadline_met,
                "slo_met": (deadline_met and req.slo.met(ttft, tpot))
                if req.slo is not None else deadline_met,
                "retries": run.retries,
                "preemptions": run.preemptions,
                "quarantines": run.quarantines,
            })
        return recs
