"""Model runner: jitted prefill/decode steps over the paged cache.

Every step is one compiled function with a fixed shape signature:

  gather  — block-table rows -> contiguous per-slot cache views
            (`kernels.paged.gather_pages`; dense ring/SSM rows slice
            directly).  The view length is the FULL slot capacity, so
            one decode compile serves every mix of request lengths —
            positions past a slot's `lengths` entry are masked to
            exactly-zero softmax terms by the attention cores, which is
            what keeps engine logits bit-identical to the static driver
            on the ref backend.
  compute — the UNCHANGED model functions (`prefill` / `decode_step`):
            the paged engine adds no second model implementation, and
            the packed-KV view flows through the same
            `vp_decode_attention` op (its scalar-prefetched `lengths`
            carries the ragged per-request spans).
  commit  — scatter ONLY the newly written positions back to the pools
            (one token per slot at decode; whole pages at prefill) and
            write back dense rows/states.  Nothing else in the cache is
            copied or dequantized.
  sample  — argmax / categorical INSIDE the jitted step, so the decode
            wall-clock measures the model, not a host-side Python
            sampling loop.

Batch steps run at power-of-two slot buckets (compile per bucket, not
per composition); inactive padding rows are distinct parked slots whose
commits are masked to the dummy page / their own old rows.

Mesh-native serving: constructed with a `mesh`, the runner swaps the
model calls for `parallel.shard_ops.sharded_forward_fns` — the SAME
compute inside `shard_map`, weights tensor-parallel over the "model"
axis (packed words sharded along d_out, outputs all-gathered), MoE
experts expert-parallel.  Gather/commit stay global: pools, block
tables and lengths are replicated, only the model forward shards.
Decode buckets whose size divides the "data" axis additionally shard
the batch dim over it (data-parallel-over-slots x tensor-parallel-over-
weights); every collective on these paths is a concatenation, so served
tokens and logits stay bit-identical to the single-device engine on the
ref backend.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import paged
from repro.models import decode_step, prefill
from .page_cache import DENSE, PAGED, PagedKVCache, SubSpec, buf_key


def _sample(logits, key, temperature: float):
    """Next-token draw inside the jitted step (B, V) -> (B, 1) int32."""
    if temperature > 0:
        tok = jax.random.categorical(key, logits / temperature)
    else:
        tok = jnp.argmax(logits, -1)
    return tok.astype(jnp.int32)[:, None]


def build_view(specs: Sequence[SubSpec], n_groups: int, pools, dense,
               block_table, lengths, slots):
    """Reassemble the `init_cache`-shaped pytree for a batch of slots.

    Paged buffers gather their block-table pages into a contiguous
    capacity-length view; dense ring buffers and SSM states slice their
    slot rows.  `len` entries broadcast the global per-slot lengths.
    """
    lens = lengths[slots]
    caches: List[dict] = [dict() for _ in range(n_groups)]
    for spec in specs:
        entry = {}
        if spec.kind == PAGED:
            bt = block_table[slots]
            for name, _, _ in spec.bufs:
                entry[name] = paged.gather_pages(
                    pools[buf_key(spec, name)], bt)
        else:
            for name, _, _ in spec.bufs:
                entry[name] = dense[buf_key(spec, name)][:, slots]
        if spec.has_len:
            entry["len"] = jnp.broadcast_to(
                lens[None], (spec.reps, lens.shape[0]))
        caches[spec.gi][spec.sub] = entry
    return caches


# Compiled prefill/decode for the degradation oracle, keyed by config
# IDENTITY (the cfg is stored to pin the id).  A fresh `jax.jit` closure
# per call would recompile on EVERY degrade — seconds charged straight
# to the engine clock, turning the escape hatch into a deadline killer.
_ORACLE_FNS: Dict[int, tuple] = {}


def _oracle_fns(cfg: ModelConfig):
    hit = _ORACLE_FNS.get(id(cfg))
    if hit is not None and hit[0] is cfg:
        return hit[1], hit[2]
    pre = jax.jit(lambda p, t, c: prefill(p, t, c, cfg))
    dec = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    _ORACLE_FNS[id(cfg)] = (cfg, pre, dec)
    return pre, dec


def oracle_generate(params, cfg: ModelConfig, prompt: Sequence[int],
                    max_new_tokens: int, capacity: int) -> List[int]:
    """Static B=1 greedy generation on the golden-baseline path.

    This is the engine's graceful-degradation fallback: a request that
    is repeatedly quarantined on the paged packed path re-runs here —
    whole-prompt `prefill` + per-token `decode_step` on a fresh DENSE
    cache (`init_cache`), exactly the PR-4 oracle the parity suites pin
    the kernels against.  No paged pools, no packed-KV gather, no shared
    state with the engine's cache — an escape hatch that cannot be
    poisoned by the paged path's failure.  `params` may be the serving
    params or a separately quantized planes/float copy (the engine's
    `degrade_params`).
    """
    from repro.models import init_cache as _init_cache

    pre, dec = _oracle_fns(cfg)
    caches = _init_cache(cfg, 1, capacity)
    logits, caches = pre(
        params, jnp.asarray([list(prompt)], jnp.int32), caches)
    toks = [int(np.asarray(logits).reshape(1, -1).argmax(-1)[0])]
    for _ in range(max_new_tokens - 1):
        logits, caches = dec(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches)
        toks.append(int(np.asarray(logits).reshape(1, -1).argmax(-1)[0]))
    return toks


def supports_chunked(specs: Sequence[SubSpec]) -> bool:
    """Chunked prefill needs offset-aware attention writes, which the
    chunk path implements for full-causal (non-windowed) layers only;
    SSM states carry across chunks natively."""
    return all(s.kind != DENSE for s in specs)


class ModelRunner:
    """Compiled-step cache + functional state threading for one engine."""

    def __init__(self, cfg: ModelConfig, kv: PagedKVCache,
                 temperature: float = 0.0, mesh=None,
                 tp_axis: str = "model", data_axis: str = "data"):
        self.cfg = cfg
        self.kv = kv
        self.temperature = float(temperature)
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.data_axis = data_axis
        if mesh is not None:
            from repro.parallel import shard_ops
            self._dp = shard_ops.tp_size(mesh, data_axis)
        else:
            self._dp = 1
        self._sharded_fns = None
        # Donation lets XLA update pools in place; CPU ignores it (and
        # warns), so only request it off-CPU.
        self._donate = jax.default_backend() != "cpu"
        self._decode_fns: Dict[Tuple[int, int], callable] = {}
        self._prefill_fns: Dict[int, callable] = {}
        self._chunk_fns: Dict[Tuple[int, bool], callable] = {}
        # (slots, active) device operands keyed by batch composition —
        # the composition only changes on admission/retirement, so this
        # avoids two host->device transfers on every decode step.
        self._comp_cache: Dict[Tuple[Tuple[int, ...], int], tuple] = {}

    # -- compiled-step builders --------------------------------------------

    def _jit(self, fn, donate):
        return jax.jit(fn, donate_argnums=donate if self._donate else ())

    def _model_fns(self, params):
        """(prefill_fn, decode_fn) — the plain model functions, or their
        shard_map wrappers when the runner was built with a mesh.  Built
        lazily at first trace (the wrappers' specs mirror the param
        tree, which the runner only sees per call)."""
        if self.mesh is None:
            cfg = self.cfg

            def prefill_fn(p, tokens, caches, chunked=False):
                return prefill(p, tokens, caches, cfg, chunked=chunked)

            def decode_fn(p, token, caches, batch_sharded=False):
                return decode_step(p, token, caches, cfg)

            return prefill_fn, decode_fn
        if self._sharded_fns is None:
            from repro.parallel import shard_ops
            pf, df = shard_ops.sharded_forward_fns(
                params, self.cfg, self.mesh, axis=self.tp_axis,
                data_axis=self.data_axis if self._dp > 1 else None)
            self._sharded_fns = (
                lambda p, t, c, chunked=False: pf(p, t, c, chunked=chunked),
                lambda p, t, c, batch_sharded=False: df(
                    p, t, c, batch_sharded=batch_sharded))
        return self._sharded_fns

    def _fresh_cache(self, prompt_pad: int):
        """Zero B=1 cache pytree for a whole-prompt prefill: paged subs
        sized to the page-rounded prompt, dense/state subs at their
        engine shapes (rows write back verbatim)."""
        kv = self.kv
        fresh: List[dict] = [dict() for _ in range(kv.group_count)]
        for spec in kv.specs:
            entry = {}
            for name, tail, dtype in spec.bufs:
                if spec.kind == PAGED:
                    shape = (spec.reps, 1, prompt_pad) + tail
                elif spec.kind == DENSE:
                    shape = (spec.reps, 1, spec.buf_len) + tail
                else:
                    shape = (spec.reps, 1) + tail
                entry[name] = jnp.zeros(shape, dtype)
            if spec.has_len:
                entry["len"] = jnp.zeros((spec.reps, 1), jnp.int32)
            fresh[spec.gi][spec.sub] = entry
        return fresh

    def _make_prefill(self, S: int):
        kv = self.kv
        ps = kv.page_size
        Sp = min(-(-S // ps) * ps, kv.capacity) if kv.has_paged else S
        n_pg = Sp // ps if kv.has_paged else 0
        temperature = self.temperature

        def fn(params, tokens, pools, dense, bt_row, lengths, slot, key):
            prefill_fn, _ = self._model_fns(params)
            logits, filled = prefill_fn(
                params, tokens, self._fresh_cache(Sp))
            nxt = _sample(logits, key, temperature)
            for spec in kv.specs:
                entry = filled[spec.gi][spec.sub]
                for name, _, _ in spec.bufs:
                    k = buf_key(spec, name)
                    if spec.kind == PAGED:
                        pools[k] = paged.scatter_pages(
                            pools[k], bt_row[:n_pg], entry[name][:, 0])
                    else:
                        dense[k] = dense[k].at[:, slot].set(entry[name][:, 0])
            lengths = lengths.at[slot].set(S)
            return nxt, logits, pools, dense, lengths

        return self._jit(fn, donate=(2, 3, 5))

    def _make_chunk(self, C: int):
        kv = self.kv
        ps = kv.page_size
        temperature = self.temperature

        def fn(params, tokens, pools, dense, block_table, lengths, slot,
               key):
            slots = jnp.reshape(slot, (1,))
            view = build_view(kv.specs, kv.group_count, pools, dense,
                              block_table, lengths, slots)
            prefill_fn, _ = self._model_fns(params)
            logits, new_caches = prefill_fn(params, tokens, view,
                                            chunked=True)
            nxt = _sample(logits, key, temperature)
            pos0 = lengths[slot]
            idxs = pos0 + jnp.arange(C, dtype=jnp.int32)
            for spec in kv.specs:
                entry = new_caches[spec.gi][spec.sub]
                for name, tail, _ in spec.bufs:
                    k = buf_key(spec, name)
                    if spec.kind == PAGED:
                        idx = idxs.reshape((1, 1, C) + (1,) * len(tail))
                        val = jnp.take_along_axis(
                            entry[name], idx, axis=2)[:, 0]
                        pools[k] = paged.scatter_positions(
                            pools[k], block_table[slot][idxs // ps],
                            idxs % ps, val)
                    else:
                        dense[k] = dense[k].at[:, slot].set(entry[name][:, 0])
            lengths = lengths.at[slot].set(pos0 + C)
            return nxt, logits, pools, dense, lengths

        return self._jit(fn, donate=(2, 3, 5))

    def _make_decode(self, Bp: int, n_steps: int):
        """Fused decode: gather the slot views ONCE, run `n_steps`
        feedback decode steps inside one `lax.scan`, scatter the
        `n_steps` new positions per slot once at the end.

        Each in-scan step is the UNCHANGED `decode_step` on the same
        contiguous view a single-step call would see (the view after an
        in-view append is elementwise identical to scatter-then-regather)
        so the emitted logits are bit-identical to `n_steps` separate
        calls — run-ahead buys dispatch/gather/scatter amortization, not
        different math."""
        kv = self.kv
        ps = kv.page_size
        temperature = self.temperature

        batch_sharded = self._dp > 1 and Bp % self._dp == 0

        def fn(params, tokens, pools, dense, block_table, lengths, slots,
               active, key):
            view = build_view(kv.specs, kv.group_count, pools, dense,
                              block_table, lengths, slots)
            _, decode_fn = self._model_fns(params)

            def body(carry, i):
                toks, caches = carry
                logits, caches = decode_fn(params, toks, caches,
                                           batch_sharded=batch_sharded)
                nxt = _sample(logits, jax.random.fold_in(key, i),
                              temperature)
                return (nxt, caches), (nxt, logits)

            (_, view), (nxts, logits) = jax.lax.scan(
                body, (tokens, view),
                jnp.arange(n_steps, dtype=jnp.int32))
            pos0 = jnp.where(active, lengths[slots], 0)
            idxs = pos0[:, None] + jnp.arange(
                n_steps, dtype=jnp.int32)[None]
            for spec in kv.specs:
                entry = view[spec.gi][spec.sub]
                if spec.kind == PAGED:
                    # Inactive rows scatter to the dummy page 0; nothing
                    # reads it, so collisions there are harmless.
                    pages = jnp.where(
                        active[:, None],
                        jnp.take_along_axis(block_table[slots],
                                            idxs // ps, axis=1), 0)
                    for name, tail, _ in spec.bufs:
                        k = buf_key(spec, name)
                        idx = idxs.reshape(
                            (1, Bp, n_steps) + (1,) * len(tail))
                        val = jnp.take_along_axis(entry[name], idx, axis=2)
                        pools[k] = paged.scatter_positions(
                            pools[k], pages, idxs % ps, val)
                else:
                    for name, _, _ in spec.bufs:
                        k = buf_key(spec, name)
                        nb = entry[name]
                        mask = active.reshape(
                            (1, Bp) + (1,) * (nb.ndim - 2))
                        dense[k] = dense[k].at[:, slots].set(
                            jnp.where(mask, nb, dense[k][:, slots]))
            lengths = lengths.at[slots].add(
                n_steps * active.astype(jnp.int32))
            nxts = jnp.where(active[None, :, None], nxts, 0)
            return nxts, logits, pools, dense, lengths

        return self._jit(fn, donate=(2, 3, 5))

    # -- public steps (thread kv state functionally) ------------------------

    def prefill_commit(self, params, prompt, slot: int, key):
        """Whole-prompt prefill into the slot's pages; returns
        (first sampled token (1,1), last-position logits (1, V))."""
        kv = self.kv
        S = int(prompt.shape[-1])
        fn = self._prefill_fns.get(S)
        if fn is None:
            fn = self._prefill_fns[S] = self._make_prefill(S)
        tokens = jnp.asarray(prompt, jnp.int32).reshape(1, S)
        bt_row = kv.block_table[slot]
        nxt, logits, kv.pools, kv.dense, kv.lengths = fn(
            params, tokens, kv.pools, kv.dense, bt_row, kv.lengths,
            jnp.int32(slot), key)
        return nxt, logits

    def chunk_prefill_commit(self, params, chunk, slot: int, key):
        """One prompt chunk through the offset-aware prefill path;
        returns (sampled token, logits) — only the FINAL chunk's sample
        is the request's first generated token."""
        kv = self.kv
        C = int(chunk.shape[-1])
        fn = self._chunk_fns.get(C)
        if fn is None:
            fn = self._chunk_fns[C] = self._make_chunk(C)
        tokens = jnp.asarray(chunk, jnp.int32).reshape(1, C)
        nxt, logits, kv.pools, kv.dense, kv.lengths = fn(
            params, tokens, kv.pools, kv.dense, kv.block_table, kv.lengths,
            jnp.int32(slot), key)
        return nxt, logits

    def decode_batch(self, params, slot_tokens: Dict[int, int], key,
                     steps: int = 1):
        """`steps` fused decode steps for every slot in `slot_tokens`.

        Pads the active set to a power-of-two bucket with DISTINCT
        parked slots (no index collisions with an active row), so
        compilation is per (bucket size, steps), not per batch
        composition.  The caller guarantees every active slot has
        `steps` positions of cache headroom.
        Returns {slot: (tokens list[int] of length `steps`, logits
        np.ndarray (steps, V))} — host values via ONE transfer each for
        tokens and logits; per-slot device slicing here would dispatch
        2B eager ops per step and dominate the step at small model
        sizes.
        """
        kv = self.kv
        act = sorted(slot_tokens)
        Bp = 1
        while Bp < len(act):
            Bp <<= 1
        Bp = min(Bp, kv.max_slots) if Bp > len(act) else Bp
        pad = [s for s in range(kv.max_slots) if s not in slot_tokens]
        slots = act + pad[:Bp - len(act)]
        comp = self._comp_cache.get((tuple(slots), len(act)))
        if comp is None:
            comp = (jnp.asarray(slots, jnp.int32),
                    jnp.asarray([True] * len(act)
                                + [False] * (Bp - len(act)), bool))
            self._comp_cache[(tuple(slots), len(act))] = comp
        tokens = [slot_tokens.get(s, 0) for s in slots]
        fn = self._decode_fns.get((Bp, steps))
        if fn is None:
            fn = self._decode_fns[(Bp, steps)] = self._make_decode(
                Bp, steps)
        nxt, logits, kv.pools, kv.dense, kv.lengths = fn(
            params, jnp.asarray(np.asarray(tokens, np.int32)[:, None]),
            kv.pools, kv.dense, kv.block_table, kv.lengths,
            comp[0], comp[1], key)
        nxt_h = np.asarray(nxt)          # (steps, Bp, 1)
        logits_h = np.asarray(logits)    # (steps, Bp, V)
        return {s: ([int(t) for t in nxt_h[:, i, 0]], logits_h[:, i])
                for i, s in enumerate(act)}
