"""Serving-side profiling helpers: panel discovery + decode autotuning.

Extracted from `launch/serve.py` so both the static CLI path and the
paged engine share one tuning surface.  Key discipline: every benchmark
tensor gets its OWN fold of the caller's key (`panel_keys`) — the old
code fed one `PRNGKey(seed)` to every weight panel AND its activations,
correlating the timed operands with each other (and, upstream, with the
model init), which biases sparsity/range-dependent timings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import canonical_formats


def panel_keys(key, idx: int, n: int = 2):
    """`n` independent keys for benchmark panel `idx`.

    fold_in(idx) separates panels; split separates the tensors WITHIN a
    panel — two tensors drawn here are never correlated with each other
    or with any other panel's draws.
    """
    return jax.random.split(jax.random.fold_in(key, idx), n)


def quantized_bytes(params) -> int:
    """Bytes of integer serving storage (packed words / significand and
    index planes; float32 scale tensors are NOT counted)."""
    return int(sum(
        l.size * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.integer)))


def weight_panels(params):
    """Distinct (d_in, d_out) of every packed weight that feeds the
    serving matmul.

    The embedding table is excluded: it is consumed by `embed_lookup` as
    a row GATHER, never by `vp_dequant_matmul` — tuning a (vocab, d)
    panel would burn vocab-sized benchmark matmuls and persist cache
    entries nothing reads (lm_head's (d, vocab) panel is the real one).
    """
    panels = set()

    def walk(node, name=""):
        if isinstance(node, dict):
            if "w_packed" in node:
                if name != "embed":
                    w = node["w_packed"]
                    panels.add((int(w.shape[-2]), int(w.shape[-1])))
                return
            for k, v in node.items():
                walk(v, k)
        elif isinstance(node, list):
            for v in node:
                walk(v, name)

    walk(params)
    return sorted(panels)


def attn_cache_geometries(cfg, max_len: int):
    """Distinct decode-attention cache geometries of the model's layer
    plan: (buf_len, window, rolling) per attention pattern — exactly the
    shapes `attn_block` will launch `vp_decode_attention` with."""
    from repro.models.model import layer_groups

    shapes = set()
    for group in layer_groups(cfg):
        for pattern in group.patterns:
            if pattern in ("mamba", "rwkv"):
                continue
            window = (cfg.sliding_window if pattern in ("swa", "moe_swa")
                      else (cfg.local_window if pattern == "local"
                            else None))
            buf_len = min(max_len, window) if window else max_len
            rolling = window is not None and buf_len <= window
            shapes.add((buf_len, window or 0, rolling))
    if cfg.family == "encdec":
        shapes.add((max_len, 0, False))
    return sorted(shapes)


def tune_decode_profile(params, cfg, batch: int, max_len: int = 0,
                        seed: int = 0):
    """Tune the serving kernels this process will launch at decode.

    Weight panels: `vp_dequant_matmul` at every M = 1..batch (persisted
    per (M, K, N)).  With a VP-quantized packed KV cache, ALSO profiles
    `vp_decode_attention` over the model's cache geometries (buf_len,
    window, rolling) at batch `batch` — the attention tile cache key
    includes the masking regime, so each geometry tunes separately.
    """
    from repro.kernels import autotune, ops, substrate
    from repro.core.packing import storage_dtype

    _, vp = canonical_formats(cfg.quant)
    backend = substrate.resolve_backend(None)
    if backend == "ref":
        # The ref path's math is tile-independent and never reads the
        # cache — measuring candidates here would record pure timer
        # noise and burn minutes of model-size matmuls for nothing.
        print("[serve] decode autotune profile skipped: backend is the "
              "jnp ref (blocks only affect kernel backends)")
        return {}
    key = jax.random.PRNGKey(seed)
    sizes = tuple(sorted({1 << p for p in range(batch.bit_length())
                          if (1 << p) <= batch} | {batch}))
    profile = {}
    for pi, (K, N) in enumerate(weight_panels(params)):
        kw, kx = panel_keys(key, pi)
        w = jax.random.randint(
            kw, (K, N), -8, 8).astype(storage_dtype(vp))
        x_full = jax.random.normal(kx, (max(sizes), K), jnp.float32)

        def bench(M, blocks, w=w, x_full=x_full):
            jax.block_until_ready(ops.vp_dequant_matmul(
                x_full[:M], w, vp, blocks=blocks))

        profile[(K, N)] = autotune.tune_serving_decode(
            "vp_dequant_matmul", K, N, (vp,), backend, bench,
            batch_sizes=sizes)
    if cfg.quant.quantize_kv_cache and cfg.quant.kv_layout == "packed" \
            and max_len:
        from repro.models.attention import kv_cache_formats

        _, kv_vp = kv_cache_formats(cfg.quant)
        KV, dh, H = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
        akey = jax.random.fold_in(key, -1)  # disjoint from panel folds
        for gi, (buf_len, window, rolling) in enumerate(
                attn_cache_geometries(cfg, max_len)):
            kk, kq = panel_keys(akey, gi)
            kw = jax.random.randint(
                kk, (batch, buf_len, KV, dh), -8, 8
            ).astype(storage_dtype(kv_vp))
            ks = jnp.ones((batch, buf_len, 1, 1), jnp.float32)
            q = jax.random.normal(kq, (batch, 1, H, dh), jnp.float32)
            lens = jnp.full((batch,), buf_len, jnp.int32)
            win = window or None

            def bench_attn(blocks, kw=kw, ks=ks, q=q, lens=lens, win=win,
                           rolling=rolling):
                jax.block_until_ready(ops.vp_decode_attention(
                    q, kw, kw, ks, ks, lens, kv_vp, window=win,
                    rolling=rolling, blocks=blocks))

            shape = (batch, buf_len, KV, dh, window, int(rolling))
            profile[("attn",) + shape] = autotune.tune(
                "vp_decode_attention", shape, (kv_vp,), backend,
                bench_attn,
                candidates=autotune.attn_candidates(H // KV, buf_len))
    return profile
