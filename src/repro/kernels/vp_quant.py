"""Pallas TPU kernel: FXP2VP tile quantizer (paper Fig. 3, vectorized).

Target: TPU (VMEM tiles, VPU integer ops).  Validated on CPU with
interpret=True against `ref.vp_quant_ref`.

The bit-window + LOD circuit is the substrate's `quantize_cascade`: an
unrolled chain of arithmetic shifts and in-range tests over the (static)
exponent list — bit-identical to the circuit (see core.convert docstring
for the equivalence proof).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FXPFormat, VPFormat
from repro.core.vp_tensor import significand_dtype
from . import substrate as sub

# Tile shape: multiple of the int8 min-tile (32, 128) and f32 min-tile (8, 128).
BLOCK_R, BLOCK_C = 256, 256


def _vp_quant_kernel(x_ref, m_ref, i_ref, *, fxp: FXPFormat, vp: VPFormat):
    m, i = sub.quantize_cascade(x_ref[...], fxp, vp)
    m_ref[...] = m.astype(m_ref.dtype)
    i_ref[...] = i.astype(jnp.uint8)


def _vp_quant_packed_kernel(x_ref, w_ref, *, fxp: FXPFormat, vp: VPFormat):
    w = sub.quantize_pack_cascade(x_ref[...], fxp, vp)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fxp", "vp", "interpret", "block"))
def vp_quant_pallas(
    x, fxp: FXPFormat, vp: VPFormat,
    interpret: bool = False,
    block=(BLOCK_R, BLOCK_C),
):
    """Quantize a 2D f32 array to VP planes with a tiled Pallas kernel."""
    R, C = x.shape
    br, bc = block
    spec = pl.BlockSpec((br, bc), lambda r, c: (r, c))
    m, i = sub.vp_pallas_call(
        functools.partial(_vp_quant_kernel, fxp=fxp, vp=vp),
        grid=(pl.cdiv(R, br), pl.cdiv(C, bc)),
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), significand_dtype(vp.M)),
            jax.ShapeDtypeStruct((R, C), jnp.uint8),
        ],
        interpret=interpret,
    )(x)
    return m, i


@functools.partial(
    jax.jit, static_argnames=("fxp", "vp", "interpret", "block"))
def vp_quant_packed_pallas(
    x, fxp: FXPFormat, vp: VPFormat,
    interpret: bool = False,
    block=(BLOCK_R, BLOCK_C),
):
    """Quantize a 2D f32 array straight to PACKED VP words (one plane).

    The Fig. 3 cascade plus the `(m << E) | i` word assembly fused into
    one kernel — the packed planes are born packed; the two-plane layout
    never exists, in HBM or anywhere else.
    """
    from repro.core.packing import storage_dtype

    R, C = x.shape
    br, bc = block
    spec = pl.BlockSpec((br, bc), lambda r, c: (r, c))
    return sub.vp_pallas_call(
        functools.partial(_vp_quant_packed_kernel, fxp=fxp, vp=vp),
        grid=(pl.cdiv(R, br), pl.cdiv(C, bc)),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), storage_dtype(vp)),
        interpret=interpret,
    )(x)
