"""Pallas TPU kernel: FXP2VP tile quantizer (paper Fig. 3, vectorized).

Target: TPU (VMEM tiles, VPU integer ops).  Validated on CPU with
interpret=True against `ref.vp_quant_ref`.

The bit-window + LOD circuit becomes an unrolled chain of arithmetic
shifts and in-range tests over the (static) exponent list — bit-identical
to the circuit (see core.convert docstring for the equivalence proof).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FXPFormat, VPFormat
from repro.core.vp_tensor import significand_dtype

# Tile shape: multiple of the int8 min-tile (32, 128) and f32 min-tile (8, 128).
BLOCK_R, BLOCK_C = 256, 256


def _vp_quant_kernel(x_ref, m_ref, i_ref, *, fxp: FXPFormat, vp: VPFormat):
    x = x_ref[...]
    raw = jnp.clip(
        jnp.round(x * jnp.float32(2.0 ** fxp.F)),
        fxp.raw_min, fxp.raw_max,
    ).astype(jnp.int32)

    lo, hi = vp.raw_min, vp.raw_max
    m_sel = jnp.zeros_like(raw)
    i_sel = jnp.zeros_like(raw)
    valid_any = jnp.zeros(raw.shape, jnp.bool_)
    for k in range(vp.K):
        s_k = fxp.F - vp.f[k]
        m_k = (
            jnp.right_shift(raw, s_k) if s_k >= 0
            else jnp.left_shift(raw, -s_k)
        )
        valid_k = (m_k >= lo) & (m_k <= hi)
        take = valid_k & ~valid_any
        m_sel = jnp.where(take, m_k, m_sel)
        i_sel = jnp.where(take, k, i_sel)
        valid_any = valid_any | valid_k
    s_last = fxp.F - vp.f[-1]
    m_last = jnp.clip(
        jnp.right_shift(raw, s_last) if s_last >= 0
        else jnp.left_shift(raw, -s_last),
        lo, hi,
    )
    m = jnp.where(valid_any, m_sel, m_last)
    i = jnp.where(valid_any, i_sel, vp.K - 1)
    m_ref[...] = m.astype(m_ref.dtype)
    i_ref[...] = i.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("fxp", "vp", "interpret", "block"))
def vp_quant_pallas(
    x, fxp: FXPFormat, vp: VPFormat,
    interpret: bool = False,
    block=(BLOCK_R, BLOCK_C),
):
    """Quantize a 2D f32 array to VP planes with a tiled Pallas kernel."""
    R, C = x.shape
    br, bc = block
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    spec = pl.BlockSpec((br, bc), lambda r, c: (r, c))
    m, i = pl.pallas_call(
        functools.partial(_vp_quant_kernel, fxp=fxp, vp=vp),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), significand_dtype(vp.M)),
            jax.ShapeDtypeStruct((R, C), jnp.uint8),
        ],
        interpret=interpret,
    )(x)
    return m, i
