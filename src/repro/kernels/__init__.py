"""Pallas TPU kernels for the VP compute hot-spots.

Each kernel has: <name>.py (kernel body + launch through substrate.py), a
pure-jnp oracle in ref.py, and a padded/dispatching public wrapper in
ops.py.  substrate.py is the shared launch layer: jax-version compat
shims, in-kernel dequant/quantize/LUT cascades, and the TPU-native /
interpret / CPU-ref backend dispatcher.
"""
from .ops import (
    vp_quant, vp_dequant, vp_matmul, block_vp_matmul, vp_quant_matmul,
    vp_dequant_matmul, vp_matmul_batched, vp_quant_matmul_batched,
    vp_decode_attention, flash_prefill,
)
from . import autotune, ref, ops, substrate

__all__ = ["vp_quant", "vp_dequant", "vp_matmul", "block_vp_matmul",
           "vp_quant_matmul", "vp_dequant_matmul",
           "vp_matmul_batched", "vp_quant_matmul_batched",
           "vp_decode_attention", "flash_prefill",
           "autotune", "ref", "ops", "substrate"]
