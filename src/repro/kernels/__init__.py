"""Pallas TPU kernels for the VP compute hot-spots.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), a pure-jnp oracle
in ref.py, and a padded/dispatching public wrapper in ops.py.
"""
from .ops import vp_quant, vp_dequant, vp_matmul, block_vp_matmul
from . import ref, ops

__all__ = ["vp_quant", "vp_dequant", "vp_matmul", "block_vp_matmul",
           "ref", "ops"]
