"""Pallas TPU kernels for the BACKWARD pass over packed VP words.

The forward serving matmul (`vp_dequant_matmul`) contracts a real
activation tile against a packed weight tile unpacked in VMEM; its VJP
needs two grad matmuls, and both keep the paper's property that the f32
weight plane never exists in HBM:

  dL/dx = g (M, N) @ dequant(w (K, N))^T          `vp_matmul_dx`
      The TRANSPOSED unpack-cascade matmul: the same packed weight tile
      the forward read is unpacked in VMEM (shift + mask + O(1)
      bit-assembled pow2 scale) and contracted over its OUTPUT dim —
      `dot_general` with both contraction dims = 1, so no materialized
      transpose either.  Grid (m, k, n) with n innermost accumulating
      the N-partials in a VMEM f32 scratch.

  dL/dB = dequant(a (M, K))^T @ g (M, N)          `vp_matmul_dw`
      The grad w.r.t. the SECOND operand of the fused quantize-matmul
      under the straight-through estimator: the packed QUANTIZED first
      operand (saved as the VJP residual at `storage_bits` per element
      instead of a float plane) is unpacked per tile and contracted over
      the batch dim M.  Grid (k, n, m) with m innermost.

Both reduce into f32 (`preferred_element_type`) — gradients are exactly
the high-dynamic-range signals the VP format exists for, so the narrow
words ride HBM and the accumulation stays wide on chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

BM, BK, BN = 256, 256, 256


def _vp_matmul_dx_kernel(
    g_ref, w_ref, o_ref, acc_ref, *, w_fmt: VPFormat, nn: int, dtype,
):
    ni = pl.program_id(2)
    sub.accum_init(acc_ref, ni)
    w = sub.dequant_packed(w_ref[...], w_fmt, dtype)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...].astype(dtype), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sub.accum_flush(o_ref, acc_ref, ni, nn)


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "interpret", "blocks", "out_dtype"),
)
def vp_matmul_dx_pallas(
    g, w,
    w_fmt: VPFormat,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """g (M, N) reals @ dequant(w (K, N) packed VP words)^T -> (M, K).

    Shapes must be tile-multiples of `blocks` = (bm, bk, bn); `ops.py`
    pads (packed word 0 decodes to real 0 and a zero g column contributes
    nothing, so padding is exact)."""
    (bm, bk, bn) = blocks
    M, N = g.shape
    K, _ = w.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    kernel = functools.partial(
        _vp_matmul_dx_kernel, w_fmt=w_fmt, nn=nn, dtype=jnp.float32)
    return sub.vp_pallas_call(
        kernel,
        grid=(nm, nk, nn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda mi, ki, ni: (mi, ni)),
            pl.BlockSpec((bk, bn), lambda mi, ki, ni: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda mi, ki, ni: (mi, ki)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[sub.vmem((bm, bk), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(g, w)


def _vp_matmul_dw_kernel(
    a_ref, g_ref, o_ref, acc_ref, *, a_fmt: VPFormat, nm: int, dtype,
):
    mi = pl.program_id(2)
    sub.accum_init(acc_ref, mi)
    a = sub.dequant_packed(a_ref[...], a_fmt, dtype)          # (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        a, g_ref[...].astype(dtype), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sub.accum_flush(o_ref, acc_ref, mi, nm)


@functools.partial(
    jax.jit,
    static_argnames=("a_fmt", "interpret", "blocks", "out_dtype"),
)
def vp_matmul_dw_pallas(
    a, g,
    a_fmt: VPFormat,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """dequant(a (M, K) packed VP words)^T @ g (M, N) reals -> (K, N)."""
    (bm, bk, bn) = blocks
    M, K = a.shape
    _, N = g.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    kernel = functools.partial(
        _vp_matmul_dw_kernel, a_fmt=a_fmt, nm=nm, dtype=jnp.float32)
    return sub.vp_pallas_call(
        kernel,
        grid=(nk, nn, nm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ki, ni, mi: (mi, ki)),
            pl.BlockSpec((bm, bn), lambda ki, ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda ki, ni, mi: (ki, ni)),
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        scratch_shapes=[sub.vmem((bk, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a, g)
