"""Block-size / dispatch autotuner with a persistent on-disk cache.

Every matmul kernel in this package takes a `(bm, bk, bn)` tile triple,
and the right triple is wildly shape-dependent: BENCH_pr2 measured a 13x
wall-clock swing between block sizes on the fused kernel at one shape,
and the hardcoded 256^3 default padded the MVM engine's (2U, B) x (B, 2)
operands up to full 256^3 tiles.  This module supplies the missing
policy, at two levels:

  * `heuristic_blocks` — the zero-measurement default: each axis clamps
    to the next power of two of the operand dimension (capped at the 256
    base), so a tile NEVER exceeds the padded operand shape.  Small
    shapes get one snug tile per axis instead of a 256^3 pad-out; big
    shapes keep the standard tiling.  This is shape-aware format/tile
    selection in the sense of Sentieys & Menard — static, cheap, always
    safe.
  * `tune` — the measured path: time a candidate set of block triples on
    the real kernel callable (min over repeats) and persist the winner
    in an on-disk JSON cache keyed by (kernel, shape, formats, backend).
    Serving processes (`resolve_blocks`) then hit the cache and launch
    the measured-best tiling with zero per-call overhead.

Cache location: `$REPRO_AUTOTUNE_CACHE` if set, else
`~/.cache/repro-vp/autotune.json`.  Delete the file (or call
`clear_cache()`) to re-tune from scratch; entries are keyed on
everything that affects kernel timing, so stale entries can only ever
cost speed, never correctness.
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

try:  # POSIX advisory file lock; absent on some platforms (Windows)
    import fcntl
except ImportError:  # pragma: no cover
    fcntl = None

from repro.analysis import vmem as _vmem

Blocks = Tuple[int, int, int]

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"
_BASE = (256, 256, 256)

_lock = threading.Lock()
# path -> {key: [bm, bk, bn]}; in-memory layer over the JSON file.
_caches: Dict[str, Dict[str, list]] = {}


# ---------------------------------------------------------------------------
# Cache plumbing
# ---------------------------------------------------------------------------

def cache_path() -> str:
    """Resolve the on-disk cache file (env override, else ~/.cache)."""
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro-vp", "autotune.json")


def _migrate_key(key: str) -> str:
    """Shim for pre-mesh cache files: 4-part `kernel|dims|fmts|backend`
    keys written before the mesh/shard segment existed describe
    single-device timings, which is exactly what `|mesh=1` now means —
    rewrite instead of invalidating them.  Keys of any other shape
    (including ad-hoc ones) pass through untouched."""
    if "|mesh=" in key or key.count("|") != 3:
        return key
    return f"{key}|mesh=1"


def _load(path: str) -> Dict[str, list]:
    with _lock:
        if path not in _caches:
            data: Dict[str, list] = {}
            try:
                with open(path) as f:
                    raw = json.load(f)
                data = {_migrate_key(k): list(v) for k, v in raw.items()
                        if isinstance(v, (list, tuple)) and len(v) == 3}
            except (OSError, ValueError):
                pass  # missing or corrupt cache: start empty
            _caches[path] = data
        return _caches[path]


def _save(path: str, data: Dict[str, list]) -> None:
    """Atomic write (tmp + rename) so concurrent tuners never torn-read."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


@contextlib.contextmanager
def _file_lock(path: str):
    """Cross-process advisory lock serializing read-merge-write cycles.

    Locks a sidecar `<path>.lock` (never the data file itself — the data
    file is replaced by rename, which would orphan a lock on its inode).
    Without it, two PROCESSES could interleave between `record`'s re-read
    and its rename and one would silently drop the other's entries; the
    `threading.Lock` only serializes threads within one process.
    No-ops where `fcntl` is unavailable (back to the narrow-window
    best-effort behavior).
    """
    if fcntl is None:
        yield
        return
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(f"{path}.lock", "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


def clear_cache() -> None:
    """Drop the cache file and the in-memory layer (cold start)."""
    path = cache_path()
    with _lock:
        _caches.pop(path, None)
    try:
        os.remove(path)
    except OSError:
        pass


_mesh_local = threading.local()


@contextlib.contextmanager
def mesh_scope(desc: str):
    """Tag autotune keys with the active mesh/shard geometry.

    Entered by the sharded execution paths (`parallel.shard_ops`, the
    sweep driver) around per-shard kernel calls: inside the scope,
    `make_key` appends `|mesh=<desc>` so a timing measured on a PER-SHARD
    operand shape can never overwrite the single-device entry for the
    same logical shape (tile feasibility and arithmetic intensity both
    change with the shard).  Outside any scope the segment is `mesh=1`
    — the canonical single-device marker legacy cache files migrate to.
    """
    prev = getattr(_mesh_local, "desc", None)
    _mesh_local.desc = str(desc)
    try:
        yield
    finally:
        _mesh_local.desc = prev


def mesh_desc(mesh, axis: str = "model", spec: str = "N") -> str:
    """Canonical scope string for a mesh axis + shard spec, e.g.
    `model8.N` (weight output dim sharded 8 ways over "model")."""
    size = mesh.shape[axis] if axis in mesh.shape else 1
    return f"{axis}{size}.{spec}"


def current_mesh_desc() -> str:
    return getattr(_mesh_local, "desc", None) or "1"


def make_key(
    kernel: str,
    shape: Sequence[int],
    formats: Sequence,
    backend: str,
    mesh: Optional[str] = None,
) -> str:
    """Cache key: everything that affects which tiling wins.

    `shape` is the logical operand shape ((M, K, N) or (G, M, K, N));
    `formats` any sequence of FXPFormat/VPFormat (their reprs are stable
    and fully determine the in-kernel cascade structure); `mesh` the
    mesh/shard geometry segment (defaults to the active `mesh_scope`,
    `"1"` when single-device).
    """
    fmts = ",".join(repr(f) for f in formats)
    dims = "x".join(str(int(d)) for d in shape)
    seg = mesh if mesh is not None else current_mesh_desc()
    return f"{kernel}|{dims}|{fmts}|{backend}|mesh={seg}"


def get_cached(key: str) -> Optional[Blocks]:
    v = _load(cache_path()).get(key)
    return tuple(v) if v else None


def record(key: str, blocks: Blocks) -> None:
    """Persist one entry, merging with what is on disk RIGHT NOW.

    The read-merge-write cycle runs under BOTH the thread lock (peers in
    this process) and a cross-process `flock` on a sidecar lock file
    (peer serving/tuning processes sharing the cache), then writes via
    temp-file + `os.replace`.  Concurrent writers therefore each persist
    the union — no interleaving can drop a peer's entries or leave a
    torn file.
    """
    path = cache_path()
    mem = _load(path)
    with _lock, _file_lock(path):
        fresh: Dict[str, list] = {}
        try:
            with open(path) as f:
                raw = json.load(f)
            fresh = {k: list(v) for k, v in raw.items()
                     if isinstance(v, (list, tuple)) and len(v) == 3}
        except (OSError, ValueError):
            pass
        fresh.update(mem)
        fresh[key] = list(blocks)
        _caches[path] = fresh
        _save(path, fresh)


# ---------------------------------------------------------------------------
# Heuristic default (no measurement): never tile beyond the padded shape
# ---------------------------------------------------------------------------

def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def heuristic_blocks(
    M: int, K: int, N: int, base: Blocks = _BASE,
) -> Blocks:
    """Shape-clamped default tiling.

    Each axis: `min(base, next_pow2(dim))` — a dimension smaller than the
    base block gets exactly one power-of-two tile covering it (the pad is
    < 2x, versus up to 128x under the hardcoded 256^3), while large
    dimensions keep the standard base tile.
    """
    return (
        min(base[0], _pow2_at_least(max(M, 1))),
        min(base[1], _pow2_at_least(max(K, 1))),
        min(base[2], _pow2_at_least(max(N, 1))),
    )


def _native_floor(blocks: Blocks) -> Blocks:
    """Mosaic-safe minimum tile for the TPU-native backend.

    The f32 min tile is (8 sublanes, 128 lanes); a heuristic tile below
    that on the lane axes (bk for the A tile, bn for B and the output)
    risks failing to lower or relayouting badly.  Interpret/ref backends
    have no such constraint and keep the snug clamp.
    """
    bm, bk, bn = blocks
    return (max(bm, 8), max(bk, 128), max(bn, 128))


def resolve_blocks(
    kernel: str,
    shape: Sequence[int],
    formats: Sequence,
    backend: str,
    blocks: Optional[Blocks] = None,
    use_cache: bool = True,
) -> Blocks:
    """The one block-resolution policy for ops.py and the MIMO engines.

    Explicit `blocks` win; otherwise a cache hit from a previous `tune`
    run (measured on this backend, so trusted as-is); otherwise the
    shape-clamped heuristic — floored to the Mosaic minimum tile on the
    TPU-native backend.  `shape`'s last three entries are (M, K, N).
    ``use_cache=False`` skips the cache layer: CSPADE-masked calls need
    a DETERMINISTIC grid (their masks were not built on a tuned entry's
    grid) but must still share this heuristic + native-floor policy.
    """
    if blocks is not None:
        return tuple(blocks)
    if use_cache:
        cached = get_cached(make_key(kernel, shape, formats, backend))
        if cached is not None:
            return cached
    M, K, N = (int(d) for d in shape[-3:])
    h = heuristic_blocks(M, K, N)
    return _native_floor(h) if backend == "native" else h


# ---------------------------------------------------------------------------
# Measured tuning
# ---------------------------------------------------------------------------

def default_candidates(M: int, K: int, N: int) -> Tuple[Blocks, ...]:
    """Candidate tilings for a shape: the heuristic plus clamped
    square-ish bases — small enough to time in seconds, wide enough to
    cover the 13x swing observed across block sizes."""
    cands = [heuristic_blocks(M, K, N)]
    for b in (128, 256, 512):
        cands.append(heuristic_blocks(M, K, N, base=(b, b, b)))
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return tuple(out)


def skinny_candidates(M: int, K: int, N: int) -> Tuple[Blocks, ...]:
    """Candidate tilings for skinny-M (LLM decode) shapes.

    Decode multiplies an (M = batch, K) activation block against a wide
    (K, N) weight panel, so M is tiny while K/N are model dimensions: the
    interesting trade is how much of the weight panel to stream per tile
    (bigger bk*bn amortizes the per-tile unpack/dequant; smaller tiles
    keep the accumulator cheap).  The square `default_candidates` never
    explore that axis, so serving adds K/N-elongated tiles at the snug M.
    """
    bm = min(_BASE[0], _pow2_at_least(max(M, 1)))
    cands = list(default_candidates(M, K, N))
    for bk, bn in ((256, 512), (512, 256), (512, 512), (128, 512)):
        cands.append((
            bm,
            min(bk, _pow2_at_least(max(K, 1))),
            min(bn, _pow2_at_least(max(N, 1))),
        ))
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return tuple(out)


def attn_candidates(sq: int, sk: int) -> Tuple[Blocks, ...]:
    """Candidate (bq, bkv, 1) chunkings for the attention kernels.

    The attention grid tiles two sequence axes instead of (M, K, N): bq
    chunks the query rows, bkv the key/value positions.  Decode is
    skinny on the query side (sq = grouped heads per KV head), so the
    interesting trade is the KV seq tile — bigger tiles amortize the
    per-tile unpack/dequant of packed cache words, smaller tiles skip
    more invalid work near the valid-length boundary.  The trailing 1
    keeps the on-disk cache's 3-entry block format.
    """
    seen, out = set(), []
    for bq in (128, 256):
        for bk in (128, 256, 512):
            c = (min(bq, _pow2_at_least(max(sq, 1))),
                 min(bk, _pow2_at_least(max(sk, 1))), 1)
            if c not in seen:
                seen.add(c)
                out.append(c)
    return tuple(out)


def resolve_attn_blocks(
    kernel: str,
    shape: Sequence[int],
    formats: Sequence,
    backend: str,
    sq: int,
    sk: int,
    blocks: Optional[Blocks] = None,
) -> Blocks:
    """Block resolution for the attention kernels (decode + flash prefill).

    Same policy as `resolve_blocks` — explicit blocks win, then a tuned
    cache entry, then a shape-clamped heuristic — but the heuristic
    clamps the (bq, bkv) seq chunks instead of (M, K, N) tiles.  `shape`
    is the full cache key, INCLUDING the window/rolling attributes the
    kernel specializes on ((B, Smax, KV, dh, window, rolling) for decode;
    (B, H, KV, dh, Sq, Sk, window) for prefill): a tiling measured for
    one masking regime must not leak to another, whose skipped-tile
    pattern differs.
    """
    if blocks is not None:
        return tuple(blocks)
    cached = get_cached(make_key(kernel, shape, formats, backend))
    if cached is not None:
        return cached
    bq = min(128, _pow2_at_least(max(sq, 1)))
    bk = min(256, _pow2_at_least(max(sk, 1)))
    if backend == "native":
        bq, bk = max(bq, 8), max(bk, 128)
    return (bq, bk, 1)


def tune_serving_decode(
    kernel: str,
    K: int,
    N: int,
    formats: Sequence,
    backend: str,
    bench_fn: Callable[[int, Blocks], None],
    batch_sizes: Sequence[int] = (1, 2, 4, 8),
    repeats: int = 3,
) -> Dict[int, Blocks]:
    """The M=1..B skinny-decode profile for a serving matmul.

    Tunes `kernel` at every decode batch size in `batch_sizes` over the
    fixed (K, N) weight panel — one persisted cache entry per (M, K, N)
    — so a serving process decoding at any of those batch sizes hits a
    measured tiling from `resolve_blocks`.  `bench_fn(M, blocks)` must
    run the kernel to completion at activation shape (M, K).
    """
    out: Dict[int, Blocks] = {}
    for M in batch_sizes:
        out[M] = tune(
            kernel, (M, K, N), formats, backend,
            functools.partial(bench_fn, M),
            candidates=skinny_candidates(M, K, N),
            repeats=repeats,
        )
    return out


def tune(
    kernel: str,
    shape: Sequence[int],
    formats: Sequence,
    backend: str,
    bench_fn: Callable[[Blocks], None],
    candidates: Optional[Iterable[Blocks]] = None,
    repeats: int = 3,
    shards: Optional[Sequence[int]] = None,
) -> Blocks:
    """Measure `bench_fn(blocks)` over candidates, persist + return the best.

    `bench_fn` must run the kernel to completion (block_until_ready) for
    the given block triple; the first call per candidate warms compile
    caches and is discarded, then the MIN over `repeats` timed runs
    scores it (min is the standard noise-robust statistic for
    wall-clock).  The winner lands in the on-disk cache under
    `make_key(...)`, so every later `resolve_blocks` call with the same
    key launches it for free.

    Candidates whose static VMEM footprint (`repro.analysis.vmem` — a
    lower bound built from the kernel's block specs and scratch shapes)
    exceeds the per-core budget are pruned BEFORE timing: they could only
    ever fail to lower, so skipping them shortens tuning without changing
    any winner.  Unmodeled kernels are never pruned.
    """
    key = make_key(kernel, shape, formats, backend)
    cached = get_cached(key)
    if cached is not None:
        return cached
    M, K, N = (int(d) for d in shape[-3:])
    cands = tuple(candidates) if candidates else default_candidates(M, K, N)
    budget = _vmem.vmem_budget_bytes()
    feasible, pruned = [], []
    for blocks in cands:
        ok, need = _vmem.vmem_feasible(
            kernel, blocks, formats, shape, budget=budget, shards=shards)
        (feasible if ok else pruned).append((blocks, need))
    if not feasible:
        raise RuntimeError(
            f"autotune: every candidate tiling for {key} exceeds the "
            f"{budget}-byte VMEM budget (smallest modeled footprint "
            f"{min(n for _, n in pruned)} bytes — repro.analysis.vmem); "
            "pass smaller explicit blocks or candidates")
    best, best_t = None, float("inf")
    last_err: Optional[Exception] = None
    for blocks, _ in feasible:
        try:
            bench_fn(blocks)  # warmup / compile
            t = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                bench_fn(blocks)
                t = min(t, time.perf_counter() - t0)
        except Exception as e:  # a candidate that fails to lower just loses
            last_err = e
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        # EVERY candidate failed: the bench_fn itself is broken (wrong
        # shapes/formats, mask-grid mismatch...).  Recording the untested
        # heuristic as a "tuned winner" would hide that forever.
        raise RuntimeError(
            f"autotune: all {len(feasible)} feasible candidates failed "
            f"for {key}; last error: {last_err!r}") from last_err
    record(key, best)
    return best
