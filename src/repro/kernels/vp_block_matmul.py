"""Pallas TPU kernel: block-VP int8 MXU matmul (beyond-paper, TPU-native).

One exponent index per (row x k-tile) of A and per (k-tile x col) of B —
the VP analogue of block floating point, but over an ARBITRARY exponent
list.  Significands stay int8 all the way into the MXU
(int8 x int8 -> int32, 2x the bf16 rate on v5e-class chips); the int32
tile accumulator is then scaled by the factorized product scales
   2^-(f_a[ia] + f_b[ib]) = lutA[ia] * lutB[ib]
(one VPU multiply per row/col vector) — the paper's "no exponent
addition" property: per-product exponent work is two tiny LUT reads
(`substrate.scale_lut_gather`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

BM, BK, BN = 256, 256, 256


def _block_vp_matmul_kernel(
    a_m_ref, a_i_ref, b_m_ref, b_i_ref, o_ref, acc_ref,
    *, a_fmt: VPFormat, b_fmt: VPFormat, nk: int,
):
    ki = pl.program_id(2)
    sub.accum_init(acc_ref, ki)

    # int8 x int8 -> int32 on the MXU.
    acc_i32 = jax.lax.dot_general(
        a_m_ref[...], b_m_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # Factorized scales: one per A row, one per B col (this k-tile) —
    # bit-assembled in O(1) per element (select-chain fallback inside).
    sa = sub.scale_of_index(a_i_ref[...], a_fmt, jnp.float32)  # (bm, 1)
    sb = sub.scale_of_index(b_i_ref[...], b_fmt, jnp.float32)  # (1, bn)
    acc_ref[...] += acc_i32.astype(jnp.float32) * sa * sb

    sub.accum_flush(o_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=("a_fmt", "b_fmt", "interpret", "blocks", "out_dtype"),
)
def block_vp_matmul_pallas(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """Block-VP matmul.

    a_m (M, K) int8, a_i (M, K/bk) uint8; b_m (K, N) int8, b_i (K/bk, N)
    uint8.  The exponent-index granularity equals the kernel k-tile.
    """
    (bm, bk, bn) = blocks
    M, K = a_m.shape
    _, N = b_m.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    assert a_i.shape == (M, nk), (a_i.shape, (M, nk))
    assert b_i.shape == (nk, N), (b_i.shape, (nk, N))

    kernel = functools.partial(
        _block_vp_matmul_kernel, a_fmt=a_fmt, b_fmt=b_fmt, nk=nk)
    return sub.vp_pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bm, 1), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a_m, a_i, b_m, b_i)
