"""Block-table-aware page ops for the paged packed-KV cache.

A paged cache stores every sequence buffer as a pool of FIXED-SIZE pages
(`(reps, n_pages, page_size, *tail)`); a request owns an ordered list of
page ids (its block-table row) instead of a contiguous span.  Admission
and eviction are then pure metadata — pages change owner by index, and
the packed VP words inside them are NEVER copied or dequantized when
requests come and go.

These ops are the only code that touches the pool layout:

  * `gather_pages`    — block table -> contiguous per-request view
                        (what `vp_decode_attention` / the jnp ref core
                        consume, masked by the scalar-prefetched
                        per-request `lengths`)
  * `scatter_pages`   — write whole pages (prefill commits a prompt)
  * `scatter_positions` — write single positions (decode commits one
                        token per request; chunked prefill commits a
                        chunk that may straddle pages)

On the jnp/ref backend these lower to one XLA gather / scatter over the
page axis.  On the TPU-native backend the same block-table row becomes
the scalar-prefetch argument of the Pallas decode kernel (the kernel
DMAs pages by id instead of gathering a contiguous view in HBM first) —
that lowering rides the existing `vp_decode_attention` grid and is
tracked in ROADMAP open item 1's follow-up; every caller goes through
this module so the swap is local.

Page 0 is reserved as the DUMMY page: free-list allocation never hands
it out, and masked writes (inactive batch rows) land there.  Nothing
ever reads it back — tests poison it to prove that.
"""
from __future__ import annotations


def gather_pages(pool, page_ids):
    """Pool view through a block table.

    pool (reps, n_pages, page_size, *tail), page_ids (B, P) int32 ->
    (reps, B, P * page_size, *tail): request b's pages concatenated in
    block-table order — a contiguous cache view whose positions
    [0, lengths[b]) are valid.
    """
    reps, _, ps = pool.shape[:3]
    B, P = page_ids.shape
    g = pool[:, page_ids]                      # (reps, B, P, ps, *tail)
    return g.reshape(reps, B, P * ps, *pool.shape[3:])


def scatter_pages(pool, page_ids, values):
    """Write whole pages (one request's prefill commit).

    page_ids (P,) int32, values (reps, P * page_size, *tail) -> pool'.
    """
    reps, _, ps = pool.shape[:3]
    P = page_ids.shape[0]
    v = values.reshape(reps, P, ps, *pool.shape[3:])
    return pool.at[:, page_ids].set(v)


def scatter_positions(pool, page_ids, offsets, values):
    """Write single in-page positions (decode / chunked-prefill commit).

    page_ids (N,) int32 (page per position — duplicates allowed only on
    the dummy page 0), offsets (N,) int32 in [0, page_size), values
    (reps, N, *tail) -> pool'.
    """
    return pool.at[:, page_ids, offsets].set(values)


def flip_bit(pool, page, offset, bit):
    """XOR one bit of the first stored element at (`page`, `offset`).

    The fault-injection primitive for the chaos harness: corrupts ONE
    packed VP word (or one float cache element, via a same-width integer
    bitcast) in place, exactly as an HBM upset would — no other word in
    the pool changes, so the chaos suite can assert the corruption never
    escapes the page's owning request.  Targets rep 0 and the first tail
    element; `bit` is masked into the dtype's width.
    """
    import jax
    import jax.numpy as jnp

    idx = (0, page, offset) + (0,) * (pool.ndim - 3)
    word = pool[idx]
    if jnp.issubdtype(pool.dtype, jnp.integer):
        # XOR in int32 (a 1<<7 mask does not FIT int8) and wrap back.
        nbits = jnp.iinfo(pool.dtype).bits
        mask = jnp.int32(1 << (bit % nbits))
        flipped = (word.astype(jnp.int32) ^ mask).astype(pool.dtype)
    else:
        itype = {2: jnp.uint16, 4: jnp.uint32,
                 8: jnp.uint64}[pool.dtype.itemsize]
        nbits = pool.dtype.itemsize * 8
        raw = jax.lax.bitcast_convert_type(word, itype)
        raw = raw ^ itype(1 << (bit % nbits))
        flipped = jax.lax.bitcast_convert_type(raw, pool.dtype)
    return pool.at[idx].set(flipped)
