"""Pallas TPU kernel: fused FXP2VP quantize + VP matmul (float in, f32 out).

The unfused path materializes (significand, index) planes in HBM between
`vp_quant` and `vp_matmul`; serving MVMs quantize operands immediately
before the product, so the extra round-trip is pure HBM traffic.  This
kernel folds the Fig. 3 quantize cascade into the matmul's VMEM tiles:
each float operand tile is quantized in-register, pushed straight through
the scale-LUT dequant (so the MXU sees exactly the VP-rounded reals the
unfused path would), and accumulated — one `pallas_call`, no quantized
plane ever touching HBM.

The tradeoff: each A tile is visited (and re-quantized) once per n-step
and each B tile once per m-step, so the cascade work scales with the grid
fan-out while the saved HBM traffic is fixed — fusion wins when the
output grid is a few tiles per axis (the serving-MVM shape), not for
huge square matmuls.  Callers that reuse quantized operands across many
products (or large grids) should prefer vp_quant + vp_matmul;
mvm_engine gates its fused default on exactly this.

CSPADE tile-activity masks work exactly as in `vp_matmul` (scalar-prefetch
flags + `pl.when` skip).  Numerics are bit-identical to
`vp_quant` -> `vp_matmul`, which is what tests/test_substrate_kernels.py
asserts against the ref oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FXPFormat, VPFormat
from . import substrate as sub

BM, BK, BN = 256, 256, 256


def _vp_quant_matmul_kernel(
    # scalar-prefetch operands (SMEM)
    a_act_ref, b_act_ref,
    # tensor operands (VMEM tiles, float)
    a_ref, b_ref,
    # outputs / scratch
    o_ref, acc_ref,
    *, a_fxp: FXPFormat, a_vp: VPFormat, b_fxp: FXPFormat, b_vp: VPFormat,
    nk: int, cspade: bool, dtype,
):
    ki = pl.program_id(2)
    sub.accum_init(acc_ref, ki)

    def _compute():
        a = sub.quantize_dequant_cascade(a_ref[...], a_fxp, a_vp, dtype)
        b = sub.quantize_dequant_cascade(b_ref[...], b_fxp, b_vp, dtype)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if cspade:
        mi, ni = pl.program_id(0), pl.program_id(1)
        active = (a_act_ref[mi, ki] | b_act_ref[ki, ni]) != 0
        pl.when(active)(_compute)
    else:
        _compute()

    sub.accum_flush(o_ref, acc_ref, ki, nk)


def _vp_quant_matmul_batched_kernel(
    # scalar-prefetch operands (SMEM)
    a_act_ref, b_act_ref,
    # tensor operands (VMEM tiles, float)
    a_ref, b_ref,
    # outputs / scratch
    o_ref, acc_ref,
    *, a_fxp: FXPFormat, a_vp: VPFormat, b_fxp: FXPFormat, b_vp: VPFormat,
    nk: int, cspade: bool, dtype,
):
    ki = pl.program_id(3)
    sub.accum_init(acc_ref, ki)

    def _compute():
        a = sub.quantize_dequant_cascade(a_ref[0], a_fxp, a_vp, dtype)
        b = sub.quantize_dequant_cascade(b_ref[0], b_fxp, b_vp, dtype)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if cspade:
        gi, mi, ni = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        active = (a_act_ref[gi, mi, ki] | b_act_ref[gi, ki, ni]) != 0
        pl.when(active)(_compute)
    else:
        _compute()

    sub.accum_flush(o_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fxp", "a_vp", "b_fxp", "b_vp", "interpret", "blocks", "out_dtype"),
)
def vp_quant_matmul_batched_pallas(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """Truly-batched fused quantize+matmul: (G, M, K) x (G, K, N) floats.

    Each batch element runs its own tile program on the (batch, m, n, k)
    grid; the Fig. 3 quantize cascade runs in-register on every operand
    tile exactly as in the unbatched fused kernel, so numerics are
    bit-identical to `vp_quant` -> `vp_matmul_batched` per batch element.
    `a_act` (G, M/bm, K/bk) / `b_act` (G, K/bk, N/bn) CSPADE flags.
    """
    (bm, bk, bn) = blocks
    G, M, K = a.shape
    _, _, N = b.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    cspade = a_act is not None
    if not cspade:
        a_act = jnp.ones((G, nm, nk), jnp.int32)
        b_act = jnp.ones((G, nk, nn), jnp.int32)

    kernel = functools.partial(
        _vp_quant_matmul_batched_kernel,
        a_fxp=a_fxp, a_vp=a_vp, b_fxp=b_fxp, b_vp=b_vp,
        nk=nk, cspade=cspade, dtype=jnp.float32,
    )
    grid, in_specs, out_specs, semantics = sub.batched_matmul_grid(
        G, nm, nn, nk, bm, bk, bn, a_copies=1, b_copies=1)
    return sub.vp_pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((G, M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        num_scalar_prefetch=2,
        dimension_semantics=semantics,
        interpret=interpret,
    )(a_act, b_act, a, b)


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fxp", "a_vp", "b_fxp", "b_vp", "interpret", "blocks", "out_dtype"),
)
def vp_quant_matmul_pallas(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """Fused quantize+matmul: float a (M, K) x float b (K, N) -> (M, N).

    `a_act` (M/bm, K/bk) / `b_act` (K/bk, N/bn) int32 CSPADE tile-activity
    flags (None disables the skip logic).  Shapes must be tile-multiples
    (ops.py pads; zero padding quantizes to (m=0, i=0) and contributes 0).
    """
    (bm, bk, bn) = blocks
    M, K = a.shape
    _, N = b.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    cspade = a_act is not None
    if not cspade:
        a_act = jnp.ones((nm, nk), jnp.int32)
        b_act = jnp.ones((nk, nn), jnp.int32)

    kernel = functools.partial(
        _vp_quant_matmul_kernel,
        a_fxp=a_fxp, a_vp=a_vp, b_fxp=b_fxp, b_vp=b_vp,
        nk=nk, cspade=cspade, dtype=jnp.float32,
    )
    return sub.vp_pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki, *_: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki, *_: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki, *_: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        num_scalar_prefetch=2,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a_act, b_act, a, b)
