"""Pallas TPU kernels: fused VP-cache attention (decode + flash prefill).

The serving hot path PR 4 did not touch: attention.  Before this module,
every decode step dequantized the ENTIRE (B, Smax, KV, dh) VP KV cache to
floats in XLA and ran a masked softmax over all Smax positions — O(Smax)
HBM traffic and compute regardless of how many cache slots are actually
valid.  These kernels keep the cache in PACKED VP words (`core.packing`:
sign + significand + exponent index in one int8/int16 per element) all
the way into VMEM and do the unpack + bit-assembled pow2 scale in-tile,
which is the paper's claim (compact formats feed the multiplier directly)
restated for the memory-bound cache read.

Two kernels, both on the shared substrate:

  * `vp_decode_attention_pallas` — single-token decode against a packed
    KV cache.  Grid is (batch, kv_head, seq-tile) with the seq dimension
    innermost; per-batch cache lengths ride scalar prefetch, and a tile
    whose span [ki*bs, ki*bs + bs) lies entirely outside the valid range
    (past `len`, before the sliding-window lower bound, or past the
    rolling ring's fill level) is SKIPPED via `pl.when` — the same
    static-bounds trick `flash_attention`'s pair enumeration uses, so
    MXU work is O(cache_len · B · H · dh), not O(Smax).  Per-position
    pow2 cache scales multiply the score/probability COLUMNS instead of
    the K/V rows (exactly equal for power-of-two scales, and it keeps
    every in-kernel operand in its natural layout).

  * `flash_prefill_pallas` — q-chunk x k-chunk online-softmax attention
    (causal / local / full masks) for the prefill pass, replacing the
    `lax.scan` pair-walk on kernel backends.  Tiles entirely above the
    causal diagonal or entirely older than the local window are skipped
    by program-id bounds; in-tile masking handles the diagonal fringe
    and the key-side padding.

Online-softmax state (running max m, denominator l, output accumulator)
lives in VMEM scratch shaped (rows, 128) / (rows, dh) and persists across
the innermost seq-tile steps; the output tile is written once, on the
last seq step, divided by the accumulated denominator.  Launch plumbing
(compat shims, scalar prefetch) is `substrate.vp_pallas_call`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

NEG_INF = -1e30
# m/l scratch rows are lane-broadcast to the TPU lane count so the
# scratch tiles are natively shaped; every lane of a row holds the same
# running statistic.
_LANES = 128


def _online_softmax_update(s, v, vs_row, m_ref, l_ref, acc_ref):
    """One flash-attention accumulation step for a scores tile `s`.

    s (rows, bs) f32 scores (already masked), v (bs, dh) values,
    `vs_row` (1, bs) per-position value scales folded into the
    probability columns (p @ (v * vs) == (p * vs) @ v, exact for pow2
    scales).  Updates the running (m, l, acc) scratch in place.
    """
    m_prev = m_ref[...]                      # (rows, LANES), lanes equal
    l_prev = l_ref[...]
    m_curr = jnp.max(s, axis=1)[:, None]     # (rows, 1)
    m_next = jnp.maximum(m_prev, m_curr)     # lane-broadcast
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next[:, :1])           # (rows, bs)
    l_next = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
    m_ref[...] = m_next
    l_ref[...] = l_next
    if vs_row is not None:
        p = p * vs_row
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)


def _flush(o_ref, m_ref, l_ref, acc_ref, ki, nk: int):
    """Write acc / l to the output tile on the last seq step."""
    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...][:, :1], 1e-30)
        out = acc_ref[...] / l
        o_ref[...] = out.astype(o_ref.dtype).reshape(o_ref.shape)


# ---------------------------------------------------------------------------
# Decode: one query token vs a packed VP KV cache
# ---------------------------------------------------------------------------

def _decode_attn_kernel(
    len_ref,                     # scalar prefetch: (B,) int32 cache lengths
    q_ref, kw_ref, ks_ref, vw_ref, vs_ref,
    o_ref,
    m_ref, l_ref, acc_ref,
    *, fmt: VPFormat, bs: int, nk: int, smax: int,
    window: Optional[int], rolling: bool,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    start = ki * bs
    # Valid-position bounds for this batch element.  `rolling` means the
    # buffer IS the window (every slot written so far is valid); `window`
    # bounds the span from below; otherwise all positions < length count.
    if rolling:
        lo = jnp.int32(0)
        hi = jnp.minimum(length, smax)
    elif window:
        lo = jnp.maximum(length - window, 0)
        hi = length
    else:
        lo = jnp.int32(0)
        hi = length
    run = (start < hi) & (start + bs > lo)

    @pl.when(run)
    def _tile():
        q = q_ref[0, 0]                          # (Gp, dh) f32, pre-scaled
        kw = kw_ref[0, :, 0, :]                  # (bs, dh) packed words
        vw = vw_ref[0, :, 0, :]
        ks_row = ks_ref[...].astype(jnp.float32)  # (1, bs) pow2 scales
        vs_row = vs_ref[...].astype(jnp.float32)
        k = sub.dequant_packed(kw, fmt, jnp.float32)
        v = sub.dequant_packed(vw, fmt, jnp.float32)
        # scores: q @ k^T, per-position cache scale folded into columns
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s = s * ks_row
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = (pos >= lo) & (pos < hi)
        s = jnp.where(valid, s, NEG_INF)
        _online_softmax_update(s, v, vs_row, m_ref, l_ref, acc_ref)

    _flush(o_ref, m_ref, l_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "window", "rolling", "bs", "smax", "interpret",
                     "out_dtype"),
)
def vp_decode_attention_pallas(
    q, k_w, v_w, k_s, v_s, lengths,
    fmt: VPFormat,
    window: Optional[int] = None,
    rolling: bool = False,
    bs: int = 256,
    smax: Optional[int] = None,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Decode attention over a PACKED VP KV cache.

    q (B, KV, Gp, dh) f32, already scaled by dh**-0.5; k_w / v_w
    (B, Smax_p, KV, dh) packed VP words; k_s / v_s (B, Smax_p)
    per-position pow2 cache scales; lengths (B,) int32 valid lengths.
    Smax_p must be a multiple of `bs` (ops.py pads).  `smax` is the REAL
    (pre-pad) buffer length: the rolling ring clamps its valid span to
    it — clamping to the padded length would admit zero-score padding
    columns into the softmax denominator once the ring wraps
    (lengths > smax).  Returns (B, KV, Gp, dh).
    """
    B, KV, Gp, dh = q.shape
    smax_p = k_w.shape[1]
    nk = smax_p // bs
    smax = smax_p if smax is None else smax
    kernel = functools.partial(
        _decode_attn_kernel, fmt=fmt, bs=bs, nk=nk, smax=smax,
        window=window, rolling=rolling)
    cache_spec = pl.BlockSpec(
        (1, bs, 1, dh), lambda b, h, ki, *_: (b, ki, h, 0))
    scale_spec = pl.BlockSpec((1, bs), lambda b, h, ki, *_: (b, ki))
    return sub.vp_pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, Gp, dh), lambda b, h, ki, *_: (b, h, 0, 0)),
            cache_spec, scale_spec, cache_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, Gp, dh), lambda b, h, ki, *_: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, Gp, dh), out_dtype),
        scratch_shapes=[
            sub.vmem((Gp, _LANES), jnp.float32),
            sub.vmem((Gp, _LANES), jnp.float32),
            sub.vmem((Gp, dh), jnp.float32),
        ],
        num_scalar_prefetch=1,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(lengths, q, k_w, k_s, v_w, v_s)


# ---------------------------------------------------------------------------
# Prefill: q-chunk x k-chunk flash attention (causal / local / full)
# ---------------------------------------------------------------------------

def _flash_prefill_kernel(
    q_ref, k_ref, v_ref,
    o_ref,
    m_ref, l_ref, acc_ref,
    *, bq: int, bk: int, nk: int, sk: int,
    pattern: str, window: Optional[int],
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Tile-level skip: a (qi, ki) tile can only contribute if some
    # (q_pos, k_pos) pair passes the mask — entirely-above-diagonal and
    # entirely-outside-window tiles never do (the kernel analogue of the
    # scan path's static pair enumeration).
    if pattern in ("causal", "local"):
        run = ki * bk <= qi * bq + bq - 1
        if pattern == "local" and window:
            run &= qi * bq - (ki * bk + bk - 1) < window
    else:
        run = True

    @pl.when(run)
    def _tile():
        q = q_ref[0, 0]                          # (bq, dh), pre-scaled
        k = k_ref[0, 0]                          # (bk, dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < sk                       # mask the seq padding
        if pattern in ("causal", "local"):
            valid &= k_pos <= q_pos
            if pattern == "local" and window:
                valid &= q_pos - k_pos < window
        s = jnp.where(valid, s, NEG_INF)
        _online_softmax_update(s, v, None, m_ref, l_ref, acc_ref)

    _flush(o_ref, m_ref, l_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=("pattern", "window", "sk", "g", "blocks", "interpret",
                     "out_dtype"),
)
def flash_prefill_pallas(
    q, k, v,
    pattern: str = "causal",
    window: Optional[int] = None,
    sk: Optional[int] = None,
    g: int = 1,
    blocks=(128, 128),
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Flash attention forward: q (B, H, Sqp, dh) x k/v (B, KV, Skp, dh).

    GQA rides the index maps (k/v head = query head // g, no materialized
    repeat).  q must already carry the dh**-0.5 scale; Sqp / Skp must be
    multiples of the (bq, bk) chunk sizes (ops.py pads — `sk` is the REAL
    key length, so padded key columns are masked; padded query rows
    compute garbage that the caller slices off).  Returns (B, H, Sqp, dh).
    """
    B, H, sqp, dh = q.shape
    KV, skp = k.shape[1], k.shape[2]
    bq, bk = blocks
    nq, nk = sqp // bq, skp // bk
    sk = skp if sk is None else sk
    kernel = functools.partial(
        _flash_prefill_kernel, bq=bq, bk=bk, nk=nk, sk=sk,
        pattern=pattern, window=window)
    kv_spec = pl.BlockSpec(
        (1, 1, bk, dh), lambda b, h, qi, ki, *_: (b, h // g, ki, 0))
    return sub.vp_pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, bq, dh), lambda b, h, qi, ki, *_: (b, h, qi, 0)),
            kv_spec, kv_spec,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dh), lambda b, h, qi, ki, *_: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sqp, dh), out_dtype),
        scratch_shapes=[
            sub.vmem((bq, _LANES), jnp.float32),
            sub.vmem((bq, _LANES), jnp.float32),
            sub.vmem((bq, dh), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        interpret=interpret,
    )(q, k, v)
