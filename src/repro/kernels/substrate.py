"""Shared Pallas kernel substrate: version compat, in-kernel helpers, dispatch.

Every VP kernel in this package launches through this module, so three
concerns live in exactly one place instead of being cloned per kernel:

  (a) jax/Pallas-TPU API compat — the compiler-params class was renamed
      (`TPUCompilerParams` on jax 0.4.x, `CompilerParams` on newer jax) and
      grid-spec construction differs between plain and scalar-prefetch
      launches; `vp_pallas_call` absorbs both so kernels never import
      `pallas.tpu` symbols directly.
  (b) in-kernel VP math — the quantize cascade (paper Fig. 3), the
      dequant/scale-LUT select cascade (Fig. 5 barrel-mux analogue), and the
      k-loop accumulator init/flush idiom shared by every matmul kernel.
  (c) backend dispatch — one `resolve_backend` mapping the public
      `interpret` argument to TPU-native / interpret / pure-jnp-ref
      execution, fixing the "explicit interpret=False forces TPU lowering on
      CPU" bug at a single site for every op in `ops.py`.

Paper mapping: the cascades below are the TPU analogue of the paper's
offline exponent LUTs (Sec. II-B) — all exponent work is a statically
unrolled select chain over the (static) exponent list; the MXU only ever
sees plain fixed-point significands or pre-scaled reals, which is the VP
cheap-multiplier claim restated as kernel structure.  Sharing one datapath
across the scalar-VP, block-VP, and fused kernels mirrors how run-time
reconfigurable multipliers share one array across formats rather than
cloning it per format.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FXPFormat, VPFormat

# ---------------------------------------------------------------------------
# (a) jax-version compat shims
# ---------------------------------------------------------------------------

# jax >= 0.5 exposes `pltpu.CompilerParams`; 0.4.x calls it
# `TPUCompilerParams`.  Same constructor signature for the fields we use.
_COMPILER_PARAMS_CLS = (
    getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
)


def compiler_params(
    dimension_semantics: Optional[Sequence[str]] = None, **kwargs
):
    """Build TPU compiler params across the CompilerParams rename."""
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return _COMPILER_PARAMS_CLS(**kwargs)


def vmem(shape: Tuple[int, ...], dtype):
    """VMEM scratch allocation (kernels never touch pltpu directly)."""
    return pltpu.VMEM(shape, dtype)


def vp_pallas_call(
    kernel,
    *,
    grid,
    in_specs,
    out_specs,
    out_shape,
    scratch_shapes: Sequence = (),
    num_scalar_prefetch: int = 0,
    dimension_semantics: Optional[Sequence[str]] = None,
    interpret: bool = False,
):
    """The one `pl.pallas_call` site for every kernel in this package.

    With `num_scalar_prefetch > 0` the launch goes through
    `PrefetchScalarGridSpec` (index maps then receive the scalar refs as
    trailing args); otherwise through the plain grid/in_specs path.
    `dimension_semantics` is attached via the version-robust compiler-params
    shim; both forms accept VMEM scratch.
    """
    kwargs = {}
    if dimension_semantics is not None:
        kwargs["compiler_params"] = compiler_params(dimension_semantics)
    if num_scalar_prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=num_scalar_prefetch,
            grid=grid,
            in_specs=list(in_specs),
            out_specs=out_specs,
            scratch_shapes=list(scratch_shapes),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
            **kwargs,
        )
    if scratch_shapes:
        kwargs["scratch_shapes"] = list(scratch_shapes)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=list(in_specs),
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **kwargs,
    )


# ---------------------------------------------------------------------------
# (b) shared in-kernel helpers
# ---------------------------------------------------------------------------

def scale_lut_gather(i, fmt: VPFormat, dtype):
    """scale[i] = 2**-f_i via an unrolled select cascade (K <= 16).

    The TPU analogue of the paper's exponent LUT read: the list is static,
    so the gather lowers to one VPU select chain — no exponent arithmetic.
    Accepts any integer index dtype (uint8 planes or in-kernel int32).
    """
    scale = jnp.full(i.shape, jnp.asarray(2.0 ** (-fmt.f[0]), dtype))
    for k in range(1, fmt.K):
        scale = jnp.where(
            i == k, jnp.asarray(2.0 ** (-fmt.f[k]), dtype), scale)
    return scale


def dequant_cascade(m, i, fmt: VPFormat, dtype):
    """(significand, index) -> real tile: m * 2**-f_i (paper Fig. 5).

    The scale comes from `scale_of_index`: O(1) bit-assembly per element
    when the format admits it, else the unrolled select cascade — both
    produce bit-identical power-of-two scales (tests/test_packing.py).
    """
    return m.astype(dtype) * scale_of_index(i, fmt, dtype)


# -- O(1) bit-assembled scale --------------------------------------------

@functools.lru_cache(maxsize=None)
def _fpack_params(fmt: VPFormat) -> Optional[Tuple[int, int, int]]:
    """Static constants for the bit-assembled scale, or None if the format
    doesn't admit it (exponents outside the f32 normal range, or the
    biased f-list doesn't fit one 32-bit constant).

    Returns (fpack, bits, fmin): the exponent list packed little-endian
    into one uint32, `bits` bits per biased entry f_k - fmin.
    """
    fmin = min(fmt.f)
    span = max(fmt.f) - fmin
    # 2**-f must be an f32 NORMAL so its bit pattern is pure exponent:
    # biased exponent 127 - f in [1, 254].
    if not all(1 <= 127 - fv <= 254 for fv in fmt.f):
        return None
    for bits in (4, 8, 16):
        if span < (1 << bits) and fmt.K * bits <= 32:
            fpack = 0
            for k, fv in enumerate(fmt.f):
                fpack |= (fv - fmin) << (bits * k)
            return fpack, bits, fmin
    return None


def scale_bit_assemble(i, fmt: VPFormat):
    """scale[i] = 2**-f_i as f32 by integer exponent arithmetic — O(1).

    Three steps, none of which grow with K:
      1. f_i  = (FPACK >> (i * bits)) & mask  + fmin   (variable shift of
         a packed static constant — the whole exponent list rides in one
         uint32 immediate);
      2. exponent field: (127 - f_i) << 23  (2**e is an f32 normal with a
         zero mantissa, so its bit pattern IS the biased exponent field);
      3. bitcast int32 -> float32.
    Bit-identical to `scale_lut_gather` (powers of two are exact), which
    stays as the oracle; callers must check `_fpack_params(fmt)` first
    (use `scale_of_index` for the automatic fallback).
    """
    fpack, bits, fmin = _fpack_params(fmt)
    ii = i.astype(jnp.uint32)
    biased = jnp.bitwise_and(
        jnp.right_shift(jnp.uint32(fpack), ii * jnp.uint32(bits)),
        jnp.uint32((1 << bits) - 1),
    ).astype(jnp.int32)
    ebits = jnp.left_shift(jnp.int32(127 - fmin) - biased, 23)
    return jax.lax.bitcast_convert_type(ebits, jnp.float32)


def scale_of_index(i, fmt: VPFormat, dtype):
    """2**-f_i per element: the kernel-wide scale policy.

    The bit-assembly costs ~7 integer ops independent of K; the select
    chain costs K dependent selects.  So the O(1) path engages for wide
    exponent lists (K > 4, where the chain serializes), while paper-class
    K <= 4 lists keep the shorter chain; both produce bit-identical
    power-of-two scales, so this is purely a cost choice.  Falls back to
    the chain for non-f32 dtypes and exponents outside the f32 normal
    range (where no pure-exponent bit pattern exists).
    """
    if (fmt.K > 4 and dtype == jnp.float32
            and _fpack_params(fmt) is not None):
        return scale_bit_assemble(i, fmt)
    return scale_lut_gather(i, fmt, dtype)


# -- packed-word in-kernel path ------------------------------------------

def unpack_cascade(w, fmt: VPFormat):
    """Packed word tile -> (int32 significand, int32 index).

    One arithmetic shift (sign extension for free) and one mask —
    cheaper than reading a second operand plane from HBM ever was.
    Delegates to `core.packing.unpack_vp` (pure jnp, in-kernel safe):
    ONE implementation of the word layout, shared with the oracle.
    """
    from repro.core.packing import unpack_vp

    return unpack_vp(w, fmt)


def dequant_packed(w, fmt: VPFormat, dtype):
    """Packed word tile -> real tile, unpack + bit-assembled dequant."""
    m, i = unpack_cascade(w, fmt)
    return m.astype(dtype) * scale_of_index(i, fmt, dtype)


def quantize_cascade(x, fxp: FXPFormat, vp: VPFormat):
    """float tile -> (int32 significand, int32 index) (paper Fig. 3).

    The bit-window + LOD circuit as an unrolled chain of arithmetic shifts
    and in-range tests over the static exponent list — bit-identical to the
    circuit (see core.convert for the equivalence proof).  Callers cast the
    planes to their storage dtypes (int8 / uint8).
    """
    raw = jnp.clip(
        jnp.round(x * jnp.float32(2.0 ** fxp.F)),
        fxp.raw_min, fxp.raw_max,
    ).astype(jnp.int32)

    lo, hi = vp.raw_min, vp.raw_max
    m_sel = jnp.zeros_like(raw)
    i_sel = jnp.zeros_like(raw)
    valid_any = jnp.zeros(raw.shape, jnp.bool_)
    for k in range(vp.K):
        s_k = fxp.F - vp.f[k]
        m_k = (
            jnp.right_shift(raw, s_k) if s_k >= 0
            else jnp.left_shift(raw, -s_k)
        )
        valid_k = (m_k >= lo) & (m_k <= hi)
        take = valid_k & ~valid_any
        m_sel = jnp.where(take, m_k, m_sel)
        i_sel = jnp.where(take, k, i_sel)
        valid_any = valid_any | valid_k
    # Out-of-range on every option: saturate at the coarsest exponent.
    s_last = fxp.F - vp.f[-1]
    m_last = jnp.clip(
        jnp.right_shift(raw, s_last) if s_last >= 0
        else jnp.left_shift(raw, -s_last),
        lo, hi,
    )
    m = jnp.where(valid_any, m_sel, m_last)
    i = jnp.where(valid_any, i_sel, vp.K - 1)
    return m, i


def quantize_pack_cascade(x, fxp: FXPFormat, vp: VPFormat):
    """float tile -> packed VP words (int32; caller casts to storage dtype).

    The Fig. 3 cascade followed by the core.packing word assembly
    ``(m << E) | i`` — the fused producer for kernels that emit packed
    planes straight from floats, never materializing the two-plane layout.
    """
    m, i = quantize_cascade(x, fxp, vp)
    return jnp.bitwise_or(jnp.left_shift(m, vp.E), i)


def quantize_dequant_cascade(x, fxp: FXPFormat, vp: VPFormat, dtype):
    """float tile -> VP-rounded reals m * 2**-f_i in ONE cascade.

    For fused kernels: equals `dequant_cascade(*quantize_cascade(x))` bit
    for bit.  The scale is re-derived from the selected index by the O(1)
    bit-assembly (`scale_of_index`) instead of riding a third K-way select
    chain alongside (m, i) — same exact power-of-two values, fewer VPU
    selects per element.
    """
    m, i = quantize_cascade(x, fxp, vp)
    return m.astype(dtype) * scale_of_index(i, fmt=vp, dtype=dtype)


def accum_init(acc_ref, ki):
    """Zero the VMEM accumulator on the first k step."""
    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)


def accum_flush(o_ref, acc_ref, ki, nk: int):
    """Write the accumulator to the output tile on the last k step.

    The reshape lets batched kernels keep a 2-D (bm, bn) accumulator while
    writing a (1, bm, bn) output block — a no-op for the unbatched kernels
    whose output tile already matches the accumulator shape.
    """
    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype).reshape(o_ref.shape)


def batched_matmul_grid(
    nb: int, nm: int, nn: int, nk: int,
    bm: int, bk: int, bn: int,
    a_copies: int = 1, b_copies: int = 1,
):
    """Grid + block specs for a batch-gridded (G, M, K) x (G, K, N) matmul.

    This is the truly-batched kernel contract: the grid gains a LEADING
    batch dimension, so each batch element runs its own (M, K) x (K, N)
    tile program — no folding of the batch into the row axis and no
    masked-diagonal waste.  Grid order is (batch, m, n, k) with k innermost
    (the accumulator idiom needs the k steps of one output tile to be
    consecutive); batch/m/n are all "parallel", k is "arbitrary".

    `a_copies` / `b_copies` give the number of identically-tiled tensors
    riding each operand's index map — the plane kernels pass 2 per operand
    (significand + exponent-index), the fused float kernel passes 1.

    Index-map lambdas take `*_` trailing args so the same specs work under
    `PrefetchScalarGridSpec` (scalar refs are appended to index-map args).
    """
    grid = (nb, nm, nn, nk)
    a_spec = pl.BlockSpec(
        (1, bm, bk), lambda b, mi, ni, ki, *_: (b, mi, ki))
    b_spec = pl.BlockSpec(
        (1, bk, bn), lambda b, mi, ni, ki, *_: (b, ki, ni))
    in_specs = [a_spec] * a_copies + [b_spec] * b_copies
    out_specs = pl.BlockSpec(
        (1, bm, bn), lambda b, mi, ni, ki, *_: (b, mi, ni))
    semantics = ("parallel", "parallel", "parallel", "arbitrary")
    return grid, in_specs, out_specs, semantics


# ---------------------------------------------------------------------------
# (c) backend dispatch
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Set only by `force_backend`; overrides the interpret/platform mapping.
_FORCED: list = []


@contextlib.contextmanager
def force_backend(backend: str) -> Iterator[None]:
    """Pin `resolve_backend` to one backend inside the context.

    Used by `repro.analysis.jaxpr_lint` to trace model forwards through
    the "interpret" path on CPU, so the traced jaxpr contains the actual
    `pallas_call` kernel launches instead of the ref oracles (whose
    full-tensor dequants are fine for an oracle but would be findings on
    the serving path).  Re-entrant; restores the previous behavior on
    exit.  Not thread-safe — linting is a single-threaded CLI activity.
    """
    if backend not in ("native", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    _FORCED.append(backend)
    try:
        yield
    finally:
        _FORCED.pop()


def resolve_backend(interpret: Optional[bool]) -> str:
    """Map a public op's `interpret` argument to an execution backend.

    ``True``          -> ``"interpret"``: run the Pallas kernel body through
                         the interpreter (any backend; the kernel tests use
                         this on CPU).
    ``None``/``False`` -> ``"native"`` on a TPU backend, ``"ref"`` (the
                         pure-jnp oracle in ref.py) everywhere else.

    An explicit ``False`` means "don't interpret", never "force native
    lowering": attempting TPU lowering on a CPU backend was the seed bug
    (`use_kernel = _on_tpu() if interpret is None else True`) that this
    dispatcher retires for every op at once.

    A `force_backend` context overrides the mapping entirely (analysis
    tracing only).
    """
    if _FORCED:
        return _FORCED[-1]
    if interpret:
        return "interpret"
    return "native" if on_tpu() else "ref"
