"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against
(`tests/test_kernels.py` sweeps shapes/dtypes and asserts allclose), and the
CPU execution path used by models / the dry-run (same math, no Pallas).

The oracle entry points are `jax.jit`-compiled (formats/tiles static):
eagerly, each quantize cascade dispatches ~10 elementwise XLA ops PER
exponent option and materializes every intermediate — at serving batch
sizes that is pure HBM/cache traffic, and it made the CPU engine path's
per-element cost grow with the working set (the BENCH_pr2 OFDM S=64
regression).  Under jit the cascades fuse into one loop; numerics are
unchanged (same ops, no reassociation), which the parity suites pin.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FXPFormat, VPFormat
from repro.core.fxp import fxp_quantize
from repro.core.convert import fxp2vp, vp_to_float
from repro.core.packing import pack_vp, unpack_vp, dequant_words


@functools.partial(jax.jit, static_argnames=("fxp", "vp"))
def vp_quant_ref(x, fxp: FXPFormat, vp: VPFormat):
    """float -> (int8 significand, uint8 index) through the FXP grid."""
    raw = fxp_quantize(x, fxp)
    m, i = fxp2vp(raw, fxp, vp)
    from repro.core.vp_tensor import significand_dtype

    return m.astype(significand_dtype(vp.M)), i.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("fxp", "vp"))
def vp_quant_packed_ref(x, fxp: FXPFormat, vp: VPFormat):
    """float -> packed VP words (`core.packing` layout, one plane)."""
    raw = fxp_quantize(x, fxp)
    m, i = fxp2vp(raw, fxp, vp)
    return pack_vp(m, i, vp)


@functools.partial(jax.jit, static_argnames=("vp", "dtype"))
def vp_dequant_ref(m, i, vp: VPFormat, dtype=jnp.float32):
    """(significand, index) -> real values m * 2^-f_i."""
    return vp_to_float(m, i, vp, dtype)


@functools.partial(jax.jit, static_argnames=("vp", "dtype"))
def vp_dequant_packed_ref(w, vp: VPFormat, dtype=jnp.float32):
    """packed VP words -> real values (word-LUT / unpack oracle)."""
    return dequant_words(w, vp, dtype)


def tile_activity(x_abs_max, threshold: float):
    """CSPADE tile-activity flag: a tile is 'loud' if its max magnitude
    reaches the threshold (paper Sec. IV-A, tile-granular adaptation)."""
    return x_abs_max >= threshold


def cspade_tile_masks(
    a_deq, b_deq, bm: int, bk: int, bn: int,
    thresh_a: float, thresh_b: float,
) -> Tuple[jax.Array, jax.Array]:
    """Per-tile activity of A (M,K) and B (K,N) on the kernel tiling grid.

    A partial-product TILE is skipped when BOTH operand tiles are quiet —
    the tile-granular analogue of CSPADE's per-scalar muting.
    Returns (a_act [M/bm, K/bk], b_act [K/bk, N/bn]) int32 flags.
    """
    M, K = a_deq.shape
    _, N = b_deq.shape
    a_tiles = jnp.abs(a_deq).reshape(M // bm, bm, K // bk, bk).max((1, 3))
    b_tiles = jnp.abs(b_deq).reshape(K // bk, bk, N // bn, bn).max((1, 3))
    return (
        tile_activity(a_tiles, thresh_a).astype(jnp.int32),
        tile_activity(b_tiles, thresh_b).astype(jnp.int32),
    )


@functools.partial(
    jax.jit, static_argnames=("a_fmt", "b_fmt", "tiles", "out_dtype"))
def vp_matmul_ref(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """VP x VP matmul oracle: dequantize then f32 matmul.

    With activity masks, contributions from tile-pairs where BOTH operands
    are quiet are zeroed (exactly what the kernel's `pl.when` skip does).
    """
    a = vp_to_float(a_m, a_i, a_fmt, out_dtype)
    b = vp_to_float(b_m, b_i, b_fmt, out_dtype)
    if a_act is None:
        return a @ b
    bm, bk, bn = tiles
    M, K = a.shape
    _, N = b.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    # mute[mi, ki, ni]: both quiet -> kill that tile-pair's contribution.
    keep = (a_act[:, :, None] | b_act[None, :, :]).astype(out_dtype)
    a_t = a.reshape(nm, bm, nk, bk).transpose(0, 2, 1, 3)
    b_t = b.reshape(nk, bk, nn, bn).transpose(0, 2, 1, 3)
    # per-(mi,ki,ni) tile product
    prod = jnp.einsum("xyab,yzbc->xyzac", a_t, b_t)
    prod = prod * keep[:, :, :, None, None]
    out = prod.sum(1)  # sum over k tiles
    return out.transpose(0, 2, 1, 3).reshape(M, N)


@functools.partial(
    jax.jit, static_argnames=("a_fmt", "b_fmt", "tiles", "out_dtype"))
def vp_matmul_packed_ref(
    a_w, b_w,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """Packed-word matmul oracle: unpack INSIDE the jit (no eager unpack
    round-trip), then the plane oracle — bit-identical to
    `vp_matmul_ref(*unpack_vp(a_w), *unpack_vp(b_w))`."""
    a_m, a_i = unpack_vp(a_w, a_fmt)
    b_m, b_i = unpack_vp(b_w, b_fmt)
    return vp_matmul_ref(
        a_m, a_i, b_m, b_i, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act, tiles=tiles, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("w_fmt", "out_dtype"))
def vp_dequant_matmul_ref(
    x, w,
    w_fmt: VPFormat,
    out_dtype=jnp.float32,
):
    """Serving-matmul oracle: real x (M, K) @ dequant(packed w (K, N)).

    Unpack + dequant happen INSIDE the jit in `out_dtype` (the model's
    compute dtype), then one plain dot — exactly the computation the
    models' legacy jnp-dequant path ran on two-plane weights, so the
    cross-arch golden-parity suite can pin the kernel path against it
    bit for bit (power-of-two scales are exact in any float dtype).
    Unlike the masked-matmul oracles this one takes NO `tiles`: the math
    is tile-independent, and a static tiling arg would force a fresh XLA
    compile per resolved block triple (pure churn on the ref backend).
    Dequant goes through the offline whole-word LUT
    (`core.packing.dequant_words`) when the format admits it — one gather
    per element instead of shift+mask+scale, bit-identical either way.
    """
    deq = dequant_words(w, w_fmt, out_dtype)
    return jnp.dot(x.astype(out_dtype), deq)


@functools.partial(
    jax.jit,
    static_argnames=("a_fxp", "a_vp", "b_fxp", "b_vp", "tiles", "out_dtype"))
def vp_quant_matmul_ref(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """Fused quantize+matmul oracle: quantize both floats, then VP matmul.

    Exactly `vp_quant_ref` on each operand followed by `vp_matmul_ref` —
    the fused kernel must reproduce this composition bit-for-bit (it runs
    the same cascades, just without the HBM round-trip).
    """
    a_m, a_i = vp_quant_ref(a, a_fxp, a_vp)
    b_m, b_i = vp_quant_ref(b, b_fxp, b_vp)
    return vp_matmul_ref(
        a_m, a_i, b_m, b_i, a_vp, b_vp,
        a_act=a_act, b_act=b_act, tiles=tiles, out_dtype=out_dtype)


def cspade_tile_masks_batched(
    a_deq, b_deq, bm: int, bk: int, bn: int,
    thresh_a: float, thresh_b: float,
) -> Tuple[jax.Array, jax.Array]:
    """Per-(batch, tile) activity of A (G,M,K) and B (G,K,N) on the batched
    kernel grid: `cspade_tile_masks` with a leading batch axis.

    Returns (a_act [G, M/bm, K/bk], b_act [G, K/bk, N/bn]) int32 flags.
    On the MVM shapes (one tile per axis) this degenerates to one flag per
    realization — the batched analogue of muting a whole quiet request.
    """
    G, M, K = a_deq.shape
    _, _, N = b_deq.shape
    a_tiles = jnp.abs(a_deq).reshape(
        G, M // bm, bm, K // bk, bk).max((2, 4))
    b_tiles = jnp.abs(b_deq).reshape(
        G, K // bk, bk, N // bn, bn).max((2, 4))
    return (
        tile_activity(a_tiles, thresh_a).astype(jnp.int32),
        tile_activity(b_tiles, thresh_b).astype(jnp.int32),
    )


@functools.partial(
    jax.jit, static_argnames=("a_fmt", "b_fmt", "tiles", "out_dtype"))
def vp_matmul_batched_ref(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """Batched VP x VP matmul oracle: (G, M, K) x (G, K, N) -> (G, M, N).

    Per batch element this is exactly `vp_matmul_ref`; with activity masks
    the muting is per (batch, tile-pair) like the batched kernel's skip.
    """
    a = vp_to_float(a_m, a_i, a_fmt, out_dtype)
    b = vp_to_float(b_m, b_i, b_fmt, out_dtype)
    if a_act is None:
        return jax.lax.dot_general(
            a, b, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=out_dtype)
    bm, bk, bn = tiles
    G, M, K = a.shape
    _, _, N = b.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    keep = (a_act[:, :, :, None] | b_act[:, None, :, :]).astype(out_dtype)
    a_t = a.reshape(G, nm, bm, nk, bk).transpose(0, 1, 3, 2, 4)
    b_t = b.reshape(G, nk, bk, nn, bn).transpose(0, 1, 3, 2, 4)
    prod = jnp.einsum("gxyab,gyzbc->gxyzac", a_t, b_t)
    prod = prod * keep[:, :, :, :, None, None]
    out = prod.sum(2)
    return out.transpose(0, 1, 3, 2, 4).reshape(G, M, N)


@functools.partial(
    jax.jit, static_argnames=("a_fmt", "b_fmt", "tiles", "out_dtype"))
def vp_matmul_batched_packed_ref(
    a_w, b_w,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """Batched packed-word matmul oracle (unpack fused into the jit)."""
    a_m, a_i = unpack_vp(a_w, a_fmt)
    b_m, b_i = unpack_vp(b_w, b_fmt)
    return vp_matmul_batched_ref(
        a_m, a_i, b_m, b_i, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act, tiles=tiles, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("a_fxp", "a_vp", "b_fxp", "b_vp", "tiles", "out_dtype"))
def vp_quant_matmul_batched_ref(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act: Optional[jax.Array] = None,
    b_act: Optional[jax.Array] = None,
    tiles: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.float32,
):
    """Batched fused quantize+matmul oracle: quantize, then batched matmul."""
    a_m, a_i = vp_quant_ref(a, a_fxp, a_vp)
    b_m, b_i = vp_quant_ref(b, b_fxp, b_vp)
    return vp_matmul_batched_ref(
        a_m, a_i, b_m, b_i, a_vp, b_vp,
        a_act=a_act, b_act=b_act, tiles=tiles, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Attention oracles (decode over a VP cache + flash prefill)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _decode_attention_core(q, k_cache, v_cache, cache_len,
                           window: Optional[int], rolling: bool):
    """Masked single-token decode attention over a FLOAT cache (traced).

    q (B, 1, H, dh), caches (B, Smax, KV, dh) -> (B, 1, H, dh).  This is
    THE decode-attention math: `models.attention.decode_attention` and
    the packed-cache oracle below both call it, so the packed-vs-planes
    parity is bit-identical by construction (they differ only in the
    dequant, which `core.packing` pins bit-for-bit).

    When a non-rolling `window` bounds the valid span and the buffer is
    statically larger, the cache is SLICED to the window before the
    einsum — scores for positions the mask would zero anyway are never
    computed, so decode work is O(window), not O(Smax).  Masked-out
    entries contribute exactly 0 after the softmax's exp, so slicing
    only drops exact zeros from the contractions.
    """
    B, _, H, dh = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    if not rolling and window and window < Smax:
        start = jnp.clip(cache_len - window, 0, Smax - window)
        slc = jax.vmap(functools.partial(
            jax.lax.dynamic_slice_in_dim, slice_size=window, axis=0))
        kc, vc = slc(k_cache, start), slc(v_cache, start)
        pos = start[:, None] + jnp.arange(window)[None, :]
    else:
        kc, vc = k_cache, v_cache
        pos = jnp.broadcast_to(jnp.arange(Smax)[None, :], (B, Smax))
    kr = kc.transpose(0, 2, 1, 3).astype(jnp.float32)
    vr = vc.transpose(0, 2, 1, 3).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qr, kr)
    if rolling:
        valid = pos < jnp.minimum(cache_len, Smax)[:, None]
    else:
        valid = pos < cache_len[:, None]
        if window:
            valid &= pos >= (cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, vr)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "rolling"))
def decode_attention_ref(q, k_cache, v_cache, cache_len,
                         window: Optional[int] = None,
                         rolling: bool = False):
    """Jitted float decode-attention oracle (see `_decode_attention_core`)."""
    return _decode_attention_core(q, k_cache, v_cache, cache_len,
                                  window, rolling)


@functools.partial(
    jax.jit, static_argnames=("fmt", "window", "rolling"))
def vp_decode_attention_ref(
    q, k_w, v_w, k_s, v_s, lengths,
    fmt: VPFormat,
    window: Optional[int] = None,
    rolling: bool = False,
):
    """Packed-KV decode oracle: dequant INSIDE the jit, then the shared
    decode core.

    k_w / v_w (B, Smax, KV, dh) packed VP words, k_s / v_s per-position
    pow2 cache scales ((B, Smax) or (B, Smax, 1, 1)).  The dequant goes
    through the offline whole-word LUT (`core.packing.dequant_words`) —
    one gather per element instead of the planes path's index-unpack +
    select cascade, which is where the ref-backend decode speedup comes
    from — and mirrors the planes path's dtype hop (f32 dequant, scale,
    cast to the model dtype) so parity is bit-identical on this backend.
    """
    if k_s.ndim == 2:
        k_s = k_s[:, :, None, None]
    if v_s.ndim == 2:
        v_s = v_s[:, :, None, None]
    kr = (dequant_words(k_w, fmt, jnp.float32) * k_s).astype(q.dtype)
    vr = (dequant_words(v_w, fmt, jnp.float32) * v_s).astype(q.dtype)
    return _decode_attention_core(q, kr, vr, lengths, window, rolling)


@functools.partial(jax.jit, static_argnames=("pattern", "window"))
def flash_prefill_ref(q, k, v, pattern: str = "causal",
                      window: Optional[int] = None):
    """Unfused prefill-attention oracle: full (Sq, Sk) scores + mask.

    q (B, Sq, H, dh), k/v (B, Sk, KV, dh) -> (B, Sq, H, dh).  O(S^2)
    memory — the oracle the flash kernel (which never materializes the
    scores) is tested against; `models.attention.flash_attention`'s
    pair-scan is the bounded-memory production path off-TPU.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    kr = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kr)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    if pattern in ("causal", "local"):
        mask = k_pos <= q_pos
        if pattern == "local" and window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("a_fmt", "b_fmt", "bk", "out_dtype"))
def block_vp_matmul_ref(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    bk: int,
    out_dtype=jnp.float32,
):
    """Block-VP matmul oracle.

    a_m (M, K) int8 significands with a_i (M, K//bk) per-(row, k-block)
    exponent indices; b_m (K, N) with b_i (K//bk, N).  Within k-block `t`:
      out += (lutA[a_i[:, t]] outer lutB[b_i[t, :]]) * (A_t @ B_t in int32)
    """
    M, K = a_m.shape
    _, N = b_m.shape
    nk = K // bk
    lut_a = jnp.asarray([2.0 ** (-fv) for fv in a_fmt.f], out_dtype)
    lut_b = jnp.asarray([2.0 ** (-fv) for fv in b_fmt.f], out_dtype)
    out = jnp.zeros((M, N), out_dtype)
    for t in range(nk):
        at = a_m[:, t * bk:(t + 1) * bk]
        bt = b_m[t * bk:(t + 1) * bk, :]
        acc = jax.lax.dot_general(
            at, bt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        sa = lut_a[a_i[:, t].astype(jnp.int32)]
        sb = lut_b[b_i[t, :].astype(jnp.int32)]
        out = out + acc.astype(out_dtype) * sa[:, None] * sb[None, :]
    return out


# ---------------------------------------------------------------------------
# Backward-pass oracles (custom-VJP grad matmuls over packed words)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("w_fmt", "out_dtype"))
def vp_matmul_dx_ref(
    g, w,
    w_fmt: VPFormat,
    out_dtype=jnp.float32,
):
    """Transposed serving-matmul oracle: g (M, N) @ dequant(w (K, N))^T.

    This is EXACTLY what `jax.grad` of `vp_dequant_matmul_ref` computes
    for the activation cotangent — XLA transposes `dot_general(x, deq,
    contract (1, 0))` into `dot_general(g, deq, contract (1, 1))` — so
    the custom-VJP grad check can pin the rule bit-for-bit against the
    autodiff-through-dequant oracle on the ref backend."""
    deq = dequant_words(w, w_fmt, out_dtype)
    return jax.lax.dot_general(
        g.astype(out_dtype), deq, (((1,), (1,)), ((), ())),
        preferred_element_type=out_dtype)


@functools.partial(jax.jit, static_argnames=("a_fmt", "out_dtype"))
def vp_matmul_dw_ref(
    a_w, g,
    a_fmt: VPFormat,
    out_dtype=jnp.float32,
):
    """Second-operand grad oracle: dequant(a_w (M, K))^T @ g (M, N).

    The STE backward of the fused quantize-matmul w.r.t. its second
    operand, consuming the PACKED quantized first operand saved as the
    VJP residual — mirrors XLA's transpose of `dot_general(deq_a, b,
    contract (1, 0))` w.r.t. b: `dot_general(deq_a, g, contract (0, 0))`."""
    deq = dequant_words(a_w, a_fmt, out_dtype)
    return jax.lax.dot_general(
        deq, g.astype(out_dtype), (((0,), (0,)), ((), ())),
        preferred_element_type=out_dtype)
