"""Pallas TPU kernel: VP matrix-multiply engine (the paper's MVM, Sec. IV).

TPU adaptation of the B-VP design:
  * operands arrive either as VP planes (int8 significand + uint8 exponent
    index — 16 HBM bits/element) or, preferably, as PACKED VP words
    (`core.packing`: sign+significand+index in one int8/int16 — 8 bits for
    the Table-I y format, halving HBM traffic);
  * each VMEM tile is dequantized in-register — packed tiles through the
    substrate's `dequant_packed` (shift/mask unpack + O(1) bit-assembled
    scale), plane tiles through `dequant_cascade` — and fed to the MXU in
    f32/bf16;
  * CSPADE is tile-granular: per-tile activity flags are scalar-prefetched
    into SMEM and `pl.when` skips the MXU op when BOTH operand tiles are
    quiet (the systolic-array analogue of partial-product muting).

Grid is (m, n, k) with k innermost; a VMEM f32 scratch accumulates across
the k steps and is flushed to the output on the last step.  Launch plumbing
(compat shims, grid-spec construction) lives in `substrate.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

BM, BK, BN = 256, 256, 256


def _vp_matmul_kernel(
    # scalar-prefetch operands (SMEM)
    a_act_ref, b_act_ref,
    # tensor operands (VMEM tiles): 2 plane refs per operand, or 1 packed
    *refs,
    a_fmt: VPFormat, b_fmt: VPFormat, nk: int, cspade: bool, dtype,
    packed: bool, batched: bool,
):
    o_ref, acc_ref = refs[-2], refs[-1]
    ki = pl.program_id(3 if batched else 2)
    sub.accum_init(acc_ref, ki)

    def _tile(r):
        return r[0] if batched else r[...]

    def _compute():
        if packed:
            a_ref, b_ref = refs[0], refs[1]
            a = sub.dequant_packed(_tile(a_ref), a_fmt, dtype)
            b = sub.dequant_packed(_tile(b_ref), b_fmt, dtype)
        else:
            a_m_ref, a_i_ref, b_m_ref, b_i_ref = refs[0], refs[1], refs[2], refs[3]
            a = sub.dequant_cascade(_tile(a_m_ref), _tile(a_i_ref), a_fmt, dtype)
            b = sub.dequant_cascade(_tile(b_m_ref), _tile(b_i_ref), b_fmt, dtype)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if cspade:
        if batched:
            gi, mi, ni = pl.program_id(0), pl.program_id(1), pl.program_id(2)
            active = (a_act_ref[gi, mi, ki] | b_act_ref[gi, ki, ni]) != 0
        else:
            mi, ni = pl.program_id(0), pl.program_id(1)
            active = (a_act_ref[mi, ki] | b_act_ref[ki, ni]) != 0
        pl.when(active)(_compute)
    else:
        _compute()

    sub.accum_flush(o_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "interpret", "blocks", "out_dtype", "packed"),
)
def vp_matmul_batched_pallas(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
    packed: bool = False,
):
    """Truly-batched VP x VP -> f32 matmul over a leading batch grid dim.

    a: (G, M, K) planes, b: (G, K, N) planes -> (G, M, N).  Every batch
    element g runs its own (M, K) x (K, N) tile program on the
    (batch, m, n, k) grid — the batch is never folded into the row axis,
    so there is no masked-diagonal FLOP waste (see mimo/mvm_engine.py).

    With ``packed=True`` the operands are packed VP word planes
    (`core.packing.pack_vp`); `a_i` / `b_i` must be None and HBM moves ONE
    word per element instead of two planes.

    `a_act` (G, M/bm, K/bk) / `b_act` (G, K/bk, N/bn) int32 CSPADE
    tile-activity flags (None disables the skip).  M/K/N must be
    tile-multiples (ops.py pads); G is the grid's leading axis and needs
    no padding.
    """
    (bm, bk, bn) = blocks
    G, M, K = a_m.shape
    _, _, N = b_m.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    cspade = a_act is not None
    if not cspade:
        a_act = jnp.ones((G, nm, nk), jnp.int32)
        b_act = jnp.ones((G, nk, nn), jnp.int32)

    kernel = functools.partial(
        _vp_matmul_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, nk=nk, cspade=cspade, dtype=jnp.float32,
        packed=packed, batched=True,
    )
    copies = 1 if packed else 2
    grid, in_specs, out_specs, semantics = sub.batched_matmul_grid(
        G, nm, nn, nk, bm, bk, bn, a_copies=copies, b_copies=copies)
    operands = (a_m, b_m) if packed else (a_m, a_i, b_m, b_i)
    return sub.vp_pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((G, M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        num_scalar_prefetch=2,
        dimension_semantics=semantics,
        interpret=interpret,
    )(a_act, b_act, *operands)


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "interpret", "blocks", "out_dtype", "packed"),
)
def vp_matmul_pallas(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
    packed: bool = False,
):
    """VP x VP -> f32 matmul.  a: (M, K) planes, b: (K, N) planes.

    With ``packed=True`` each operand is ONE packed VP word plane
    (`a_i` / `b_i` None) — half the HBM traffic of the two-plane layout.
    `a_act` (M/bm, K/bk) / `b_act` (K/bk, N/bn) int32 CSPADE tile-activity
    flags (None disables the skip logic entirely).
    Shapes must be tile-multiples (ops.py pads).
    """
    (bm, bk, bn) = blocks
    M, K = a_m.shape
    _, N = b_m.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    cspade = a_act is not None
    if not cspade:
        a_act = jnp.ones((nm, nk), jnp.int32)
        b_act = jnp.ones((nk, nn), jnp.int32)

    kernel = functools.partial(
        _vp_matmul_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, nk=nk, cspade=cspade, dtype=jnp.float32,
        packed=packed, batched=False,
    )
    a_spec = pl.BlockSpec((bm, bk), lambda mi, ni, ki, *_: (mi, ki))
    b_spec = pl.BlockSpec((bk, bn), lambda mi, ni, ki, *_: (ki, ni))
    copies = 1 if packed else 2
    operands = (a_m, b_m) if packed else (a_m, a_i, b_m, b_i)
    return sub.vp_pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[a_spec] * copies + [b_spec] * copies,
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki, *_: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        num_scalar_prefetch=2,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a_act, b_act, *operands)
