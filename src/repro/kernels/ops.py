"""Public ops: padding, backend dispatch (TPU kernel vs CPU ref), reshaping.

Models and the MIMO application call these; they never touch pallas_call
directly.  Dispatch is `substrate.resolve_backend` in every op: on a TPU
backend the Pallas kernels run natively; elsewhere the pure-jnp refs run
(same math — the refs ARE the oracles the kernels are tested against), so
the dry-run lowers a graph with identical FLOP/byte structure.
`interpret=True` forces the Pallas kernel body through the interpreter on
any backend (used by the kernel tests); an explicit `interpret=False`
means "don't interpret" and still falls back to the refs off-TPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.formats import FXPFormat, VPFormat
from . import ref, substrate
from .vp_quant import vp_quant_pallas
from .vp_dequant import vp_dequant_pallas
from .vp_matmul import vp_matmul_pallas, vp_matmul_batched_pallas
from .vp_block_matmul import block_vp_matmul_pallas
from .vp_quant_matmul import (
    vp_quant_matmul_pallas,
    vp_quant_matmul_batched_pallas,
)


def _pad2(x, br, bc, value=0):
    R, C = x.shape
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)
    return x


def _pad3(x, br, bc, value=0):
    """Pad the trailing two dims of a (G, R, C) batch to tile multiples."""
    _, R, C = x.shape
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, 0), (0, pr), (0, pc)), constant_values=value)
    return x


def _check_masks(a_act, b_act, M, K, N, blocks):
    """Validate optional CSPADE masks against the kernel tile grid.

    Out-of-grid masks would be silently mis-indexed in the kernel (Pallas
    clamps out-of-bounds scalar reads), so mismatches must fail loudly."""
    if (a_act is None) != (b_act is None):
        raise ValueError(
            "CSPADE masks come in pairs: pass both a_act and b_act or neither")
    if a_act is None:
        return
    bm, bk, bn = blocks
    if M % bm or K % bk or N % bn:
        raise ValueError("CSPADE masks require tile-aligned operand shapes")
    want_a, want_b = (M // bm, K // bk), (K // bk, N // bn)
    if tuple(a_act.shape) != want_a or tuple(b_act.shape) != want_b:
        raise ValueError(
            f"CSPADE mask shapes {tuple(a_act.shape)}/{tuple(b_act.shape)} "
            f"do not match the blocks={blocks} tile grid "
            f"(want {want_a}/{want_b}); rebuild the masks on this grid")


def _check_masks_batched(a_act, b_act, G, M, K, N, blocks):
    """Validate optional batched CSPADE masks against the (G, tile) grid."""
    if (a_act is None) != (b_act is None):
        raise ValueError(
            "CSPADE masks come in pairs: pass both a_act and b_act or neither")
    if a_act is None:
        return
    bm, bk, bn = blocks
    if M % bm or K % bk or N % bn:
        raise ValueError("CSPADE masks require tile-aligned operand shapes")
    want_a = (G, M // bm, K // bk)
    want_b = (G, K // bk, N // bn)
    if tuple(a_act.shape) != want_a or tuple(b_act.shape) != want_b:
        raise ValueError(
            f"batched CSPADE mask shapes {tuple(a_act.shape)}/"
            f"{tuple(b_act.shape)} do not match the blocks={blocks} grid "
            f"(want {want_a}/{want_b}); rebuild the masks on this grid")


def vp_quant(x, fxp: FXPFormat, vp: VPFormat, interpret: Optional[bool] = None):
    """float tensor (any rank) -> (significand, index) planes, same shape."""
    backend = substrate.resolve_backend(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    if backend == "ref":
        m, i = ref.vp_quant_ref(x2, fxp, vp)
    else:
        R, C = x2.shape
        xp = _pad2(x2, 256, 256)
        m, i = vp_quant_pallas(
            xp, fxp, vp, interpret=(backend == "interpret"))
        m, i = m[:R, :C], i[:R, :C]
    return m.reshape(shape), i.reshape(shape)


def vp_dequant(m, i, vp: VPFormat, dtype=jnp.float32,
               interpret: Optional[bool] = None):
    backend = substrate.resolve_backend(interpret)
    shape = m.shape
    m2 = m.reshape(-1, shape[-1]) if m.ndim != 2 else m
    i2 = i.reshape(-1, shape[-1]) if i.ndim != 2 else i
    if backend == "ref":
        out = ref.vp_dequant_ref(m2, i2, vp, dtype)
    else:
        R, C = m2.shape
        mp, ip = _pad2(m2, 256, 256), _pad2(i2, 256, 256)
        out = vp_dequant_pallas(
            mp, ip, vp, dtype, interpret=(backend == "interpret"))
        out = out[:R, :C]
    return out.reshape(shape)


def vp_matmul(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    blocks: Tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """(M,K) x (K,N) VP matmul; CSPADE masks optional (tile grid = blocks)."""
    M, K = a_m.shape
    _, N = b_m.shape
    _check_masks(a_act, b_act, M, K, N, blocks)
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_matmul_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    am, ai = _pad2(a_m, bm, bk), _pad2(a_i, bm, bk)
    bm_, bi = _pad2(b_m, bk, bn), _pad2(b_i, bk, bn)
    out = vp_matmul_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


def vp_quant_matmul(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    blocks: Tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Fused float->VP quantize + matmul: a (M,K) x b (K,N) floats -> (M,N).

    Numerically identical to `vp_quant` on each operand followed by
    `vp_matmul`, without materializing the quantized planes in HBM.
    CSPADE masks follow the `blocks` tile grid and require tile-aligned
    operands (mask calibration needs the planes anyway — see mvm_engine).
    """
    bm, bk, bn = blocks
    M, K = a.shape
    _, N = b.shape
    _check_masks(a_act, b_act, M, K, N, blocks)
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_quant_matmul_ref(
            a, b, a_fxp, a_vp, b_fxp, b_vp,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    ap, bp = _pad2(a, bm, bk), _pad2(b, bk, bn)
    out = vp_quant_matmul_pallas(
        ap, bp, a_fxp, a_vp, b_fxp, b_vp,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


def vp_matmul_batched(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    blocks: Tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """(G,M,K) x (G,K,N) truly-batched VP matmul.

    Each batch element runs its own tile program on the kernel's leading
    batch grid dimension — the scalable replacement for folding G into the
    row axis and discarding off-diagonal columns.  CSPADE masks are per
    (batch, tile): a_act (G, M/bm, K/bk), b_act (G, K/bk, N/bn).
    """
    G, M, K = a_m.shape
    _, _, N = b_m.shape
    _check_masks_batched(a_act, b_act, G, M, K, N, blocks)
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_matmul_batched_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    am, ai = _pad3(a_m, bm, bk), _pad3(a_i, bm, bk)
    bm_, bi = _pad3(b_m, bk, bn), _pad3(b_i, bk, bn)
    out = vp_matmul_batched_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:, :M, :N]


def vp_quant_matmul_batched(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    blocks: Tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Truly-batched fused float->VP quantize + matmul over (G, M, K) x
    (G, K, N) floats.

    Numerically identical to `vp_quant` on each operand followed by
    `vp_matmul_batched`, with no quantized-plane HBM round-trip — ONE
    pallas_call for the whole batch.
    """
    G, M, K = a.shape
    _, _, N = b.shape
    _check_masks_batched(a_act, b_act, G, M, K, N, blocks)
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_quant_matmul_batched_ref(
            a, b, a_fxp, a_vp, b_fxp, b_vp,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    ap, bp = _pad3(a, bm, bk), _pad3(b, bk, bn)
    out = vp_quant_matmul_batched_pallas(
        ap, bp, a_fxp, a_vp, b_fxp, b_vp,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:, :M, :N]


def block_vp_matmul(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    bk: int = 256,
    blocks: Tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Block-VP int8 matmul; index granularity = (row, k-block)."""
    assert blocks[1] == bk, "kernel k-tile must equal index block size"
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.block_vp_matmul_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt, bk=bk, out_dtype=out_dtype)
    M, K = a_m.shape
    _, N = b_m.shape
    bm, _, bn = blocks
    am = _pad2(a_m, bm, bk)
    bm_ = _pad2(b_m, bk, bn)
    ai = _pad2(a_i, bm, 1)
    bi = _pad2(b_i, 1, bn)
    out = block_vp_matmul_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]
