"""Public ops: padding, backend dispatch (TPU kernel vs CPU ref), reshaping.

Models and the MIMO application call these; they never touch pallas_call
directly.  Dispatch is `substrate.resolve_backend` in every op: on a TPU
backend the Pallas kernels run natively; elsewhere the pure-jnp refs run
(same math — the refs ARE the oracles the kernels are tested against), so
the dry-run lowers a graph with identical FLOP/byte structure.
`interpret=True` forces the Pallas kernel body through the interpreter on
any backend (used by the kernel tests); an explicit `interpret=False`
means "don't interpret" and still falls back to the refs off-TPU.

Two PR-3 layers live here:

  * PACKED operands.  `vp_quant(..., packed=True)` emits one packed VP
    word plane (`core.packing`) instead of the two-plane layout; the
    matmul/dequant ops accept EITHER layout — pass the packed plane as
    the significand argument with the index argument None.  Packed kernels
    move half the HBM bytes; outputs are bit-identical (the unpack +
    bit-assembled dequant reproduce the plane path exactly;
    tests/test_packing.py pins it).
  * AUTOTUNED blocks.  Every matmul op takes `blocks=None` by default and
    resolves it through `kernels.autotune`: a persisted measured-best
    tiling when one is cached for (kernel, shape, formats, backend), else
    a shape-clamped heuristic that never tiles beyond the padded operand
    shape — so small operands (the MVM engine's (2U, B) x (B, 2)) stop
    padding up to 256^3 tiles.  CSPADE masks pin their grid: pass
    explicit `blocks` alongside masks.

The PR-9 layer: the packed matmul ops are DIFFERENTIABLE.  Each carries
a `jax.custom_vjp` rule whose backward passes are themselves Pallas
kernels over packed words (`vp_bwd_matmul`): dL/dx comes from the
transposed unpack-cascade kernel (`vp_matmul_dx`) without ever
materializing the f32 weight plane; packed-word operands get symbolic
`float0` cotangents (frozen integer storage); the float operands of
`vp_quant_matmul` / `vp_qat_matmul` get straight-through-estimator
gradients, with the quantized residuals saved as PACKED words
(`storage_bits` per element instead of a float plane).  The rules are
grad-checked bit-identical to autodiff through the dequant oracles on
the ref backend (tests/test_train_vjp.py) and linted by JX-BWDMAT.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.core.formats import FXPFormat, VPFormat
from repro.core import packing as pk
from . import autotune, ref, substrate
from .vp_attention import flash_prefill_pallas, vp_decode_attention_pallas
from .vp_quant import vp_quant_pallas, vp_quant_packed_pallas
from .vp_dequant import vp_dequant_pallas, vp_dequant_packed_pallas
from .vp_dequant_matmul import vp_dequant_matmul_pallas
from .vp_bwd_matmul import vp_matmul_dx_pallas, vp_matmul_dw_pallas
from .vp_matmul import vp_matmul_pallas, vp_matmul_batched_pallas
from .vp_block_matmul import block_vp_matmul_pallas
from .vp_quant_matmul import (
    vp_quant_matmul_pallas,
    vp_quant_matmul_batched_pallas,
)


def _float0_zeros(x):
    """Symbolic-zero cotangent for an integer primal (packed VP words are
    frozen storage: there is no meaningful gradient w.r.t. bit patterns)."""
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


def _static_blocks(blocks):
    """Hashable `blocks` for custom_vjp nondiff argnums."""
    return None if blocks is None else tuple(int(b) for b in blocks)


def _static_dtype(dtype):
    """Canonical dtype NAME for custom_vjp nondiff argnums — `np.dtype`
    instances are rejected by the custom_vjp arg flattener ("not a valid
    JAX type"), strings pass through and every consumer re-canonicalizes.
    """
    return jnp.dtype(dtype).name


def _pad2(x, br, bc, value=0):
    R, C = x.shape
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)
    return x


def _pad3(x, br, bc, value=0):
    """Pad the trailing two dims of a (G, R, C) batch to tile multiples."""
    _, R, C = x.shape
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, 0), (0, pr), (0, pc)), constant_values=value)
    return x


def _elementwise_block(R: int, C: int, backend: str) -> Tuple[int, int]:
    """Shape-clamped tile for the elementwise (quant/dequant) kernels —
    same policy as `autotune.heuristic_blocks`, two axes.  On the
    TPU-native backend the tile is floored to the int8-plane Mosaic
    minimum (32 sublanes, 128 lanes); interpret keeps the snug clamp.
    """
    b = autotune.heuristic_blocks(R, C, 1)
    if backend == "native":
        return max(b[0], 32), max(b[1], 128)
    return b[0], b[1]


def _resolve_blocks(kernel, shape, formats, backend, blocks, masks):
    """Autotune-resolve `blocks=None`.

    CSPADE masks pin their tile grid, so masked calls with `blocks=None`
    resolve with `use_cache=False` — the deterministic heuristic (+
    native floor) only, never a tuned cache entry, whose grid the masks
    were not built on; `_check_masks` then validates the grid loudly
    either way.

    Mesh awareness rides the cache key (`autotune.make_key` appends the
    active `mesh_scope` segment) and the VMEM contract: `shape` here is
    whatever the op was CALLED with, which under shard_map is the
    per-shard local operand — so both the cache lookup and the
    feasibility proof reason about the tile each device actually
    launches, never the unsharded logical shape.
    """
    resolved = autotune.resolve_blocks(
        kernel, shape, formats, backend, blocks, use_cache=masks is None)
    if backend == "native":
        # Off-TPU backends stage no VMEM; on TPU an over-budget tile
        # dies at Mosaic lowering, so fail it here with the accounting.
        contracts.require_vmem_feasible(
            kernel, tuple(resolved), tuple(formats),
            tuple(int(d) for d in shape), what=kernel)
    return resolved


def _check_masks(a_act, b_act, M, K, N, blocks):
    """Validate optional CSPADE masks against the kernel tile grid.

    Out-of-grid masks would be silently mis-indexed in the kernel (Pallas
    clamps out-of-bounds scalar reads), so mismatches must fail loudly."""
    if (a_act is None) != (b_act is None):
        raise ValueError(
            "CSPADE masks come in pairs: pass both a_act and b_act or neither")
    if a_act is None:
        return
    bm, bk, bn = blocks
    if M % bm or K % bk or N % bn:
        raise ValueError("CSPADE masks require tile-aligned operand shapes")
    want_a, want_b = (M // bm, K // bk), (K // bk, N // bn)
    if tuple(a_act.shape) != want_a or tuple(b_act.shape) != want_b:
        raise ValueError(
            f"CSPADE mask shapes {tuple(a_act.shape)}/{tuple(b_act.shape)} "
            f"do not match the blocks={blocks} tile grid "
            f"(want {want_a}/{want_b}); rebuild the masks on this grid")


def _check_masks_batched(a_act, b_act, G, M, K, N, blocks):
    """Validate optional batched CSPADE masks against the (G, tile) grid."""
    if (a_act is None) != (b_act is None):
        raise ValueError(
            "CSPADE masks come in pairs: pass both a_act and b_act or neither")
    if a_act is None:
        return
    bm, bk, bn = blocks
    if M % bm or K % bk or N % bn:
        raise ValueError("CSPADE masks require tile-aligned operand shapes")
    want_a = (G, M // bm, K // bk)
    want_b = (G, K // bk, N // bn)
    if tuple(a_act.shape) != want_a or tuple(b_act.shape) != want_b:
        raise ValueError(
            f"batched CSPADE mask shapes {tuple(a_act.shape)}/"
            f"{tuple(b_act.shape)} do not match the blocks={blocks} grid "
            f"(want {want_a}/{want_b}); rebuild the masks on this grid")


def _unpack_pair(x_m, x_i, fmt: VPFormat):
    """Either-layout normalization: (packed, None) -> planes, else pass."""
    if x_i is None:
        return pk.unpack_vp(x_m, fmt)
    return x_m, x_i


def vp_quant(x, fxp: FXPFormat, vp: VPFormat,
             interpret: Optional[bool] = None, packed: bool = False):
    """float tensor (any rank) -> VP-quantized planes, same shape.

    ``packed=False``: (significand, index) two-plane layout.
    ``packed=True``: ONE packed word plane (`core.packing` layout,
    `vp.storage_bits` bits/element) — the layout every matmul op accepts
    as (plane, None).
    """
    contracts.require_quant_safe(fxp, vp, "vp_quant")
    backend = substrate.resolve_backend(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    if backend == "ref":
        if packed:
            return ref.vp_quant_packed_ref(x2, fxp, vp).reshape(shape)
        m, i = ref.vp_quant_ref(x2, fxp, vp)
    else:
        R, C = x2.shape
        blk = _elementwise_block(R, C, backend)
        xp = _pad2(x2, *blk)
        if packed:
            w = vp_quant_packed_pallas(
                xp, fxp, vp, interpret=(backend == "interpret"), block=blk)
            return w[:R, :C].reshape(shape)
        m, i = vp_quant_pallas(
            xp, fxp, vp, interpret=(backend == "interpret"), block=blk)
        m, i = m[:R, :C], i[:R, :C]
    return m.reshape(shape), i.reshape(shape)


def vp_dequant(m, i=None, vp: VPFormat = None, dtype=jnp.float32,
               interpret: Optional[bool] = None):
    """(significand, index) planes — or packed words with ``i=None`` —
    back to real values: ``vp_dequant(m, i, fmt)`` or
    ``vp_dequant(w, None, fmt)``."""
    if isinstance(i, VPFormat) or vp is None:
        raise TypeError(
            "vp_dequant takes (m, i, vp) for planes or (w, None, vp) for "
            "packed words — the format is always the THIRD argument")
    contracts.require_format_serviceable(vp, "vp_dequant")
    backend = substrate.resolve_backend(interpret)
    packed = i is None
    shape = m.shape
    m2 = m.reshape(-1, shape[-1]) if m.ndim != 2 else m
    if backend == "ref":
        if packed:
            out = ref.vp_dequant_packed_ref(m2, vp, dtype)
        else:
            i2 = i.reshape(-1, shape[-1]) if i.ndim != 2 else i
            out = ref.vp_dequant_ref(m2, i2, vp, dtype)
    else:
        R, C = m2.shape
        blk = _elementwise_block(R, C, backend)
        if packed:
            out = vp_dequant_packed_pallas(
                _pad2(m2, *blk), vp, dtype,
                interpret=(backend == "interpret"), block=blk)
        else:
            i2 = i.reshape(-1, shape[-1]) if i.ndim != 2 else i
            out = vp_dequant_pallas(
                _pad2(m2, *blk), _pad2(i2, *blk), vp, dtype,
                interpret=(backend == "interpret"), block=blk)
        out = out[:R, :C]
    return out.reshape(shape)


def vp_matmul(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """(M,K) x (K,N) VP matmul; CSPADE masks optional (tile grid = blocks).

    Operands may be two-plane (m, i) pairs OR packed word planes (pass
    the packed plane as `a_m`/`b_m` with `a_i`/`b_i` None); the packed
    kernel path moves one HBM word per element.  `blocks=None` resolves
    through the autotuner (cache, else shape-clamped heuristic).
    """
    contracts.check_formats(a_fmt, b_fmt, what="vp_matmul")
    if a_i is None and b_i is None and a_act is None and b_act is None:
        # Packed unmasked path carries the custom-VJP rule (float0
        # cotangents for the frozen word planes); forward is unchanged.
        return _vp_matmul_packed_vjp(
            a_m, b_m, a_fmt, b_fmt, _static_dtype(out_dtype),
            _static_blocks(blocks), interpret)
    M, K = a_m.shape
    _, N = b_m.shape
    backend = substrate.resolve_backend(interpret)
    packed = a_i is None and b_i is None
    # The operand layout changes the kernel body (and its HBM traffic),
    # so packed and plane launches tune/cache independently.
    blocks = _resolve_blocks(
        "vp_matmul_packed" if packed else "vp_matmul",
        (M, K, N), (a_fmt, b_fmt), backend, blocks, a_act)
    _check_masks(a_act, b_act, M, K, N, blocks)
    if backend == "ref":
        if packed:
            return ref.vp_matmul_packed_ref(
                a_m, b_m, a_fmt, b_fmt,
                a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
        a_m, a_i = _unpack_pair(a_m, a_i, a_fmt)
        b_m, b_i = _unpack_pair(b_m, b_i, b_fmt)
        return ref.vp_matmul_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    if (a_i is None) != (b_i is None):
        # Mixed layouts: normalize to planes (no kernel for the mix).
        a_m, a_i = _unpack_pair(a_m, a_i, a_fmt)
        b_m, b_i = _unpack_pair(b_m, b_i, b_fmt)
        packed = False
    bm, bk, bn = blocks
    if packed:
        ap, bp = _pad2(a_m, bm, bk), _pad2(b_m, bk, bn)
        out = vp_matmul_pallas(
            ap, None, bp, None, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act,
            interpret=(backend == "interpret"), blocks=blocks,
            out_dtype=out_dtype, packed=True)
        return out[:M, :N]
    am, ai = _pad2(a_m, bm, bk), _pad2(a_i, bm, bk)
    bm_, bi = _pad2(b_m, bk, bn), _pad2(b_i, bk, bn)
    out = vp_matmul_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


def vp_matmul_dx(
    g, w,
    w_fmt: VPFormat,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Backward op: upstream cotangent g (M, N) @ dequant(w (K, N))^T.

    The TRANSPOSED serving matmul — the dL/dx half of every packed-weight
    VJP.  The same packed word plane the forward read is consumed
    directly by the kernel (unpack + bit-assembled scale in VMEM,
    contracted over its OUTPUT dim via `dot_general`), so the backward
    pass moves the same `storage_bits`-per-element HBM traffic as the
    forward and never materializes the f32 weight plane.
    """
    contracts.require_format_serviceable(w_fmt, "vp_matmul_dx")
    M, N = g.shape
    K, _ = w.shape
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        # Tile-independent oracle: exactly the dot_general XLA's
        # transpose rule emits for the forward, so VJP grad checks are
        # bit-identical against autodiff-through-dequant on this backend.
        return ref.vp_matmul_dx_ref(g, w, w_fmt, out_dtype=out_dtype)
    blocks = _resolve_blocks(
        "vp_matmul_dx", (M, K, N), (w_fmt,), backend, blocks, None)
    bm, bk, bn = blocks
    gp, wp = _pad2(g, bm, bn), _pad2(w, bk, bn)
    out = vp_matmul_dx_pallas(
        gp, wp, w_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :K]


def vp_matmul_dw(
    a_w, g,
    a_fmt: VPFormat,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Backward op: dequant(a_w (M, K) packed words)^T @ g (M, N).

    The dL/dB half of the fused quantize-matmul VJP under the
    straight-through estimator: `a_w` is the QUANTIZED first operand
    saved as the VJP residual in packed form (`storage_bits` per element
    instead of a float activation plane), unpacked per tile and reduced
    over the batch dim into an f32 accumulator.
    """
    contracts.require_format_serviceable(a_fmt, "vp_matmul_dw")
    M, K = a_w.shape
    _, N = g.shape
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_matmul_dw_ref(a_w, g, a_fmt, out_dtype=out_dtype)
    blocks = _resolve_blocks(
        "vp_matmul_dw", (M, K, N), (a_fmt,), backend, blocks, None)
    bm, bk, bn = blocks
    ap, gp = _pad2(a_w, bm, bk), _pad2(g, bm, bn)
    out = vp_matmul_dw_pallas(
        ap, gp, a_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:K, :N]


def _vp_dequant_matmul_impl(x, w, w_fmt, out_dtype, blocks, interpret):
    M, K = x.shape
    _, N = w.shape
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        # The ref's math is tile-independent: skip block resolution
        # entirely (no cache reads, no per-tiling jit signatures).
        return ref.vp_dequant_matmul_ref(x, w, w_fmt, out_dtype=out_dtype)
    blocks = _resolve_blocks(
        "vp_dequant_matmul", (M, K, N), (w_fmt,), backend, blocks, None)
    bm, bk, bn = blocks
    xp, wp = _pad2(x, bm, bk), _pad2(w, bk, bn)
    out = vp_dequant_matmul_pallas(
        xp, wp, w_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _vp_dequant_matmul_vjp(x, w, w_fmt, out_dtype, x_dtype, blocks,
                           interpret):
    return _vp_dequant_matmul_impl(
        x, w, w_fmt, np.dtype(out_dtype), blocks, interpret)


def _vp_dequant_matmul_fwd(x, w, w_fmt, out_dtype, x_dtype, blocks,
                           interpret):
    out = _vp_dequant_matmul_impl(
        x, w, w_fmt, np.dtype(out_dtype), blocks, interpret)
    # The packed words ARE the residual — `storage_bits` per element,
    # where autodiff through a dequant would have checkpointed the f32
    # weight plane.
    return out, (w,)


def _vp_dequant_matmul_bwd(w_fmt, out_dtype, x_dtype, blocks, interpret,
                           res, g):
    (w,) = res
    dx = vp_matmul_dx(
        g, w, w_fmt, blocks=blocks, interpret=interpret,
        out_dtype=np.dtype(x_dtype))
    # Packed words are frozen integer storage: symbolic-zero cotangent.
    return dx, _float0_zeros(w)


_vp_dequant_matmul_vjp.defvjp(_vp_dequant_matmul_fwd, _vp_dequant_matmul_bwd)


def vp_dequant_matmul(
    x, w,
    w_fmt: VPFormat,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=None,
):
    """Serving matmul: real x (M, K) @ dequant(w (K, N) packed VP words).

    THE model-zoo decode/prefill hot path (`models.layers.qdot`, mode
    "vp"): one real operand, one packed-word operand consumed directly by
    the kernel — no f32 weight plane in HBM.  `blocks=None` resolves
    through the autotuner, so skinny decode shapes (M = batch) launch the
    tuned/clamped tiling instead of padding up to 256^3 (see
    `autotune.tune_serving_decode` for the M=1..B profile).  `out_dtype`
    defaults to the activation dtype (the models' compute dtype).

    DIFFERENTIABLE in x: the custom VJP computes dL/dx with the
    transposed packed-word kernel (`vp_matmul_dx`) from the same word
    plane, and gives the frozen integer words a symbolic `float0`
    cotangent — so QAT/fine-tune graphs backprop through the serving
    path without an f32 weight plane in either direction.
    """
    contracts.require_format_serviceable(w_fmt, "vp_dequant_matmul")
    out_dtype = x.dtype if out_dtype is None else out_dtype
    return _vp_dequant_matmul_vjp(
        x, w, w_fmt, _static_dtype(out_dtype), _static_dtype(x.dtype),
        _static_blocks(blocks), interpret)


def _vp_quant_matmul_impl(
        a, b, a_fxp, a_vp, b_fxp, b_vp, out_dtype, blocks, interpret):
    M, K = a.shape
    _, N = b.shape
    backend = substrate.resolve_backend(interpret)
    blocks = _resolve_blocks(
        "vp_quant_matmul", (M, K, N), (a_fxp, a_vp, b_fxp, b_vp),
        backend, blocks, None)
    if backend == "ref":
        return ref.vp_quant_matmul_ref(
            a, b, a_fxp, a_vp, b_fxp, b_vp,
            tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    ap, bp = _pad2(a, bm, bk), _pad2(b, bk, bn)
    out = vp_quant_matmul_pallas(
        ap, bp, a_fxp, a_vp, b_fxp, b_vp,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10))
def _vp_quant_matmul_vjp(
        a, b, a_fxp, a_vp, b_fxp, b_vp, out_dtype, a_dtype, b_dtype,
        blocks, interpret):
    return _vp_quant_matmul_impl(
        a, b, a_fxp, a_vp, b_fxp, b_vp, np.dtype(out_dtype), blocks,
        interpret)


def _vp_quant_matmul_fwd(
        a, b, a_fxp, a_vp, b_fxp, b_vp, out_dtype, a_dtype, b_dtype,
        blocks, interpret):
    out = _vp_quant_matmul_impl(
        a, b, a_fxp, a_vp, b_fxp, b_vp, np.dtype(out_dtype), blocks,
        interpret)
    # STE residuals are the QUANTIZED operands saved as PACKED words —
    # `storage_bits` per element each, where autodiff through a fake
    # quant would checkpoint both float planes.
    a_w = vp_quant(a, a_fxp, a_vp, interpret=interpret, packed=True)
    b_w = vp_quant(b, b_fxp, b_vp, interpret=interpret, packed=True)
    return out, (a_w, b_w)


def _vp_quant_matmul_bwd(
        a_fxp, a_vp, b_fxp, b_vp, out_dtype, a_dtype, b_dtype, blocks,
        interpret, res, g):
    a_w, b_w = res
    # Straight-through estimator: the quantizer Jacobians are taken as
    # identity, so both grads are packed-word matmuls over the quantized
    # residuals — da = g qb^T by the transposed unpack-cascade kernel,
    # db = qa^T g by the second-operand kernel, both reduced in f32.
    da = vp_matmul_dx(
        g, b_w, b_vp, interpret=interpret, out_dtype=np.dtype(a_dtype))
    db = vp_matmul_dw(
        a_w, g, a_vp, interpret=interpret, out_dtype=np.dtype(b_dtype))
    return da, db


_vp_quant_matmul_vjp.defvjp(_vp_quant_matmul_fwd, _vp_quant_matmul_bwd)


def vp_quant_matmul(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Fused float->VP quantize + matmul: a (M,K) x b (K,N) floats -> (M,N).

    Numerically identical to `vp_quant` on each operand followed by
    `vp_matmul`, without materializing the quantized planes in HBM.
    CSPADE masks follow the `blocks` tile grid and require tile-aligned
    operands (mask calibration needs the planes anyway — see mvm_engine).

    DIFFERENTIABLE (unmasked path) under the straight-through estimator:
    both cotangents come from packed-word Pallas kernels over the
    quantized residuals (see `_vp_quant_matmul_bwd`).  The CSPADE-masked
    path stays forward-only — masks are calibration-time artifacts.
    """
    contracts.require_quant_safe(a_fxp, a_vp, "vp_quant_matmul")
    contracts.require_quant_safe(b_fxp, b_vp, "vp_quant_matmul")
    if a_act is None and b_act is None:
        return _vp_quant_matmul_vjp(
            a, b, a_fxp, a_vp, b_fxp, b_vp, _static_dtype(out_dtype),
            _static_dtype(a.dtype), _static_dtype(b.dtype),
            _static_blocks(blocks), interpret)
    M, K = a.shape
    _, N = b.shape
    backend = substrate.resolve_backend(interpret)
    blocks = _resolve_blocks(
        "vp_quant_matmul", (M, K, N), (a_fxp, a_vp, b_fxp, b_vp),
        backend, blocks, a_act)
    _check_masks(a_act, b_act, M, K, N, blocks)
    if backend == "ref":
        return ref.vp_quant_matmul_ref(
            a, b, a_fxp, a_vp, b_fxp, b_vp,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    ap, bp = _pad2(a, bm, bk), _pad2(b, bk, bn)
    out = vp_quant_matmul_pallas(
        ap, bp, a_fxp, a_vp, b_fxp, b_vp,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]


def _vp_matmul_packed_impl(a_w, b_w, a_fmt, b_fmt, out_dtype, blocks,
                           interpret):
    M, K = a_w.shape
    _, N = b_w.shape
    backend = substrate.resolve_backend(interpret)
    blocks = _resolve_blocks(
        "vp_matmul_packed", (M, K, N), (a_fmt, b_fmt), backend, blocks, None)
    if backend == "ref":
        return ref.vp_matmul_packed_ref(
            a_w, b_w, a_fmt, b_fmt, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    ap, bp = _pad2(a_w, bm, bk), _pad2(b_w, bk, bn)
    out = vp_matmul_pallas(
        ap, None, bp, None, a_fmt, b_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype, packed=True)
    return out[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _vp_matmul_packed_vjp(a_w, b_w, a_fmt, b_fmt, out_dtype, blocks,
                          interpret):
    return _vp_matmul_packed_impl(
        a_w, b_w, a_fmt, b_fmt, np.dtype(out_dtype), blocks, interpret)


def _vp_matmul_packed_fwd(a_w, b_w, a_fmt, b_fmt, out_dtype, blocks,
                          interpret):
    out = _vp_matmul_packed_impl(
        a_w, b_w, a_fmt, b_fmt, np.dtype(out_dtype), blocks, interpret)
    return out, (a_w, b_w)


def _vp_matmul_packed_bwd(a_fmt, b_fmt, out_dtype, blocks, interpret,
                          res, g):
    # Both operands are frozen integer word planes — there is no
    # gradient w.r.t. bit patterns, only the explicit statement that the
    # rule exists (so traced training graphs do not die trying to
    # transpose through pallas_call).
    a_w, b_w = res
    return _float0_zeros(a_w), _float0_zeros(b_w)


_vp_matmul_packed_vjp.defvjp(_vp_matmul_packed_fwd, _vp_matmul_packed_bwd)


def _vp_qat_matmul_impl(x, w, fxp, vp, blocks, interpret):
    w_q = vp_quant(w.astype(jnp.float32), fxp, vp,
                   interpret=interpret, packed=True)
    out = _vp_dequant_matmul_impl(
        x, w_q, vp, np.dtype(x.dtype), blocks, interpret)
    return out, w_q


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _vp_qat_matmul_vjp(x, w, fxp, vp, w_dtype, blocks, interpret):
    out, _ = _vp_qat_matmul_impl(x, w, fxp, vp, blocks, interpret)
    return out


def _vp_qat_matmul_fwd(x, w, fxp, vp, w_dtype, blocks, interpret):
    out, w_q = _vp_qat_matmul_impl(x, w, fxp, vp, blocks, interpret)
    # Residual = activations + the PACKED quantized weight (what the
    # forward actually multiplied by) — never the f32 weight plane.
    return out, (x, w_q)


def _vp_qat_matmul_bwd(fxp, vp, w_dtype, blocks, interpret, res, g):
    x, w_q = res
    dx = vp_matmul_dx(
        g, w_q, vp, blocks=blocks, interpret=interpret, out_dtype=x.dtype)
    # STE on the master weight: the quantizer's Jacobian is identity, so
    # dW = x^T g reduced in f32 — a plain dense contraction (x is real;
    # no packed operand exists on this side), handed back in the master
    # dtype for the optimizer to step and the next fwd to re-quantize.
    dw = jax.lax.dot_general(
        x.astype(jnp.float32), g.astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(np.dtype(w_dtype))
    return dx, dw


_vp_qat_matmul_vjp.defvjp(_vp_qat_matmul_fwd, _vp_qat_matmul_bwd)


def vp_qat_matmul(
    x, w,
    fxp: FXPFormat, vp: VPFormat,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
):
    """QAT matmul: x (M, K) reals @ quantize-then-dequant(w (K, N) float
    master weights) — the trainable twin of `vp_dequant_matmul`.

    Forward quantizes the float master weight into ONE packed word plane
    (`vp_quant(..., packed=True)`) and runs the packed serving kernel on
    it, so training sees bit-identical numerics to what serving will run.
    Backward is straight-through: dL/dx comes from the transposed
    packed-word kernel over the SAME quantized words (never the float
    plane), dL/dW = x^T g in f32 as if the quantizer were identity.
    `models.layers._qdot_local` rides this when `QuantConfig.qat_mode ==
    "packed"`.
    """
    contracts.require_quant_safe(fxp, vp, "vp_qat_matmul")
    return _vp_qat_matmul_vjp(
        x, w, fxp, vp, _static_dtype(w.dtype), _static_blocks(blocks),
        interpret)


def vp_matmul_batched(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    a_act=None, b_act=None,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """(G,M,K) x (G,K,N) truly-batched VP matmul.

    Each batch element runs its own tile program on the kernel's leading
    batch grid dimension — the scalable replacement for folding G into the
    row axis and discarding off-diagonal columns.  CSPADE masks are per
    (batch, tile): a_act (G, M/bm, K/bk), b_act (G, K/bk, N/bn).
    Packed-word operands: pass the packed planes with `a_i`/`b_i` None.
    """
    contracts.check_formats(a_fmt, b_fmt, what="vp_matmul_batched")
    G, M, K = a_m.shape
    _, _, N = b_m.shape
    backend = substrate.resolve_backend(interpret)
    packed = a_i is None and b_i is None
    blocks = _resolve_blocks(
        "vp_matmul_batched_packed" if packed else "vp_matmul_batched",
        (G, M, K, N), (a_fmt, b_fmt), backend, blocks, a_act)
    _check_masks_batched(a_act, b_act, G, M, K, N, blocks)
    if backend == "ref":
        if packed:
            return ref.vp_matmul_batched_packed_ref(
                a_m, b_m, a_fmt, b_fmt,
                a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
        a_m, a_i = _unpack_pair(a_m, a_i, a_fmt)
        b_m, b_i = _unpack_pair(b_m, b_i, b_fmt)
        return ref.vp_matmul_batched_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    if (a_i is None) != (b_i is None):
        a_m, a_i = _unpack_pair(a_m, a_i, a_fmt)
        b_m, b_i = _unpack_pair(b_m, b_i, b_fmt)
        packed = False
    bm, bk, bn = blocks
    if packed:
        ap, bp = _pad3(a_m, bm, bk), _pad3(b_m, bk, bn)
        out = vp_matmul_batched_pallas(
            ap, None, bp, None, a_fmt, b_fmt,
            a_act=a_act, b_act=b_act,
            interpret=(backend == "interpret"), blocks=blocks,
            out_dtype=out_dtype, packed=True)
        return out[:, :M, :N]
    am, ai = _pad3(a_m, bm, bk), _pad3(a_i, bm, bk)
    bm_, bi = _pad3(b_m, bk, bn), _pad3(b_i, bk, bn)
    out = vp_matmul_batched_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:, :M, :N]


def vp_quant_matmul_batched(
    a, b,
    a_fxp: FXPFormat, a_vp: VPFormat,
    b_fxp: FXPFormat, b_vp: VPFormat,
    a_act=None, b_act=None,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Truly-batched fused float->VP quantize + matmul over (G, M, K) x
    (G, K, N) floats.

    Numerically identical to `vp_quant` on each operand followed by
    `vp_matmul_batched`, with no quantized-plane HBM round-trip — ONE
    pallas_call for the whole batch.
    """
    contracts.require_quant_safe(a_fxp, a_vp, "vp_quant_matmul_batched")
    contracts.require_quant_safe(b_fxp, b_vp, "vp_quant_matmul_batched")
    G, M, K = a.shape
    _, _, N = b.shape
    backend = substrate.resolve_backend(interpret)
    blocks = _resolve_blocks(
        "vp_quant_matmul_batched", (G, M, K, N),
        (a_fxp, a_vp, b_fxp, b_vp), backend, blocks, a_act)
    _check_masks_batched(a_act, b_act, G, M, K, N, blocks)
    if backend == "ref":
        return ref.vp_quant_matmul_batched_ref(
            a, b, a_fxp, a_vp, b_fxp, b_vp,
            a_act=a_act, b_act=b_act, tiles=blocks, out_dtype=out_dtype)
    bm, bk, bn = blocks
    ap, bp = _pad3(a, bm, bk), _pad3(b, bk, bn)
    out = vp_quant_matmul_batched_pallas(
        ap, bp, a_fxp, a_vp, b_fxp, b_vp,
        a_act=a_act, b_act=b_act,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:, :M, :N]


def vp_decode_attention(
    q, k_w, v_w, k_s, v_s, lengths,
    fmt: VPFormat,
    window: Optional[int] = None,
    rolling: bool = False,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
):
    """Single-token decode attention over a PACKED VP KV cache.

    q (B, 1, H, dh); k_w / v_w (B, Smax, KV, dh) packed VP words
    (`core.packing`); k_s / v_s (B, Smax, 1, 1) per-position pow2 cache
    scales; lengths (B,) valid cache lengths.  The cache words feed the
    kernel directly — unpack + bit-assembled scale happen in VMEM, and
    seq tiles entirely outside the valid span (past `lengths`, outside
    the sliding `window`, or past the `rolling` ring's fill level) are
    skipped, so decode work is O(cache_len), not O(Smax).  `blocks=None`
    resolves the (bq, bkv, 1) chunking through the autotuner, keyed on
    (B, Smax, KV, dh, window, rolling).
    """
    contracts.require_format_serviceable(fmt, "vp_decode_attention")
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.vp_decode_attention_ref(
            q, k_w, v_w, k_s, v_s, lengths, fmt,
            window=window, rolling=rolling)
    B, _, H, dh = q.shape
    Smax, KV = k_w.shape[1], k_w.shape[2]
    G = H // KV
    blocks = autotune.resolve_attn_blocks(
        "vp_decode_attention",
        (B, Smax, KV, dh, window or 0, int(rolling)), (fmt,), backend,
        sq=G, sk=Smax, blocks=blocks)
    bs = blocks[1]
    ks, vs = k_s.reshape(B, Smax), v_s.reshape(B, Smax)
    kw, vw = k_w, v_w
    pad = (-Smax) % bs
    if pad:
        # The kernel masks padded positions (the real `Smax` rides the
        # launch as the ring clamp), but re-padding four whole cache
        # planes EVERY decode step is the O(Smax) copy this kernel
        # exists to remove — prefer a smaller tile that divides the
        # buffer (floor: the int8-plane sublane minimum on native).
        floor = 32 if backend == "native" else 8
        bs_div = bs
        while Smax % bs_div and bs_div > floor:
            bs_div //= 2
        if Smax % bs_div == 0:
            bs, pad = bs_div, 0
    if pad:
        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pad)))
        vs = jnp.pad(vs, ((0, 0), (0, pad)))
    qr = q.reshape(B, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    gp = max(G, 8) if backend == "native" else G
    if gp != G:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, gp - G), (0, 0)))
    out = vp_decode_attention_pallas(
        qr, kw, vw, ks, vs, lengths.astype(jnp.int32), fmt,
        window=window, rolling=rolling, bs=bs, smax=Smax,
        interpret=(backend == "interpret"))
    return out[:, :, :G].reshape(B, 1, H, dh).astype(q.dtype)


def flash_prefill(
    q, k, v,
    pattern: str = "causal",
    window: Optional[int] = None,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
):
    """Flash-attention prefill: q (B, Sq, H, dh) x k/v (B, Sk, KV, dh).

    q-chunk x k-chunk online softmax in ONE pallas_call (scores never
    materialize); causal/local tiles above the diagonal or outside the
    window are skipped at tile granularity.  GQA rides the kernel index
    maps (kv head = head // G).  `blocks=None` resolves the (bq, bk, 1)
    chunking through the autotuner.
    """
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.flash_prefill_ref(q, k, v, pattern=pattern,
                                     window=window)
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if pattern in ("causal", "local") and Sq != Sk:
        # A real serving-input condition, not an internal invariant — it
        # must survive `python -O` (asserts are stripped).
        raise ValueError(
            f"causal/local prefill requires Sq == Sk, got {Sq} != {Sk}")
    blocks = autotune.resolve_attn_blocks(
        "flash_prefill",
        (B, H, KV, dh, Sq, Sk, window or 0), (), backend,
        sq=Sq, sk=Sk, blocks=blocks)
    bq, bk = blocks[0], blocks[1]
    qt = q.transpose(0, 2, 1, 3) * jnp.asarray(dh ** -0.5, q.dtype)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_prefill_pallas(
        qt, kt, vt, pattern=pattern, window=window, sk=Sk, g=G,
        blocks=(bq, bk), interpret=(backend == "interpret"))
    return out[:, :, :Sq].transpose(0, 2, 1, 3).astype(q.dtype)


def block_vp_matmul(
    a_m, a_i, b_m, b_i,
    a_fmt: VPFormat, b_fmt: VPFormat,
    bk: int = 256,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
):
    """Block-VP int8 matmul; index granularity = (row, k-block)."""
    contracts.check_formats(a_fmt, b_fmt, what="block_vp_matmul")
    # Each k-tile's raw-significand dot accumulates `bk` int products
    # before the f32 rescale — prove that sum cannot wrap int32.
    contracts.require_int_accum_safe(a_fmt, b_fmt, bk)
    if blocks is not None and blocks[1] != bk:
        # Validate on EVERY backend (the ref path is the parity oracle;
        # a contract violation must not pass on CPU and crash on TPU).
        raise ValueError(
            f"kernel k-tile {blocks[1]} must equal index block size {bk}")
    backend = substrate.resolve_backend(interpret)
    if backend == "ref":
        return ref.block_vp_matmul_ref(
            a_m, a_i, b_m, b_i, a_fmt, b_fmt, bk=bk, out_dtype=out_dtype)
    M, K = a_m.shape
    _, N = b_m.shape
    if blocks is None:
        # Autotune-resolve like every other matmul op (the qdot vp_block
        # path used to hardcode 256^3-class tiles here, bypassing the
        # cache entirely); the k-tile stays pinned to the index block
        # size whatever the cache says — it is part of the format, not a
        # free tiling axis, so the kernel name carries it in the key.
        r = autotune.resolve_blocks(
            f"block_vp_matmul_bk{bk}", (M, K, N), (a_fmt, b_fmt),
            backend, None)
        blocks = (r[0], bk, r[2])
    bm, _, bn = blocks
    am = _pad2(a_m, bm, bk)
    bm_ = _pad2(b_m, bk, bn)
    ai = _pad2(a_i, bm, 1)
    bi = _pad2(b_i, 1, bn)
    out = block_vp_matmul_pallas(
        am, ai, bm_, bi, a_fmt, b_fmt,
        interpret=(backend == "interpret"), blocks=blocks,
        out_dtype=out_dtype)
    return out[:M, :N]
