"""Pallas TPU kernel: float activations x PACKED VP weights (LLM serving).

The serving-datapath analogue of the paper's B-VP MVM for the model zoo:
activations arrive as reals (bf16/f32 — they were just produced by the
previous layer), weights arrive as packed VP words (`core.packing`: sign +
significand + exponent index in ONE int8/int16 per element).  Each weight
tile is unpacked in-register (arithmetic shift + mask) and scaled by the
O(1) bit-assembled power-of-two (`substrate.dequant_packed`) before the
MXU dot — the f32 weight matrix never exists in HBM, which is the VP
claim (compact words feed the multiplier directly) restated as a serving
kernel.

Grid is (m, n, k) with k innermost; a VMEM f32 scratch accumulates across
k steps and flushes on the last step.  Compared to `vp_matmul` this kernel
has exactly ONE quantized operand: LLM decode multiplies a skinny real
activation block (M = batch) against a wide packed weight panel, so the A
tile rides HBM at its real dtype while B moves `storage_bits` per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

BM, BK, BN = 256, 256, 256


def _vp_dequant_matmul_kernel(
    x_ref, w_ref, o_ref, acc_ref, *, w_fmt: VPFormat, nk: int, dtype,
):
    ki = pl.program_id(2)
    sub.accum_init(acc_ref, ki)
    w = sub.dequant_packed(w_ref[...], w_fmt, dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(dtype), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sub.accum_flush(o_ref, acc_ref, ki, nk)


@functools.partial(
    jax.jit,
    static_argnames=("w_fmt", "interpret", "blocks", "out_dtype"),
)
def vp_dequant_matmul_pallas(
    x, w,
    w_fmt: VPFormat,
    interpret: bool = False,
    blocks=(BM, BK, BN),
    out_dtype=jnp.float32,
):
    """x (M, K) reals @ dequant(w (K, N) packed VP words) -> (M, N).

    The weight tile is unpacked + dequantized in VMEM (shift, mask, O(1)
    bit-assembled scale) and contracted on the MXU in f32.  Shapes must be
    tile-multiples of `blocks` (ops.py pads; packed-word 0 decodes to the
    real value 0, so padding is exact).
    """
    (bm, bk, bn) = blocks
    M, K = x.shape
    _, N = w.shape
    nm, nk, nn = M // bm, K // bk, N // bn
    kernel = functools.partial(
        _vp_dequant_matmul_kernel, w_fmt=w_fmt, nk=nk, dtype=jnp.float32)
    return sub.vp_pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[sub.vmem((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, w)
