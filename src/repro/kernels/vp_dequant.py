"""Pallas TPU kernel: VP2FXP / VP-to-real tile dequantizer (paper Fig. 5).

The K-way shift mux is the substrate's `dequant_cascade`: `m * scale[i]`
with the (static) scale list unrolled as a where-chain (K <= 16), i.e. one
VPU select cascade — the TPU analogue of the barrel mux.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import VPFormat
from . import substrate as sub

BLOCK_R, BLOCK_C = 256, 256


def _vp_dequant_kernel(m_ref, i_ref, o_ref, *, vp: VPFormat, dtype):
    o_ref[...] = sub.dequant_cascade(m_ref[...], i_ref[...], vp, dtype)


def _vp_dequant_packed_kernel(w_ref, o_ref, *, vp: VPFormat, dtype):
    o_ref[...] = sub.dequant_packed(w_ref[...], vp, dtype)


@functools.partial(
    jax.jit, static_argnames=("vp", "dtype", "interpret", "block"))
def vp_dequant_pallas(
    m, i, vp: VPFormat,
    dtype=jnp.float32,
    interpret: bool = False,
    block=(BLOCK_R, BLOCK_C),
):
    R, C = m.shape
    br, bc = block
    spec = pl.BlockSpec((br, bc), lambda r, c: (r, c))
    return sub.vp_pallas_call(
        functools.partial(_vp_dequant_kernel, vp=vp, dtype=dtype),
        grid=(pl.cdiv(R, br), pl.cdiv(C, bc)),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(m, i)


@functools.partial(
    jax.jit, static_argnames=("vp", "dtype", "interpret", "block"))
def vp_dequant_packed_pallas(
    w, vp: VPFormat,
    dtype=jnp.float32,
    interpret: bool = False,
    block=(BLOCK_R, BLOCK_C),
):
    """Dequantize PACKED VP words: one HBM plane in, reals out.

    Unpack is two integer ops (shift + mask) and the scale is the O(1)
    bit-assembly — no second plane read and no K-way select chain.
    """
    R, C = w.shape
    br, bc = block
    spec = pl.BlockSpec((br, bc), lambda r, c: (r, c))
    return sub.vp_pallas_call(
        functools.partial(_vp_dequant_packed_kernel, vp=vp, dtype=dtype),
        grid=(pl.cdiv(R, br), pl.cdiv(C, bc)),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(w)
