"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX).

Optimizer state is a pytree shaped like the params (sharded identically by
the launcher — ZeRO-1-style sharding of m/v over the model axis comes for
free since they inherit the weight specs).

VP-COMPRESSED MOMENTS (``OptConfig.moment_codec="vp"``): Adam's mu and
especially nu = EMA(g^2) are the textbook high-dynamic-range tensors the
paper's format exists for — nu spans the SQUARE of the gradient range, so
a linear int8 grid either clips the head or flushes the tail.  With the
codec on, each moment leaf is stored between steps as ACTUAL packed VP
words + one f32 pow2 scale (`core.quantize.vp_pack_tensor` — the same
`core.packing` word layout the serving kernels consume), cutting moment
HBM from 8 bytes/param to 2*storage_bits/8 (2 bytes/param at the default
M=6, E=2).  Each step decodes to f32, runs the exact Adam recurrence, and
re-encodes.  No error-feedback residual is carried for moments (a f32
residual would cost back the memory the codec saves); instead the EMA
recurrence itself contracts the injected quantization error — an error e
in a stored moment decays as b1^k (resp. b2^k) under subsequent updates,
so the fixed point of training is unchanged (tests/test_train_step.py
pins the loss trajectory against the f32-moment baseline).

nu is stored as sqrt(nu): the second moment spans the SQUARE of the
gradient dynamic range, so coordinates whose gradients sit ~2^-6 below
the leaf max already fall 2^-12 below it in nu — under the quantizer
they flush to zero while the matching mu survives, and
mhat / (sqrt(0) + eps) turns a modest update into a 1e8x one (observed:
divergence within 3 steps).  sqrt(nu) has exactly mu's dynamic range, so
both moments flush at the same threshold and the preconditioned ratio
stays bounded — the same trick 8-bit Adam variants use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FXPFormat, VPFormat, default_vp_format
from repro.core.quantize import vp_pack_tensor, vp_unpack_tensor


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # Moment storage codec: None = f32 planes (classic); "vp" = packed VP
    # words + per-leaf pow2 scale between steps (see module docstring).
    moment_codec: Optional[str] = None
    moment_M: int = 6              # VP significand bits (incl. sign)
    moment_E: int = 2              # VP exponent-index bits
    moment_W: int = 12             # FXP proxy grid width

    def __post_init__(self):
        if self.moment_codec not in (None, "vp"):
            raise ValueError(
                f"unknown moment codec {self.moment_codec!r}; "
                f"pick None or 'vp'")

    def moment_formats(self) -> Tuple[FXPFormat, VPFormat]:
        fxp = FXPFormat(self.moment_W, self.moment_W - 1)
        return fxp, default_vp_format(fxp, self.moment_M, self.moment_E)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


# A packed moment leaf is the dict {"w": packed words, "s": f32 scale}.
# Moment pytrees mix these with plain f32 leaves only at the boundary
# (init vs restored state), so every walker below flattens with this
# `is_leaf` and the two layouts coexist.
_PACKED_KEYS = frozenset(("w", "s"))


def is_packed_moment(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf.keys()) == _PACKED_KEYS


def _moment_leaves(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=is_packed_moment)


def encode_moment(x, fxp: FXPFormat, vp: VPFormat):
    """f32 moment plane -> {"w": packed words, "s": pow2 scale} leaf."""
    w, s = vp_pack_tensor(x, fxp, vp)
    return {"w": w, "s": s}


def decode_moment(leaf, vp: VPFormat):
    """Packed-moment leaf (or a plain f32 plane) -> f32 plane."""
    if is_packed_moment(leaf):
        return vp_unpack_tensor(leaf["w"], leaf["s"], vp, jnp.float32)
    return leaf.astype(jnp.float32)


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params, cfg: Optional[OptConfig] = None) -> OptState:
    """Zero state.  With cfg.moment_codec="vp", moments start as packed
    zero words (scale 1.0) so the state NEVER materializes f32 planes."""
    if cfg is not None and cfg.moment_codec == "vp":
        fxp, vp = cfg.moment_formats()

        def zero_moment(p):
            return encode_moment(jnp.zeros(p.shape, jnp.float32), fxp, vp)

        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree_util.tree_map(zero_moment, params),
                        nu=jax.tree_util.tree_map(zero_moment, params))
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_state, metrics).

    The Adam recurrence always runs in f32; with moment_codec="vp" the
    moments are decoded from packed words on entry and re-encoded on
    exit, so only the BETWEEN-step storage is compressed.
    """
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    packed = cfg.moment_codec == "vp"
    if packed:
        m_fxp, m_vp = cfg.moment_formats()

    def upd(p, g, m, v):
        if packed:
            m = decode_moment(m, m_vp)
            # nu rides storage as sqrt(nu) — see module docstring.
            v = jnp.square(decode_moment(v, m_vp))
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if packed:
            # Re-encode AFTER the param delta was computed from the f32
            # moments — the delta sees exact Adam, storage sees VP words.
            m = encode_moment(m, m_fxp, m_vp)
            v = encode_moment(jnp.sqrt(v), m_fxp, m_vp)
        return new_p, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = _moment_leaves(state.mu)
    flat_v = _moment_leaves(state.nu)
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
