"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX).

Optimizer state is a pytree shaped like the params (sharded identically by
the launcher — ZeRO-1-style sharding of m/v over the model axis comes for
free since they inherit the weight specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
