"""End-to-end training driver with checkpoint/restart + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--quant vp]

On CPU this trains the reduced (smoke) configs; on a TPU fleet the same
driver runs the full configs under the production mesh (--mesh prod).
The loop is crash-contained: every step the data position advances
deterministically; on restart the latest INTACT checkpoint + data index
resume bit-exactly (`CheckpointManager.restore_latest` walks past any
checkpoint that fails its manifest checksums).

`--ft-sim` exercises the full fault-tolerance stack against a simulated
host set: each step every live simulated host heartbeats the
`FaultToleranceController` (a designated straggler reports 3x step
durations), `--ft-fail-steps` crashes one host at the named steps
(killing the loop with a RuntimeError), and `run_with_restarts`
restarts the loop — which resumes from the latest intact checkpoint
while the controller evicts the dead host and proposes a shrunken
elastic mesh.  The same controller/restart machinery a real fleet runs,
driven end-to-end on one process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.optim import OptConfig, init_opt_state
from repro.optim.optimizer import OptState
from repro.train import make_train_step, CheckpointManager, \
    FaultToleranceController, run_with_restarts
from repro.train.compression import CompressionConfig, init_compressor_state
from repro.data import DataConfig, SyntheticLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="error-feedback DP gradient compression "
                         "(codec per --grad-codec)")
    ap.add_argument("--grad-codec", default="int8",
                    choices=["int8", "vp"],
                    help="gradient codec for --compress-grads: int8 "
                         "linear, or packed VP words + pow2 scale")
    ap.add_argument("--compress-moments", action="store_true",
                    help="store Adam mu/nu between steps as packed VP "
                         "words (sqrt(nu) encoding)")
    ap.add_argument("--qat", default="off",
                    choices=["off", "fake", "packed"],
                    help="quantization-aware fine-tune: every qdot "
                         "quantizes through the serving VP format — "
                         "'fake' = STE in the float graph, 'packed' = "
                         "packed-word Pallas forward AND backward")
    ap.add_argument("--quant", default="none",
                    choices=["none", "fxp", "vp", "vp_block"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ft-sim", action="store_true",
                    help="drive the FT controller + restart wrapper "
                         "with a simulated host set")
    ap.add_argument("--ft-hosts", type=int, default=4,
                    help="simulated host count for --ft-sim")
    ap.add_argument("--ft-fail-steps", default="",
                    help="comma-separated steps at which a simulated "
                         "host crashes (kills the loop; restarted)")
    ap.add_argument("--ft-straggler", type=int, default=-1,
                    help="simulated host id reporting 3x step durations")
    ap.add_argument("--ft-max-restarts", type=int, default=3)
    args = ap.parse_args()

    quant = QuantConfig(mode=args.quant)
    cfg = (registry.get_smoke_config(args.arch, quant) if args.smoke
           else registry.get_config(args.arch, quant))
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10),
                        total_steps=args.steps,
                        moment_codec="vp" if args.compress_moments
                        else None)
    qat = (QuantConfig(mode="vp", qat_mode=args.qat)
           if args.qat != "off" else None)
    cmp_cfg = (CompressionConfig(codec=args.grad_codec)
               if args.compress_grads else False)
    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, microbatches=args.microbatches,
        compress_grads=cmp_cfg, qat=qat))

    extra_batch = {}
    if cfg.family == "encdec":
        extra_batch["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra_batch["patches"] = jnp.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)

    # The checkpoint manager also lives OUTSIDE the restartable loop: an
    # in-process restart (unlike a real crash) leaves the previous
    # attempt's async writer thread alive, and a fresh manager would
    # sweep its half-written tmp dir out from under it — losing the very
    # checkpoint the restart needs.  One manager means `restore_latest`
    # joins the in-flight save before reading.
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    # FT simulation state lives OUTSIDE the restartable loop: the
    # controller's view of the fleet (and which hosts already died)
    # must survive a crash-restart, exactly as it does on a real fleet
    # where the controller is a separate service.
    ft = None
    sim = None
    if args.ft_sim:
        ft = FaultToleranceController(args.ft_hosts)
        sim = {
            "dead": set(),
            "pending": sorted({int(s) for s in
                               args.ft_fail_steps.split(",") if s.strip()}),
            "healthy": ft.healthy(),
            "now": 0.0,
        }
        if args.ckpt_dir is None:
            print("[ft] warning: --ft-sim without --ckpt-dir restarts "
                  "from step 0 every crash")

    def _ft_step(i: int) -> None:
        """One simulated fleet round: heartbeats, aging, crash injection."""
        sim["now"] += 1.0
        for h in range(args.ft_hosts):
            if h in sim["dead"]:
                continue
            dur = 0.3 if h == args.ft_straggler else 0.1
            ft.heartbeat(h, dur, now=sim["now"])
        ft.tick()
        if ft.topology_changed(sim["healthy"]):
            sim["healthy"] = ft.healthy()
            mesh = ft.propose_mesh(chips_per_host=1, model_axis=1)
            print(f"[ft] topology changed: healthy={sim['healthy']} "
                  f"-> elastic mesh {mesh} (generation {ft.generation})")
        if sim["pending"] and i >= sim["pending"][0]:
            sim["pending"].pop(0)
            live = [h for h in range(args.ft_hosts) if h not in sim["dead"]]
            victim = live[-1] if live else 0
            sim["dead"].add(victim)
            raise RuntimeError(
                f"simulated failure of host{victim} at step {i}")

    def train_loop(attempt: int = 0):
        if attempt:
            print(f"[restart] attempt {attempt}")
        start = 0
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(params, opt_cfg)
        cmp_state = (init_compressor_state(params)
                     if args.compress_grads else None)
        if mgr:
            template = {"params": params, "opt": opt_state._asdict()}
            if cmp_state is not None:
                template["cmp"] = cmp_state
            res = mgr.restore_latest(template)
            if res is not None:
                restored, manifest, s = res
                params = restored["params"]
                opt_state = OptState(**restored["opt"])
                if cmp_state is not None:
                    # resume the error-feedback residual too — dropping
                    # it re-injects one step's quantization error
                    # unbalanced
                    cmp_state = restored.get("cmp", cmp_state)
                start = manifest["extra"]["data_index"]
                print(f"[resume] from step {s}, data index {start}")

        t0 = time.time()
        for i in range(start, args.steps):
            batch = {**data.batch_at(i), **extra_batch}
            if args.compress_grads:
                params, opt_state, metrics, cmp_state = step_fn(
                    params, opt_state, batch, cmp_state)
            else:
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
            if ft is not None:
                _ft_step(i)
            if mgr and (i + 1) % args.ckpt_every == 0:
                state = {"params": params, "opt": opt_state._asdict()}
                if cmp_state is not None:
                    state["cmp"] = cmp_state
                mgr.save(i + 1, state, extra={"data_index": i + 1})
        if mgr:
            state = {"params": params, "opt": opt_state._asdict()}
            if cmp_state is not None:
                state["cmp"] = cmp_state
            mgr.save(args.steps, state, extra={"data_index": args.steps})
            mgr.wait()
        print("done.")

    if args.ft_sim:
        run_with_restarts(train_loop, max_restarts=args.ft_max_restarts)
    else:
        train_loop()


if __name__ == "__main__":
    main()
