"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32 --quant vp

With --quant vp the weights are served as PACKED VP words (sign +
significand + exponent index in one int8/int16 per element,
`core.packing`), and every weight matmul routes through the Pallas
`vp_dequant_matmul` kernel — the packed words are consumed directly
in-tile, never materializing an f32 weight matrix in HBM.  This is the
paper's technique as a serving feature; the MIMO equalizer and LLM decode
now exercise the same kernel substrate.

  --layout planes   legacy two-plane jnp-dequant serving (the golden
                    baseline the parity suite pins the kernel against)
  --kv-quant        additionally VP-quantizes the KV cache into PACKED
                    words consumed by the `vp_decode_attention` kernel
                    (unpack + pow2 scale in-tile, cache_len-aware tile
                    skip — the whole-cache dequant is gone)
  --kv-layout planes  legacy two-plane KV cache, dequantized whole in
                    jnp every step (the golden packed-cache baseline)
  --tune-decode     run the M=1..B skinny-decode autotune profile over the
                    model's weight panels — and, with --kv-quant, the
                    decode-attention cache geometries — before serving
                    (persisted in the autotune cache, so later launches
                    hit measured tilings)
  --json F          write a serving report (tokens/sec, packed bytes) to F
  --smoke           reduced config; also CHECKS finite logits end to end
                    (a real raise, not an assert — survives `python -O`)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)
from repro.models.layers import canonical_formats


def _require_finite(logits, what: str) -> None:
    """Raise if any logit is NaN/inf.

    This is a runtime serving check on real model output, not an
    internal invariant — it must fire under `python -O` too, where
    `assert` statements are stripped, so it raises explicitly.
    """
    if not bool(jnp.isfinite(logits).all()):
        raise FloatingPointError(f"non-finite {what} logits")


def _quantized_bytes(params) -> int:
    """Bytes of integer serving storage (packed words / significand and
    index planes; float32 scale tensors are NOT counted)."""
    return int(sum(
        l.size * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.integer)))


def _weight_panels(params):
    """Distinct (d_in, d_out) of every packed weight that feeds the
    serving matmul.

    The embedding table is excluded: it is consumed by `embed_lookup` as
    a row GATHER, never by `vp_dequant_matmul` — tuning a (vocab, d)
    panel would burn vocab-sized benchmark matmuls and persist cache
    entries nothing reads (lm_head's (d, vocab) panel is the real one).
    """
    panels = set()

    def walk(node, name=""):
        if isinstance(node, dict):
            if "w_packed" in node:
                if name != "embed":
                    w = node["w_packed"]
                    panels.add((int(w.shape[-2]), int(w.shape[-1])))
                return
            for k, v in node.items():
                walk(v, k)
        elif isinstance(node, list):
            for v in node:
                walk(v, name)

    walk(params)
    return sorted(panels)


def _attn_cache_geometries(cfg, max_len: int):
    """Distinct decode-attention cache geometries of the model's layer
    plan: (buf_len, window, rolling) per attention pattern — exactly the
    shapes `attn_block` will launch `vp_decode_attention` with."""
    from repro.models.model import layer_groups

    shapes = set()
    for group in layer_groups(cfg):
        for pattern in group.patterns:
            if pattern in ("mamba", "rwkv"):
                continue
            window = (cfg.sliding_window if pattern in ("swa", "moe_swa")
                      else (cfg.local_window if pattern == "local"
                            else None))
            buf_len = min(max_len, window) if window else max_len
            rolling = window is not None and buf_len <= window
            shapes.add((buf_len, window or 0, rolling))
    if cfg.family == "encdec":
        shapes.add((max_len, 0, False))
    return sorted(shapes)


def tune_decode_profile(params, cfg, batch: int, max_len: int = 0,
                        seed: int = 0):
    """Tune the serving kernels this process will launch at decode.

    Weight panels: `vp_dequant_matmul` at every M = 1..batch (persisted
    per (M, K, N)).  With a VP-quantized packed KV cache, ALSO profiles
    `vp_decode_attention` over the model's cache geometries (buf_len,
    window, rolling) at batch `batch` — the attention tile cache key
    includes the masking regime, so each geometry tunes separately.
    """
    from repro.kernels import autotune, ops, substrate
    from repro.core.packing import storage_dtype

    _, vp = canonical_formats(cfg.quant)
    backend = substrate.resolve_backend(None)
    if backend == "ref":
        # The ref path's math is tile-independent and never reads the
        # cache — measuring candidates here would record pure timer
        # noise and burn minutes of model-size matmuls for nothing.
        print("[serve] decode autotune profile skipped: backend is the "
              "jnp ref (blocks only affect kernel backends)")
        return {}
    key = jax.random.PRNGKey(seed)
    sizes = tuple(sorted({1 << p for p in range(batch.bit_length())
                          if (1 << p) <= batch} | {batch}))
    profile = {}
    for K, N in _weight_panels(params):
        w = jax.random.randint(
            key, (K, N), -8, 8).astype(storage_dtype(vp))
        x_full = jax.random.normal(key, (max(sizes), K), jnp.float32)

        def bench(M, blocks, w=w, x_full=x_full):
            jax.block_until_ready(ops.vp_dequant_matmul(
                x_full[:M], w, vp, blocks=blocks))

        profile[(K, N)] = autotune.tune_serving_decode(
            "vp_dequant_matmul", K, N, (vp,), backend, bench,
            batch_sizes=sizes)
    if cfg.quant.quantize_kv_cache and cfg.quant.kv_layout == "packed" \
            and max_len:
        from repro.models.attention import kv_cache_formats

        _, kv_vp = kv_cache_formats(cfg.quant)
        KV, dh, H = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
        for buf_len, window, rolling in _attn_cache_geometries(cfg,
                                                               max_len):
            kw = jax.random.randint(
                key, (batch, buf_len, KV, dh), -8, 8
            ).astype(storage_dtype(kv_vp))
            ks = jnp.ones((batch, buf_len, 1, 1), jnp.float32)
            q = jax.random.normal(key, (batch, 1, H, dh), jnp.float32)
            lens = jnp.full((batch,), buf_len, jnp.int32)
            win = window or None

            def bench_attn(blocks, kw=kw, ks=ks, q=q, lens=lens, win=win,
                           rolling=rolling):
                jax.block_until_ready(ops.vp_decode_attention(
                    q, kw, kw, ks, ks, lens, kv_vp, window=win,
                    rolling=rolling, blocks=blocks))

            shape = (batch, buf_len, KV, dh, window, int(rolling))
            profile[("attn",) + shape] = autotune.tune(
                "vp_decode_attention", shape, (kv_vp,), backend,
                bench_attn,
                candidates=autotune.attn_candidates(H // KV, buf_len))
    return profile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fxp", "vp", "vp_block"])
    ap.add_argument("--layout", default="packed",
                    choices=["packed", "planes"],
                    help="VP weight storage: packed kernel words (default)"
                         " or the legacy jnp-dequant two-plane baseline")
    ap.add_argument("--M", type=int, default=7,
                    help="VP significand bits; M+E <= 8 packs weights "
                         "into int8 words (half the bytes of bf16)")
    ap.add_argument("--E", type=int, default=2,
                    help="VP exponent-index bits (2^E exponent options)")
    ap.add_argument("--block", type=int, default=256,
                    help="vp_block index granularity; must divide the "
                         "contraction dims to engage the int8-MXU path "
                         "(non-tileable weights fall back to per-element "
                         "packed VP)")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--kv-layout", default="packed",
                    choices=["packed", "planes"],
                    help="VP KV-cache storage: packed kernel words "
                         "(default) or the legacy two-plane jnp-dequant "
                         "baseline")
    ap.add_argument("--tune-decode", action="store_true",
                    help="autotune the serving kernel at M=1..batch first")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write a serving report (tokens/sec) to FILE")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    quant = QuantConfig(mode=args.quant, M=args.M, E=args.E,
                        block=args.block,
                        quantize_kv_cache=args.kv_quant,
                        kv_layout=args.kv_layout)
    cfg = (registry.get_smoke_config(args.arch, quant) if args.smoke
           else registry.get_config(args.arch, quant))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    report = {"arch": args.arch, "quant": args.quant, "layout": args.layout,
              "kv_quant": bool(args.kv_quant), "kv_layout": args.kv_layout,
              "smoke": bool(args.smoke), "batch": args.batch,
              "prompt_len": args.prompt_len, "gen": args.gen}
    if args.kv_quant and args.kv_layout == "packed":
        from repro.models.attention import kv_cache_formats
        _, kv_vp = kv_cache_formats(cfg.quant)
        print(f"[serve] packed VP KV cache: {kv_vp.storage_bits} "
              f"bits/element ({kv_vp.M}+{kv_vp.E} info bits), "
              "kernel-backed decode attention")
    if args.quant != "none":
        params = quantize_params(params, cfg, layout=args.layout)
        qbytes = _quantized_bytes(params)
        report["quantized_bytes"] = qbytes
        if args.quant == "vp" and args.layout == "packed":
            _, vp = canonical_formats(cfg.quant)
            print(f"[serve] packed VP words: {qbytes/1e6:.2f} MB "
                  f"({vp.storage_bits} bits/param, kernel-backed qdot)")
        else:
            print(f"[serve] quantized planes: {qbytes/1e6:.2f} MB")
    # Tunable decode surfaces: packed-word weight panels (vp + packed
    # layout) and/or the packed KV decode-attention cache — the latter is
    # independent of the weight quantization mode.
    tunable = (args.quant == "vp" and args.layout == "packed") or \
        (args.kv_quant and args.kv_layout == "packed")
    if args.tune_decode and tunable:
        t0 = time.time()
        prof = tune_decode_profile(
            params, cfg, args.batch,
            max_len=args.prompt_len + args.gen)
        if prof:
            n_entries = sum(
                len(v) if isinstance(v, dict) else 1
                for v in prof.values())
            print(f"[serve] decode autotune profile: "
                  f"{n_entries} entries over "
                  f"{len(prof)} shapes in {time.time()-t0:.1f}s")

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    caches = init_cache(cfg, B, args.prompt_len + args.gen)

    extra = None
    cross_kv = None
    if cfg.family == "vlm":
        extra = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        from repro.models.model import _encoder_forward, _cross_kv
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc = _encoder_forward(params, frames, cfg)
        cross_kv = _cross_kv(params, enc, cfg)
        extra = cross_kv

    t0 = time.time()
    logits, caches = prefill(params, prompts, caches, cfg, patches=extra)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    report["prefill_s"] = prefill_s
    print(f"[prefill] {B}x{args.prompt_len} in {prefill_s:.2f}s")
    if args.smoke:
        _require_finite(
            logits, f"prefill ({args.arch}, {args.quant})")

    decode = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg, cross_kv=cross_kv)
        if cfg.family == "encdec" else decode_step(p, t, c, cfg))

    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, caches = decode(params, tok, caches)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(logits)
    dt = time.time() - t0
    if args.smoke:
        _require_finite(
            logits, f"decode ({args.arch}, {args.quant})")
    gen = jnp.concatenate(out_tokens, axis=1)
    tok_s = B * args.gen / dt
    report["decode_s"] = dt
    report["tokens_per_s"] = tok_s
    print(f"[decode] {args.gen} steps x batch {B}: {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("[sample tokens]", np_preview(gen))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[serve] wrote report to {args.json}")


def np_preview(x):
    import numpy as np
    a = np.asarray(x)
    return a[:, :12].tolist()


if __name__ == "__main__":
    main()
