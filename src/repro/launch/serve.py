"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32 --quant vp

With --quant vp the weights are served as VP planes (int8 significands +
packed 2-bit exponent indices) — the paper's technique as a serving
feature; --kv-quant additionally VP-quantizes the KV cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fxp", "vp", "vp_block"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    quant = QuantConfig(mode=args.quant, quantize_kv_cache=args.kv_quant)
    cfg = (registry.get_smoke_config(args.arch, quant) if args.smoke
           else registry.get_config(args.arch, quant))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    if args.quant != "none":
        params = quantize_params(params, cfg)
        n_int8 = sum(l.size for l in jax.tree_util.tree_leaves(params)
                     if hasattr(l, "dtype") and l.dtype == jnp.int8)
        print(f"[serve] VP planes: {n_int8/1e6:.2f}M int8 significands")

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    caches = init_cache(cfg, B, args.prompt_len + args.gen)

    extra = None
    cross_kv = None
    if cfg.family == "vlm":
        extra = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        from repro.models.model import _encoder_forward, _cross_kv
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc = _encoder_forward(params, frames, cfg)
        cross_kv = _cross_kv(params, enc, cfg)
        extra = cross_kv

    t0 = time.time()
    logits, caches = prefill(params, prompts, caches, cfg, patches=extra)
    print(f"[prefill] {B}x{args.prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg, cross_kv=cross_kv)
        if cfg.family == "encdec" else decode_step(p, t, c, cfg))

    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, caches = decode(params, tok, caches)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[decode] {args.gen} steps x batch {B}: {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s)")
    print("[sample tokens]", np_preview(gen))


def np_preview(x):
    import numpy as np
    a = np.asarray(x)
    return a[:, :12].tolist()


if __name__ == "__main__":
    main()
